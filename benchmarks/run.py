"""Benchmark harness — one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only <name>] [--fast]``

Prints ``name,us_per_call,derived`` CSV rows; each benchmark reproduces one
of the paper's quantitative artifacts and reports the headline ratio it
claims, next to the paper's value:

  fig2_traffic_volume      traffic share by parallelism (Fig 2)
  fig3_timeline            per-phase forward timings (Fig 3/17)
  fig10_testbed            end-to-end iteration, MixNet vs EPS (Fig 10)
  fig11_cost               networking cost vs cluster size (Fig 11)
  fig12_speedups           training iteration time across fabrics (Fig 12)
  fig13_pareto             cost-efficiency ratios (Fig 13)
  fig14_failures           NIC / GPU / node failure overheads (Fig 14)
  fig16_nvl72              high-radix scale-up comparison (Fig 16)
  fig19_copilot            COPILOT prediction accuracy (Fig 19)
  fig21_reconfig_delay     reconfiguration turnaround profile (Fig 21)
  fig26_scalability        cluster-size scaling (Fig 26)
  fig27_optical_degree     optical degree sweep (Fig 27)
  fig28_reconfig_latency   reconfiguration latency sweep (Fig 28)
  copilot_refit            batched vs looped COPILOT refit (BENCH_copilot.json)
  moe_dispatch             sort-based vs one-hot dispatch (BENCH_moe_dispatch.json)
  collectives              flat vs hierarchical vs fused a2a (BENCH_collectives.json)
  overlap                  serial vs chunked comm/compute schedule (BENCH_overlap.json)
  serve                    reconfigurable serving engine + priced scenario (BENCH_serve.json)
  fleet                    multi-replica steering: locality vs least-loaded vs one big replica (BENCH_fleet.json)
  spec_decode              speculative vs serial decode + priced acceptance sweep (BENCH_spec.json)
  paper_scale              32-1024 GPU goodput-per-dollar curves + cached autotuner (BENCH_paper_scale.json)
  observability            tracer throughput + serve-tick tracing overhead + §3 locality (BENCH_obs.json)
  kernels                  Pallas-kernel oracle timings (framework table)
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def _timeit(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------


def fig2_traffic_volume(fast=False):
    """Fig 2: traffic volume by parallelism for the paper's models."""
    from repro.configs.paper_models import SIM_MODELS

    for name, m in SIM_MODELS.items():
        a2a = 4 * m.num_blocks * m.a2a_bytes_total() * m.num_microbatches
        tp = (
            4 * m.num_blocks * m.tokens_per_microbatch * m.d_model * 2
            * m.num_microbatches * (m.tp_degree - 1)
        )
        pp = 2 * m.pp_degree * m.tokens_per_microbatch * m.d_model * 2 * m.num_microbatches
        dp = m.param_count() * 2
        total = a2a + tp + pp + dp
        _row(
            f"fig2_traffic_volume/{name}", 0.0,
            f"EP%={a2a/total*100:.0f} TP%={tp/total*100:.0f} "
            f"PP%={pp/total*100:.0f} DP%={dp/total*100:.0f}",
        )


def fig3_timeline(fast=False):
    """Fig 3/17: per-phase forward times; the expert phase must leave a
    window larger than the 25 ms OCS reconfiguration."""
    from repro.configs.paper_models import SIM_MODELS

    for name, m in SIM_MODELS.items():
        attn = m.attention_time() * 1e3
        exp = m.expert_time() * 1e3
        _row(
            f"fig3_timeline/{name}", 0.0,
            f"attn_ms={attn:.1f} expert_ms={exp:.1f} "
            f"hides_25ms_ocs={exp + attn > 25.0}",
        )


def fig10_testbed(fast=False):
    """Fig 10: end-to-end iteration time of a (reduced) Mixtral-8x7B-family
    model trained with the mixnet dispatch path vs the einsum baseline —
    the CPU-scale analogue of the 32-GPU prototype comparison."""
    import dataclasses

    import jax

    from repro.configs.paper_models import MIXTRAL_8X7B_CONFIG
    from repro.data.pipeline import SyntheticLM
    from repro.models.config import reduced
    from repro.optim.adamw import AdamWConfig
    from repro.parallel.sharding import make_plan
    from repro.train.train_step import init_all, make_train_step

    plan = make_plan(None)
    cfg = reduced(MIXTRAL_8X7B_CONFIG, d_model=128, d_ff=256, num_layers=4)
    data = SyntheticLM(cfg.vocab_size, 64, 4, seed=0)
    for backend in ("mixnet", "einsum"):
        c = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, backend=backend)
        )
        opt = AdamWConfig(lr=1e-3)
        params, _, opt_state = init_all(jax.random.PRNGKey(0), c, plan, opt)
        step = jax.jit(make_train_step(c, plan, opt))
        b = next(data)
        batch = {"tokens": b.tokens, "labels": b.labels}
        us = _timeit(lambda: jax.block_until_ready(step(params, opt_state, batch)[2]["loss"]))
        _row(f"fig10_testbed/{backend}", us, f"iter_ms={us/1e3:.1f}")


def fig11_cost(fast=False):
    from repro.core import cost as costm

    for servers in (16, 128, 512) if not fast else (128,):
        for gbps in (100, 400):
            cm = costm.fabric_cost("mixnet", servers, gbps)
            cf = costm.fabric_cost("fat-tree", servers, gbps)
            cr = costm.fabric_cost("rail-optimized", servers, gbps)
            ct = costm.fabric_cost("topoopt", servers, gbps)
            _row(
                f"fig11_cost/{servers}srv_{gbps}G", 0.0,
                f"mixnet=${cm/1e6:.2f}M ft_over_mixnet={cf/cm:.2f}x "
                f"rail_over_mixnet={cr/cm:.2f}x topoopt=${ct/1e6:.2f}M",
            )


def _fabric_iter_times(model, gbps, servers=128, iters=5):
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_training

    out = {}
    for fname in ("mixnet", "fat-tree", "oversub-fat-tree", "rail-optimized", "topoopt"):
        fab = make_fabric(fname, FabricConfig(num_servers=servers, link_gbps=gbps))
        res = simulate_training(
            model, fab, iterations=iters, use_copilot=(fname == "mixnet")
        )
        out[fname] = float(np.mean([r.total for r in res[1:]]))
    return out


def fig12_speedups(fast=False):
    """Fig 12: iteration time across fabrics; paper: MixNet ~ fat-tree,
    beats TopoOpt by 1.3-1.5x avg and oversub by up to 1.6x."""
    from repro.configs.paper_models import SIM_MODELS

    models = list(SIM_MODELS.items())
    if fast:
        models = models[:1]
    for name, m in models:
        for gbps in (100, 400):
            t0 = time.perf_counter()
            times = _fabric_iter_times(m, gbps)
            us = (time.perf_counter() - t0) * 1e6
            tm = times["mixnet"]
            _row(
                f"fig12_speedups/{name}_{gbps}G", us,
                f"vs_fat_tree={times['fat-tree']/tm:.2f}x "
                f"vs_topoopt={times['topoopt']/tm:.2f}x "
                f"vs_oversub={times['oversub-fat-tree']/tm:.2f}x "
                f"(paper: ~1.0 / 1.3-1.5 / <=1.6)",
            )


def fig13_pareto(fast=False):
    """Fig 13: performance-per-dollar; paper: 1.2-1.5x over fat-tree @100G,
    1.9-2.3x @400G, 1.4-1.5x over rail @100G, 2.3-2.4x @400G."""
    from repro.configs.paper_models import SIM_MODELS
    from repro.core import cost as costm

    models = list(SIM_MODELS.items())
    if fast:
        models = models[:1]
    for name, m in models:
        for gbps in (100, 400):
            times = _fabric_iter_times(m, gbps)
            eff = {
                f: costm.cost_efficiency(t, costm.fabric_cost(f, 128, gbps))
                for f, t in times.items()
            }
            _row(
                f"fig13_pareto/{name}_{gbps}G", 0.0,
                f"vs_fat_tree={eff['mixnet']/eff['fat-tree']:.2f}x "
                f"vs_rail={eff['mixnet']/eff['rail-optimized']:.2f}x "
                f"(paper@{gbps}G: ft {'1.2-1.5' if gbps==100 else '1.9-2.3'}x, "
                f"rail {'1.4-1.5' if gbps==100 else '2.3-2.4'}x)",
            )


def fig14_failures(fast=False):
    """Fig 14: failure resiliency; paper: NIC ~3.3%, GPU ~5.1%, node ~6.5%.

    Failures are injected through the shared control-plane engine so they
    flow through the same decide/apply path as routine reconfiguration."""
    from repro.configs.paper_models import MIXTRAL_8X22B, DEEPSEEK_R1
    from repro.core.controlplane import ControlPlane
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_training

    for name, model in (("mixtral-8x22b", MIXTRAL_8X22B), ("deepseek-r1", DEEPSEEK_R1)):
        cfg = FabricConfig(num_servers=128, link_gbps=400)
        fab = make_fabric("mixnet", cfg)
        base = np.mean([r.total for r in simulate_training(model, fab, iterations=4)[1:]])
        # NIC failure: one server loses ONE optical NIC (reroute via rest+EPS).
        fab_n = make_fabric("mixnet", cfg)
        cp_n = ControlPlane.for_simulation(model, fab_n)
        cp_n.fail_nic(0, failed_nics=1)
        nic = np.mean([r.total for r in simulate_training(
            model, fab_n, iterations=4, seed=1, controlplane=cp_n)[1:]])
        # GPU failure: backup GPU reachable via OCS forwarding -> one server's
        # effective optical degree drops by the forwarding share (~2 NICs).
        fab_g = make_fabric("mixnet", cfg)
        cp_g = ControlPlane.for_simulation(model, fab_g)
        cp_g.fail_nic(0, failed_nics=2)
        gpu = np.mean([r.total for r in simulate_training(
            model, fab_g, iterations=4, seed=2, controlplane=cp_g)[1:]])
        # Full-node failure: the replacement node connects via EPS only (§5.4).
        fab_f = make_fabric("mixnet", cfg)
        cp_f = ControlPlane.for_simulation(model, fab_f)
        cp_f.fail_device(0)
        node = np.mean([r.total for r in simulate_training(
            model, fab_f, iterations=4, seed=3, controlplane=cp_f)[1:]])
        _row(
            f"fig14_failures/{name}", 0.0,
            f"nic=+{(nic/base-1)*100:.1f}% gpu=+{(gpu/base-1)*100:.1f}% "
            f"node=+{(node/base-1)*100:.1f}% (paper: 3.3/5.1/6.5%)",
        )


def fig16_nvl72(fast=False):
    """Fig 16: MixNet with optical I/O vs NVL72-style scale-up; the paper
    reports 1.3x lower iteration time from offloading EP to regional OCS."""
    from repro.configs.paper_models import DEEPSEEK_R1
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_training
    import dataclasses

    model = dataclasses.replace(DEEPSEEK_R1, ep_degree=128, pp_degree=16)
    # NVL72: EP crosses scale-up domains over 800G Ethernet scale-out;
    # MixNet (optical I/O) matches total GPU bandwidth but gives EP a
    # reconfigurable regional OCS (half the NVLink budget moved to OCS).
    nvl = make_fabric("fat-tree", FabricConfig(
        num_servers=256, link_gbps=800, nics_per_server=1,
        nvlink_bytes_per_s=7.2e12 / 8))
    mix = make_fabric("mixnet", FabricConfig(
        num_servers=256, link_gbps=800, nics_per_server=5, eps_nics=1, ocs_nics=4,
        nvlink_bytes_per_s=3.6e12 / 8))
    t_nvl = np.mean([r.total for r in simulate_training(model, nvl, iterations=3)[1:]])
    t_mix = np.mean([r.total for r in simulate_training(model, mix, iterations=3)[1:]])
    _row("fig16_nvl72/deepseek-v3", 0.0,
         f"mixnet_speedup={t_nvl/t_mix:.2f}x (paper: 1.3x)")


def fig19_copilot(fast=False):
    """Fig 19: COPILOT top-k accuracy vs unchanged/random baselines."""
    from repro.core.copilot import CopilotPredictor, topk_accuracy
    from repro.core.netsim import GateTraceGenerator
    from repro.core.traffic import TrafficMonitor

    layers, e = 8, 16
    trace = GateTraceGenerator(layers, e, seed=5)
    monitor = TrafficMonitor(layers, e)
    cop = CopilotPredictor(layers, e, fit_steps=100)
    rng = np.random.default_rng(0)
    acc = {"copilot": [], "unchanged": [], "random": []}
    iters = 15 if fast else 40
    t0 = time.perf_counter()
    for it in range(iters):
        loads = trace.step()
        for l in range(layers):
            monitor.record(l, loads[l] * 1000)
        if it >= 3:
            for l in range(layers - 1):
                acc["copilot"].append(topk_accuracy(cop.predict(l, loads[l]), loads[l + 1], 4))
                acc["unchanged"].append(
                    topk_accuracy(cop.baseline_unchanged(loads[l]), loads[l + 1], 4))
                acc["random"].append(topk_accuracy(cop.baseline_random(rng), loads[l + 1], 4))
        cop.update(monitor)
        monitor.advance()
    us = (time.perf_counter() - t0) * 1e6
    _row("fig19_copilot/top4", us,
         f"copilot={np.mean(acc['copilot']):.2f} unchanged={np.mean(acc['unchanged']):.2f} "
         f"random={np.mean(acc['random']):.2f} (paper ordering: copilot highest)")


def fig21_reconfig_delay(fast=False):
    """Fig 21: reconfiguration turnaround vs number of switched pairs
    (control-plane cost of Algorithm 1 + the modeled 25 ms OCS actuation)."""
    from repro.core import topology as topo

    rng = np.random.default_rng(0)
    for pairs in (1, 4, 16):
        n = max(2 * pairs, 4)
        demand = rng.random((n, n)) * 1e9
        us = _timeit(lambda: topo.reconfigure_ocs(demand, alpha=6, num_servers=n,
                                                  experts_per_server=1), reps=5)
        _row(f"fig21_reconfig_delay/{pairs}pairs", us,
             f"solver_ms={us/1e3:.2f} total_with_ocs_ms={us/1e3 + 25:.1f} "
             f"(paper testbed: 41-47ms)")


def fig26_scalability(fast=False):
    """Fig 26: scaling cluster size; MixNet keeps ~fat-tree throughput and
    ~2x perf-per-dollar as GPUs grow."""
    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core import cost as costm

    sizes = (128, 512) if fast else (128, 512, 2048)
    for servers in sizes:
        times = _fabric_iter_times(MIXTRAL_8X7B, 400, servers=servers, iters=3)
        eff_m = costm.cost_efficiency(times["mixnet"], costm.fabric_cost("mixnet", servers, 400))
        eff_f = costm.cost_efficiency(times["fat-tree"], costm.fabric_cost("fat-tree", servers, 400))
        _row(f"fig26_scalability/{servers*8}gpus", 0.0,
             f"vs_ft_speed={times['fat-tree']/times['mixnet']:.2f}x "
             f"perf_per_dollar_vs_ft={eff_m/eff_f:.2f}x (paper: ~2x)")


def fig27_optical_degree(fast=False):
    """Fig 27: more optical circuits -> faster a2a (cost-equivalent sweep)."""
    from repro.configs.paper_models import MIXTRAL_8X22B
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_training

    # Paper semantics: EPS stays fixed (2 NICs); alpha sweeps the cheap
    # optical fanout ("more communication-intensive GPU pairs can be
    # provisioned with dedicated high-bandwidth optical circuits").
    prev = None
    for alpha in (2, 4, 6):
        fab = make_fabric("mixnet", FabricConfig(
            num_servers=128, link_gbps=100, ocs_nics=alpha, eps_nics=2))
        t = float(np.mean([r.total for r in simulate_training(
            MIXTRAL_8X22B, fab, iterations=3)[1:]]))
        trend = "" if prev is None else f" ({'faster' if t <= prev else 'slower'})"
        _row(f"fig27_optical_degree/alpha{alpha}", 0.0, f"iter_ms={t*1e3:.0f}{trend}")
        prev = t


def fig28_reconfig_latency(fast=False):
    """Fig 28: iteration time vs OCS reconfiguration latency; flat through
    ms-scale, cliff at second-scale."""
    from repro.configs.paper_models import MIXTRAL_8X22B
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_training

    base = None
    for delay in (1e-6, 0.025, 1.0, 10.0):
        fab = make_fabric("mixnet", FabricConfig(num_servers=128, link_gbps=400,
                                                 reconfig_delay_s=delay))
        t = float(np.mean([r.total for r in simulate_training(
            MIXTRAL_8X22B, fab, iterations=3)[1:]]))
        base = base or t
        _row(f"fig28_reconfig_latency/{delay}s", 0.0,
             f"normalized={t/base:.2f} (paper: ~1.0 until ~1s, then degrades)")


def copilot_refit(fast=False):
    """Batched COPILOT refit (one vmapped fit across all layers) vs the
    per-layer jit-call loop, at the paper-scale 16 transitions.

    Records the wall-clock ratio and the max transition deviation into
    BENCH_copilot.json (repo root) so the perf trajectory is tracked."""
    import json
    import os

    from repro.core.copilot import CopilotPredictor
    from repro.core.netsim import GateTraceGenerator
    from repro.core.traffic import TrafficMonitor

    layers, e = 17, 32  # 16 fitted transition matrices
    trace = GateTraceGenerator(layers, e, seed=0)
    monitor = TrafficMonitor(layers, e)
    for _ in range(8):
        loads = trace.step()
        for l in range(layers):
            monitor.record(l, loads[l] * 1000)
        monitor.advance()

    entries = []
    for fit_steps in (60, 150):
        looped = CopilotPredictor(layers, e, fit_steps=fit_steps, batched_refit=False)
        batched = CopilotPredictor(layers, e, fit_steps=fit_steps)
        us_loop = _timeit(lambda: looped.update(monitor), reps=5)
        us_batch = _timeit(lambda: batched.update(monitor), reps=5)
        err = float(np.max(np.abs(looped.state.transitions - batched.state.transitions)))
        speedup = us_loop / max(us_batch, 1e-9)
        _row(
            f"copilot_refit/steps{fit_steps}", us_batch,
            f"looped_ms={us_loop/1e3:.1f} batched_ms={us_batch/1e3:.1f} "
            f"speedup={speedup:.2f}x max_dev={err:.2e} (atol 1e-5 required)",
        )
        entries.append({
            "bench": "copilot_refit",
            "layers": layers,
            "experts": e,
            "fit_steps": fit_steps,
            "looped_us": round(us_loop, 1),
            "batched_us": round(us_batch, 1),
            "speedup": round(speedup, 3),
            "max_transition_deviation": err,
        })
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_copilot.json")
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.extend(entries)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)


def moe_dispatch(fast=False):
    """Sort-based vs one-hot MoE dispatch at the paper-scale T=16384, E=64.

    Both paths build the same ``[E·C, D]`` capacity-layout dispatch buffers
    from identical router choices; the one-hot baseline computes in-bucket
    ranks with the historical O(T·E) ``one_hot``+``cumsum`` machinery, the
    sort path with the routing core's O(N log N) stable argsort
    (``repro.models.routing.bucket_ranks``).  Also times the dropless block
    layout (argsort + block padding, the MegaBlocks-style default).  Records
    the ratio into BENCH_moe_dispatch.json (repo root)."""
    import json
    import os

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops
    from repro.models import routing

    t = 4096 if fast else 16384
    e, k, d = 64, 2, 128
    n = t * k
    cap = routing.capacity(t, k, e, 1.25)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (t, d))
    logits = jax.random.normal(jax.random.PRNGKey(1), (t, e))
    _, idx = jax.lax.top_k(logits, k)
    dest = idx.reshape(n)
    src_rows = jnp.arange(n, dtype=jnp.int32) // k

    @jax.jit
    def onehot_path(x, dest):
        oh = jax.nn.one_hot(dest, e, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=0) - oh
        rank = jnp.sum(pos * oh, axis=1)
        keep = rank < cap
        slot = jnp.where(keep, dest * cap + rank, e * cap)
        buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(
            jnp.where(keep[:, None], x[src_rows], 0)
        )
        return buf[:-1]

    @jax.jit
    def sort_path(x, dest):
        rank, _ = routing.bucket_ranks(dest, e)
        plan = routing.capacity_plan(dest, rank, None, e, cap)
        src = jnp.where(plan.src >= 0, plan.src // k, -1)
        return ops.moe_dispatch(x, src, backend="ref")

    @jax.jit
    def sort_dropless_path(x, dest):
        rank, counts = routing.bucket_ranks(dest, e)
        plan = routing.dropless_plan(dest, rank, counts, None, e, 64)
        src = jnp.where(plan.src >= 0, plan.src // k, -1)
        return ops.moe_dispatch(x, src, backend="ref")

    err = float(jnp.max(jnp.abs(onehot_path(x, dest) - sort_path(x, dest))))
    us_onehot = _timeit(lambda: jax.block_until_ready(onehot_path(x, dest)), reps=5)
    us_sort = _timeit(lambda: jax.block_until_ready(sort_path(x, dest)), reps=5)
    us_dropless = _timeit(
        lambda: jax.block_until_ready(sort_dropless_path(x, dest)), reps=5
    )
    speedup = us_onehot / max(us_sort, 1e-9)
    _row(
        f"moe_dispatch/T{t}_E{e}", us_sort,
        f"onehot_ms={us_onehot/1e3:.2f} sort_ms={us_sort/1e3:.2f} "
        f"dropless_ms={us_dropless/1e3:.2f} speedup={speedup:.2f}x "
        f"max_dev={err:.1e} (sort must beat one-hot)",
    )
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_moe_dispatch.json")
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append({
        "bench": "moe_dispatch",
        "tokens": t,
        "experts": e,
        "top_k": k,
        "d_model": d,
        "capacity": cap,
        "onehot_us": round(us_onehot, 1),
        "sort_us": round(us_sort, 1),
        "sort_dropless_us": round(us_dropless, 1),
        "speedup": round(speedup, 3),
        "max_deviation": err,
    })
    with open(path, "w") as f:
        json.dump(history, f, indent=2)


_COLLECTIVES_BENCH = """
import json, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.commruntime import AllToAll, CommSpec
from repro.launch.mesh import make_mesh
from repro.parallel.sharding import shard_map

PDEV, C, D, REPS = 8, %(C)d, %(D)d, 10
mesh = make_mesh((PDEV,), ("model",))
x = jax.random.normal(jax.random.PRNGKey(0), (PDEV * PDEV, C, D), jnp.float32)
e = jax.random.randint(jax.random.PRNGKey(1), (PDEV * PDEV, C), 0, 7).astype(jnp.int32)


def timeit(fn):
    fn()  # compile
    t0 = time.perf_counter()
    for _ in range(REPS):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / REPS * 1e6


def pair(group):
    op = AllToAll(CommSpec(axis="model", axis_size=PDEV, group_size=group))
    f = shard_map(lambda v, m: (op(v), op(m[..., None])[..., 0]), mesh=mesh,
                  in_specs=(P("model"), P("model")),
                  out_specs=(P("model"), P("model")), check_vma=False)
    return jax.jit(f)


def fused(group):
    op = AllToAll(CommSpec(axis="model", axis_size=PDEV, group_size=group))
    f = shard_map(lambda v, m: op.fused(v, m), mesh=mesh,
                  in_specs=(P("model"), P("model")),
                  out_specs=(P("model"), P("model")), check_vma=False)
    return jax.jit(f)


flat_us = timeit(lambda f=pair(1): f(x, e))
hier_us = timeit(lambda f=pair(4): f(x, e))
fused_us = timeit(lambda f=fused(4): f(x, e))
fx, fe = fused(4)(x, e)
ux, ue = pair(4)(x, e)
exact = bool((fx == ux).all()) and bool((fe == ue).all())
print("BENCH " + json.dumps({
    "bench": "collectives",
    "devices": PDEV, "chunk": C, "d_model": D,
    "flat_pair_us": round(flat_us, 1),
    "hier_pair_us": round(hier_us, 1),
    "hier_fused_us": round(fused_us, 1),
    "fused_speedup_over_pair": round(hier_us / max(fused_us, 1e-9), 3),
    "fused_bit_identical": exact,
}))
"""


def collectives(fast=False):
    """CommRuntime a2a lowerings on 8 forced host devices (subprocess, like
    the multidevice tests): flat vs hierarchical delegation, and the fused
    payload+metadata transfer vs the unfused pair.  Appends the wall-clock
    numbers and the bit-identity check to BENCH_collectives.json."""
    import json
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    script = _COLLECTIVES_BENCH % {"C": 64 if fast else 256, "D": 128}
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=900, env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"collectives bench subprocess failed:\n{proc.stderr[-2000:]}")
    entry = json.loads(
        [l for l in proc.stdout.splitlines() if l.startswith("BENCH ")][-1][6:]
    )
    assert entry["fused_bit_identical"], "fused a2a diverged from unfused pair"
    _row(
        "collectives/a2a_8dev", entry["hier_fused_us"],
        f"flat_pair_ms={entry['flat_pair_us']/1e3:.2f} "
        f"hier_pair_ms={entry['hier_pair_us']/1e3:.2f} "
        f"hier_fused_ms={entry['hier_fused_us']/1e3:.2f} "
        f"fused_speedup={entry['fused_speedup_over_pair']:.2f}x "
        f"(fused must stay bit-identical)",
    )
    path = os.path.join(root, "BENCH_collectives.json")
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)


_OVERLAP_BENCH = """
import dataclasses, json, time
import jax, jax.numpy as jnp, numpy as np
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig, MoEConfig
from repro.parallel.sharding import make_plan
from repro.launch.mesh import make_mesh, use_mesh

mesh = make_mesh((2, 4), ('data', 'model'))
plan = make_plan(mesh)
cfg = ModelConfig('t', 'moe', 2, 64, 4, 2, 128, 128, dtype='float32',
                  moe=MoEConfig(num_experts=8, top_k=2, d_ff=%(DFF)d,
                                capacity_factor=8.0, a2a_group=2))
params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, plan)
x = jax.random.normal(jax.random.PRNGKey(1), (4, %(SEQ)d, 64))
REPS = 5

def timeit(fn, *a):
    jax.block_until_ready(fn(*a))
    t0 = time.perf_counter()
    for _ in range(REPS):
        jax.block_until_ready(fn(*a))
    return (time.perf_counter() - t0) / REPS * 1e6

entry = {"bench": "overlap", "devices": 8, "seq": %(SEQ)d, "d_ff": %(DFF)d}
with use_mesh(mesh):
    outs = {}
    for c in (1, 2, 4):
        cfg_c = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, overlap_chunks=c))
        f = jax.jit(lambda p, v: moe_mod.moe_apply(p, v, cfg_c, plan, mesh=mesh,
                                                   backend='mixnet')[0])
        entry[f"chunks{c}_us"] = round(timeit(f, params, x), 1)
        outs[c] = np.asarray(f(params, x))
entry["bit_identical"] = bool((outs[2] == outs[1]).all() and (outs[4] == outs[1]).all())
print("BENCH " + json.dumps(entry))
"""


def overlap(fast=False):
    """Chunked comm/compute overlap (DESIGN.md §8): (a) wall-clock of the
    mixnet MoE layer serial vs chunked on 8 forced host devices (bit-identity
    asserted every run), (b) netsim's priced schedule — serial vs chunked
    iteration time and the exposed-comm fraction for a production-shape
    model at 25 ms OCS.  Appends both to BENCH_overlap.json."""
    import dataclasses as dc
    import json
    import os
    import subprocess
    import sys

    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import GateTraceGenerator, simulate_iteration

    # --- (a) execution side: subprocess on 8 forced devices ----------------
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    script = _OVERLAP_BENCH % {"SEQ": 32 if fast else 128, "DFF": 64 if fast else 256}
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=900, env=env,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"overlap bench subprocess failed:\n{proc.stderr[-2000:]}")
    entry = json.loads(
        [l for l in proc.stdout.splitlines() if l.startswith("BENCH ")][-1][6:]
    )
    assert entry["bit_identical"], "chunked schedule diverged from serial path"
    _row(
        "overlap/moe_8dev", entry["chunks4_us"],
        f"serial_ms={entry['chunks1_us']/1e3:.2f} "
        f"chunks2_ms={entry['chunks2_us']/1e3:.2f} "
        f"chunks4_ms={entry['chunks4_us']/1e3:.2f} "
        f"(chunked must stay bit-identical)",
    )

    # --- (b) pricing side: netsim event timeline ---------------------------
    model = dc.replace(MIXTRAL_8X7B, num_blocks=8)
    sim_entries = []
    for chunks in (1, 4):
        m = dc.replace(model, overlap_chunks=chunks)
        fab = make_fabric(
            "mixnet", FabricConfig(num_servers=16, link_gbps=400)
        )
        trace = GateTraceGenerator(m.layers_per_stage, m.num_experts, seed=7)
        res = simulate_iteration(m, fab, trace, num_servers_region=4)
        frac = res.exposed_comm / max(res.a2a, 1e-12)
        sim_entries.append({
            "chunks": chunks,
            "iter_ms": round(res.total * 1e3, 3),
            "hidden_comm_ms": round(res.hidden_comm * 1e3, 3),
            "exposed_comm_ms": round(res.exposed_comm * 1e3, 3),
            "exposed_fraction": round(frac, 4),
        })
        _row(
            f"overlap/netsim_chunks{chunks}", 0.0,
            f"iter_ms={res.total*1e3:.1f} hidden_ms={res.hidden_comm*1e3:.2f} "
            f"exposed_frac={frac:.2f}",
        )
    assert sim_entries[1]["iter_ms"] <= sim_entries[0]["iter_ms"] + 1e-6
    assert sim_entries[1]["hidden_comm_ms"] > 0.0
    entry["netsim"] = sim_entries

    path = os.path.join(root, "BENCH_overlap.json")
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)


def serve(fast=False):
    """Serving engine + priced scenario (DESIGN.md §9, BENCH_serve.json).

    (a) Engine side: a toy MoE served through ServeEngine with decode-time
    reconfiguration ON vs OFF on the identical workload — tokens/s, TTFT
    p50/p99, and the generation-consistency guarantee asserted bit-for-bit.
    (b) Pricing side: netsim's serving tick loop — a reconfigured MixNet
    fabric vs the static fat-tree EPS baseline, reporting TPOT, the
    exposed-comm fraction per tick, and goodput-per-dollar.  The acceptance
    gate: reconfigured goodput/$ must be >= the static EPS baseline."""
    import dataclasses as dc
    import json
    import os

    import jax

    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_serving
    from repro.models.config import ModelConfig, MoEConfig
    from repro.models.transformer import init_model
    from repro.parallel.sharding import make_plan
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.workload import WorkloadGenerator

    # --- (a) engine side ----------------------------------------------------
    plan = make_plan(None)
    cfg = ModelConfig(
        "srv", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=8.0,
                      backend="mixnet"),
    )
    params, _ = init_model(jax.random.PRNGKey(0), cfg, plan)
    gen = WorkloadGenerator("chat", seed=3, vocab_size=cfg.vocab_size)
    reqs = [
        dc.replace(r, prompt_len=min(r.prompt_len, 24),
                   max_new_tokens=min(r.max_new_tokens, 8))
        for r in gen.generate(4 if fast else 8)
    ]

    def run_engine(reconfig):
        scfg = ServeConfig(
            slots=2, max_len=48, prefill_chunk=8,
            reconfig_every=(4 if reconfig else 0), reconfig_min_gain=0.0,
            num_devices=4,
        )
        eng = ServeEngine(jax.tree.map(lambda a: a, params), cfg, plan, scfg)
        rep = eng.run(reqs, gen)
        toks = {r.rid: tuple(r.out) for r in eng.batcher.finished}
        return rep, toks

    rep_off, toks_off = run_engine(False)
    rep_on, toks_on = run_engine(True)
    assert toks_on == toks_off, "reconfiguration changed generated tokens"
    assert rep_on.reconfig_count > 0, "control loop never reconfigured"
    _row(
        "serve/engine", rep_on.wall_s * 1e6,
        f"tok_s={rep_on.tokens_per_s:.1f} ttft_p50={rep_on.ttft_ticks_p50:.0f}t "
        f"ttft_p99={rep_on.ttft_ticks_p99:.0f}t reconfigs={rep_on.reconfig_count} "
        f"(tokens bit-identical to static run)",
    )
    entry = {
        "bench": "serve",
        "engine": {
            "requests": rep_on.requests,
            "tokens_out": rep_on.tokens_out,
            "tokens_per_s": round(rep_on.tokens_per_s, 2),
            "ttft_ticks_p50": rep_on.ttft_ticks_p50,
            "ttft_ticks_p99": rep_on.ttft_ticks_p99,
            "tpot_ticks_mean": round(rep_on.tpot_ticks_mean, 3),
            "reconfig_count": rep_on.reconfig_count,
            "a2a_bytes": rep_on.a2a_bytes,
            "bit_identical_to_static": toks_on == toks_off,
        },
    }

    # --- (b) pricing side ---------------------------------------------------
    model = dc.replace(MIXTRAL_8X7B, num_blocks=8, overlap_chunks=4)
    n_req = 24 if fast else 48
    sims = []
    for fname, reconfig in (("mixnet", True), ("fat-tree", False)):
        fab = make_fabric(fname, FabricConfig(num_servers=128, link_gbps=400))
        r = simulate_serving(
            model, fab, mix="agentic", num_requests=n_req,
            use_reconfig=reconfig, seed=1,
        )
        sims.append({
            "fabric": fname,
            "reconfig": reconfig,
            "goodput_tok_s": round(r.goodput_tok_s, 1),
            "goodput_per_mdollar": round(r.goodput_per_mdollar, 2),
            "ttft_p50_ms": round(r.ttft_p50_s * 1e3, 3),
            "tpot_p50_us": round(r.tpot_p50_s * 1e6, 2),
            "exposed_comm_fraction": round(r.exposed_comm_fraction, 4),
            "reconfig_count": r.reconfig_count,
            "reconfig_blocked_ms": round(r.reconfig_blocked_s * 1e3, 3),
        })
        _row(
            f"serve/netsim_{fname}", 0.0,
            f"goodput={r.goodput_tok_s:.0f}tok/s per_M$={r.goodput_per_mdollar:.1f} "
            f"tpot_p50={r.tpot_p50_s*1e6:.1f}us exposed={r.exposed_comm_fraction:.2f} "
            f"reconfigs={r.reconfig_count}",
        )
    ratio = sims[0]["goodput_per_mdollar"] / sims[1]["goodput_per_mdollar"]
    assert ratio >= 1.0, (
        f"reconfigured goodput/$ fell below the static EPS baseline: {ratio:.2f}"
    )
    _row("serve/goodput_per_dollar", 0.0,
         f"reconfigured_over_static={ratio:.2f}x (acceptance: >= 1.0)")
    entry["netsim"] = sims
    entry["goodput_per_dollar_ratio"] = round(ratio, 3)

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_serve.json")
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)


def fleet(fast=False):
    """Fleet serving scenario (DESIGN.md §12, BENCH_fleet.json).

    The priced fleet netsim at EQUAL total GPUs: N steered replicas
    (gate-locality vs least-loaded admission) vs one big replica with the
    same server count and slot budget.  Reports fleet goodput-per-dollar,
    the per-replica resident-expert working set (the §3 locality win: a
    region-pure replica streams a few hot experts per decode tick where a
    blended one streams most of E), and the degradation gate — one replica
    failing mid-run must strand nothing.  Acceptance: locality steering
    >= least-loaded on goodput/$ for the region-skewed mix."""
    import dataclasses as dc
    import json
    import os

    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core.netsim import simulate_fleet

    model = dc.replace(MIXTRAL_8X7B, num_blocks=8, overlap_chunks=4)
    n_req = 32 if fast else 64
    replicas, servers = 4, 2
    common = dict(
        num_requests=n_req, mixes=("chat", "agentic"), seed=0,
        arrival_scale=0.05, num_servers_replica=servers, slots=16,
    )
    runs = []
    for label, kw in (
        ("locality", dict(policy="locality", num_replicas=replicas)),
        ("least_loaded", dict(policy="least_loaded", num_replicas=replicas)),
        # one big replica at equal total GPUs: R x S servers, R x slots
        ("single_big", dict(policy="least_loaded", num_replicas=1,
                            num_servers_replica=replicas * servers,
                            slots=16 * replicas)),
        ("locality_fail", dict(policy="locality", num_replicas=replicas,
                               fail=(0, 200))),
    ):
        r = simulate_fleet(model, **{**common, **kw})
        runs.append({
            "run": label,
            "policy": r.policy,
            "num_replicas": r.num_replicas,
            "completed": r.completed,
            "requests": r.requests,
            "goodput_tok_s": round(r.goodput_tok_s, 1),
            "fleet_cost_usd": round(r.fleet_cost_usd, 2),
            "cross_tier_cost_usd": round(r.cross_tier_cost_usd, 2),
            "goodput_per_mdollar": round(r.goodput_per_mdollar, 2),
            "ttft_p50_ms": round(r.ttft_p50_s * 1e3, 3),
            "slo_attainment": r.slo_attainment,
            "reconfig_count": r.reconfig_count,
            "reconfig_blocked_ms": round(r.reconfig_blocked_s * 1e3, 3),
            "mean_active_experts": [
                round(x, 2) for x in r.replica_mean_active_experts
            ],
        })
        _row(
            f"fleet/{label}", 0.0,
            f"goodput={r.goodput_tok_s:.0f}tok/s per_M$={r.goodput_per_mdollar:.1f} "
            f"completed={r.completed}/{r.requests} reconfigs={r.reconfig_count} "
            f"neff={[round(x, 1) for x in r.replica_mean_active_experts]}",
        )
    by = {e["run"]: e for e in runs}
    ratio = (
        by["locality"]["goodput_per_mdollar"]
        / by["least_loaded"]["goodput_per_mdollar"]
    )
    assert ratio >= 1.0, (
        f"locality steering fell below least-loaded on goodput/$: {ratio:.2f}"
    )
    assert by["locality_fail"]["completed"] == by["locality_fail"]["requests"], (
        "replica failure stranded requests"
    )
    _row("fleet/steering_gain", 0.0,
         f"locality_over_least_loaded={ratio:.2f}x (acceptance: >= 1.0); "
         f"single_big per_M$={by['single_big']['goodput_per_mdollar']:.1f} "
         f"at equal total GPUs")

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_fleet.json")
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append({
        "bench": "fleet",
        "runs": runs,
        "locality_over_least_loaded": round(ratio, 3),
    })
    with open(path, "w") as f:
        json.dump(history, f, indent=2)


def paged_decode(fast=False):
    """Paged KV cache vs dense ring buffer at EQUAL HBM budget
    (DESIGN.md §10, BENCH_paged.json).

    (a) Engine side: the shared-prefix agentic mix served twice through
    ServeEngine with the SAME KV pool bytes — dense preallocates
    ``slots x max_len`` so the budget caps it at 3 slots; paged spends the
    same bytes as a page pool, admits by live footprint, and prefix-registry
    hits skip the shared 64-token prefill.  Acceptance gate: paged
    tokens/s >= 2x dense.
    (b) Pricing side: netsim's serving scenario with ``paged_kv`` on vs off
    under the same ``kv_budget_tokens`` — goodput-per-dollar must improve
    (same fabric, same cost, more concurrent decode)."""
    import dataclasses as dc
    import json
    import os
    import time

    import jax

    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_serving
    from repro.models.config import ModelConfig
    from repro.models.transformer import init_model
    from repro.parallel.sharding import make_plan
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.workload import MIXES, WorkloadGenerator

    # --- (a) engine side ----------------------------------------------------
    plan = make_plan(None)
    cfg = ModelConfig("pgd", "dense", 2, 32, 4, 2, 64, 64, dtype="float32",
                      remat="none")
    params, _ = init_model(jax.random.PRNGKey(0), cfg, plan)
    # Single-tenant agentic serving: every carrier sends the SAME 64-token
    # system prompt.  (The 4-region variant splits the budget across four
    # distinct prefixes, which at this toy pool size leaves no headroom for
    # the paged path to convert into extra concurrency.)
    mix = dc.replace(MIXES["agentic_shared"], num_regions=1)
    gen = WorkloadGenerator(mix, seed=5, vocab_size=cfg.vocab_size)
    n_req = 12 if fast else 24
    reqs = [
        dc.replace(r, prompt_len=min(r.prompt_len, 80),
                   max_new_tokens=min(r.max_new_tokens, 12), arrival_s=0.0)
        for r in gen.generate(n_req)
    ]
    page, max_len = 16, 96
    budget_tokens = 3 * max_len  # the HBM budget BOTH configs get

    def run_engine(paged, slots):
        scfg = ServeConfig(
            slots=slots, max_len=max_len, prefill_chunk=8, paged=paged,
            page_size=page,
            num_pages=(budget_tokens // page if paged else 0),
        )
        eng = ServeEngine(jax.tree.map(lambda a: a, params), cfg, plan, scfg)
        warm = [dc.replace(reqs[0], rid=10_000)]
        eng.run(warm, gen)  # compile prefill/chunk/decode steps
        n0 = sum(len(r.out) for r in eng.batcher.finished)
        t0 = time.perf_counter()
        eng.run(reqs, gen)
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in eng.batcher.finished) - n0
        rep = eng.report(dt)
        assert rep.completed == len(reqs) + 1
        return toks / dt, rep

    # dense: the budget preallocates 3 full-length slots; paged: the same
    # bytes as a shared pool serve 8 slots' live footprints.
    tok_s_dense, rep_d = run_engine(False, slots=budget_tokens // max_len)
    tok_s_paged, rep_p = run_engine(True, slots=8)
    resident_dense = budget_tokens  # preallocated, always fully resident
    resident_paged = rep_p.kv_resident_pages_peak * page
    speedup = tok_s_paged / tok_s_dense
    _row(
        "paged_decode/engine", 0.0,
        f"paged={tok_s_paged:.1f}tok/s dense={tok_s_dense:.1f}tok/s "
        f"speedup={speedup:.2f}x prefix_hit_pages={rep_p.kv_prefix_hit_pages} "
        f"resident_peak={resident_paged}/{budget_tokens}tok",
    )
    assert rep_p.kv_prefix_hit_pages > 0, "prefix registry never hit"
    assert resident_paged <= budget_tokens, "paged run exceeded the HBM budget"
    assert speedup >= 2.0, (
        f"paged tokens/s only {speedup:.2f}x dense at equal HBM budget"
    )
    entry = {
        "bench": "paged_decode",
        "engine": {
            "mix": "agentic_shared",
            "requests": n_req,
            "kv_budget_tokens": budget_tokens,
            "dense_tokens_per_s": round(tok_s_dense, 2),
            "paged_tokens_per_s": round(tok_s_paged, 2),
            "speedup": round(speedup, 3),
            "dense_slots": budget_tokens // max_len,
            "paged_slots": 8,
            "kv_resident_tokens_peak": resident_paged,
            "kv_resident_tokens_dense": resident_dense,
            "prefix_hit_pages": rep_p.kv_prefix_hit_pages,
            "cow_forks": rep_p.kv_cow_forks,
            "evictions": rep_p.kv_evictions,
        },
    }

    # --- (b) pricing side ---------------------------------------------------
    model = dc.replace(MIXTRAL_8X7B, num_blocks=8, overlap_chunks=4)
    fab = make_fabric("mixnet", FabricConfig(num_servers=128, link_gbps=400))
    n_sim = 24 if fast else 48
    # Compress arrivals so the run is service-limited (not arrival-limited)
    # and pick a budget that BINDS: admission must stall on KV residency for
    # the footprint difference to change the makespan.
    sim_mix = dc.replace(MIXES["agentic_shared"], rate_rps=500.0,
                         arrival="poisson", num_regions=1)
    sim_budget = 288
    sims = {}
    for paged in (False, True):
        r = simulate_serving(
            model, fab, mix=sim_mix, num_requests=n_sim, slots=64,
            use_reconfig=True, seed=1, paged_kv=paged,
            kv_budget_tokens=sim_budget, kv_page_tokens=page,
        )
        sims[paged] = r
        _row(
            f"paged_decode/netsim_{'paged' if paged else 'dense'}", 0.0,
            f"goodput={r.goodput_tok_s:.0f}tok/s "
            f"per_M$={r.goodput_per_mdollar:.1f} "
            f"resident_peak={r.kv_resident_tokens_peak}tok "
            f"ttft_p50={r.ttft_p50_s*1e3:.2f}ms",
        )
    ratio = sims[True].goodput_per_mdollar / sims[False].goodput_per_mdollar
    assert ratio > 1.0, (
        f"paged KV did not improve goodput/$ at equal budget: {ratio:.3f}"
    )
    _row("paged_decode/goodput_per_dollar", 0.0,
         f"paged_over_dense={ratio:.2f}x (acceptance: > 1.0)")
    entry["netsim"] = {
        "kv_budget_tokens": sim_budget,
        "dense_goodput_per_mdollar": round(sims[False].goodput_per_mdollar, 2),
        "paged_goodput_per_mdollar": round(sims[True].goodput_per_mdollar, 2),
        "goodput_per_dollar_ratio": round(ratio, 3),
        "dense_resident_tokens_peak": sims[False].kv_resident_tokens_peak,
        "paged_resident_tokens_peak": sims[True].kv_resident_tokens_peak,
    }

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_paged.json")
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)


def spec_decode(fast=False):
    """Speculative vs serial decode through the paged serving engine
    (DESIGN.md §11, BENCH_spec.json).

    (a) Engine side: a shared-expert MoE whose routed-expert outputs are
    damped post-init (the converged shared-dominant regime the shared_only
    draft is built for) serves the agentic mix twice — serial decode vs
    draft/verify at K=4 — on the SAME paged pool.  The spec run must emit
    token-for-token identical outputs (bit-exact acceptance) and deliver
    >= 1.5x decode tokens/s at the measured acceptance rate.
    (b) Pricing side: netsim's serving scenario with ``spec_decode=(K, p)``
    across acceptance p — the draft pass is priced (flops + KV restream),
    so low p LOSES goodput/$ and high p wins; the crossover acceptance is
    logged next to the ratio curve."""
    import dataclasses as dc
    import json
    import os
    import time

    import jax

    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_serving
    from repro.models.config import ModelConfig, MoEConfig
    from repro.models.transformer import init_model
    from repro.parallel.sharding import make_plan
    from repro.serve.batching import Request
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.workload import MIXES, WorkloadGenerator, clamp_requests

    # --- (a) engine side ----------------------------------------------------
    plan = make_plan(None)
    cfg = ModelConfig(
        "spd", "moe", 2, 64, 4, 2, 0, 256, dtype="float32", remat="none",
        moe=MoEConfig(8, 2, 64, num_shared_experts=1, capacity_factor=8.0,
                      backend="mixnet", a2a_group=2, dispatch="dropless"),
    )
    params, _ = init_model(jax.random.PRNGKey(0), cfg, plan)
    # Shared-dominant regime: damp the routed experts' output projection so
    # the logits are carried by the shared expert + attention — the model a
    # shared_only draft can actually predict.  Random routed weights would
    # bury acceptance at ~0; a converged shared-expert MoE looks like this.
    for bp in params["blocks"].values():
        if "moe" in bp:
            bp["moe"]["w_out"] = bp["moe"]["w_out"] * 0.05
    mix = dc.replace(MIXES["agentic_shared"], num_regions=1)
    gen = WorkloadGenerator(mix, seed=5, vocab_size=cfg.vocab_size)
    n_req = 8 if fast else 16
    k_spec = 4
    base_reqs = clamp_requests(gen.generate(n_req), prompt_max=32,
                               max_new=40, arrival_s=0.0)

    def make_reqs(offset=0):
        return [
            Request(
                rid=r.rid + offset,
                prompt=gen.prompt_tokens(r),
                max_new_tokens=r.max_new_tokens,
            )
            for r in base_reqs
        ]

    def make_engine(spec_k):
        scfg = ServeConfig(slots=4, max_len=96, prefill_chunk=16, paged=True,
                           page_size=16, spec_k=spec_k)
        eng = ServeEngine(jax.tree.map(lambda a: a, params), cfg, plan, scfg)
        # Warm batch fills every slot: compiles prefill/chunk + (draft,
        # verify) programs AND runs the full-occupancy tick once, so the
        # timed trials never see a cold path.
        for warm in make_reqs(offset=10_000)[:4]:
            eng.submit(warm)
        while eng.batcher.busy:
            eng.step()
        eng.batcher.finished.clear()
        eng.batcher.spec_drafted = eng.batcher.spec_accepted = 0
        return eng

    def trial(eng, offset):
        t0 = time.perf_counter()
        for r in make_reqs(offset=offset):
            eng.submit(r)
        while eng.batcher.busy:
            eng.step()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out) for r in eng.batcher.finished)
        outs = {r.rid % 100_000: list(r.out) for r in eng.batcher.finished}
        eng.batcher.finished.clear()
        return toks / dt, outs

    # Interleave the serial and spec arms trial-by-trial so host drift
    # (shared-CPU noise) hits both equally, and gate on the MEDIAN of 5 —
    # best-of per arm would let one lucky serial trial sink the ratio.
    eng_base, eng_spec = make_engine(0), make_engine(k_spec)
    base_samples, spec_samples = [], []
    for t in range(5):
        tb, outs_base = trial(eng_base, (t + 1) * 100_000)
        ts, outs_spec = trial(eng_spec, (t + 1) * 100_000)
        base_samples.append(tb)
        spec_samples.append(ts)
    tok_s_base = float(np.median(base_samples))
    tok_s_spec = float(np.median(spec_samples))
    rep = eng_spec.report(1.0)
    speedup = tok_s_spec / tok_s_base
    acc = rep.spec_acceptance
    _row(
        "spec_decode/engine", 0.0,
        f"spec={tok_s_spec:.1f}tok/s serial={tok_s_base:.1f}tok/s "
        f"speedup={speedup:.2f}x K={k_spec} acceptance={acc:.3f} "
        f"truncations={rep.draft_truncations} "
        f"pages_reclaimed={rep.pages_reclaimed}",
    )
    assert outs_spec == outs_base, "speculative decode diverged from serial"
    assert rep.spec_drafted > 0, "spec run never drafted"
    assert speedup >= 1.5, (
        f"spec decode only {speedup:.2f}x serial at acceptance {acc:.3f}"
    )
    entry = {
        "bench": "spec_decode",
        "engine": {
            "mix": "agentic_shared",
            "requests": n_req,
            "spec_k": k_spec,
            "serial_tokens_per_s": round(tok_s_base, 2),
            "spec_tokens_per_s": round(tok_s_spec, 2),
            "speedup": round(speedup, 3),
            "acceptance": round(acc, 4),
            "draft_truncations": rep.draft_truncations,
            "pages_reclaimed": rep.pages_reclaimed,
            "bit_exact": outs_spec == outs_base,
        },
    }

    # --- (b) pricing side ---------------------------------------------------
    model = dc.replace(MIXTRAL_8X7B, num_blocks=8, overlap_chunks=4)
    fab = make_fabric("mixnet", FabricConfig(num_servers=128, link_gbps=400))
    n_sim = 24 if fast else 48
    sim_mix = dc.replace(MIXES["agentic_shared"], rate_rps=500.0,
                         arrival="poisson", num_regions=1)
    base_sim = simulate_serving(model, fab, mix=sim_mix, num_requests=n_sim,
                                slots=64, use_reconfig=True, seed=1)
    curve, crossover = [], None
    for p in (0.05, 0.2, 0.4, 0.6, 0.8, 0.95):
        r = simulate_serving(model, fab, mix=sim_mix, num_requests=n_sim,
                             slots=64, use_reconfig=True, seed=1,
                             spec_decode=(k_spec, p))
        ratio = r.goodput_per_mdollar / base_sim.goodput_per_mdollar
        if crossover is None and ratio >= 1.0:
            crossover = p
        curve.append({"acceptance": p, "goodput_per_dollar_ratio": round(ratio, 4),
                      "tpot_p50_ms": round(r.tpot_p50_s * 1e3, 4)})
        _row(
            f"spec_decode/netsim_p{int(p*100):02d}", 0.0,
            f"goodput_per_dollar_ratio={ratio:.3f} "
            f"tpot_p50={r.tpot_p50_s*1e3:.3f}ms "
            f"(serial {base_sim.tpot_p50_s*1e3:.3f}ms)",
        )
    # The draft pass is priced, so the curve must actually cross: spec loses
    # goodput/$ at low acceptance and wins at high acceptance.
    assert curve[0]["goodput_per_dollar_ratio"] < 1.0 <= curve[-1][
        "goodput_per_dollar_ratio"], "acceptance curve never crossed 1.0"
    _row("spec_decode/crossover", 0.0,
         f"goodput_per_dollar crosses 1.0 at acceptance~{crossover}")
    entry["netsim"] = {
        "spec_k": k_spec,
        "serial_goodput_per_mdollar": round(base_sim.goodput_per_mdollar, 2),
        "curve": curve,
        "crossover_acceptance": crossover,
    }

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_spec.json")
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)


def paper_scale(fast=False):
    """Paper-scale composition (DESIGN.md §13, BENCH_paper_scale.json).

    (a) Headline gates: MixNet-vs-fat-tree goodput-per-dollar for Mixtral
    8x7B on the 1024-GPU fabric must land in the paper's Fig 13 bands —
    >= 1.2x at 100 Gbps, >= 1.9x at 400 Gbps (gated every run).
    (b) Scale curve: the same ratio across 32-1024 GPU cluster shapes
    (``scale_layout`` re-factors EP x TP x PP per size), with the
    pipeline-tier bubble-filling overlap on — the advantage must hold
    (> 1.0) at every size.
    (c) Cached autotuner: :mod:`repro.core.autotune` grid-searches
    overlap_chunks x dispatch x a2a lowering x dp_compress per model and
    writes ``autotune_cache.json`` (the same file the trainer consumes);
    tuned goodput must be >= the default constants on BOTH tuned models.
    The recorded ``gates`` dict is what benchmarks/check_regressions.py
    re-validates in CI."""
    import dataclasses as dc
    import json
    import os

    from repro.configs.paper_models import (
        MIXTRAL_8X7B,
        QWEN_MOE,
        scale_layout,
    )
    from repro.core import autotune
    from repro.core import cost as costm
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_training

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    def goodput_per_dollar(model, fname, gbps, servers, iters):
        fab = make_fabric(fname, FabricConfig(num_servers=servers, link_gbps=gbps))
        res = simulate_training(
            model, fab, iterations=iters, use_copilot=(fname == "mixnet")
        )[1:]
        t = float(np.mean([r.total for r in res]))
        kept = float(np.mean([r.kept_fraction for r in res]))
        toks = model.num_microbatches * model.tokens_per_microbatch
        return kept * toks / t / costm.fabric_cost(fname, servers, gbps)

    # --- (a) headline gates -------------------------------------------------
    iters = 3 if fast else 5
    headline = {}
    for gbps in (100, 400):
        r_mix = goodput_per_dollar(MIXTRAL_8X7B, "mixnet", gbps, 128, iters)
        r_ft = goodput_per_dollar(MIXTRAL_8X7B, "fat-tree", gbps, 128, iters)
        headline[f"ratio_{gbps}G"] = round(r_mix / r_ft, 3)
        _row(
            f"paper_scale/headline_{gbps}G", 0.0,
            f"goodput_per_dollar_vs_ft={r_mix/r_ft:.2f}x "
            f"(paper: {'1.2-1.5x' if gbps == 100 else '1.9-2.3x'})",
        )
    gates = {"headline.ratio_100G": 1.2, "headline.ratio_400G": 1.9}
    assert headline["ratio_100G"] >= 1.2, headline
    assert headline["ratio_400G"] >= 1.9, headline

    # --- (b) 32-1024 GPU scale curve (pipeline-tier overlap on) -------------
    sizes = (32, 128) if fast else (32, 128, 512, 1024)
    curve = []
    for gpus in sizes:
        m = dc.replace(scale_layout(MIXTRAL_8X7B, gpus), pp_overlap=True)
        servers = max(gpus // 8, 4)
        r_mix = goodput_per_dollar(m, "mixnet", 400, servers, 3)
        r_ft = goodput_per_dollar(m, "fat-tree", 400, servers, 3)
        ratio = r_mix / r_ft
        curve.append({
            "gpus": gpus,
            "layout": f"ep{m.ep_degree}xtp{m.tp_degree}xpp{m.pp_degree}",
            "ratio": round(ratio, 3),
        })
        gates[f"curve.{len(curve) - 1}.ratio"] = 1.0
        _row(
            f"paper_scale/curve_{gpus}gpus", 0.0,
            f"layout=ep{m.ep_degree}xtp{m.tp_degree}xpp{m.pp_degree} "
            f"goodput_per_dollar_vs_ft={ratio:.2f}x",
        )
        assert ratio > 1.0, (gpus, ratio)

    # --- (c) cached autotuner: tuned >= default on two model configs --------
    cache_path = os.path.join(root, "autotune_cache.json")
    tuned = {}
    for model in (MIXTRAL_8X7B, QWEN_MOE):
        r = autotune.tune(
            model, "mixnet", 400, cache_path=cache_path,
            iterations=2, refresh=not fast,
        )
        tuned[model.name] = {
            "key": r.key,
            "knobs": r.knobs,
            "speedup": round(r.speedup, 3),
        }
        gates[f"autotune.{model.name}.speedup"] = 1.0
        _row(
            f"paper_scale/autotune_{model.name}", 0.0,
            f"tuned_over_default={r.speedup:.3f}x knobs={r.knobs} "
            f"(cache: autotune_cache.json)",
        )
        assert r.speedup >= 1.0, (model.name, r.speedup)

    entry = {
        "bench": "paper_scale",
        "headline": headline,
        "curve": curve,
        "autotune": tuned,
        "gates": gates,
    }
    path = os.path.join(root, "BENCH_paper_scale.json")
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)


def observability(fast=False):
    """Measurement plane (DESIGN.md §14, BENCH_obs.json).

    (a) Tracer throughput: enabled span+counter emission rate into the ring
    buffer, and the per-call cost of the disabled no-op path.
    (b) Serve-tick overhead: ONE warmed engine decoding a chat-mix workload
    with reconfiguration off (every tick does the same decode work);
    tracer state follows an ABBA pattern (off-on-on-off) per 4-tick group,
    and the statistic is the median of per-pair differences — pairing
    cancels host drift, the ABBA order cancels the linear tick growth from
    the lengthening KV cache, and the median rejects scheduler stalls.
    Acceptance gate (re-checked by check_regressions.py): < 3%.
    (c) The §3 traffic study from the same run: expert-locality score,
    regional skew, and mean effective experts on the chat mix."""
    import json
    import os
    import time

    import jax

    from repro.models.config import ModelConfig, MoEConfig
    from repro.models.transformer import init_model
    from repro.obs import trace
    from repro.obs.trace import Tracer, validate_events
    from repro.parallel.sharding import make_plan
    from repro.serve.batching import Request
    from repro.serve.engine import ServeConfig, ServeEngine
    from repro.serve.workload import MIXES, WorkloadGenerator

    # --- (a) tracer micro-costs --------------------------------------------
    n = 20_000 if fast else 100_000
    tr = Tracer()
    tr.enabled = True
    tid = tr.track("bench")
    t0 = time.perf_counter()
    for i in range(n):
        with tr.span("s", tid=tid):
            pass
        tr.counter("c", float(i), tid=tid)
    dt = time.perf_counter() - t0
    events_per_s = 2 * n / dt
    assert validate_events(tr.events()[-1000:]) == []
    tr.enabled = False
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("s"):
            pass
        tr.counter("c", 1.0)
    disabled_ns = (time.perf_counter() - t0) / (2 * n) * 1e9
    _row("observability/tracer", 0.0,
         f"enabled={events_per_s/1e6:.2f}M events/s "
         f"disabled={disabled_ns:.0f}ns/op")

    # --- (b) serve-tick overhead, enabled vs disabled ----------------------
    plan = make_plan(None)
    cfg = ModelConfig(
        "obs", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=8.0,
                      backend="mixnet"),
    )
    params, _ = init_model(jax.random.PRNGKey(0), cfg, plan)
    mix = MIXES["chat"]
    gen = WorkloadGenerator("chat", seed=3, vocab_size=cfg.vocab_size)
    scfg = ServeConfig(slots=2, max_len=2048, num_devices=4,
                       num_regions=mix.num_regions)
    eng = ServeEngine(params, cfg, plan, scfg)
    # Identical prompt lengths (one prefill shape, compiled in warmup) and
    # decode budgets far past the measurement horizon: no request finishes
    # or is admitted mid-measurement, so every timed tick is the same
    # 2-row decode.
    for r in gen.generate(8):
        eng.submit(Request(rid=r.rid, prompt=gen.prompt_tokens(r)[:16],
                           max_new_tokens=2000, region=r.region))
    trace.disable()
    for _ in range(8):  # compile prefill + decode
        eng.step()
    import statistics

    def _tick(enabled):
        (trace.enable if enabled else trace.disable)()
        t0 = time.perf_counter()
        eng.step()
        return time.perf_counter() - t0

    groups = 40 if fast else 80  # ABBA groups of 4 ticks -> 2 pairs each
    diffs, d_ticks, e_ticks = [], [], []
    for _ in range(groups):
        assert eng.batcher.busy, "workload drained mid-measurement"
        d1 = _tick(False)
        e1 = _tick(True)
        e2 = _tick(True)
        d2 = _tick(False)
        diffs += [e1 - d1, e2 - d2]
        d_ticks += [d1, d2]
        e_ticks += [e1, e2]
    trace.disable()
    ticks = 4 * groups
    med_d = statistics.median(d_ticks)
    med_e = statistics.median(e_ticks)
    overhead = statistics.median(diffs) / med_d
    _row("observability/serve_tick", med_e * 1e6,
         f"disabled_ms={med_d*1e3:.2f} enabled_ms={med_e*1e3:.2f} "
         f"overhead={overhead*100:+.2f}% (gate: < 3%)")
    assert overhead < 0.03, (
        f"enabled tracing costs {overhead*100:.2f}% per serve tick (gate 3%)"
    )
    assert validate_events(trace.default().events()) == []
    trace.default().clear()

    # --- (c) the §3 study on the chat mix ----------------------------------
    obs = eng.observatory
    locality = obs.locality_score()
    skew = obs.regional_skew()
    eff = float(np.mean(obs.effective_experts()))
    _row("observability/traffic_chat", 0.0,
         f"locality={locality:.3f} regional_skew={skew:.3f} "
         f"mean_effective_experts={eff:.2f} over {obs.ticks} ticks")
    assert 0.0 <= locality <= 1.0

    entry = {
        "bench": "observability",
        "tracer": {
            "enabled_events_per_s": round(events_per_s, 1),
            "disabled_ns_per_op": round(disabled_ns, 2),
        },
        "serve": {
            "ticks_timed": ticks,
            "disabled_us_per_tick": round(med_d * 1e6, 1),
            "enabled_us_per_tick": round(med_e * 1e6, 1),
            "overhead_fraction": round(overhead, 5),
        },
        "traffic": {
            "mix": "chat",
            "ticks": obs.ticks,
            "locality_score": round(locality, 4),
            "regional_skew": round(skew, 4),
            "mean_effective_experts": round(eff, 3),
        },
    }
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, "BENCH_obs.json")
    history = []
    if os.path.exists(path):
        with open(path) as f:
            history = json.load(f)
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2)


def kernels(fast=False):
    """Framework table: Pallas kernels validated against oracles (interpret)
    + oracle-path timings on CPU."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 128, 256))
    w = jax.random.normal(key, (8, 256, 512))
    us = _timeit(lambda: jax.block_until_ready(ref.grouped_matmul(x, w)))
    _row("kernels/grouped_matmul_ref", us, "oracle=jnp einsum")
    logits = jax.random.normal(key, (4096, 64))
    us = _timeit(lambda: jax.block_until_ready(ref.topk_gating(logits, 6)[0]))
    _row("kernels/topk_gating_ref", us, "oracle=softmax+top_k")
    q = jax.random.normal(key, (1, 8, 1024, 64))
    k = jax.random.normal(key, (1, 2, 1024, 64))
    us = _timeit(lambda: jax.block_until_ready(
        ref.flash_attention_chunked(q, k, k, causal=True)))
    _row("kernels/flash_attention_chunked", us, "oracle=streaming softmax")



def beyond_placement(fast=False):
    """Beyond-paper ablation: the TPU-analogue expert re-placement — how many
    bytes-on-wire Algorithm-1-driven placement removes from realized MoE
    traffic, across trace seeds (the gain the runtime controller banks each
    reconfiguration)."""
    from repro.core.netsim import GateTraceGenerator
    from repro.core.placement import solve_expert_placement

    rng_gains = []
    devices, experts = 8, 32
    for seed in range(3 if fast else 8):
        trace = GateTraceGenerator(4, experts, seed=seed)
        loads = trace.step()
        demand = np.zeros((devices, experts))
        g = np.random.default_rng(seed)
        for d in range(devices):
            w = g.dirichlet(loads[0] * 6 + 1e-2)
            demand[d] = w * 1e9
        plan = solve_expert_placement(demand, experts // devices)
        rng_gains.append(plan.gain / max(plan.cost_before, 1e-9))
    _row(
        "beyond_placement/gain", 0.0,
        f"mean_wire_reduction={np.mean(rng_gains)*100:.0f}% "
        f"min={np.min(rng_gains)*100:.0f}% max={np.max(rng_gains)*100:.0f}% "
        f"(runtime re-placement, DESIGN.md §2)",
    )


def beyond_a2a_hierarchy(fast=False):
    """Beyond-paper ablation: the delegation a2a's per-stage traffic split —
    stage 1 (scale-up analogue) vs stage 2 (scale-out analogue) wire bytes
    for a 16-wide region at different group sizes."""
    payload = 1.0  # normalized per-device send volume
    p = 16
    for g in (2, 4, 8):
        n_groups = p // g
        stage1 = payload * (g - 1) / g       # intra-group exchange
        stage2 = payload * (n_groups - 1) / n_groups  # inter-group exchange
        flat = payload * (p - 1) / p
        _row(
            f"beyond_a2a_hierarchy/group{g}", 0.0,
            f"stage1={stage1:.2f} stage2={stage2:.2f} flat={flat:.2f} "
            f"scale_out_reduction={(1 - stage2/flat)*100:.0f}%",
        )


ALL = {
    "fig2_traffic_volume": fig2_traffic_volume,
    "fig3_timeline": fig3_timeline,
    "fig10_testbed": fig10_testbed,
    "fig11_cost": fig11_cost,
    "fig12_speedups": fig12_speedups,
    "fig13_pareto": fig13_pareto,
    "fig14_failures": fig14_failures,
    "fig16_nvl72": fig16_nvl72,
    "fig19_copilot": fig19_copilot,
    "fig21_reconfig_delay": fig21_reconfig_delay,
    "fig26_scalability": fig26_scalability,
    "fig27_optical_degree": fig27_optical_degree,
    "fig28_reconfig_latency": fig28_reconfig_latency,
    "copilot_refit": copilot_refit,
    "moe_dispatch": moe_dispatch,
    "collectives": collectives,
    "overlap": overlap,
    "serve": serve,
    "fleet": fleet,
    "paged_decode": paged_decode,
    "spec_decode": spec_decode,
    "paper_scale": paper_scale,
    "observability": observability,
    "kernels": kernels,
    "beyond_placement": beyond_placement,
    "beyond_a2a_hierarchy": beyond_a2a_hierarchy,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=tuple(ALL), default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in ALL.items():
        if args.only and name != args.only:
            continue
        fn(fast=args.fast)


if __name__ == "__main__":
    main()
