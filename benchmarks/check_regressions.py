"""Benchmark-regression guard: re-validate every committed BENCH_*.json.

``PYTHONPATH=src python -m benchmarks.check_regressions [--root DIR]``

Each benchmark that carries an acceptance gate records the measured ratio
next to the gate it had to clear.  This script walks all committed
``BENCH_*.json`` histories and fails (exit 1) when any entry's gated
metric sits below its gate — i.e. when a regression was *committed*, not
merely measured.  Two gate encodings are understood:

* the generic form: an entry-level ``"gates"`` dict mapping a dotted path
  into the entry (``"headline.ratio_400G"``, ``"curve.0.ratio"``) to the
  minimum acceptable value (``BENCH_paper_scale.json`` writes this);
* legacy per-file rules for the histories that predate the generic form
  (serve/fleet/paged/spec ratios, collectives bit-identity, copilot
  refit deviation, the enabled-tracing serve-tick overhead bound).

Entries whose file has no rule and no ``gates`` dict are ignored — wall
-clock microbenchmarks drift with the host and are tracked, not gated.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def _dig(entry, dotted: str):
    cur = entry
    for part in dotted.split("."):
        if isinstance(cur, list):
            cur = cur[int(part)]
        else:
            cur = cur[part]
    return cur


def _generic_gates(entry: dict) -> list[str]:
    """Entry-level ``gates`` dict: dotted path -> minimum value."""
    failures = []
    for path, floor in entry.get("gates", {}).items():
        try:
            val = _dig(entry, path)
        except (KeyError, IndexError, ValueError, TypeError):
            failures.append(f"gated path {path!r} missing from entry")
            continue
        if not float(val) >= float(floor):
            failures.append(f"{path} = {val} < gate {floor}")
    return failures


# Legacy rules: file basename -> fn(entry) -> list of failure strings.
def _serve(entry):
    r = entry.get("goodput_per_dollar_ratio")
    return [] if r is None or r >= 1.0 else [f"goodput_per_dollar_ratio {r} < 1.0"]


def _fleet(entry):
    r = entry.get("locality_over_least_loaded")
    return [] if r is None or r >= 1.0 else [f"locality_over_least_loaded {r} < 1.0"]


def _paged(entry):
    r = entry.get("netsim", {}).get("goodput_per_dollar_ratio")
    return [] if r is None or r > 1.0 else [f"netsim goodput_per_dollar_ratio {r} <= 1.0"]


def _spec(entry):
    curve = entry.get("netsim", {}).get("curve") or []
    if not curve:
        return []
    last = curve[-1].get("goodput_per_dollar_ratio", 1.0)
    return [] if last >= 1.0 else [f"high-acceptance ratio {last} < 1.0"]


def _collectives(entry):
    ok = entry.get("fused_bit_identical", True)
    return [] if ok else ["fused a2a no longer bit-identical"]


def _copilot(entry):
    dev = entry.get("max_transition_deviation")
    return [] if dev is None or dev <= 1e-5 else [f"refit deviation {dev} > 1e-5"]


def _moe_dispatch(entry):
    s = entry.get("speedup")
    return [] if s is None or s >= 1.0 else [f"sort dispatch speedup {s} < 1.0"]


def _obs(entry):
    f = entry.get("serve", {}).get("overhead_fraction")
    return [] if f is None or f < 0.03 else [
        f"enabled-tracing serve-tick overhead {f} >= 0.03"
    ]


LEGACY_RULES = {
    "BENCH_serve.json": _serve,
    "BENCH_fleet.json": _fleet,
    "BENCH_paged.json": _paged,
    "BENCH_spec.json": _spec,
    "BENCH_collectives.json": _collectives,
    "BENCH_copilot.json": _copilot,
    "BENCH_moe_dispatch.json": _moe_dispatch,
    "BENCH_obs.json": _obs,
}


def check_file(path: str) -> list[str]:
    name = os.path.basename(path)
    with open(path) as f:
        history = json.load(f)
    if not isinstance(history, list):
        history = [history]
    rule = LEGACY_RULES.get(name)
    failures = []
    for i, entry in enumerate(history):
        if not isinstance(entry, dict):
            continue
        for msg in _generic_gates(entry):
            failures.append(f"{name}[{i}]: {msg}")
        if rule is not None:
            for msg in rule(entry):
                failures.append(f"{name}[{i}]: {msg}")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--root",
        default=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    args = ap.parse_args()
    paths = sorted(glob.glob(os.path.join(args.root, "BENCH_*.json")))
    if not paths:
        print("no BENCH_*.json files found", file=sys.stderr)
        return 1
    failures = []
    for p in paths:
        msgs = check_file(p)
        failures.extend(msgs)
        status = "FAIL" if msgs else "ok"
        print(f"{os.path.basename(p)}: {status}")
    for msg in failures:
        print(f"REGRESSION: {msg}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
