"""Algorithm 1: greedy OCS circuit allocation (paper §5.2).

This is control-plane code — it runs between training steps on the host,
never inside the XLA graph — so it is written in plain numpy.

The solver takes the expert-level all-to-all demand matrix ``E`` (bytes to
move between every (src_expert, dst_expert) pair), folds it down to an
inter-server demand matrix ``D`` (Step 1), then greedily assigns optical
circuits to the current *bottleneck* server pair — the pair whose remaining
transfer would finish last given the circuits allocated so far (Steps 2-3) —
until every server has exhausted its optical degree ``alpha``.  Finally the
circuit matrix is expanded to a NIC-level port mapping with NUMA-balanced
permutation (Step 4) ready to be pushed to the OCS (Step 5).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "OCSTopology",
    "calculate_server_demand",
    "reconfigure_ocs",
    "topology_completion_time",
]


@dataclasses.dataclass(frozen=True)
class OCSTopology:
    """Result of one run of Algorithm 1.

    Attributes:
      circuits: ``[N, N]`` int matrix; ``circuits[i, j]`` = number of optical
        circuits provisioned between servers *i* and *j* (symmetric).
      nic_map: list of ``(src_server, src_nic, dst_server, dst_nic)`` tuples —
        the physical cross-connect list pushed to the OCS.
      alpha: per-server optical degree used.
      demand: the upper-triangular inter-server demand the solver saw (bytes).
    """

    circuits: np.ndarray
    nic_map: tuple
    alpha: int
    demand: np.ndarray

    @property
    def num_servers(self) -> int:
        return self.circuits.shape[0]

    def links_of(self, server: int) -> int:
        return int(self.circuits[server].sum())


def calculate_server_demand(
    expert_demand: np.ndarray,
    experts_per_server: int,
) -> np.ndarray:
    """Step 1 — fold the expert-level demand into inter-server demand.

    TX and RX demand of a pair are provisioned together (the OCS link is
    bidirectional), so the result is upper-triangular with
    ``D[i, j] = demand(i->j) + demand(j->i)`` for ``i < j`` and zero diagonal
    (intra-server traffic rides NVSwitch / intra-chip, not the OCS).
    """
    expert_demand = np.asarray(expert_demand, dtype=np.float64)
    n_experts = expert_demand.shape[0]
    if expert_demand.shape != (n_experts, n_experts):
        raise ValueError(f"expert demand must be square, got {expert_demand.shape}")
    if n_experts % experts_per_server != 0:
        raise ValueError(
            f"{n_experts} experts not divisible by {experts_per_server} per server"
        )
    n_servers = n_experts // experts_per_server
    # Sum expert blocks into server blocks.
    server = expert_demand.reshape(
        n_servers, experts_per_server, n_servers, experts_per_server
    ).sum(axis=(1, 3))
    np.fill_diagonal(server, 0.0)
    upper = np.triu(server + server.T, k=1)
    return upper


def _find_bottleneck_link(
    demand: np.ndarray, circuits: np.ndarray, eps_bw: float
) -> tuple[int, int, float]:
    """Step 2 — the (i, j) pair with the longest remaining completion time.

    Completion time of a pair = demand / bandwidth, where bandwidth is the
    allocated circuit count (plus the EPS fallback share ``eps_bw`` expressed
    in circuit-equivalents so pairs with zero circuits still finish).
    """
    with np.errstate(divide="ignore"):
        t = demand / (circuits + eps_bw)
    t = np.where(demand > 0, t, 0.0)
    idx = int(np.argmax(t))
    i, j = divmod(idx, demand.shape[1])
    return i, j, float(t[i, j])


def reconfigure_ocs(
    expert_demand: np.ndarray,
    alpha: int,
    num_servers: int,
    experts_per_server: int | None = None,
    *,
    eps_bw_fraction: float = 0.25,
    nics_per_numa: int = 2,
    rng: np.random.Generator | None = None,
) -> OCSTopology:
    """Algorithm 1 (paper §5.2): greedy bottleneck-relief circuit allocation.

    Args:
      expert_demand: ``[E, E]`` all-to-all demand in bytes between experts.
      alpha: optical degree — number of OCS-facing NICs per server.
      num_servers: N.
      experts_per_server: defaults to ``E // num_servers``.
      eps_bw_fraction: bandwidth of the EPS fallback path relative to one
        optical circuit (pairs without circuits still drain via EPS).
      nics_per_numa: used by the Step-4 NUMA-balanced port permutation.

    Returns:
      :class:`OCSTopology` with the circuit matrix and NIC-level mapping.
    """
    expert_demand = np.asarray(expert_demand, dtype=np.float64)
    n_experts = expert_demand.shape[0]
    if experts_per_server is None:
        if n_experts % num_servers != 0:
            raise ValueError("cannot infer experts_per_server")
        experts_per_server = n_experts // num_servers
    if alpha < 0:
        raise ValueError("alpha must be >= 0")

    # Step 1: inter-server demand (upper triangular).
    demand = calculate_server_demand(expert_demand, experts_per_server)
    if demand.shape[0] != num_servers:
        raise ValueError(
            f"demand folds to {demand.shape[0]} servers, expected {num_servers}"
        )

    circuits = np.zeros((num_servers, num_servers), dtype=np.int64)
    avail = np.full(num_servers, alpha, dtype=np.int64)

    # Steps 2-3: iteratively relieve the bottleneck pair.
    while True:
        # Only pairs whose BOTH endpoints still have free optical NICs are
        # eligible; mask others out of the bottleneck search.
        eligible = (avail[:, None] > 0) & (avail[None, :] > 0)
        masked = np.where(np.triu(eligible, k=1), demand, 0.0)
        if not masked.any():
            break
        i, j, t = _find_bottleneck_link(masked, circuits, eps_bw_fraction)
        if t <= 0.0:
            break
        circuits[i, j] += 1
        circuits[j, i] += 1
        avail[i] -= 1
        avail[j] -= 1

    # Step 4: NIC-level mapping with NUMA-balanced permutation.  Circuits of
    # the same server pair are spread across NUMA nodes round-robin so
    # multi-circuit pairs do not converge on one PCIe root complex.
    # NIC k of a server lives on NUMA node ``k // nics_per_numa``.  Pairs are
    # walked heaviest-first and each extra circuit of the same pair strides the
    # cursor by ``nics_per_numa`` (mod alpha) so that a 2-circuit pair lands on
    # two different NUMA nodes — the paper's permuteLinks step.
    nic_used = [set() for _ in range(num_servers)]
    nic_map = []

    def _next_nic(server: int, preferred: int) -> int:
        for off in range(max(alpha, 1)):
            cand = (preferred + off) % max(alpha, 1)
            if cand not in nic_used[server]:
                nic_used[server].add(cand)
                return cand
        raise RuntimeError("optical degree exhausted — solver bug")

    order = np.dstack(np.unravel_index(np.argsort(-demand, axis=None), demand.shape))[0]
    for i, j in order:
        count = int(circuits[i, j]) if i < j else 0
        for c in range(count):
            stride = c * max(nics_per_numa, 1)
            src_nic = _next_nic(int(i), stride % max(alpha, 1))
            dst_nic = _next_nic(int(j), stride % max(alpha, 1))
            nic_map.append((int(i), src_nic, int(j), dst_nic))

    return OCSTopology(
        circuits=circuits,
        nic_map=tuple(nic_map),
        alpha=alpha,
        demand=demand,
    )


def topology_completion_time(
    topo_circuits: np.ndarray,
    demand: np.ndarray,
    circuit_bw: float,
    eps_bw: float,
) -> float:
    """All-to-all completion time (seconds) on a given circuit allocation.

    The all-to-all finishes when its slowest pair finishes; each pair drains
    over its optical circuits plus the shared EPS fallback.  Used both by the
    greedy solver's evaluation and by tests/benchmarks.
    """
    demand = np.triu(np.asarray(demand, dtype=np.float64), k=1)
    bw = topo_circuits * circuit_bw + eps_bw
    with np.errstate(divide="ignore", invalid="ignore"):
        t = np.where(demand > 0, demand / bw, 0.0)
    return float(np.max(t)) if t.size else 0.0


def uniform_topology(num_servers: int, alpha: int) -> np.ndarray:
    """Round-robin circuit placement (the topology-oblivious baseline)."""
    circuits = np.zeros((num_servers, num_servers), dtype=np.int64)
    avail = np.full(num_servers, alpha, dtype=np.int64)
    hop = 1
    while hop < num_servers and avail.min() > 0:
        for i in range(num_servers):
            j = (i + hop) % num_servers
            if i < j and avail[i] > 0 and avail[j] > 0:
                circuits[i, j] += 1
                circuits[j, i] += 1
                avail[i] -= 1
                avail[j] -= 1
        hop += 1
    return circuits
