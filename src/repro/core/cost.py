"""Networking cost model (paper §7.2, Table 4, Fig 11) and Pareto analysis
(Fig 13: performance-per-dollar).

Component prices are Table 4 verbatim.  Only *actually used* switch ports are
billed, matching the paper's methodology (which follows TopoOpt's).
"""

from __future__ import annotations

import dataclasses
import math

__all__ = [
    "ComponentPrices",
    "PRICES",
    "fabric_cost",
    "cost_efficiency",
]


@dataclasses.dataclass(frozen=True)
class ComponentPrices:
    transceiver: float
    nic: float
    eps_port: float
    ocs_port: float
    patch_panel_port: float
    fiber: float = 20.0  # per-link fiber cost, TopoOpt methodology


# Table 4 (USD), keyed by link bandwidth in Gbps.
PRICES: dict[int, ComponentPrices] = {
    100: ComponentPrices(99, 659, 187, 520, 100),
    200: ComponentPrices(239, 1079, 374, 520, 100),
    400: ComponentPrices(659, 1499, 1090, 520, 100),
    800: ComponentPrices(1399, 2248, 1400, 520, 100),
}


def _fat_tree_ports(num_servers: int, nics_per_server: int) -> int:
    """Used EPS switch ports for a 3-tier 1:1 fat-tree hosting N*nics links.

    A k-ary fat-tree serves k^3/4 hosts with 5k^3/4 switch ports (k^3/2 edge +
    k^3/2 aggregation + k^3/4 core): 5 switch ports per host link, each port
    carrying its own transceiver.
    """
    host_links = num_servers * nics_per_server
    return 5 * host_links


def fabric_cost(
    fabric_name: str,
    num_servers: int,
    link_gbps: int,
    *,
    nics_per_server: int = 8,
    eps_nics: int = 2,
    ocs_nics: int = 6,
    oversub_ratio: float = 3.0,
) -> float:
    """Total networking cost (USD) of one cluster interconnect.

    Components per fabric:
      fat-tree / rail-optimized: NICs + host transceivers + 3-tier switch
        ports with a transceiver on every switch port.
      oversub fat-tree: core tier divided by the over-subscription ratio.
      topoopt: NICs + host transceivers + patch-panel ports (flat).
      mixnet: EPS share like fat-tree on ``eps_nics`` + OCS ports on
        ``ocs_nics`` (OCS ports need no per-port transceiver on the switch
        side — layer-1 mirrors), Fig 11's advantage.
    """
    p = PRICES[link_gbps]
    host_links = num_servers * nics_per_server
    nic_cost = host_links * p.nic + host_links * p.transceiver + host_links * p.fiber

    if fabric_name in ("fat-tree", "rail-optimized"):
        ports = _fat_tree_ports(num_servers, nics_per_server)
        switch = ports * p.eps_port + ports * p.transceiver
        if fabric_name == "rail-optimized":
            switch *= 0.97  # slightly better port packing per rail (Fig 11)
        return nic_cost + switch
    if fabric_name == "oversub-fat-tree":
        # Edge tier at full width; aggregation/core capacity divided by the
        # over-subscription ratio (4 of the 5 per-host ports live above edge).
        ports = host_links * (1 + 4 / oversub_ratio)
        return nic_cost + ports * p.eps_port + ports * p.transceiver
    if fabric_name == "topoopt":
        # Flat patch panel; >1K GPUs needs multi-tier panels + long-reach
        # transceivers (paper §7.2) — surcharge beyond 128 servers.
        panel_ports = host_links
        tiers = max(1, math.ceil(math.log(max(num_servers / 128, 1), 4)) + 1)
        return nic_cost + panel_ports * p.patch_panel_port * tiers
    if fabric_name == "mixnet":
        eps_links = num_servers * eps_nics
        eps_ports = 3 * eps_links
        eps = eps_ports * p.eps_port + eps_ports * p.transceiver
        ocs_links = num_servers * ocs_nics
        ocs = ocs_links * p.ocs_port
        # NIC/transceiver/fiber already counted in nic_cost for all 8 NICs.
        return nic_cost + eps + ocs
    raise ValueError(f"unknown fabric {fabric_name!r}")


def cost_efficiency(iteration_time_s: float, cost_usd: float) -> float:
    """Performance per dollar: 1 / (iteration time * cost), Fig 13's metric."""
    return 1.0 / (iteration_time_s * cost_usd)
