"""Chunked comm/compute overlap engine (DESIGN.md §8).

MixNet's cost-efficiency case rests on the EP all-to-all being *hideable*
behind expert compute once the circuits match the demand (Fig 28's flat
region).  This module is the scheduling layer that turns the CommRuntime's
staged ops (:meth:`repro.core.commruntime.AllToAll.stages`, the ``Permute``
ring steps of ``AllGather``/``ReduceScatter``) into an actual schedule, on
both sides of the repo:

* **Execution side** (the trainer / MoE data plane):
  :func:`software_pipeline` runs S stage functions over K chunks in the
  skewed tick order ``stage s of chunk k at tick k+s``, draining late stages
  before issuing early ones.  Within a tick every stage call is data
  independent, which is exactly what lets the compiler overlap chunk k+1's
  dispatch all-to-all under chunk k's expert FFN under chunk k-1's combine
  (MoNTA-style chunked software pipelining; the math is unchanged because
  every chunk's rows are independent — see DESIGN.md §8 for the static-shape
  argument).

* **Pricing side** (netsim): :func:`pipelined_phase` is the flow-level event
  timeline of the same schedule — two resources (network, compute), chunked
  dispatch -> expert -> combine with precedence, greedy non-preemptive list
  scheduling in the identical skewed order.  With ``chunks=1`` it degenerates
  *exactly* to the additive serial sum, so the pre-overlap simulator results
  are reproduced bit-for-bit; with ``chunks>1`` it reports how much of the
  priced communication was hidden under the compute window
  (``IterationResult.hidden_comm``/``exposed_comm``).

Both sides consume the same per-stage ``bytes_on_link`` accounting carried
by the ops themselves — there is no second model of what a stage moves.
"""

from __future__ import annotations

__all__ = [
    "chunk_count",
    "software_pipeline",
    "pipelined_phase",
    "decode_tick_phase",
    "ring_gather_leaf",
]


def chunk_count(total: int, requested: int) -> int:
    """Largest divisor of ``total`` that is <= ``requested``.

    The overlap scheduler needs equal static chunk shapes (dynamic shapes
    would force recompilation, DESIGN.md §6), so a request that does not
    divide the token count degrades to the nearest divisor instead of
    failing mid-train.
    """
    c = max(min(int(requested), int(total)), 1)
    while total % c:
        c -= 1
    return c


def software_pipeline(num_chunks: int, stages):
    """Run ``stages`` (list of ``fn(prev_result, chunk_index)``) over
    ``num_chunks`` chunks in software-pipeline order.

    Stage ``s`` of chunk ``k`` is issued at tick ``k + s``; within a tick,
    later stages are issued first (drain order), mirroring
    :func:`pipelined_phase`'s event model.  Stage 0 receives ``prev=None``;
    stage ``s>0`` receives stage ``s-1``'s result for the same chunk.
    Returns the list of last-stage results, one per chunk.

    This is a *schedule*, not a semantic change: every stage call only
    depends on its own chunk's previous stage, so the interleaving is free
    to overlap on hardware while the composed dataflow — and therefore the
    numerics — is identical to running each chunk start-to-finish.
    """
    s_count = len(stages)
    if s_count == 0:
        return [None] * num_chunks
    results = [[None] * num_chunks for _ in range(s_count)]
    for t in range(num_chunks + s_count - 1):
        for s in reversed(range(s_count)):
            k = t - s
            if 0 <= k < num_chunks:
                prev = results[s - 1][k] if s > 0 else None
                results[s][k] = stages[s](prev, k)
    return results[-1]


def pipelined_phase(
    dispatch: float,
    compute: float,
    combine: float,
    chunks: int,
    *,
    serial_prefix: float = 0.0,
) -> tuple[float, float]:
    """Event-timeline completion of one chunked dispatch->compute->combine
    phase on two resources (network, compute engine).

    ``dispatch``/``combine`` are the phase's total network seconds (e.g. the
    fabric-priced EP all-to-all pair), ``compute`` the total expert-FFN
    seconds, each split into ``chunks`` equal chunks.  ``serial_prefix`` is
    un-overlappable compute preceding the phase (the attention block).

    Precedence per chunk k: dispatch_k -> compute_k -> combine_k; the network
    serializes dispatches and combines (shared NICs), the compute engine
    serializes FFN chunks.  Tasks are issued greedily in the skewed tick
    order with combines drained before later dispatches — the same order
    :func:`software_pipeline` executes.

    Returns ``(total_seconds, exposed_comm_seconds)`` where
    ``exposed = total - serial_prefix - compute`` — the network time not
    hidden under the compute window.  Invariants (tested):
    ``chunks=1`` gives exactly the additive serial sum (all comm exposed);
    ``total`` never exceeds the serial sum and never undercuts
    ``max(compute path, network busy time)``; ``0 <= exposed <= comm``.
    """
    c = max(int(chunks), 1)
    d, e, cb = dispatch / c, compute / c, combine / c
    net_free = 0.0
    comp_free = 0.0
    d_done = [0.0] * c
    e_done = [0.0] * c
    c_done = [0.0] * c
    for t in range(c + 2):
        k = t - 2  # combine of chunk t-2 (drain first)
        if 0 <= k < c:
            start = max(net_free, e_done[k])
            c_done[k] = start + cb
            net_free = c_done[k]
        k = t - 1  # expert FFN of chunk t-1
        if 0 <= k < c:
            start = max(comp_free, d_done[k])
            e_done[k] = start + e
            comp_free = e_done[k]
        k = t  # dispatch of chunk t
        if 0 <= k < c:
            d_done[k] = net_free + d
            net_free = d_done[k]
    total = serial_prefix + c_done[c - 1]
    exposed = max(total - serial_prefix - compute, 0.0)
    return total, exposed


def decode_tick_phase(
    dispatch: float,
    expert: float,
    combine: float,
    chunks: int,
    *,
    attn: float = 0.0,
    prefill_compute: float = 0.0,
) -> tuple[float, float]:
    """Event timeline of ONE serving decode tick per MoE layer (DESIGN.md §9).

    A decode tick is the same dispatch -> expert-FFN -> combine phase the
    trainer pipelines, at live-batch scale, with two serving-specific terms:
    ``attn`` is the tick's un-overlappable decode-attention prefix (the
    router needs its output), and ``prefill_compute`` is the interleaved
    chunked-prefill work scheduled INTO this tick — compute with no ordering
    dependence on the decode a2a, so it joins the hideable window.  That is
    the scheduling argument for chunked prefill: tiny decode batches leave
    the network exposed, and the prefill chunk is what widens the compute
    window the a2a hides under.

    Returns ``(total_seconds, exposed_comm_seconds)`` with the same
    invariants as :func:`pipelined_phase` (``chunks=1`` with no prefill is
    the additive serial tick).
    """
    return pipelined_phase(
        dispatch, expert + prefill_compute, combine, chunks, serial_prefix=attn
    )


def ring_gather_leaf(
    x, mesh, fsdp_axis: str, fsdp_dim: int, model_axis: str | None = None,
    model_dim: int | None = None,
):
    """Gather one FSDP-sharded weight leaf with the explicit AllGather ring.

    This is the FSDP-prefetch building block: the transformer scan issues it
    for block l+1's FFN weights while block l computes, so the gather's
    collective_permute hops overlap the FFN instead of XLA's on-demand
    gather serializing at first use.  ``fsdp_dim`` is the leaf dim sharded
    over ``fsdp_axis`` (gathered away); ``model_dim``'s sharding over
    ``model_axis`` is preserved through the shard_map.  Leaves the leaf
    untouched when the dim does not divide the axis (matching how the init
    specs shard conditionally).
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.commruntime import AllGather, CommSpec
    from repro.parallel.sharding import shard_map

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fsize = sizes.get(fsdp_axis, 1)
    if fsize <= 1 or x.shape[fsdp_dim] % fsize != 0:
        return x
    in_axes: list = [None] * x.ndim
    in_axes[fsdp_dim] = fsdp_axis
    if (
        model_axis is not None
        and model_dim is not None
        and sizes.get(model_axis, 1) > 1
        and x.shape[model_dim] % sizes[model_axis] == 0
    ):
        in_axes[model_dim] = model_axis
    out_axes = list(in_axes)
    out_axes[fsdp_dim] = None
    ag = AllGather(CommSpec(axis=fsdp_axis, axis_size=fsize), impl="ring")
    fn = shard_map(
        lambda v: ag(v, axis=fsdp_dim),
        mesh=mesh,
        in_specs=P(*in_axes),
        out_specs=P(*out_axes),
        check_vma=False,
    )
    return fn(x)
