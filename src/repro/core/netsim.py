"""Flow-level training-iteration simulator (paper §7: large-scale simulations).

The paper drives htsim (packet level) with a FlexFlow task DAG.  On a CPU-only
container we replace packet fidelity with a flow-level completion-time model
(see DESIGN.md §2) but keep the *same experiment structure*:

  model + parallelization --> per-layer timeline of compute phases and
  all-to-all/all-reduce/p2p communication phases --> composed through the
  1F1B pipeline schedule --> one iteration time, per fabric.

The gate-trace generator reproduces the §3 measurement characteristics:
temporally varying, spatially sparse expert loads with cross-layer
conditional structure (which is what MIXNET-COPILOT exploits) and a
load-balancing-loss-driven slow convergence toward uniformity.

Reconfiguration is driven exclusively through the shared
:class:`repro.core.controlplane.ControlPlane` engine (the same engine the
trainer uses): the simulator observes loads into its monitor, asks it for
per-layer plans (COPILOT-predicted for the FP's first all-to-all), and
applies them against the fabric with hide-or-block accounting.

Communication phases are priced through the SAME CommRuntime ops the trainer
executes (:mod:`repro.core.commruntime`, DESIGN.md §7): an ``AllToAll`` /
``AllReduce`` built from a fabric-derived :class:`CommSpec` owns both the
byte accounting (``ep_alltoall_bytes``, ``dp_gradient_bytes``) and the
phase-latency costing — this module keeps no private collective formulas.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import commruntime as comm
from repro.core import overlap
from repro.core.controlplane import ControlPlane
from repro.core.fabric import Fabric
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

__all__ = [
    "SimModel",
    "GateTraceGenerator",
    "IterationResult",
    "simulate_iteration",
    "simulate_training",
    "ServingResult",
    "simulate_serving",
    "ReconfigAmortizer",
    "FleetServingResult",
    "simulate_fleet",
]


@dataclasses.dataclass
class SimModel:
    """Just enough of an MoE model + parallelization to cost one iteration.

    Mirrors Table 1 / §D.1 configurations.
    """

    name: str
    num_blocks: int
    d_model: int
    d_ff: int
    num_experts: int
    top_k: int
    num_heads: int
    seq_len: int = 4096
    micro_batch: int = 8
    num_microbatches: int = 8
    ep_degree: int = 8
    tp_degree: int = 4
    pp_degree: int = 4
    dtype_bytes: int = 2
    vocab: int = 32000
    # Effective per-GPU compute throughput (flop/s) — A100 bf16 peak x MFU.
    flops_per_gpu: float = 312e12 * 0.4
    # Per-GPU HBM bandwidth (bytes/s) — the serving scenario's decode ticks
    # are memory-bound (every live token streams the expert weights), so
    # their compute floor is weights-read time, not flops (DESIGN.md §9).
    hbm_bytes_per_s: float = 1.6e12
    # Chunked comm/compute overlap (repro.core.overlap, DESIGN.md §8): the
    # per-layer dispatch->expert->combine phases run as a C-chunk software
    # pipeline on the event timeline.  1 = the serial (additive) schedule,
    # reproduced exactly.
    overlap_chunks: int = 1
    # Price the DP gradient reduction as int8-compressed (the trainer's
    # dp_compress path): wire bytes scale by 1/dtype_bytes through the SAME
    # AllReduce byte accounting.
    dp_compress: bool = False
    # A2A pricing lowering (commruntime.AllToAll.lowering, DESIGN.md §13):
    # "hier" (delegation, default), "flat" (per-GPU messages, pays the
    # per-message latency delegation amortizes), or "ring" (store-and-
    # forward; latency-optimal only for tiny payloads).
    a2a_lowering: str = "hier"
    # MoE dispatch mode: "dropless" routes every token (full a2a payload);
    # "capacity" caps each expert at capacity_factor * fair share — dropped
    # tokens skip the wire AND the expert FFN, but goodput only counts kept
    # tokens (IterationResult.kept_fraction), so the autotuner sees a real
    # throughput-vs-quality tradeoff, not a free discount.
    moe_dispatch: str = "dropless"
    capacity_factor: float = 1.25
    # Pipeline-tier overlap (DESIGN.md §13): treat the GPipe warmup/drain
    # bubble as a hideable window for comm the chunk tier left exposed —
    # first the residual EP a2a, then the DP gradient reduce-scatter.  Off
    # by default (the historical additive accounting).
    pp_overlap: bool = False

    # ---- derived sizes -----------------------------------------------------
    @property
    def tokens_per_microbatch(self) -> int:
        return self.micro_batch * self.seq_len

    @property
    def layers_per_stage(self) -> int:
        return max(self.num_blocks // self.pp_degree, 1)

    @property
    def gpus_per_stage(self) -> int:
        return self.ep_degree * self.tp_degree

    def param_count(self) -> float:
        attn = 4 * self.d_model * self.d_model
        expert = 3 * self.d_model * self.d_ff
        return self.num_blocks * (attn + self.num_experts * expert) + 2 * self.vocab * self.d_model

    # ---- per-microbatch per-stage compute times -----------------------------
    def attention_flops(self) -> float:
        t = self.tokens_per_microbatch
        proj = 2 * t * 4 * self.d_model * self.d_model
        attn = 2 * 2 * self.micro_batch * self.seq_len**2 * self.d_model
        return (proj + attn) * self.layers_per_stage

    def expert_flops(self) -> float:
        t = self.tokens_per_microbatch
        return 2 * t * self.top_k * 3 * self.d_model * self.d_ff * self.layers_per_stage

    def attention_time(self) -> float:
        return self.attention_flops() / (self.flops_per_gpu * self.gpus_per_stage)

    def expert_time(self) -> float:
        return self.expert_flops() / (self.flops_per_gpu * self.gpus_per_stage)

    def expert_time_per_layer(self) -> float:
        return self.expert_time() / self.layers_per_stage

    def attention_time_per_layer(self) -> float:
        return self.attention_time() / self.layers_per_stage

    # ---- communication sizes -------------------------------------------------
    # Byte formulas live in the CommRuntime (the same accounting the trainer's
    # ops carry); these wrappers only feed it this model's shapes.
    def a2a_bytes_total(self) -> float:
        """Bytes moved by ONE all-to-all phase of one layer (whole EP group)."""
        return comm.ep_alltoall_bytes(
            self.tokens_per_microbatch, self.top_k, self.d_model, self.dtype_bytes
        )

    def dp_gradient_bytes_per_server(self, gpus_per_server: int = 8) -> float:
        """Gradient bytes a server contributes to the DP ring (hierarchical
        all-reduce §5.3 — the server gateway aggregates its GPUs' shards)."""
        return comm.dp_gradient_bytes(
            self.param_count(),
            max(self.gpus_per_stage * self.pp_degree, 1),
            gpus_per_server,
            self.dtype_bytes,
        )


class GateTraceGenerator:
    """Synthetic per-layer expert-load traces with §3's statistics.

    Layer l+1's load is a noisy linear image of layer l's load through a
    slowly drifting column-stochastic matrix; all loads relax toward uniform
    over iterations (load-balancing loss) while staying sparse per iteration.
    """

    def __init__(
        self,
        num_layers: int,
        num_experts: int,
        *,
        seed: int = 0,
        sparsity: float = 3.0,
        drift: float = 0.02,
        balance_rate: float = 2e-3,
    ):
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.rng = np.random.default_rng(seed)
        self.sparsity = sparsity
        self.drift = drift
        self.balance_rate = balance_rate
        self._transition = np.stack(
            [self._random_stochastic() for _ in range(max(num_layers - 1, 1))]
        )
        self._x0 = self.rng.dirichlet(np.full(num_experts, 1.0 / sparsity))
        self.iteration = 0

    def _random_stochastic(self) -> np.ndarray:
        e = self.num_experts
        m = self.rng.dirichlet(np.full(e, 1.0 / self.sparsity), size=e).T  # cols sum 1
        return m

    def step(self) -> np.ndarray:
        """Advance one iteration; return ``[L, E]`` per-layer load fractions."""
        e = self.num_experts
        uniform = np.full(e, 1.0 / e)
        # Drift the transitions and the entry distribution.
        blend = min(self.balance_rate * self.iteration, 0.9)
        for i in range(self._transition.shape[0]):
            if self.rng.random() < self.drift * 10:
                noise = self._random_stochastic()
                self._transition[i] = 0.95 * self._transition[i] + 0.05 * noise
        # Per-iteration spikiness (Fig 4a): the entry distribution jumps
        # substantially between iterations even late in training.
        x = (1 - blend) * self._x0 + blend * uniform
        x = 0.65 * x + 0.35 * self.rng.dirichlet(np.full(e, 1.0 / self.sparsity))
        x = x / x.sum()
        loads = [x]
        for l in range(self.num_layers - 1):
            x = self._transition[l] @ x
            x = 0.9 * x + 0.1 * self.rng.dirichlet(np.full(e, 1.0 / self.sparsity))
            x = (1 - blend) * x + blend * uniform
            x = x / x.sum()
            loads.append(x)
        self.iteration += 1
        return np.stack(loads)

    def device_demand(
        self,
        load: np.ndarray,
        model: SimModel,
        num_servers: int,
        *,
        node_limit: int = 4,
        total_bytes: float | None = None,
    ) -> np.ndarray:
        """Expert load fraction -> inter-server byte demand for one a2a.

        Two production effects shape the matrix (Fig 4b / Fig 5):
          * tokens within one batch shard are semantically correlated and
            concentrate on few experts (low-concentration Dirichlet rows);
          * group-limited gating (DeepSeek-V2/V3, cited by the paper) caps
            the number of *nodes* a token may route to, keeping the matrix
            sparse at server granularity even with hundreds of experts.

        ``total_bytes`` overrides the phase volume (default: one training
        microbatch's a2a) — the serving scenario passes the tick's live
        decode + prefill-chunk payload instead (DESIGN.md §9).
        """
        e = self.num_experts
        total = (
            SimModel.a2a_bytes_total(model) if total_bytes is None else total_bytes
        )
        per_src = total / max(num_servers, 1)
        per_server = max(e // max(num_servers, 1), 1)
        # Server-level attractiveness = summed load of its experts.
        srv_load = np.add.reduceat(
            np.resize(load, per_server * num_servers), np.arange(num_servers) * per_server
        )
        srv_load = srv_load / srv_load.sum()
        dem = np.zeros((num_servers, num_servers))
        limit = min(max(node_limit, 1), num_servers)
        for src in range(num_servers):
            # Group-limited gating: this shard's tokens reach <= limit servers.
            p = srv_load + 1e-9
            p = p / p.sum()
            dests = self.rng.choice(num_servers, size=limit, replace=False, p=p)
            weights = self.rng.dirichlet(srv_load[dests] * 8.0 + 0.1)
            dem[src, dests] += per_src * weights
        np.fill_diagonal(dem, 0.0)
        return dem


@dataclasses.dataclass
class IterationResult:
    total: float
    attn_compute: float
    expert_compute: float
    a2a: float
    reconfig_blocked: float
    dp_allreduce: float
    pp_bubble: float
    # Overlap accounting (DESIGN.md §8): the additive a2a total splits into
    # the part hidden under the compute window by the chunked pipeline and
    # the part that stays on the critical path.  hidden + exposed == a2a.
    # These two are the CHUNK tier; with ``pp_overlap`` the PIPELINE tier
    # then absorbs up to ``pp_bubble`` seconds of the still-exposed comm
    # into the warmup/drain idle slots (``pp_hidden_comm``) and the DP
    # reduction after it (``dp_hidden``).  Final critical-path comm =
    # ``exposed_comm - pp_hidden_comm``.
    hidden_comm: float = 0.0
    exposed_comm: float = 0.0
    pp_hidden_comm: float = 0.0
    dp_hidden: float = 0.0
    # Fraction of routed tokens actually delivered (== 1.0 for dropless;
    # < 1 when capacity dispatch drops overflow).  Goodput accounting
    # multiplies token throughput by this.
    kept_fraction: float = 1.0
    # Per-link-class bytes of ONE EP a2a phase, from the op's staged
    # accounting (AllToAllStage.bytes_on_link — the same numbers the
    # trainer's overlap scheduler consumes).
    a2a_link_bytes: dict = dataclasses.field(default_factory=dict)

    def breakdown(self) -> dict:
        return dataclasses.asdict(self)


def _stage_times(
    model: SimModel,
    fabric: Fabric,
    loads: np.ndarray,
    trace: GateTraceGenerator,
    num_servers_region: int,
    cp: ControlPlane,
    a2a_op: comm.AllToAll,
) -> tuple[float, float, float, float]:
    """One PP stage's event timeline over a FULL iteration (all microbatches).

    Reconfiguration semantics follow Fig 20, driven entirely through the
    shared control-plane engine (DESIGN.md §3): the topology is reconfigured
    *twice per MoE layer per iteration* (once covering the FP pair of
    all-to-alls, once the BP pair), amortized across microbatches — each
    layer gets its own OCS cross-map via ``cp.plan``/``cp.apply`` ->
    ``fabric.prepare``.  A reconfiguration blocks only if its delay exceeds
    the pipelined compute window between consecutive all-to-alls of that
    layer — with 25 ms OCS and production-size compute this is fully hidden
    (Fig 28's flat region), and degradation appears once the delay
    approaches the per-layer compute budget, reproducing Fig 28's cliff.

    Each layer's dispatch->expert->combine phases run through the chunked
    event timeline (:func:`repro.core.overlap.pipelined_phase`) with
    ``model.overlap_chunks`` chunks; with 1 chunk the timeline IS the
    pre-overlap additive sum.  Returns ``(timeline_seconds,
    additive_a2a_seconds, blocked_seconds, exposed_comm_seconds,
    kept_fraction)`` — the last is the routed-token fraction actually
    delivered (capacity dispatch drops overflow tokens from both the wire
    and the expert FFN; dropless keeps it at 1.0).
    """
    attn_f = model.attention_time_per_layer()
    exp_f = model.expert_time_per_layer()
    m = model.num_microbatches
    chunks = max(model.overlap_chunks, 1)
    cap = (
        model.capacity_factor / model.num_experts
        if model.moe_dispatch == "capacity"
        else None
    )
    # Compute window available to hide one reconfiguration: the layer's
    # compute across the iteration's microbatches (fwd + bwd ~ 3x fwd).
    hide_window = m * (attn_f + exp_f)
    a2a_total = 0.0
    blocked = 0.0
    timeline = 0.0
    exposed = 0.0
    kept_sum = 0.0
    for li in range(model.layers_per_stage):
        load = loads[li % loads.shape[0]]
        kept = float(np.minimum(load, cap).sum()) if cap is not None else 1.0
        kept_sum += kept
        exp_l = exp_f * kept
        demand = trace.device_demand(load, model, num_servers_region) * kept
        # --- FP reconfig. For the layer's FIRST a2a the true matrix is not
        # yet known (§5.1): COPILOT predicts it (accurate prediction ->
        # near-matching circuits); without COPILOT the fabric keeps the
        # previous layer's topology (never blocks, but circuits mismatch).
        if fabric.cfg.reconfig_delay_s <= 1e-3:
            # Microsecond-scale OCS: exact reconfig fits before a2a#1 (Fig 28).
            blocked += cp.apply(cp.plan(li, demand))
        else:
            pred = cp.predict_load(li)
            if pred is not None:
                pred_demand = trace.device_demand(pred, model, num_servers_region)
                blocked += cp.apply(cp.plan(li, pred_demand, predicted=True))
            # else: reuse previous topology — no plan at all.
        t_disp = a2a_op.cost(fabric, demand)
        # --- FP a2a #2 (combine, transposed matrix): reconfig hidden when the
        # compute window allows; otherwise the overflow blocks the pipe.
        blocked += cp.apply(cp.plan(li, demand.T), hide_window=hide_window)
        t_comb = a2a_op.cost(fabric, demand.T)
        # --- BP reconfig + a2a pair (same matrices, §5.1; window = bwd
        # compute) — priced AFTER the BP prepare, whose circuits come from
        # the observed matrix (the FP pair may have run on predicted ones).
        blocked += cp.apply(cp.plan(li, demand), hide_window=2.0 * hide_window)
        t_disp_bp = a2a_op.cost(fabric, demand)
        t_comb_bp = a2a_op.cost(fabric, demand.T)
        a2a_total += m * (t_disp + t_comb + t_disp_bp + t_comb_bp)
        # Event timeline: attention is un-overlappable prefix compute; the
        # chunked dispatch/FFN/combine pipeline hides comm under the expert
        # window (bwd compute ~ 2x fwd, same a2a matrices).
        fp_t, fp_x = overlap.pipelined_phase(
            t_disp, exp_l, t_comb, chunks, serial_prefix=attn_f
        )
        bp_t, bp_x = overlap.pipelined_phase(
            t_disp_bp, 2.0 * exp_l, t_comb_bp, chunks, serial_prefix=2.0 * attn_f
        )
        timeline += m * (fp_t + bp_t)
        exposed += m * (fp_x + bp_x)
        cp.observe(li, load * model.tokens_per_microbatch * model.top_k)
    kept_mean = kept_sum / max(model.layers_per_stage, 1)
    return timeline, a2a_total, blocked, exposed, kept_mean


def _flush_ledger(scenario: str, **seconds_or_bytes) -> None:
    """Fold a scenario's comm ledger into the process metrics registry as
    ``netsim.<field>{scenario=...}`` counters (DESIGN.md §14)."""
    reg = obs_metrics.default()
    for field, v in seconds_or_bytes.items():
        if v:
            reg.counter(f"netsim.{field}", scenario=scenario).inc(float(v))


def _traced_scenario(fn):
    """Wrap a simulate_* entry point in a tracer span on the shared
    ``netsim`` track (no-op when tracing is disabled)."""
    import functools

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        tr = obs_trace.default()
        if not tr.enabled:
            return fn(*args, **kwargs)
        with tr.span(f"netsim.{fn.__name__}", cat="netsim",
                     tid=tr.track("netsim")):
            return fn(*args, **kwargs)

    return wrapper


def simulate_iteration(
    model: SimModel,
    fabric: Fabric,
    trace: GateTraceGenerator,
    *,
    num_servers_region: int | None = None,
    controlplane: ControlPlane | None = None,
    gpus_per_server: int = 8,
) -> IterationResult:
    """Cost one training iteration of ``model`` on ``fabric``.

    ``controlplane`` is the engine driving reconfiguration for this region;
    a fresh one (no COPILOT history) is built when not supplied.
    """
    if num_servers_region is None:
        num_servers_region = max(model.gpus_per_stage // gpus_per_server, 2)
    if controlplane is None:
        controlplane = ControlPlane.for_simulation(
            model, fabric, num_servers_region=num_servers_region, use_copilot=False
        )
    loads = trace.step()

    # The comm phases are priced through the SAME CollectiveOp API the
    # trainer executes; the spec's region/group factorization comes from the
    # fabric topology (servers x intra-server scale-up domain).
    a2a_op = comm.AllToAll(
        comm.CommSpec.from_fabric(fabric, num_servers_region),
        lowering=model.a2a_lowering,
    )
    dp_op = comm.AllReduce(comm.CommSpec(
        axis=None,
        axis_size=max(gpus_per_server, 1),
        group_size=max(gpus_per_server, 1),
        outer_size=max(fabric.cfg.num_servers, 1),
    ))
    timeline, a2a, blocked, exposed, kept = _stage_times(
        model, fabric, loads, trace, num_servers_region, controlplane, a2a_op
    )
    # 1F1B: the critical path stretches the per-stage work by (M+P-1)/M.
    # ``timeline`` is the event-timeline per-stage time (== compute + a2a
    # when overlap_chunks=1, smaller when the chunked pipeline hides comm).
    m, p = model.num_microbatches, model.pp_degree
    stretch = (m + p - 1) / m
    pipeline = stretch * timeline
    bubble = (stretch - 1.0) * timeline
    # DP gradient all-reduce (hierarchical on MixNet), half overlapped with
    # bwd; dp_compress prices the int8 wire through the same op accounting.
    dp_bytes = model.dp_gradient_bytes_per_server(gpus_per_server)
    dp_ratio = (1.0 / model.dtype_bytes) if model.dp_compress else 1.0
    dp = 0.5 * dp_op.cost(fabric, dp_bytes, compress_ratio=dp_ratio)
    # Pipeline-tier overlap (DESIGN.md §13): the warmup/drain bubble is
    # stage-idle time — the NICs are free, so comm the chunk tier left
    # exposed can be deferred into those slots instead of stretching the
    # critical path.  Exposed a2a fills the bubble first (it is produced
    # throughout the schedule), the DP reduce-scatter takes what remains
    # (gradients become final exactly as stages drain).  The floor is
    # exact: pipeline - pp_hidden >= timeline (pure compute+residual path).
    pp_hidden = 0.0
    dp_hidden = 0.0
    if model.pp_overlap:
        pp_hidden = min(bubble, stretch * exposed)
        dp_hidden = min(bubble - pp_hidden, dp)
    total = pipeline + blocked + dp - pp_hidden - dp_hidden
    # Per-link bytes of one EP a2a phase through the op's staged accounting
    # (the identical AllToAllStage.bytes_on_link the trainer's scheduler
    # consumes for its chunk schedule).
    phase_bytes = model.a2a_bytes_total() / max(num_servers_region, 1)
    link_bytes: dict = {}
    for stage in a2a_op.stages():
        lb = stage.bytes_on_link(phase_bytes)
        link_bytes[stage.link_class] = (
            link_bytes.get(stage.link_class, 0.0) + getattr(lb, stage.link_class)
        )
    _flush_ledger(
        "training",
        hidden_comm_s=stretch * (a2a - exposed),
        exposed_comm_s=stretch * exposed,
        pp_hidden_comm_s=pp_hidden,
        dp_hidden_s=dp_hidden,
        reconfig_blocked_s=blocked,
    )
    return IterationResult(
        total=total,
        attn_compute=m * model.attention_time() * 3.0,
        expert_compute=m * model.expert_time() * 3.0,
        a2a=stretch * a2a,
        reconfig_blocked=blocked,
        dp_allreduce=dp,
        pp_bubble=bubble,
        hidden_comm=stretch * (a2a - exposed),
        exposed_comm=stretch * exposed,
        pp_hidden_comm=pp_hidden,
        dp_hidden=dp_hidden,
        kept_fraction=kept,
        a2a_link_bytes=link_bytes,
    )


# ---------------------------------------------------------------------------
# Serving scenario (DESIGN.md §9) — the inference analogue of Fig 26-28
# ---------------------------------------------------------------------------


class ReconfigAmortizer:
    """Per-window accounting for serving-cadence reconfiguration hiding.

    §5.1's rule amortizes an OCS reconfiguration over the pipelined compute
    between reconfigurations.  The old inline accounting extrapolated the
    *current tick's* compute over one global window
    (``every_ticks * tick_compute``) — wrong whenever ticks are
    heterogeneous (bursty prefill, draining slots, spec rounds), and
    unusable for a fleet where each replica has its own cadence and its own
    realized window.  This helper accumulates the compute that actually ran
    since the previous reconfiguration and hands exactly that budget to
    ``hide_window`` when the cadence fires.

    The FIRST firing gets an infinite window: it is the cold-start topology
    setup before any traffic was served, not a runtime reconfiguration —
    there is no elapsed window to amortize against and nothing in flight to
    stall.  Both :func:`simulate_serving` and :func:`simulate_fleet` (one
    instance per replica) share this accounting.
    """

    def __init__(self, every_ticks: int):
        self.every = int(every_ticks)
        self._budget = 0.0
        self._fired = False

    def due(self, tick: int) -> bool:
        return self.every > 0 and tick % self.every == 0

    def window(self) -> float:
        """Hide budget for a reconfiguration firing NOW; resets the
        accumulator so the next window starts empty."""
        if not self._fired:
            self._fired = True
            self._budget = 0.0
            return math.inf
        w = self._budget
        self._budget = 0.0
        return w

    def accumulate(self, hideable_s: float) -> None:
        """Record one tick's realized hideable compute (all phases that run
        while an OCS slice could be idling)."""
        self._budget += hideable_s


@dataclasses.dataclass
class ServingResult:
    """Priced serving run on one fabric: latency percentiles, goodput, and
    the Fig-13-style goodput-per-dollar the acceptance gate compares."""

    fabric: str
    ticks: int
    sim_seconds: float
    requests: int
    completed: int
    tokens_out: int
    ttft_p50_s: float
    ttft_p99_s: float
    tpot_p50_s: float
    tpot_p99_s: float
    goodput_tok_s: float
    cost_usd: float
    goodput_per_mdollar: float  # decode tokens/s per M$ of interconnect
    exposed_comm_fraction: float  # mean exposed/total a2a per tick
    reconfig_count: int
    reconfig_blocked_s: float
    # Total EP a2a payload bytes across the run, accounted through the SAME
    # CommRuntime formula (ep_alltoall_bytes) the real engine reports — the
    # serving cross-check in tests/test_serve.py.
    a2a_bytes_total: float
    # Paged-KV accounting (DESIGN.md §10): decode HBM reads priced from the
    # resident pages actually touched per tick, and admission gated by the
    # KV token budget instead of a fixed slot preallocation.
    kv_paged: bool = False
    kv_resident_tokens_peak: int = 0
    kv_budget_tokens: int = 0
    # Speculative decoding (DESIGN.md §11): draft span, modeled per-token
    # acceptance, and the resulting expected emitted tokens per verify round
    # (1.0 = non-speculative).
    spec_k: int = 0
    spec_acceptance: float = 0.0
    spec_tokens_per_round: float = 1.0

    def breakdown(self) -> dict:
        return dataclasses.asdict(self)


@_traced_scenario
def simulate_serving(
    model: SimModel,
    fabric: Fabric,
    *,
    mix="chat",
    num_requests: int = 64,
    slots: int = 16,
    seed: int = 0,
    use_reconfig: bool = True,
    reconfig_every_ticks: int = 256,
    prefill_chunk_tokens: int = 256,
    num_servers_region: int | None = None,
    gpus_per_server: int = 8,
    max_ticks: int = 200_000,
    paged_kv: bool = False,
    kv_page_tokens: int = 16,
    kv_budget_tokens: int = 0,
    spec_decode: tuple | None = None,
) -> ServingResult:
    """Price a continuous-batching serving run of ``model`` on ``fabric``.

    One tick = one engine decode step (:mod:`repro.serve.engine` at flow
    level): every live slot decodes one token, up to ``prefill_chunk_tokens``
    of pending prompt stream through the same tick (chunked prefill), and
    each MoE layer pays a dispatch/combine a2a pair priced through the
    CommRuntime op on the fabric — hidden under the decode + interleaved
    prefill compute window by the chunked event timeline
    (:func:`repro.core.overlap.decode_tick_phase`).

    With ``use_reconfig`` the shared ControlPlane re-solves the regional OCS
    cross-maps every ``reconfig_every_ticks`` from the drifting decode
    demand (the request mix moves, §3's locality), amortizing the
    reconfiguration delay over the window's compute; a static EPS fabric
    (e.g. fat-tree) with ``use_reconfig=False`` is the baseline the
    goodput-per-dollar gate compares against.

    **Paged KV** (``paged_kv=True``, DESIGN.md §10): each request's KV
    footprint is its *page-rounded live context* instead of a full-length
    slot preallocation, a region's shared prompt prefix is resident ONCE
    (copy-on-write pages, mirroring the engine's prefix registry), and the
    per-tick decode HBM-read term charges only the resident pages touched.
    ``kv_budget_tokens`` caps resident KV tokens: admission stalls at the
    head of the prefill queue until retiring requests release pages —
    exactly how :class:`repro.serve.paged.PageAllocator` gates the engine —
    so at equal HBM budget the paged run sustains more concurrent decodes.

    **Speculative decoding** (``spec_decode=(K, acceptance_model)``,
    DESIGN.md §11): each live slot verifies a K-token draft span per tick,
    so one a2a launch (and one KV-cache streaming pass) amortizes over the
    expected ``1 + sum(p^i)`` emitted tokens — while the verify a2a payload
    and FLOPs scale with all K+1 positions and the draft pass adds K cheap
    (attention + one expert-equivalent) steps that re-stream KV each step.
    ``acceptance_model`` is the per-token draft acceptance probability
    (i.i.d. model), or a callable ``f(K) -> expected accepted tokens``.
    At low acceptance the extra positions/draft FLOPs are pure waste — the
    goodput-per-dollar crossover against ``spec_decode=None`` is exactly
    what ``benchmarks/run.py::spec_decode`` sweeps.
    """
    from repro.core import cost as costm
    from repro.serve.workload import WorkloadGenerator

    requests = WorkloadGenerator(mix, seed=seed).generate(num_requests)
    spec_k, spec_acc, spec_emit = 0, 0.0, 1.0
    if spec_decode is not None:
        spec_k, acc_model = int(spec_decode[0]), spec_decode[1]
        if spec_k > 0:
            if callable(acc_model):
                exp_acc = float(acc_model(spec_k))
            else:
                exp_acc = sum(float(acc_model) ** i for i in range(1, spec_k + 1))
            spec_acc = exp_acc / spec_k
            spec_emit = 1.0 + exp_acc  # + verify's correction/bonus token
        else:
            spec_k = 0
    region = num_servers_region or max(model.gpus_per_stage // gpus_per_server, 2)
    trace = GateTraceGenerator(model.layers_per_stage, model.num_experts, seed=seed)
    cp = (
        ControlPlane.for_simulation(
            model, fabric, num_servers_region=region, use_copilot=False
        )
        if use_reconfig
        else None
    )
    a2a_op = comm.AllToAll(comm.CommSpec.from_fabric(fabric, region))
    rate = model.flops_per_gpu * model.gpus_per_stage
    layers = model.layers_per_stage
    d, dff, k, dt = model.d_model, model.d_ff, model.top_k, model.dtype_bytes

    pending = sorted(requests, key=lambda r: r.arrival_s)
    cursor = 0
    amort = ReconfigAmortizer(reconfig_every_ticks if cp is not None else 0)

    # -- KV residency bookkeeping (tokens) --------------------------------
    # Dense: an admitted request pins its full prompt+output length for its
    # whole lifetime (slot preallocation).  Paged: it pins page-rounded live
    # context, and a region's shared prompt prefix is resident once across
    # all carriers (the engine's refcounted prefix pages).
    page = max(int(kv_page_tokens), 1)
    region_refs: dict[int, int] = {}  # carriers per region's shared prefix
    resident_tokens = 0
    resident_peak = 0

    def _kv_parts(req):
        total = req.prompt_len + req.max_new_tokens
        if not paged_kv:
            return 0, total
        pfx = min(getattr(req, "prefix_len", 0), req.prompt_len)
        return -(-pfx // page) * page, -(-(total - pfx) // page) * page

    def _kv_acquire(req):
        nonlocal resident_tokens
        shared, private = _kv_parts(req)
        resident_tokens += private
        if shared:
            n = region_refs.get(req.region, 0)
            region_refs[req.region] = n + 1
            if n == 0:
                resident_tokens += shared

    def _kv_release(req):
        nonlocal resident_tokens
        shared, private = _kv_parts(req)
        resident_tokens -= private
        if shared:
            n = region_refs[req.region] - 1
            region_refs[req.region] = n
            if n == 0:
                resident_tokens -= shared

    def _kv_fresh_cost(req):
        shared, private = _kv_parts(req)
        if shared and region_refs.get(req.region, 0) > 0:
            shared = 0  # prefix already resident: pages map for free
        return private + shared

    prefill_q: list = []  # [req, tokens_left]
    live: list = []  # [req, tokens_left, context_len]
    ttft: list[float] = []
    tpot: list[float] = []
    clock = 0.0
    ticks = 0
    tokens_out = 0
    completed = 0
    blocked_total = 0.0
    a2a_total_s = 0.0
    exposed_total_s = 0.0
    a2a_bytes_total = 0.0
    loads = trace.step()

    while ticks < max_ticks:
        # -- admission --------------------------------------------------------
        while cursor < len(pending) and pending[cursor].arrival_s <= clock:
            prefill_q.append([pending[cursor], pending[cursor].prompt_len])
            cursor += 1
        if not prefill_q and not live:
            if cursor >= len(pending):
                break
            clock = pending[cursor].arrival_s  # idle: jump to next arrival
            continue

        # -- this tick's work -------------------------------------------------
        n_live = len(live)
        pf_tokens = 0
        budget = prefill_chunk_tokens
        finished_prefills = []
        for item in prefill_q:
            if budget <= 0 or len(live) + len(finished_prefills) >= slots:
                break
            if item[1] == item[0].prompt_len:  # starting this request now
                need = _kv_fresh_cost(item[0])
                if (
                    kv_budget_tokens
                    and resident_tokens + need > kv_budget_tokens
                    and resident_tokens > 0  # an empty pool always admits one
                ):
                    break  # head-of-line waits for retiring requests' pages
                _kv_acquire(item[0])
            take = min(budget, item[1])
            item[1] -= take
            budget -= take
            pf_tokens += take
            if item[1] == 0:
                finished_prefills.append(item[0])
        resident_peak = max(resident_peak, resident_tokens)

        # Per-layer phase pricing: the a2a moves every routed token copy of
        # the tick (live decode + prefill chunk) — the same byte formula the
        # engine accounts (comm.ep_alltoall_bytes).  Speculative ticks route
        # the whole verify span (K+1 positions per live slot) through ONE
        # launch per layer: payload scales with positions, launches don't.
        vpos = n_live * (spec_k + 1) if spec_k else n_live
        routed = vpos + pf_tokens
        tick_s = 0.0
        blocked_tick = 0.0
        if routed:
            tick_bytes = comm.ep_alltoall_bytes(routed, k, d, dt)
            a2a_bytes_total += layers * tick_bytes
            mean_ctx = (
                np.mean([it[2] for it in live]) if live else 64.0
            )
            # Per-layer compute terms (flow level): decode attention is the
            # un-overlappable prefix, expert FFN + the interleaved prefill
            # chunk form the hideable window.  Decode is memory-bound: the
            # floor is streaming the layer's expert weights (+ the KV cache)
            # from HBM, which is what puts real decode ticks at ms scale and
            # makes the 25 ms OCS hideable across a reconfiguration window.
            hbm = model.hbm_bytes_per_s * model.gpus_per_stage
            if paged_kv:
                # KV read = resident pages TOUCHED this tick: each slot
                # streams its own page-rounded context, but a shared prefix
                # page transits HBM once for all carriers reading it.
                shared_touch: dict[int, int] = {}
                private_pages = 0
                for it in live:
                    pfx = min(getattr(it[0], "prefix_len", 0), it[2])
                    shared_touch[it[0].region] = max(
                        shared_touch.get(it[0].region, 0), -(-pfx // page)
                    )
                    private_pages += -(-(it[2] - pfx) // page)
                kv_read_tokens = (
                    private_pages + sum(shared_touch.values())
                ) * page
            else:
                kv_read_tokens = n_live * mean_ctx
            attn_t = max(
                # Matmul/score FLOPs scale with every verified position; the
                # KV HBM read does NOT — the whole span streams the cache
                # once per round (the speculative amortization).
                (2 * vpos * 4 * d * d + 2 * 2 * vpos * mean_ctx * d) / rate,
                (kv_read_tokens * 2 * d * dt) / hbm,  # KV read
            )
            exp_t = max(
                2 * vpos * k * 3 * d * dff / rate,
                # dense-decode weight streaming: every expert's FFN weights
                # transit HBM once per tick when any token is live.
                (model.num_experts * 3 * d * dff * dt) / hbm,
            )
            pf_t = pf_tokens * (2 * 4 * d * d + 2 * k * 3 * d * dff) / rate
            draft_t = 0.0
            if spec_k and n_live:
                # K draft steps: full attention + ONE expert-equivalent FFN
                # per token (shared_only / topk1 drafts), each step
                # re-streaming the live KV (serial steps can't amortize it)
                # plus one expert's weights.  Rides the hideable window with
                # the prefill chunk — wasted entirely when acceptance is low.
                draft_t = spec_k * max(
                    (
                        2 * n_live * 4 * d * d
                        + 2 * 2 * n_live * mean_ctx * d
                        + 2 * n_live * 3 * d * dff
                    ) / rate,
                    (kv_read_tokens * 2 * d * dt + 3 * d * dff * dt) / hbm,
                )
            if ticks % 8 == 0:
                loads = trace.step()
            # Amortized over the REALIZED window: one layer's OCS slice is
            # idle while every other phase of the inter-reconfiguration
            # stretch runs, so the hide budget is the compute that actually
            # accumulated since the previous reconfiguration (§5.1's rule at
            # serving cadence, per-window accounting via ReconfigAmortizer —
            # every layer's slice of one firing shares the window).
            window = amort.window() if cp is not None and amort.due(ticks) else None
            for li in range(layers):
                demand = trace.device_demand(
                    loads[li % loads.shape[0]], model, region,
                    total_bytes=tick_bytes,
                )
                if window is not None:
                    blocked_tick += cp.apply(
                        cp.plan(li, demand), hide_window=window
                    )
                t_disp = a2a_op.cost(fabric, demand)
                t_comb = a2a_op.cost(fabric, demand.T)
                total_t, exposed_t = overlap.decode_tick_phase(
                    t_disp, exp_t, t_comb, max(model.overlap_chunks, 1),
                    attn=attn_t, prefill_compute=pf_t + draft_t,
                )
                tick_s += total_t
                a2a_total_s += t_disp + t_comb
                exposed_total_s += exposed_t
            amort.accumulate(layers * (attn_t + exp_t + pf_t + draft_t))
            if cp is not None:
                for li in range(layers):
                    cp.observe(
                        li, loads[li % loads.shape[0]] * max(routed, 1) * k
                    )
                cp.end_step()
        blocked_total += blocked_tick
        clock += tick_s + blocked_tick  # un-hidden reconfig stalls the tick
        ticks += 1

        # -- bookkeeping: decode completions FIRST (only the slots that were
        # live — and therefore routed — this tick emit), then the tick's
        # finished prefills join the live set for the NEXT tick.
        still = []
        for it in live:
            # Speculative rounds emit the expected accepted prefix + the
            # verify correction/bonus token (flow level: the i.i.d.
            # acceptance expectation), clamped to what the request needs.
            emit = min(spec_emit, it[1]) if spec_k else 1
            it[1] -= emit
            it[2] += emit
            tokens_out += emit
            if it[1] <= 0:
                completed += 1
                _kv_release(it[0])
                span = max(clock - it[3], 0.0)
                tpot.append(span / max(it[0].max_new_tokens - 1, 1))
            else:
                still.append(it)
        live = still
        for req in finished_prefills:
            prefill_q = [it for it in prefill_q if it[0] is not req]
            ttft.append(clock - req.arrival_s)
            tokens_out += 1  # the prefill's next-token (first output)
            if req.max_new_tokens <= 1:
                completed += 1
                _kv_release(req)
            else:
                live.append([req, req.max_new_tokens - 1, req.prompt_len, clock])

    pct = lambda a, q: float(np.percentile(a, q)) if len(a) else 0.0
    cost_usd = costm.fabric_cost(
        fabric.name,
        fabric.cfg.num_servers,
        int(fabric.cfg.link_gbps),
        nics_per_server=fabric.cfg.nics_per_server,
        eps_nics=fabric.cfg.eps_nics,
        ocs_nics=fabric.cfg.ocs_nics,
        oversub_ratio=fabric.cfg.oversub_ratio,
    )
    sim_seconds = max(clock, 1e-12)
    goodput = tokens_out / sim_seconds
    _flush_ledger(
        "serving",
        a2a_s=a2a_total_s,
        exposed_comm_s=exposed_total_s,
        reconfig_blocked_s=blocked_total,
        a2a_bytes=a2a_bytes_total,
    )
    return ServingResult(
        fabric=fabric.name,
        ticks=ticks,
        sim_seconds=sim_seconds,
        requests=len(requests),
        completed=completed,
        tokens_out=int(round(tokens_out)),
        ttft_p50_s=pct(ttft, 50),
        ttft_p99_s=pct(ttft, 99),
        tpot_p50_s=pct(tpot, 50),
        tpot_p99_s=pct(tpot, 99),
        goodput_tok_s=goodput,
        cost_usd=cost_usd,
        goodput_per_mdollar=goodput / (cost_usd / 1e6),
        exposed_comm_fraction=exposed_total_s / max(a2a_total_s, 1e-12),
        reconfig_count=cp.reconfig_count if cp is not None else 0,
        reconfig_blocked_s=blocked_total,
        a2a_bytes_total=a2a_bytes_total,
        kv_paged=bool(paged_kv),
        kv_resident_tokens_peak=int(resident_peak),
        kv_budget_tokens=int(kv_budget_tokens),
        spec_k=spec_k,
        spec_acceptance=spec_acc,
        spec_tokens_per_round=spec_emit,
    )


@dataclasses.dataclass
class FleetServingResult:
    """Priced multi-replica serving run: fleet goodput-per-dollar with
    per-replica fabrics plus the cross-region electrical admission tier
    (the paper's regional-locality argument at fleet scale, DESIGN.md §12)."""

    policy: str
    fabric: str
    num_replicas: int
    ticks: int
    sim_seconds: float
    requests: int
    completed: int
    tokens_out: int
    ttft_p50_s: float
    ttft_p99_s: float
    goodput_tok_s: float
    fleet_cost_usd: float  # sum of per-replica fabric costs
    cross_tier_cost_usd: float  # electrical admission/steering tier
    goodput_per_mdollar: float
    slo_attainment: dict  # SLO class name -> fraction meeting TTFT target
    steer_counts: dict  # steering-reason -> requests
    reconfig_count: int
    reconfig_blocked_s: float
    # Per-replica EP a2a accounting: payload bytes and routed token copies,
    # tied by the SAME CommRuntime formula the engine reports —
    # a2a_bytes[j] == layers * ep_alltoall_bytes(routed_tokens[j], ...)
    # (cross-checked in tests/test_fleet.py like the single-engine tests).
    replica_a2a_bytes: list
    replica_routed_tokens: list
    replica_mean_active_experts: list  # mean per-tick effective experts
    cross_tier_bytes: float

    def breakdown(self) -> dict:
        return dataclasses.asdict(self)


def _region_expert_mixes(
    num_regions: int, num_experts: int, seed: int, concentration: float
) -> np.ndarray:
    """Per-region gate entry mixes ``[R, E]``: sparse Dirichlet draws, the
    §3 regional skew at fleet granularity.  Deterministic in ``seed``; low
    ``concentration`` = few hot experts per region (strong locality)."""
    rng = np.random.default_rng((seed << 8) ^ 0xF1EE7)
    mixes = rng.dirichlet(np.full(num_experts, concentration), size=num_regions)
    mixes = mixes + 1e-4  # keep every expert reachable
    return mixes / mixes.sum(axis=1, keepdims=True)


def _mix_demand(
    mix: np.ndarray, perm: np.ndarray, num_servers: int, epd: int,
    total_bytes: float,
) -> np.ndarray:
    """``[S, S]`` inter-server demand of serving ``mix`` under a placement:
    each source server holds an equal token share, sends each expert's slice
    to the server owning its slot; sender-local traffic never hits the wire."""
    share = np.zeros(num_servers)
    np.add.at(share, np.asarray(perm) // epd, mix)
    dem = np.tile((total_bytes / num_servers) * share[None, :], (num_servers, 1))
    np.fill_diagonal(dem, 0.0)
    return dem


@_traced_scenario
def simulate_fleet(
    model: SimModel,
    *,
    fabric_name: str = "mixnet",
    num_replicas: int = 4,
    link_gbps: float = 400.0,
    num_servers_replica: int | None = None,
    gpus_per_server: int = 8,
    mixes=("chat", "agentic", "batch_summarize"),
    num_requests: int = 96,
    seed: int = 0,
    policy: str = "locality",
    slots: int = 16,
    prefill_chunk_tokens: int = 256,
    use_reconfig: bool = True,
    reconfig_every_ticks: int = 64,
    reconfig_min_gain: float = 0.1,
    region_concentration: float = 0.15,
    arrival_scale: float = 1.0,
    cross_region_gbps: float = 400.0,
    locality_gamma: float = 0.5,
    steer_load_beta: float = 0.25,
    drain: tuple | None = None,  # (replica_idx, at_tick)
    fail: tuple | None = None,  # (replica_idx, at_tick)
    max_ticks: int = 200_000,
) -> FleetServingResult:
    """Price a multi-replica serving fleet with cross-replica steering.

    ``num_replicas`` replicas each own a ``num_servers_replica``-server
    fabric (priced individually) and a placement-mode ControlPlane; one
    global admission queue dispatches by SLO class priority
    (:data:`repro.serve.workload.SLO_CLASSES`) and steers by ``policy``:

    * ``locality`` — :func:`repro.serve.fleet.locality_score` against each
      replica's served-mix EWMA and placement fit (the engine-side score,
      reused verbatim at flow level);
    * ``least_loaded`` / ``round_robin`` — the baselines.

    The priced locality mechanism is expert-weight **residency**: a decode
    tick's HBM floor streams only the experts its served mix actually
    touches (effective experts ``1 / sum(mix^2)``, inverse Simpson), so a
    region-pure replica streams 2–3 hot experts where a blended one streams
    most of E — the §3 locality argument, cashed out as tokens/s.  Each
    replica's a2a is priced on its own fabric from the mix mapped through
    its placement; on the fleet cadence a replica whose *served* mix has
    drifted off its placement (its ControlPlane's min-gain hysteresis — the
    steer-vs-reconfigure rule) re-solves locally, paying the OCS delay
    against its :class:`ReconfigAmortizer` window.

    The **cross-region electrical tier** is the admission/steering fabric
    above the replicas: priced as a small packet-switched layer over
    ``num_replicas`` endpoints, and each steered request pays its prompt
    transfer across it before prefill starts (a TTFT adder).  Replicas tick
    synchronously off the admission clock (the slowest busy replica sets
    the tick — flow-level conservatism).

    ``drain=(j, t)`` / ``fail=(j, t)`` script degradation: a drained
    replica finishes in-flight work while its queued requests re-steer; a
    failed replica loses in-flight generation (those tokens are uncounted
    and the requests restart elsewhere).
    """
    from repro.core import cost as costm
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.placement import placement_cost
    from repro.serve.fleet import locality_score
    from repro.serve.workload import MIXES, WorkloadGenerator, slo_for

    if policy not in ("locality", "least_loaded", "round_robin"):
        raise ValueError(f"unknown steering policy {policy!r}")
    mixes = (mixes,) if isinstance(mixes, str) else tuple(mixes)
    num_regions = max(MIXES[m].num_regions for m in mixes)
    region_mix = _region_expert_mixes(
        num_regions, model.num_experts, seed, region_concentration
    )

    # -- the tagged request stream (one queue over all SLO classes) -------
    reqs = []
    for i, mname in enumerate(mixes):
        gen = WorkloadGenerator(mname, seed=seed + i)
        cls = slo_for(mname)
        share = num_requests // len(mixes) + (
            1 if i < num_requests % len(mixes) else 0
        )
        for sr in gen.generate(share):
            reqs.append({
                "rid": i * num_requests + sr.rid,
                "arrival_s": sr.arrival_s * arrival_scale,
                "prompt_len": sr.prompt_len,
                "max_new": sr.max_new_tokens,
                "region": sr.region % num_regions,
                "slo": cls,
            })
    pending = sorted(reqs, key=lambda r: (r["arrival_s"], r["rid"]))
    cursor = 0

    # -- per-replica state ------------------------------------------------
    S = num_servers_replica or max(model.gpus_per_stage // gpus_per_server, 2)
    layers = model.layers_per_stage
    d, dff, k, dt = model.d_model, model.d_ff, model.top_k, model.dtype_bytes
    E = model.num_experts
    rate = model.flops_per_gpu * S * gpus_per_server
    hbm = model.hbm_bytes_per_s * S * gpus_per_server
    R = num_replicas
    fabrics = [
        make_fabric(fabric_name, FabricConfig(
            num_servers=S, gpus_per_server=gpus_per_server,
            link_gbps=link_gbps,
        ))
        for _ in range(R)
    ]
    cps = [
        ControlPlane(
            num_layers=1, num_experts=E, num_devices=S,
            min_gain_fraction=reconfig_min_gain, use_copilot=False,
        )
        for _ in range(R)
    ]
    epd = cps[0].experts_per_device
    a2a_ops = [
        comm.AllToAll(comm.CommSpec.from_fabric(f, S)) for f in fabrics
    ]
    amorts = [ReconfigAmortizer(reconfig_every_ticks) for _ in range(R)]
    prefill_q = [[] for _ in range(R)]  # [req, tokens_left]
    live = [[] for _ in range(R)]  # [req, tokens_left, ctx, start_clock]
    mix_ewma = [np.full(E, 1.0 / E) for _ in range(R)]
    alive = [True] * R
    draining = [False] * R
    a2a_bytes = [0.0] * R
    routed_tokens = [0] * R
    neff_sum = [0.0] * R
    neff_ticks = [0] * R
    blocked_total = 0.0
    reconfig_count = 0
    steer_counts: dict[str, int] = {}
    cross_tier_bytes = 0.0
    xfer_s: dict[int, float] = {}  # rid -> cross-tier prompt-transfer delay
    queue: list = []  # (priority, arrival_s, seq, req)
    seq = 0
    hits_by_class: dict[str, list] = {}
    ttft_all: list[float] = []
    clock = 0.0
    busy_s = 0.0  # fleet service time (excludes idle arrival gaps)
    ticks = 0
    tokens_out = 0
    completed = 0
    drain_j, drain_t = drain if drain else (-1, -1)
    fail_j, fail_t = fail if fail else (-1, -1)

    def _backlog(j):
        return len(prefill_q[j]) + len(live[j])

    def _requeue(req):
        nonlocal seq
        import heapq

        heapq.heappush(queue, (req["slo"].priority, req["arrival_s"], seq, req))
        seq += 1

    def _replica_mix(j):
        """The mix replica j is serving right now (live + admitted)."""
        regs = [it[0]["region"] for it in live[j]]
        regs += [it[0]["region"] for it in prefill_q[j]]
        if not regs:
            return None
        return region_mix[regs].mean(axis=0)

    import heapq

    while ticks < max_ticks:
        while cursor < len(pending) and pending[cursor]["arrival_s"] <= clock:
            _requeue(pending[cursor])
            cursor += 1
        if drain_t == ticks and 0 <= drain_j < R:
            draining[drain_j] = True
            started = [it for it in prefill_q[drain_j]
                       if it[1] < it[0]["prompt_len"]]
            for it in prefill_q[drain_j]:
                if it[1] == it[0]["prompt_len"]:  # unstarted: re-steer
                    _requeue(it[0])
            prefill_q[drain_j] = started
        if fail_t == ticks and 0 <= fail_j < R and alive[fail_j]:
            alive[fail_j] = False
            for it in prefill_q[fail_j]:
                _requeue(it[0])
            for it in live[fail_j]:
                tokens_out -= it[0]["max_new"] - it[1]  # emitted-then-lost
                _requeue(it[0])
            prefill_q[fail_j] = []
            live[fail_j] = []

        busy = any(prefill_q[j] or live[j] for j in range(R))
        if not queue and not busy:
            if cursor >= len(pending):
                break
            clock = pending[cursor]["arrival_s"]
            # Keep scripted events tick-addressable across idle jumps.
            ticks += 1
            continue
        if queue and not busy and not any(
            alive[j] and not draining[j] for j in range(R)
        ):
            break  # whole fleet drained/failed: queued work is stranded

        # -- dispatch (strict SLO priority, steering policy) ---------------
        rr = ticks  # round-robin phase
        while queue:
            cands = [
                j for j in range(R)
                if alive[j] and not draining[j] and _backlog(j) < slots + 4
            ]
            if not cands:
                break
            _, _, _, req = heapq.heappop(queue)
            if policy == "round_robin":
                j = cands[rr % len(cands)]
                rr += 1
                reason = "round-robin"
            elif policy == "least_loaded":
                j = min(cands, key=lambda c: (_backlog(c), c))
                reason = "least-loaded"
            else:
                pm = region_mix[req["region"]]
                scored = sorted(
                    (
                        locality_score(
                            pm, mix_ewma[c],
                            placement_fit=placement_cost(
                                np.tile(pm[None, :], (S, 1)),
                                cps[c].layer_perms[0], epd,
                            ) / S,
                            backlog=_backlog(c), slots=slots,
                            gamma=locality_gamma, beta=steer_load_beta,
                        ),
                        c,
                    )
                    for c in cands
                )
                j = scored[0][1]
                reason = "locality"
            steer_counts[reason] = steer_counts.get(reason, 0) + 1
            if req["rid"] not in xfer_s:
                pbytes = req["prompt_len"] * dt * d  # activation-width proxy
                cross_tier_bytes += pbytes
                xfer_s[req["rid"]] = (
                    pbytes * 8 / max(cross_region_gbps * 1e9, 1e-9) + 1e-3
                    if R > 1 else 0.0
                )
            prefill_q[j].append([req, req["prompt_len"]])

        # -- one synchronized priced tick across replicas ------------------
        tick_dur = 0.0
        for j in range(R):
            if not alive[j] or not (prefill_q[j] or live[j]):
                continue
            n_live = len(live[j])
            pf_tokens = 0
            budget = prefill_chunk_tokens
            done_pf = []
            for item in prefill_q[j]:
                if budget <= 0 or n_live + len(done_pf) >= slots:
                    break
                take = min(budget, item[1])
                item[1] -= take
                budget -= take
                pf_tokens += take
                if item[1] == 0:
                    done_pf.append(item[0])
            routed = n_live + pf_tokens
            rep_t = 0.0
            blocked = 0.0
            if routed:
                mix = _replica_mix(j)
                mix_ewma[j] = 0.7 * mix_ewma[j] + 0.3 * mix
                served = mix_ewma[j]
                n_eff = 1.0 / float((served ** 2).sum())  # inverse Simpson
                neff_sum[j] += n_eff
                neff_ticks[j] += 1
                tick_bytes = comm.ep_alltoall_bytes(routed, k, d, dt)
                a2a_bytes[j] += layers * tick_bytes
                routed_tokens[j] += routed
                mean_ctx = (
                    float(np.mean([it[2] for it in live[j]])) if live[j] else 64.0
                )
                attn_t = max(
                    (2 * n_live * 4 * d * d + 2 * 2 * n_live * mean_ctx * d)
                    / rate,
                    (n_live * mean_ctx * 2 * d * dt) / hbm,
                )
                # The residency floor: only the experts the served mix
                # touches stream from HBM each tick (hot-expert caching) —
                # a region-pure replica's floor is its few hot experts.
                exp_t = max(
                    2 * routed * k * 3 * d * dff / rate,
                    (min(n_eff, E) * 3 * d * dff * dt) / hbm,
                )
                pf_t = pf_tokens * (2 * 4 * d * d + 2 * k * 3 * d * dff) / rate
                cps[j].observe(0, served * routed * k)
                cps[j].end_step()
                if use_reconfig and amorts[j].due(ticks):
                    window = amorts[j].window()
                    plan = cps[j].plan(0)
                    if plan.reconfigure:
                        # Steering stopped keeping this replica's mix
                        # resident: re-solve locally, pay the OCS delay
                        # against the realized window.
                        cps[j].apply(plan)
                        blocked = max(
                            0.0, fabrics[j].cfg.reconfig_delay_s - window
                        )
                        reconfig_count += 1
                demand = _mix_demand(
                    served, cps[j].layer_perms[0], S, epd, tick_bytes
                )
                if hasattr(fabrics[j], "prepare"):
                    fabrics[j].prepare(demand, can_hide=True)
                t_disp = a2a_ops[j].cost(fabrics[j], demand)
                t_comb = a2a_ops[j].cost(fabrics[j], demand.T)
                total_t, _ = overlap.decode_tick_phase(
                    t_disp, exp_t, t_comb, max(model.overlap_chunks, 1),
                    attn=attn_t, prefill_compute=pf_t,
                )
                rep_t = layers * total_t
                amorts[j].accumulate(layers * (attn_t + exp_t + pf_t))
            blocked_total += blocked
            tick_dur = max(tick_dur, rep_t + blocked)
            # completions (as simulate_serving: live decode emits first,
            # the tick's finished prefills join live for the NEXT tick)
            still = []
            for it in live[j]:
                it[1] -= 1
                it[2] += 1
                tokens_out += 1
                if it[1] <= 0:
                    completed += 1
                else:
                    still.append(it)
            live[j] = still
            for req in done_pf:
                prefill_q[j] = [it for it in prefill_q[j] if it[0] is not req]
                t1 = clock + rep_t + blocked - req["arrival_s"] + xfer_s.get(
                    req["rid"], 0.0
                )
                ttft_all.append(t1)
                name = req["slo"].name
                hits_by_class.setdefault(name, []).append(
                    int(t1 <= req["slo"].ttft_target_s)
                )
                tokens_out += 1  # the prefill's next-token
                if req["max_new"] <= 1:
                    completed += 1
                else:
                    live[j].append(
                        [req, req["max_new"] - 1, req["prompt_len"], clock]
                    )
        clock += tick_dur
        busy_s += tick_dur
        ticks += 1

    # -- pricing ----------------------------------------------------------
    fleet_cost = sum(
        costm.fabric_cost(
            f.name, f.cfg.num_servers, int(f.cfg.link_gbps),
            nics_per_server=f.cfg.nics_per_server, eps_nics=f.cfg.eps_nics,
            ocs_nics=f.cfg.ocs_nics, oversub_ratio=f.cfg.oversub_ratio,
        )
        for f in fabrics
    )
    cross_cost = (
        costm.fabric_cost(
            "fat-tree", max(R, 2), int(cross_region_gbps), nics_per_server=2
        )
        if R > 1
        else 0.0
    )
    sim_seconds = max(clock, 1e-12)
    # Goodput over fleet SERVICE time, not wall clock: an open-loop arrival
    # stream can leave the fleet idle between bursts, and that idle time is
    # a property of the workload, not of the steering policy under test.
    goodput = tokens_out / max(busy_s, 1e-12)
    pct = lambda a, q: float(np.percentile(a, q)) if len(a) else 0.0
    _flush_ledger(
        "fleet",
        a2a_bytes=float(sum(a2a_bytes)),
        cross_tier_bytes=cross_tier_bytes,
        reconfig_blocked_s=blocked_total,
    )
    return FleetServingResult(
        policy=policy,
        fabric=fabric_name,
        num_replicas=R,
        ticks=ticks,
        sim_seconds=sim_seconds,
        requests=len(pending),
        completed=completed,
        tokens_out=int(round(tokens_out)),
        ttft_p50_s=pct(ttft_all, 50),
        ttft_p99_s=pct(ttft_all, 99),
        goodput_tok_s=goodput,
        fleet_cost_usd=fleet_cost,
        cross_tier_cost_usd=cross_cost,
        goodput_per_mdollar=goodput / ((fleet_cost + cross_cost) / 1e6),
        slo_attainment={
            name: float(np.mean(v)) for name, v in sorted(hits_by_class.items())
        },
        steer_counts=steer_counts,
        reconfig_count=reconfig_count,
        reconfig_blocked_s=blocked_total,
        replica_a2a_bytes=list(a2a_bytes),
        replica_routed_tokens=[int(t) for t in routed_tokens],
        replica_mean_active_experts=[
            (neff_sum[j] / neff_ticks[j]) if neff_ticks[j] else 0.0
            for j in range(R)
        ],
        cross_tier_bytes=cross_tier_bytes,
    )


@_traced_scenario
def simulate_training(
    model: SimModel,
    fabric: Fabric,
    *,
    iterations: int = 10,
    seed: int = 0,
    use_copilot: bool = True,
    gpus_per_server: int = 8,
    controlplane: ControlPlane | None = None,
) -> list[IterationResult]:
    """Run several iterations through one persistent control-plane engine,
    fitting COPILOT online like the real system (Fig 20's outer loop).

    Pass ``controlplane`` to inject failures or custom engine settings — e.g.
    ``cp.fail_device(0)`` before calling to reproduce §5.4 scenarios."""
    region = max(model.gpus_per_stage // gpus_per_server, 2)
    trace = GateTraceGenerator(model.layers_per_stage, model.num_experts, seed=seed)
    cp = controlplane or ControlPlane.for_simulation(
        model, fabric, num_servers_region=region, use_copilot=use_copilot
    )
    results = []
    for _ in range(iterations):
        res = simulate_iteration(
            model,
            fabric,
            trace,
            num_servers_region=region,
            controlplane=cp,
            gpus_per_server=gpus_per_server,
        )
        results.append(res)
        cp.end_step()
    return results
