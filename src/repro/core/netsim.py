"""Flow-level training-iteration simulator (paper §7: large-scale simulations).

The paper drives htsim (packet level) with a FlexFlow task DAG.  On a CPU-only
container we replace packet fidelity with a flow-level completion-time model
(see DESIGN.md §2) but keep the *same experiment structure*:

  model + parallelization --> per-layer timeline of compute phases and
  all-to-all/all-reduce/p2p communication phases --> composed through the
  1F1B pipeline schedule --> one iteration time, per fabric.

The gate-trace generator reproduces the §3 measurement characteristics:
temporally varying, spatially sparse expert loads with cross-layer
conditional structure (which is what MIXNET-COPILOT exploits) and a
load-balancing-loss-driven slow convergence toward uniformity.

Reconfiguration is driven exclusively through the shared
:class:`repro.core.controlplane.ControlPlane` engine (the same engine the
trainer uses): the simulator observes loads into its monitor, asks it for
per-layer plans (COPILOT-predicted for the FP's first all-to-all), and
applies them against the fabric with hide-or-block accounting.

Communication phases are priced through the SAME CommRuntime ops the trainer
executes (:mod:`repro.core.commruntime`, DESIGN.md §7): an ``AllToAll`` /
``AllReduce`` built from a fabric-derived :class:`CommSpec` owns both the
byte accounting (``ep_alltoall_bytes``, ``dp_gradient_bytes``) and the
phase-latency costing — this module keeps no private collective formulas.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import commruntime as comm
from repro.core import overlap
from repro.core.controlplane import ControlPlane
from repro.core.fabric import Fabric

__all__ = [
    "SimModel",
    "GateTraceGenerator",
    "IterationResult",
    "simulate_iteration",
    "simulate_training",
    "ServingResult",
    "simulate_serving",
]


@dataclasses.dataclass
class SimModel:
    """Just enough of an MoE model + parallelization to cost one iteration.

    Mirrors Table 1 / §D.1 configurations.
    """

    name: str
    num_blocks: int
    d_model: int
    d_ff: int
    num_experts: int
    top_k: int
    num_heads: int
    seq_len: int = 4096
    micro_batch: int = 8
    num_microbatches: int = 8
    ep_degree: int = 8
    tp_degree: int = 4
    pp_degree: int = 4
    dtype_bytes: int = 2
    vocab: int = 32000
    # Effective per-GPU compute throughput (flop/s) — A100 bf16 peak x MFU.
    flops_per_gpu: float = 312e12 * 0.4
    # Per-GPU HBM bandwidth (bytes/s) — the serving scenario's decode ticks
    # are memory-bound (every live token streams the expert weights), so
    # their compute floor is weights-read time, not flops (DESIGN.md §9).
    hbm_bytes_per_s: float = 1.6e12
    # Chunked comm/compute overlap (repro.core.overlap, DESIGN.md §8): the
    # per-layer dispatch->expert->combine phases run as a C-chunk software
    # pipeline on the event timeline.  1 = the serial (additive) schedule,
    # reproduced exactly.
    overlap_chunks: int = 1
    # Price the DP gradient reduction as int8-compressed (the trainer's
    # dp_compress path): wire bytes scale by 1/dtype_bytes through the SAME
    # AllReduce byte accounting.
    dp_compress: bool = False

    # ---- derived sizes -----------------------------------------------------
    @property
    def tokens_per_microbatch(self) -> int:
        return self.micro_batch * self.seq_len

    @property
    def layers_per_stage(self) -> int:
        return max(self.num_blocks // self.pp_degree, 1)

    @property
    def gpus_per_stage(self) -> int:
        return self.ep_degree * self.tp_degree

    def param_count(self) -> float:
        attn = 4 * self.d_model * self.d_model
        expert = 3 * self.d_model * self.d_ff
        return self.num_blocks * (attn + self.num_experts * expert) + 2 * self.vocab * self.d_model

    # ---- per-microbatch per-stage compute times -----------------------------
    def attention_flops(self) -> float:
        t = self.tokens_per_microbatch
        proj = 2 * t * 4 * self.d_model * self.d_model
        attn = 2 * 2 * self.micro_batch * self.seq_len**2 * self.d_model
        return (proj + attn) * self.layers_per_stage

    def expert_flops(self) -> float:
        t = self.tokens_per_microbatch
        return 2 * t * self.top_k * 3 * self.d_model * self.d_ff * self.layers_per_stage

    def attention_time(self) -> float:
        return self.attention_flops() / (self.flops_per_gpu * self.gpus_per_stage)

    def expert_time(self) -> float:
        return self.expert_flops() / (self.flops_per_gpu * self.gpus_per_stage)

    def expert_time_per_layer(self) -> float:
        return self.expert_time() / self.layers_per_stage

    def attention_time_per_layer(self) -> float:
        return self.attention_time() / self.layers_per_stage

    # ---- communication sizes -------------------------------------------------
    # Byte formulas live in the CommRuntime (the same accounting the trainer's
    # ops carry); these wrappers only feed it this model's shapes.
    def a2a_bytes_total(self) -> float:
        """Bytes moved by ONE all-to-all phase of one layer (whole EP group)."""
        return comm.ep_alltoall_bytes(
            self.tokens_per_microbatch, self.top_k, self.d_model, self.dtype_bytes
        )

    def dp_gradient_bytes_per_server(self, gpus_per_server: int = 8) -> float:
        """Gradient bytes a server contributes to the DP ring (hierarchical
        all-reduce §5.3 — the server gateway aggregates its GPUs' shards)."""
        return comm.dp_gradient_bytes(
            self.param_count(),
            max(self.gpus_per_stage * self.pp_degree, 1),
            gpus_per_server,
            self.dtype_bytes,
        )


class GateTraceGenerator:
    """Synthetic per-layer expert-load traces with §3's statistics.

    Layer l+1's load is a noisy linear image of layer l's load through a
    slowly drifting column-stochastic matrix; all loads relax toward uniform
    over iterations (load-balancing loss) while staying sparse per iteration.
    """

    def __init__(
        self,
        num_layers: int,
        num_experts: int,
        *,
        seed: int = 0,
        sparsity: float = 3.0,
        drift: float = 0.02,
        balance_rate: float = 2e-3,
    ):
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.rng = np.random.default_rng(seed)
        self.sparsity = sparsity
        self.drift = drift
        self.balance_rate = balance_rate
        self._transition = np.stack(
            [self._random_stochastic() for _ in range(max(num_layers - 1, 1))]
        )
        self._x0 = self.rng.dirichlet(np.full(num_experts, 1.0 / sparsity))
        self.iteration = 0

    def _random_stochastic(self) -> np.ndarray:
        e = self.num_experts
        m = self.rng.dirichlet(np.full(e, 1.0 / self.sparsity), size=e).T  # cols sum 1
        return m

    def step(self) -> np.ndarray:
        """Advance one iteration; return ``[L, E]`` per-layer load fractions."""
        e = self.num_experts
        uniform = np.full(e, 1.0 / e)
        # Drift the transitions and the entry distribution.
        blend = min(self.balance_rate * self.iteration, 0.9)
        for i in range(self._transition.shape[0]):
            if self.rng.random() < self.drift * 10:
                noise = self._random_stochastic()
                self._transition[i] = 0.95 * self._transition[i] + 0.05 * noise
        # Per-iteration spikiness (Fig 4a): the entry distribution jumps
        # substantially between iterations even late in training.
        x = (1 - blend) * self._x0 + blend * uniform
        x = 0.65 * x + 0.35 * self.rng.dirichlet(np.full(e, 1.0 / self.sparsity))
        x = x / x.sum()
        loads = [x]
        for l in range(self.num_layers - 1):
            x = self._transition[l] @ x
            x = 0.9 * x + 0.1 * self.rng.dirichlet(np.full(e, 1.0 / self.sparsity))
            x = (1 - blend) * x + blend * uniform
            x = x / x.sum()
            loads.append(x)
        self.iteration += 1
        return np.stack(loads)

    def device_demand(
        self,
        load: np.ndarray,
        model: SimModel,
        num_servers: int,
        *,
        node_limit: int = 4,
        total_bytes: float | None = None,
    ) -> np.ndarray:
        """Expert load fraction -> inter-server byte demand for one a2a.

        Two production effects shape the matrix (Fig 4b / Fig 5):
          * tokens within one batch shard are semantically correlated and
            concentrate on few experts (low-concentration Dirichlet rows);
          * group-limited gating (DeepSeek-V2/V3, cited by the paper) caps
            the number of *nodes* a token may route to, keeping the matrix
            sparse at server granularity even with hundreds of experts.

        ``total_bytes`` overrides the phase volume (default: one training
        microbatch's a2a) — the serving scenario passes the tick's live
        decode + prefill-chunk payload instead (DESIGN.md §9).
        """
        e = self.num_experts
        total = (
            SimModel.a2a_bytes_total(model) if total_bytes is None else total_bytes
        )
        per_src = total / max(num_servers, 1)
        per_server = max(e // max(num_servers, 1), 1)
        # Server-level attractiveness = summed load of its experts.
        srv_load = np.add.reduceat(
            np.resize(load, per_server * num_servers), np.arange(num_servers) * per_server
        )
        srv_load = srv_load / srv_load.sum()
        dem = np.zeros((num_servers, num_servers))
        limit = min(max(node_limit, 1), num_servers)
        for src in range(num_servers):
            # Group-limited gating: this shard's tokens reach <= limit servers.
            p = srv_load + 1e-9
            p = p / p.sum()
            dests = self.rng.choice(num_servers, size=limit, replace=False, p=p)
            weights = self.rng.dirichlet(srv_load[dests] * 8.0 + 0.1)
            dem[src, dests] += per_src * weights
        np.fill_diagonal(dem, 0.0)
        return dem


@dataclasses.dataclass
class IterationResult:
    total: float
    attn_compute: float
    expert_compute: float
    a2a: float
    reconfig_blocked: float
    dp_allreduce: float
    pp_bubble: float
    # Overlap accounting (DESIGN.md §8): the additive a2a total splits into
    # the part hidden under the compute window by the chunked pipeline and
    # the part that stays on the critical path.  hidden + exposed == a2a.
    hidden_comm: float = 0.0
    exposed_comm: float = 0.0
    # Per-link-class bytes of ONE EP a2a phase, from the op's staged
    # accounting (AllToAllStage.bytes_on_link — the same numbers the
    # trainer's overlap scheduler consumes).
    a2a_link_bytes: dict = dataclasses.field(default_factory=dict)

    def breakdown(self) -> dict:
        return dataclasses.asdict(self)


def _stage_times(
    model: SimModel,
    fabric: Fabric,
    loads: np.ndarray,
    trace: GateTraceGenerator,
    num_servers_region: int,
    cp: ControlPlane,
    a2a_op: comm.AllToAll,
) -> tuple[float, float, float, float]:
    """One PP stage's event timeline over a FULL iteration (all microbatches).

    Reconfiguration semantics follow Fig 20, driven entirely through the
    shared control-plane engine (DESIGN.md §3): the topology is reconfigured
    *twice per MoE layer per iteration* (once covering the FP pair of
    all-to-alls, once the BP pair), amortized across microbatches — each
    layer gets its own OCS cross-map via ``cp.plan``/``cp.apply`` ->
    ``fabric.prepare``.  A reconfiguration blocks only if its delay exceeds
    the pipelined compute window between consecutive all-to-alls of that
    layer — with 25 ms OCS and production-size compute this is fully hidden
    (Fig 28's flat region), and degradation appears once the delay
    approaches the per-layer compute budget, reproducing Fig 28's cliff.

    Each layer's dispatch->expert->combine phases run through the chunked
    event timeline (:func:`repro.core.overlap.pipelined_phase`) with
    ``model.overlap_chunks`` chunks; with 1 chunk the timeline IS the
    pre-overlap additive sum.  Returns ``(timeline_seconds,
    additive_a2a_seconds, blocked_seconds, exposed_comm_seconds)``.
    """
    attn_f = model.attention_time_per_layer()
    exp_f = model.expert_time_per_layer()
    m = model.num_microbatches
    chunks = max(model.overlap_chunks, 1)
    # Compute window available to hide one reconfiguration: the layer's
    # compute across the iteration's microbatches (fwd + bwd ~ 3x fwd).
    hide_window = m * (attn_f + exp_f)
    a2a_total = 0.0
    blocked = 0.0
    timeline = 0.0
    exposed = 0.0
    for li in range(model.layers_per_stage):
        load = loads[li % loads.shape[0]]
        demand = trace.device_demand(load, model, num_servers_region)
        # --- FP reconfig. For the layer's FIRST a2a the true matrix is not
        # yet known (§5.1): COPILOT predicts it (accurate prediction ->
        # near-matching circuits); without COPILOT the fabric keeps the
        # previous layer's topology (never blocks, but circuits mismatch).
        if fabric.cfg.reconfig_delay_s <= 1e-3:
            # Microsecond-scale OCS: exact reconfig fits before a2a#1 (Fig 28).
            blocked += cp.apply(cp.plan(li, demand))
        else:
            pred = cp.predict_load(li)
            if pred is not None:
                pred_demand = trace.device_demand(pred, model, num_servers_region)
                blocked += cp.apply(cp.plan(li, pred_demand, predicted=True))
            # else: reuse previous topology — no plan at all.
        t_disp = a2a_op.cost(fabric, demand)
        # --- FP a2a #2 (combine, transposed matrix): reconfig hidden when the
        # compute window allows; otherwise the overflow blocks the pipe.
        blocked += cp.apply(cp.plan(li, demand.T), hide_window=hide_window)
        t_comb = a2a_op.cost(fabric, demand.T)
        # --- BP reconfig + a2a pair (same matrices, §5.1; window = bwd
        # compute) — priced AFTER the BP prepare, whose circuits come from
        # the observed matrix (the FP pair may have run on predicted ones).
        blocked += cp.apply(cp.plan(li, demand), hide_window=2.0 * hide_window)
        t_disp_bp = a2a_op.cost(fabric, demand)
        t_comb_bp = a2a_op.cost(fabric, demand.T)
        a2a_total += m * (t_disp + t_comb + t_disp_bp + t_comb_bp)
        # Event timeline: attention is un-overlappable prefix compute; the
        # chunked dispatch/FFN/combine pipeline hides comm under the expert
        # window (bwd compute ~ 2x fwd, same a2a matrices).
        fp_t, fp_x = overlap.pipelined_phase(
            t_disp, exp_f, t_comb, chunks, serial_prefix=attn_f
        )
        bp_t, bp_x = overlap.pipelined_phase(
            t_disp_bp, 2.0 * exp_f, t_comb_bp, chunks, serial_prefix=2.0 * attn_f
        )
        timeline += m * (fp_t + bp_t)
        exposed += m * (fp_x + bp_x)
        cp.observe(li, load * model.tokens_per_microbatch * model.top_k)
    return timeline, a2a_total, blocked, exposed


def simulate_iteration(
    model: SimModel,
    fabric: Fabric,
    trace: GateTraceGenerator,
    *,
    num_servers_region: int | None = None,
    controlplane: ControlPlane | None = None,
    gpus_per_server: int = 8,
) -> IterationResult:
    """Cost one training iteration of ``model`` on ``fabric``.

    ``controlplane`` is the engine driving reconfiguration for this region;
    a fresh one (no COPILOT history) is built when not supplied.
    """
    if num_servers_region is None:
        num_servers_region = max(model.gpus_per_stage // gpus_per_server, 2)
    if controlplane is None:
        controlplane = ControlPlane.for_simulation(
            model, fabric, num_servers_region=num_servers_region, use_copilot=False
        )
    loads = trace.step()

    # The comm phases are priced through the SAME CollectiveOp API the
    # trainer executes; the spec's region/group factorization comes from the
    # fabric topology (servers x intra-server scale-up domain).
    a2a_op = comm.AllToAll(comm.CommSpec.from_fabric(fabric, num_servers_region))
    dp_op = comm.AllReduce(comm.CommSpec(
        axis=None,
        axis_size=max(gpus_per_server, 1),
        group_size=max(gpus_per_server, 1),
        outer_size=max(fabric.cfg.num_servers, 1),
    ))
    timeline, a2a, blocked, exposed = _stage_times(
        model, fabric, loads, trace, num_servers_region, controlplane, a2a_op
    )
    # 1F1B: the critical path stretches the per-stage work by (M+P-1)/M.
    # ``timeline`` is the event-timeline per-stage time (== compute + a2a
    # when overlap_chunks=1, smaller when the chunked pipeline hides comm).
    m, p = model.num_microbatches, model.pp_degree
    stretch = (m + p - 1) / m
    pipeline = stretch * timeline
    bubble = (stretch - 1.0) * timeline
    # DP gradient all-reduce (hierarchical on MixNet), half overlapped with
    # bwd; dp_compress prices the int8 wire through the same op accounting.
    dp_bytes = model.dp_gradient_bytes_per_server(gpus_per_server)
    dp_ratio = (1.0 / model.dtype_bytes) if model.dp_compress else 1.0
    dp = 0.5 * dp_op.cost(fabric, dp_bytes, compress_ratio=dp_ratio)
    total = pipeline + blocked + dp
    # Per-link bytes of one EP a2a phase through the op's staged accounting
    # (the identical AllToAllStage.bytes_on_link the trainer's scheduler
    # consumes for its chunk schedule).
    phase_bytes = model.a2a_bytes_total() / max(num_servers_region, 1)
    link_bytes: dict = {}
    for stage in a2a_op.stages():
        lb = stage.bytes_on_link(phase_bytes)
        link_bytes[stage.link_class] = (
            link_bytes.get(stage.link_class, 0.0) + getattr(lb, stage.link_class)
        )
    return IterationResult(
        total=total,
        attn_compute=m * model.attention_time() * 3.0,
        expert_compute=m * model.expert_time() * 3.0,
        a2a=stretch * a2a,
        reconfig_blocked=blocked,
        dp_allreduce=dp,
        pp_bubble=bubble,
        hidden_comm=stretch * (a2a - exposed),
        exposed_comm=stretch * exposed,
        a2a_link_bytes=link_bytes,
    )


# ---------------------------------------------------------------------------
# Serving scenario (DESIGN.md §9) — the inference analogue of Fig 26-28
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServingResult:
    """Priced serving run on one fabric: latency percentiles, goodput, and
    the Fig-13-style goodput-per-dollar the acceptance gate compares."""

    fabric: str
    ticks: int
    sim_seconds: float
    requests: int
    completed: int
    tokens_out: int
    ttft_p50_s: float
    ttft_p99_s: float
    tpot_p50_s: float
    tpot_p99_s: float
    goodput_tok_s: float
    cost_usd: float
    goodput_per_mdollar: float  # decode tokens/s per M$ of interconnect
    exposed_comm_fraction: float  # mean exposed/total a2a per tick
    reconfig_count: int
    reconfig_blocked_s: float
    # Total EP a2a payload bytes across the run, accounted through the SAME
    # CommRuntime formula (ep_alltoall_bytes) the real engine reports — the
    # serving cross-check in tests/test_serve.py.
    a2a_bytes_total: float
    # Paged-KV accounting (DESIGN.md §10): decode HBM reads priced from the
    # resident pages actually touched per tick, and admission gated by the
    # KV token budget instead of a fixed slot preallocation.
    kv_paged: bool = False
    kv_resident_tokens_peak: int = 0
    kv_budget_tokens: int = 0
    # Speculative decoding (DESIGN.md §11): draft span, modeled per-token
    # acceptance, and the resulting expected emitted tokens per verify round
    # (1.0 = non-speculative).
    spec_k: int = 0
    spec_acceptance: float = 0.0
    spec_tokens_per_round: float = 1.0

    def breakdown(self) -> dict:
        return dataclasses.asdict(self)


def simulate_serving(
    model: SimModel,
    fabric: Fabric,
    *,
    mix="chat",
    num_requests: int = 64,
    slots: int = 16,
    seed: int = 0,
    use_reconfig: bool = True,
    reconfig_every_ticks: int = 256,
    prefill_chunk_tokens: int = 256,
    num_servers_region: int | None = None,
    gpus_per_server: int = 8,
    max_ticks: int = 200_000,
    paged_kv: bool = False,
    kv_page_tokens: int = 16,
    kv_budget_tokens: int = 0,
    spec_decode: tuple | None = None,
) -> ServingResult:
    """Price a continuous-batching serving run of ``model`` on ``fabric``.

    One tick = one engine decode step (:mod:`repro.serve.engine` at flow
    level): every live slot decodes one token, up to ``prefill_chunk_tokens``
    of pending prompt stream through the same tick (chunked prefill), and
    each MoE layer pays a dispatch/combine a2a pair priced through the
    CommRuntime op on the fabric — hidden under the decode + interleaved
    prefill compute window by the chunked event timeline
    (:func:`repro.core.overlap.decode_tick_phase`).

    With ``use_reconfig`` the shared ControlPlane re-solves the regional OCS
    cross-maps every ``reconfig_every_ticks`` from the drifting decode
    demand (the request mix moves, §3's locality), amortizing the
    reconfiguration delay over the window's compute; a static EPS fabric
    (e.g. fat-tree) with ``use_reconfig=False`` is the baseline the
    goodput-per-dollar gate compares against.

    **Paged KV** (``paged_kv=True``, DESIGN.md §10): each request's KV
    footprint is its *page-rounded live context* instead of a full-length
    slot preallocation, a region's shared prompt prefix is resident ONCE
    (copy-on-write pages, mirroring the engine's prefix registry), and the
    per-tick decode HBM-read term charges only the resident pages touched.
    ``kv_budget_tokens`` caps resident KV tokens: admission stalls at the
    head of the prefill queue until retiring requests release pages —
    exactly how :class:`repro.serve.paged.PageAllocator` gates the engine —
    so at equal HBM budget the paged run sustains more concurrent decodes.

    **Speculative decoding** (``spec_decode=(K, acceptance_model)``,
    DESIGN.md §11): each live slot verifies a K-token draft span per tick,
    so one a2a launch (and one KV-cache streaming pass) amortizes over the
    expected ``1 + sum(p^i)`` emitted tokens — while the verify a2a payload
    and FLOPs scale with all K+1 positions and the draft pass adds K cheap
    (attention + one expert-equivalent) steps that re-stream KV each step.
    ``acceptance_model`` is the per-token draft acceptance probability
    (i.i.d. model), or a callable ``f(K) -> expected accepted tokens``.
    At low acceptance the extra positions/draft FLOPs are pure waste — the
    goodput-per-dollar crossover against ``spec_decode=None`` is exactly
    what ``benchmarks/run.py::spec_decode`` sweeps.
    """
    from repro.core import cost as costm
    from repro.serve.workload import WorkloadGenerator

    requests = WorkloadGenerator(mix, seed=seed).generate(num_requests)
    spec_k, spec_acc, spec_emit = 0, 0.0, 1.0
    if spec_decode is not None:
        spec_k, acc_model = int(spec_decode[0]), spec_decode[1]
        if spec_k > 0:
            if callable(acc_model):
                exp_acc = float(acc_model(spec_k))
            else:
                exp_acc = sum(float(acc_model) ** i for i in range(1, spec_k + 1))
            spec_acc = exp_acc / spec_k
            spec_emit = 1.0 + exp_acc  # + verify's correction/bonus token
        else:
            spec_k = 0
    region = num_servers_region or max(model.gpus_per_stage // gpus_per_server, 2)
    trace = GateTraceGenerator(model.layers_per_stage, model.num_experts, seed=seed)
    cp = (
        ControlPlane.for_simulation(
            model, fabric, num_servers_region=region, use_copilot=False
        )
        if use_reconfig
        else None
    )
    a2a_op = comm.AllToAll(comm.CommSpec.from_fabric(fabric, region))
    rate = model.flops_per_gpu * model.gpus_per_stage
    layers = model.layers_per_stage
    d, dff, k, dt = model.d_model, model.d_ff, model.top_k, model.dtype_bytes

    pending = sorted(requests, key=lambda r: r.arrival_s)
    cursor = 0

    # -- KV residency bookkeeping (tokens) --------------------------------
    # Dense: an admitted request pins its full prompt+output length for its
    # whole lifetime (slot preallocation).  Paged: it pins page-rounded live
    # context, and a region's shared prompt prefix is resident once across
    # all carriers (the engine's refcounted prefix pages).
    page = max(int(kv_page_tokens), 1)
    region_refs: dict[int, int] = {}  # carriers per region's shared prefix
    resident_tokens = 0
    resident_peak = 0

    def _kv_parts(req):
        total = req.prompt_len + req.max_new_tokens
        if not paged_kv:
            return 0, total
        pfx = min(getattr(req, "prefix_len", 0), req.prompt_len)
        return -(-pfx // page) * page, -(-(total - pfx) // page) * page

    def _kv_acquire(req):
        nonlocal resident_tokens
        shared, private = _kv_parts(req)
        resident_tokens += private
        if shared:
            n = region_refs.get(req.region, 0)
            region_refs[req.region] = n + 1
            if n == 0:
                resident_tokens += shared

    def _kv_release(req):
        nonlocal resident_tokens
        shared, private = _kv_parts(req)
        resident_tokens -= private
        if shared:
            n = region_refs[req.region] - 1
            region_refs[req.region] = n
            if n == 0:
                resident_tokens -= shared

    def _kv_fresh_cost(req):
        shared, private = _kv_parts(req)
        if shared and region_refs.get(req.region, 0) > 0:
            shared = 0  # prefix already resident: pages map for free
        return private + shared

    prefill_q: list = []  # [req, tokens_left]
    live: list = []  # [req, tokens_left, context_len]
    ttft: list[float] = []
    tpot: list[float] = []
    clock = 0.0
    ticks = 0
    tokens_out = 0
    completed = 0
    blocked_total = 0.0
    a2a_total_s = 0.0
    exposed_total_s = 0.0
    a2a_bytes_total = 0.0
    loads = trace.step()

    while ticks < max_ticks:
        # -- admission --------------------------------------------------------
        while cursor < len(pending) and pending[cursor].arrival_s <= clock:
            prefill_q.append([pending[cursor], pending[cursor].prompt_len])
            cursor += 1
        if not prefill_q and not live:
            if cursor >= len(pending):
                break
            clock = pending[cursor].arrival_s  # idle: jump to next arrival
            continue

        # -- this tick's work -------------------------------------------------
        n_live = len(live)
        pf_tokens = 0
        budget = prefill_chunk_tokens
        finished_prefills = []
        for item in prefill_q:
            if budget <= 0 or len(live) + len(finished_prefills) >= slots:
                break
            if item[1] == item[0].prompt_len:  # starting this request now
                need = _kv_fresh_cost(item[0])
                if (
                    kv_budget_tokens
                    and resident_tokens + need > kv_budget_tokens
                    and resident_tokens > 0  # an empty pool always admits one
                ):
                    break  # head-of-line waits for retiring requests' pages
                _kv_acquire(item[0])
            take = min(budget, item[1])
            item[1] -= take
            budget -= take
            pf_tokens += take
            if item[1] == 0:
                finished_prefills.append(item[0])
        resident_peak = max(resident_peak, resident_tokens)

        # Per-layer phase pricing: the a2a moves every routed token copy of
        # the tick (live decode + prefill chunk) — the same byte formula the
        # engine accounts (comm.ep_alltoall_bytes).  Speculative ticks route
        # the whole verify span (K+1 positions per live slot) through ONE
        # launch per layer: payload scales with positions, launches don't.
        vpos = n_live * (spec_k + 1) if spec_k else n_live
        routed = vpos + pf_tokens
        tick_s = 0.0
        blocked_tick = 0.0
        if routed:
            tick_bytes = comm.ep_alltoall_bytes(routed, k, d, dt)
            a2a_bytes_total += layers * tick_bytes
            mean_ctx = (
                np.mean([it[2] for it in live]) if live else 64.0
            )
            # Per-layer compute terms (flow level): decode attention is the
            # un-overlappable prefix, expert FFN + the interleaved prefill
            # chunk form the hideable window.  Decode is memory-bound: the
            # floor is streaming the layer's expert weights (+ the KV cache)
            # from HBM, which is what puts real decode ticks at ms scale and
            # makes the 25 ms OCS hideable across a reconfiguration window.
            hbm = model.hbm_bytes_per_s * model.gpus_per_stage
            if paged_kv:
                # KV read = resident pages TOUCHED this tick: each slot
                # streams its own page-rounded context, but a shared prefix
                # page transits HBM once for all carriers reading it.
                shared_touch: dict[int, int] = {}
                private_pages = 0
                for it in live:
                    pfx = min(getattr(it[0], "prefix_len", 0), it[2])
                    shared_touch[it[0].region] = max(
                        shared_touch.get(it[0].region, 0), -(-pfx // page)
                    )
                    private_pages += -(-(it[2] - pfx) // page)
                kv_read_tokens = (
                    private_pages + sum(shared_touch.values())
                ) * page
            else:
                kv_read_tokens = n_live * mean_ctx
            attn_t = max(
                # Matmul/score FLOPs scale with every verified position; the
                # KV HBM read does NOT — the whole span streams the cache
                # once per round (the speculative amortization).
                (2 * vpos * 4 * d * d + 2 * 2 * vpos * mean_ctx * d) / rate,
                (kv_read_tokens * 2 * d * dt) / hbm,  # KV read
            )
            exp_t = max(
                2 * vpos * k * 3 * d * dff / rate,
                # dense-decode weight streaming: every expert's FFN weights
                # transit HBM once per tick when any token is live.
                (model.num_experts * 3 * d * dff * dt) / hbm,
            )
            pf_t = pf_tokens * (2 * 4 * d * d + 2 * k * 3 * d * dff) / rate
            draft_t = 0.0
            if spec_k and n_live:
                # K draft steps: full attention + ONE expert-equivalent FFN
                # per token (shared_only / topk1 drafts), each step
                # re-streaming the live KV (serial steps can't amortize it)
                # plus one expert's weights.  Rides the hideable window with
                # the prefill chunk — wasted entirely when acceptance is low.
                draft_t = spec_k * max(
                    (
                        2 * n_live * 4 * d * d
                        + 2 * 2 * n_live * mean_ctx * d
                        + 2 * n_live * 3 * d * dff
                    ) / rate,
                    (kv_read_tokens * 2 * d * dt + 3 * d * dff * dt) / hbm,
                )
            if ticks % 8 == 0:
                loads = trace.step()
            for li in range(layers):
                demand = trace.device_demand(
                    loads[li % loads.shape[0]], model, region,
                    total_bytes=tick_bytes,
                )
                if cp is not None and reconfig_every_ticks and (
                    ticks % reconfig_every_ticks == 0
                ):
                    # Amortized over the window: one layer's OCS slice is
                    # idle while every OTHER phase of the stretch runs, so
                    # the hide window is the full-tick compute of the whole
                    # inter-reconfiguration stretch (§5.1's rule at serving
                    # cadence).
                    window = (
                        reconfig_every_ticks
                        * layers
                        * (attn_t + exp_t + pf_t + draft_t)
                    )
                    blocked_tick += cp.apply(
                        cp.plan(li, demand), hide_window=window
                    )
                t_disp = a2a_op.cost(fabric, demand)
                t_comb = a2a_op.cost(fabric, demand.T)
                total_t, exposed_t = overlap.decode_tick_phase(
                    t_disp, exp_t, t_comb, max(model.overlap_chunks, 1),
                    attn=attn_t, prefill_compute=pf_t + draft_t,
                )
                tick_s += total_t
                a2a_total_s += t_disp + t_comb
                exposed_total_s += exposed_t
            if cp is not None:
                for li in range(layers):
                    cp.observe(
                        li, loads[li % loads.shape[0]] * max(routed, 1) * k
                    )
                cp.end_step()
        blocked_total += blocked_tick
        clock += tick_s + blocked_tick  # un-hidden reconfig stalls the tick
        ticks += 1

        # -- bookkeeping: decode completions FIRST (only the slots that were
        # live — and therefore routed — this tick emit), then the tick's
        # finished prefills join the live set for the NEXT tick.
        still = []
        for it in live:
            # Speculative rounds emit the expected accepted prefix + the
            # verify correction/bonus token (flow level: the i.i.d.
            # acceptance expectation), clamped to what the request needs.
            emit = min(spec_emit, it[1]) if spec_k else 1
            it[1] -= emit
            it[2] += emit
            tokens_out += emit
            if it[1] <= 0:
                completed += 1
                _kv_release(it[0])
                span = max(clock - it[3], 0.0)
                tpot.append(span / max(it[0].max_new_tokens - 1, 1))
            else:
                still.append(it)
        live = still
        for req in finished_prefills:
            prefill_q = [it for it in prefill_q if it[0] is not req]
            ttft.append(clock - req.arrival_s)
            tokens_out += 1  # the prefill's next-token (first output)
            if req.max_new_tokens <= 1:
                completed += 1
                _kv_release(req)
            else:
                live.append([req, req.max_new_tokens - 1, req.prompt_len, clock])

    pct = lambda a, q: float(np.percentile(a, q)) if len(a) else 0.0
    cost_usd = costm.fabric_cost(
        fabric.name,
        fabric.cfg.num_servers,
        int(fabric.cfg.link_gbps),
        nics_per_server=fabric.cfg.nics_per_server,
        eps_nics=fabric.cfg.eps_nics,
        ocs_nics=fabric.cfg.ocs_nics,
        oversub_ratio=fabric.cfg.oversub_ratio,
    )
    sim_seconds = max(clock, 1e-12)
    goodput = tokens_out / sim_seconds
    return ServingResult(
        fabric=fabric.name,
        ticks=ticks,
        sim_seconds=sim_seconds,
        requests=len(requests),
        completed=completed,
        tokens_out=int(round(tokens_out)),
        ttft_p50_s=pct(ttft, 50),
        ttft_p99_s=pct(ttft, 99),
        tpot_p50_s=pct(tpot, 50),
        tpot_p99_s=pct(tpot, 99),
        goodput_tok_s=goodput,
        cost_usd=cost_usd,
        goodput_per_mdollar=goodput / (cost_usd / 1e6),
        exposed_comm_fraction=exposed_total_s / max(a2a_total_s, 1e-12),
        reconfig_count=cp.reconfig_count if cp is not None else 0,
        reconfig_blocked_s=blocked_total,
        a2a_bytes_total=a2a_bytes_total,
        kv_paged=bool(paged_kv),
        kv_resident_tokens_peak=int(resident_peak),
        kv_budget_tokens=int(kv_budget_tokens),
        spec_k=spec_k,
        spec_acceptance=spec_acc,
        spec_tokens_per_round=spec_emit,
    )


def simulate_training(
    model: SimModel,
    fabric: Fabric,
    *,
    iterations: int = 10,
    seed: int = 0,
    use_copilot: bool = True,
    gpus_per_server: int = 8,
    controlplane: ControlPlane | None = None,
) -> list[IterationResult]:
    """Run several iterations through one persistent control-plane engine,
    fitting COPILOT online like the real system (Fig 20's outer loop).

    Pass ``controlplane`` to inject failures or custom engine settings — e.g.
    ``cp.fail_device(0)`` before calling to reproduce §5.4 scenarios."""
    region = max(model.gpus_per_stage // gpus_per_server, 2)
    trace = GateTraceGenerator(model.layers_per_stage, model.num_experts, seed=seed)
    cp = controlplane or ControlPlane.for_simulation(
        model, fabric, num_servers_region=region, use_copilot=use_copilot
    )
    results = []
    for _ in range(iterations):
        res = simulate_iteration(
            model,
            fabric,
            trace,
            num_servers_region=region,
            controlplane=cp,
            gpus_per_server=gpus_per_server,
        )
        results.append(res)
        cp.end_step()
    return results
