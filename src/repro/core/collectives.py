"""MixNet data plane: topology-aware collectives (paper §5.3) as
``shard_map`` primitives.

The paper routes EP all-to-all through a delegation hierarchy: intra-host
gather over NVSwitch -> inter-host transfer on the OCS circuits -> intra-host
all-to-all -> scatter, with the two inner steps overlapped.  On a TPU mesh
the same structure is a *two-stage factored all-to-all* over the ``model``
axis: the axis of size P is treated as a (G groups x H per-group) grid; stage
1 exchanges within a group (the scale-up analogue), stage 2 across groups
(the scale-out analogue).  The composition is bit-identical to the flat
``lax.all_to_all`` (tested), but each stage's transfer only crosses one
hierarchy level — which is what lets the compiler schedule them on different
link classes and overlap them.

DP gradients use the paper's hierarchical all-reduce: reduce-scatter inside
the region, all-reduce across regions on the gateway shard, all-gather back.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "hierarchical_all_to_all",
    "flat_all_to_all",
    "hierarchical_psum",
    "mixnet_all_to_all",
    "ring_all_gather",
]


def _axis_size(axis_name: str) -> int:
    """Static mesh-axis size; ``lax.psum(1, axis)`` constant-folds on jax
    releases predating ``lax.axis_size``."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def _grid_groups(p: int, group_size: int) -> tuple[list[list[int]], list[list[int]]]:
    if p % group_size != 0:
        raise ValueError(f"axis size {p} not divisible by group size {group_size}")
    g = p // group_size
    intra = [[gg * group_size + h for h in range(group_size)] for gg in range(g)]
    inter = [[gg * group_size + h for gg in range(g)] for h in range(group_size)]
    return intra, inter


def flat_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Baseline single-shot all-to-all. ``x``: [P, ...] chunks by destination."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0)


def hierarchical_all_to_all(
    x: jax.Array, axis_name: str, group_size: int
) -> jax.Array:
    """Two-stage (delegation) all-to-all over a factored axis.

    Args:
      x: ``[P, ...]`` local chunks ordered by destination device on
        ``axis_name`` (device index = g * group_size + h).
      axis_name: mesh axis of size P = G * group_size.
      group_size: size of the scale-up (intra-host analogue) stage H.

    Returns:
      ``[P, ...]`` chunks ordered by source device — identical to
      :func:`flat_all_to_all`.
    """
    p = _axis_size(axis_name)
    h = group_size
    if p == 1 or h == 1 or h >= p:
        return flat_all_to_all(x, axis_name)
    g = p // h
    intra, inter = _grid_groups(p, h)
    xr = x.reshape(g, h, *x.shape[1:])
    # Stage 1 — intra-group exchange (scale-up): split/concat the h-chunk dim.
    z = lax.all_to_all(xr, axis_name, split_axis=1, concat_axis=1, axis_index_groups=intra)
    # Stage 2 — inter-group exchange (scale-out): split/concat the g-chunk dim.
    w = lax.all_to_all(z, axis_name, split_axis=0, concat_axis=0, axis_index_groups=inter)
    return w.reshape(x.shape)


def mixnet_all_to_all(
    x: jax.Array,
    axis_name: str,
    group_size: int,
    *,
    dest_perm: jax.Array | None = None,
    src_perm: jax.Array | None = None,
) -> jax.Array:
    """Hierarchical all-to-all with an expert-placement permutation.

    ``dest_perm`` re-addresses outgoing chunks (chunk for logical destination
    ``d`` is physically sent to ``dest_perm[d]``); ``src_perm`` restores the
    logical ordering of received chunks.  This is how the runtime-reconfigured
    placement from :mod:`repro.core.placement` is realized on the wire without
    touching the collective itself — the analogue of pushing a new cross-map
    to the OCS.
    """
    if dest_perm is not None:
        x = x[dest_perm]
    y = hierarchical_all_to_all(x, axis_name, group_size)
    if src_perm is not None:
        y = y[src_perm]
    return y


def hierarchical_psum(
    x: jax.Array,
    inner_axis: str,
    outer_axis: str | None = None,
    *,
    scatter_dim: int = 0,
) -> jax.Array:
    """Paper §5.3 hierarchical all-reduce.

    reduce-scatter over ``inner_axis`` (intra-host reduction to the gateway
    shard) -> all-reduce over ``outer_axis`` (the global ring over EPS) ->
    all-gather over ``inner_axis`` (broadcast back).  Cross-region bytes drop
    by a factor of the inner axis size versus a flat all-reduce.
    """
    inner = _axis_size(inner_axis)
    if inner == 1 or x.shape[scatter_dim] % inner != 0:
        y = lax.psum(x, inner_axis)
        return lax.psum(y, outer_axis) if outer_axis else y
    part = lax.psum_scatter(x, inner_axis, scatter_dimension=scatter_dim, tiled=True)
    if outer_axis is not None:
        part = lax.psum(part, outer_axis)
    return lax.all_gather(part, inner_axis, axis=scatter_dim, tiled=True)


def ring_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """Explicit ring all-gather via collective_permute (comm/compute overlap
    building block for the perf path; semantically = lax.all_gather(tiled))."""
    p = _axis_size(axis_name)
    if p == 1:
        return x
    idx = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % p) for i in range(p)]

    def body(carry, _):
        block, rot = carry
        nxt = lax.ppermute(block, axis_name, perm)
        return (nxt, rot - 1), nxt

    (_, _), rest = lax.scan(body, (x, p - 1), None, length=p - 1)
    # rest[k] came from device (idx - 1 - k); roll into ascending device order.
    all_blocks = jnp.concatenate([x[None], rest], axis=0)  # [P, ...] by hop
    src = (idx - jnp.arange(p)) % p
    order = jnp.argsort(src)
    return all_blocks[order].reshape(p * x.shape[0], *x.shape[1:])
