"""DEPRECATED shim — the MixNet data plane moved to
:mod:`repro.core.commruntime` (DESIGN.md §7).

The topology-aware collectives now live behind the shared CommRuntime API:
build a :class:`repro.core.commruntime.CommSpec` and one of the
:class:`CollectiveOp` objects (``AllToAll``, ``AllReduce``, ``AllGather``,
``ReduceScatter``, ``Permute``), which carry the executable lowering, the
per-link-class byte accounting the simulator prices, and the control-plane
reconfiguration hook.  The free functions below are re-exported unchanged so
existing callers keep working; new code should not import this module.
"""

from __future__ import annotations

import warnings

warnings.warn(
    "repro.core.collectives is deprecated; build a CommSpec + CollectiveOp "
    "from repro.core.commruntime instead (DESIGN.md §7)",
    DeprecationWarning,
    stacklevel=2,
)

from repro.core.commruntime import (  # noqa: E402
    flat_all_to_all,
    hierarchical_all_to_all,
    hierarchical_psum,
    mixnet_all_to_all,
    ring_all_gather,
)

__all__ = [
    "hierarchical_all_to_all",
    "flat_all_to_all",
    "hierarchical_psum",
    "mixnet_all_to_all",
    "ring_all_gather",
]
