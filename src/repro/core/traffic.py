"""All-to-all traffic characterization (paper §5.1).

Each MoE block performs four all-to-all phases per iteration — dispatch and
combine in the forward pass, and their mirror images in the backward pass —
all sharing one traffic matrix (or its transpose).  The matrix is fully
determined by the gate output *before* the communication happens, which is
what makes in-training reconfiguration possible at all.

These helpers are pure ``jnp`` so they can run inside the training step (the
monitor adds no extra pass over the data — the dispatch indices already
exist, exactly as Megatron's token dispatcher exposes them).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TrafficRecord",
    "expert_load_from_gates",
    "alltoall_matrix_from_gates",
    "device_traffic_matrix",
    "TrafficMonitor",
]


def expert_load_from_gates(expert_indices: jax.Array, num_experts: int) -> jax.Array:
    """Tokens routed to each expert: ``[E]`` counts from ``[..., top_k]`` ids."""
    one_hot = jax.nn.one_hot(expert_indices.reshape(-1), num_experts, dtype=jnp.int32)
    return one_hot.sum(axis=0)


def alltoall_matrix_from_gates(
    expert_indices: jax.Array,
    token_src_device: jax.Array,
    num_experts: int,
    num_devices: int,
    bytes_per_token: float = 1.0,
) -> jax.Array:
    """``[num_devices, E]`` dispatch matrix: bytes device *d* sends to expert *e*.

    ``token_src_device`` assigns each token (flattened) to its source device;
    ``expert_indices`` is ``[tokens, top_k]``.
    """
    flat_idx = expert_indices.reshape(expert_indices.shape[0], -1)  # [T, k]
    tok_dev = token_src_device.reshape(-1)
    k = flat_idx.shape[-1]
    dev_rep = jnp.repeat(tok_dev, k)
    exp_flat = flat_idx.reshape(-1)
    mat = jnp.zeros((num_devices, num_experts), dtype=jnp.float32)
    mat = mat.at[dev_rep, exp_flat].add(bytes_per_token)
    return mat


def device_traffic_matrix(
    dispatch: jax.Array | np.ndarray,
    experts_per_device: int,
) -> np.ndarray:
    """Fold ``[D, E]`` dispatch into the ``[D, D]`` device all-to-all matrix."""
    dispatch = np.asarray(dispatch, dtype=np.float64)
    n_dev, n_exp = dispatch.shape
    owner_devices = n_exp // experts_per_device
    per_owner = dispatch.reshape(n_dev, owner_devices, experts_per_device).sum(-1)
    if owner_devices == n_dev:
        mat = per_owner
    else:
        # Experts live on a subset/superset of the sending devices — pad/fold.
        mat = np.zeros((n_dev, n_dev))
        mat[:, :owner_devices] = per_owner
    np.fill_diagonal(mat, 0.0)
    return mat


@dataclasses.dataclass
class TrafficRecord:
    """One observation: per-layer expert load + device a2a matrix."""

    layer: int
    step: int
    expert_load: np.ndarray  # [E]
    device_matrix: np.ndarray  # [D, D]


class TrafficMonitor:
    """Rolling window of per-layer traffic records (host-side ring buffer).

    The monitor is the producer side of the control loop: the MoE layer emits
    its realized expert load every step, the monitor keeps the last ``window``
    observations per layer, and :mod:`repro.core.copilot` consumes them to fit
    the transition matrices.
    """

    def __init__(self, num_layers: int, num_experts: int, window: int = 8):
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.window = window
        self._loads: list[list[np.ndarray]] = [[] for _ in range(num_layers)]
        self._matrices: list[list[np.ndarray]] = [[] for _ in range(num_layers)]
        self.step = 0

    def record(self, layer: int, expert_load, device_matrix=None) -> None:
        load = np.asarray(expert_load, dtype=np.float64)
        if load.shape != (self.num_experts,):
            raise ValueError(f"expert load shape {load.shape}")
        buf = self._loads[layer]
        buf.append(load)
        if len(buf) > self.window:
            buf.pop(0)
        if device_matrix is not None:
            mbuf = self._matrices[layer]
            mbuf.append(np.asarray(device_matrix, dtype=np.float64))
            if len(mbuf) > self.window:
                mbuf.pop(0)

    def advance(self) -> None:
        self.step += 1

    def loads(self, layer: int) -> np.ndarray:
        """``[window, E]`` recent loads for a layer (newest last)."""
        return np.stack(self._loads[layer]) if self._loads[layer] else np.zeros((0, self.num_experts))

    def latest_matrix(self, layer: int) -> np.ndarray | None:
        return self._matrices[layer][-1] if self._matrices[layer] else None

    def layer_pairs(self):
        """Consecutive (prev_layer_loads, next_layer_loads) training pairs."""
        for layer in range(self.num_layers - 1):
            x, y = self.loads(layer), self.loads(layer + 1)
            n = min(len(x), len(y))
            if n:
                yield layer, x[-n:], y[-n:]
