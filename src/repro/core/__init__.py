"""MixNet core: the paper's contribution as composable JAX modules.

Control plane: :mod:`repro.core.traffic` (demand characterization),
:mod:`repro.core.copilot` (COPILOT prediction), :mod:`repro.core.topology`
(Algorithm 1), :mod:`repro.core.placement` (TPU-native expert re-placement),
:mod:`repro.core.controlplane` (the unified observe/plan/apply engine +
failure handling, shared by the trainer and the simulator).

Data plane: :mod:`repro.core.commruntime` (the shared CommSpec/CollectiveOp
runtime — hierarchical a2a, all-reduce, all-gather, with the byte/cost model
the simulator prices; :mod:`repro.core.collectives` is a deprecated shim).

Evaluation plane: :mod:`repro.core.fabric`, :mod:`repro.core.netsim`,
:mod:`repro.core.cost` (the paper's §7 simulations).
"""

from repro.core import (
    commruntime,
    controlplane,
    copilot,
    cost,
    fabric,
    netsim,
    overlap,
    placement,
    reconfig,
    topology,
    traffic,
)

__all__ = [
    "collectives", "commruntime", "controlplane", "copilot", "cost", "fabric",
    "netsim", "overlap", "placement", "reconfig", "topology", "traffic",
]


def __getattr__(name):
    if name == "collectives":
        # Imported lazily so `import repro.core` does not fire the shim's
        # DeprecationWarning — only actual shim users see it.
        from repro.core import collectives

        return collectives
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
