"""MixNet core: the paper's contribution as composable JAX modules.

Control plane: :mod:`repro.core.traffic` (demand characterization),
:mod:`repro.core.copilot` (COPILOT prediction), :mod:`repro.core.topology`
(Algorithm 1), :mod:`repro.core.placement` (TPU-native expert re-placement),
:mod:`repro.core.controlplane` (the unified observe/plan/apply engine +
failure handling, shared by the trainer and the simulator).

Data plane: :mod:`repro.core.commruntime` (the shared CommSpec/CollectiveOp
runtime — hierarchical a2a, all-reduce, all-gather, with the byte/cost model
the simulator prices).

Evaluation plane: :mod:`repro.core.fabric`, :mod:`repro.core.netsim`,
:mod:`repro.core.cost` (the paper's §7 simulations).
"""

from repro.core import (
    commruntime,
    controlplane,
    copilot,
    cost,
    fabric,
    netsim,
    overlap,
    placement,
    reconfig,
    topology,
    traffic,
)

__all__ = [
    "commruntime", "controlplane", "copilot", "cost", "fabric",
    "netsim", "overlap", "placement", "reconfig", "topology", "traffic",
]
