"""Back-compat shim — the runtime reconfiguration engine moved.

The old ``ReconfigController`` (trainer-only, one global permutation tiled
across layers) and the standalone ``FailureHandler`` were unified into
:mod:`repro.core.controlplane`: one engine with the explicit
``observe -> end_step -> plan -> apply`` lifecycle drives per-layer
decisions for both the trainer (expert placement) and the simulator (OCS
cross-maps), with failure handling folded into the same decide/apply path.

Import from :mod:`repro.core.controlplane` in new code.
"""

from __future__ import annotations

from repro.core.controlplane import ControlPlane, FailureHandler, LayerPlan

__all__ = ["ControlPlane", "FailureHandler", "LayerPlan"]
