"""Runtime reconfiguration engine + failure handling (paper §5.2, §5.4).

`ReconfigController` is the decentralized per-region topology controller: it
consumes the traffic monitor, fits COPILOT, runs the placement solver (the
TPU analogue of pushing a new OCS cross-map) and tells the trainer when a new
expert placement is worth the blocking cost — the same hide-or-block decision
the paper makes for the 25 ms OCS delay.

`FailureHandler` implements §5.4 at the framework level: failed devices are
excluded from the placement candidate set, their experts re-homed to backup
slots, and the topology regenerated regionally (no global controller).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.copilot import CopilotPredictor
from repro.core.placement import PlacementPlan, solve_expert_placement
from repro.core.traffic import TrafficMonitor

__all__ = ["ReconfigDecision", "ReconfigController", "FailureHandler"]


@dataclasses.dataclass
class ReconfigDecision:
    reconfigure: bool
    plan: PlacementPlan | None
    predicted_gain_bytes: float
    reason: str


class ReconfigController:
    """One controller per reconfigurable region (per EP group)."""

    def __init__(
        self,
        num_layers: int,
        num_experts: int,
        experts_per_device: int,
        *,
        window: int = 8,
        reconfig_cost_bytes: float = 0.0,
        min_gain_fraction: float = 0.05,
        use_copilot: bool = True,
    ):
        self.monitor = TrafficMonitor(num_layers, num_experts, window=window)
        self.copilot = (
            CopilotPredictor(num_layers, num_experts) if use_copilot and num_layers > 1 else None
        )
        self.experts_per_device = experts_per_device
        self.reconfig_cost_bytes = reconfig_cost_bytes
        self.min_gain_fraction = min_gain_fraction
        self.current_perm = np.arange(num_experts)
        self.reconfig_count = 0

    # -- data collection (called from the training loop every step) ---------
    def observe(self, layer: int, expert_load, device_matrix=None) -> None:
        self.monitor.record(layer, expert_load, device_matrix)

    def end_step(self) -> None:
        self.monitor.advance()
        if self.copilot is not None:
            self.copilot.update(self.monitor)

    # -- placement decision ---------------------------------------------------
    def decide(self, token_demand: np.ndarray) -> ReconfigDecision:
        """Given ``[D, E]`` demand (bytes device->expert), decide re-placement.

        Mirrors §5.1's hide-or-block reasoning: only reconfigure when the
        predicted byte savings beat the permutation's own traffic cost plus a
        hysteresis margin.
        """
        plan = solve_expert_placement(token_demand, self.experts_per_device)
        gain = plan.gain
        threshold = self.min_gain_fraction * max(plan.cost_before, 1e-9)
        if gain <= max(threshold, 0.0) or gain <= self.reconfig_cost_bytes:
            return ReconfigDecision(False, None, gain, "gain below reconfig cost")
        self.current_perm = plan.perm.copy()
        self.reconfig_count += 1
        return ReconfigDecision(True, plan, gain, "bottleneck relief")

    def predicted_demand(self, layer: int, observed_load: np.ndarray) -> np.ndarray | None:
        """COPILOT forecast for the next layer's load (§B.1), or None."""
        if self.copilot is None or layer >= self.copilot.num_layers - 1:
            return None
        return self.copilot.predict(layer, observed_load)


class FailureHandler:
    """§5.4 failure handling at the placement level.

    Devices are slots on the ``model`` axis.  A failed device's experts are
    re-homed onto the designated backup device (single-GPU failure) or spread
    over survivors (full-node failure), producing a new expert permutation
    that the runtime applies exactly like a routine reconfiguration.
    """

    def __init__(self, num_experts: int, num_devices: int):
        if num_experts % num_devices != 0:
            raise ValueError("experts must divide devices for slot bookkeeping")
        self.num_experts = num_experts
        self.num_devices = num_devices
        self.experts_per_device = num_experts // num_devices
        self.failed: set[int] = set()

    def fail_device(self, device: int) -> None:
        if device < 0 or device >= self.num_devices:
            raise ValueError("bad device id")
        self.failed.add(device)
        if len(self.failed) >= self.num_devices:
            raise RuntimeError("all devices failed — unrecoverable")

    def restore_device(self, device: int) -> None:
        self.failed.discard(device)

    def healthy_devices(self) -> list[int]:
        return [d for d in range(self.num_devices) if d not in self.failed]

    def remap(self) -> np.ndarray:
        """Expert -> slot permutation avoiding failed devices.

        Experts originally on failed devices round-robin onto healthy ones;
        healthy experts keep their slots where possible (minimal movement,
        'minor topology adjustments' per §5.4).
        """
        epd = self.experts_per_device
        healthy = self.healthy_devices()
        if not healthy:
            raise RuntimeError("no healthy devices")
        slots = np.full(self.num_experts, -1, dtype=np.int64)
        # Keep healthy experts in place.
        for e in range(self.num_experts):
            dev = e // epd
            if dev not in self.failed:
                slots[e] = e
        # Re-home the rest onto healthy devices' overflow slots (experts
        # per healthy device grows — capacity is elastic in the MoE layer).
        overflow = {d: 0 for d in healthy}
        cursor = 0
        for e in range(self.num_experts):
            if slots[e] >= 0:
                continue
            dev = healthy[cursor % len(healthy)]
            cursor += 1
            # Overflow slots live past the nominal range; the MoE layer's
            # capacity map translates slot -> (device, local_index).
            slots[e] = self.num_experts + dev * epd + overflow[dev]
            overflow[dev] += 1
        return slots

    def device_of_slot(self, slot: int) -> int:
        if slot < self.num_experts:
            return slot // self.experts_per_device
        return (slot - self.num_experts) // self.experts_per_device
