"""CommRuntime: ONE topology-aware collective API shared by the trainer, the
flow-level simulator, and the control plane.

The paper's prototype rests on a "customized collective communication
runtime" that routes EP all-to-all through the regionally reconfigurable OCS
domain.  This module is that runtime's repo-level analogue: a declarative
:class:`CommSpec` (mesh axes + region/group factorization + runtime wire
permutations) and a family of :class:`CollectiveOp` objects, each carrying

  (a) an **executable lowering** — the ``shard_map`` program a TPU mesh runs
      (flat, hierarchical/delegation, or ring, selected per spec),
  (b) an **analytic cost function** — bytes per link class
      (:class:`LinkBytes`) and phase latency priced against a
      :class:`repro.core.fabric.Fabric`'s link rates, which
      :func:`repro.core.netsim.simulate_iteration` consumes instead of
      private formulas, and
  (c) a **reconfiguration hook** — ``op.reconfigure(dest_perm, src_perm)``
      re-addresses wire chunks after a ControlPlane plan without any caller
      changing (the analogue of pushing a new cross-map to the OCS; the same
      permutation also re-routes the op's demand matrix in the cost model).

The delegation structure (paper §5.3): intra-host gather over NVSwitch ->
inter-host transfer on the OCS circuits -> intra-host all-to-all -> scatter.
On a TPU mesh the same structure is a *two-stage factored all-to-all* over
the regional axis: the axis of size P is treated as a (G groups x H
per-group) grid; stage 1 exchanges within a group (the scale-up analogue),
stage 2 across groups (the scale-out analogue).  The composition is
bit-identical to the flat ``lax.all_to_all`` (tested), but each stage's
transfer only crosses one hierarchy level — which is what lets the compiler
schedule them on different link classes and overlap them.

DP gradients use the paper's hierarchical all-reduce (§5.3): reduce-scatter
inside the region, all-reduce across regions on the gateway shard,
all-gather back.

This module is the only home of the collective lowerings (the historical
``repro.core.collectives`` shim has been removed); build :class:`CommSpec`
+ ops (DESIGN.md §7).
"""

from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.obs import metrics

__all__ = [
    "CommSpec",
    "LinkBytes",
    "record_link_bytes",
    "CollectiveOp",
    "AllToAll",
    "AllReduce",
    "AllGather",
    "ReduceScatter",
    "Permute",
    "AllToAllStage",
    "ep_alltoall_bytes",
    "dp_gradient_bytes",
    "device_perm_from_slots",
    "fuse_pack",
    "fuse_unpack",
    # functional lowerings (the shard_map programs the ops execute)
    "flat_all_to_all",
    "hierarchical_all_to_all",
    "mixnet_all_to_all",
    "hierarchical_psum",
    "ring_all_gather",
    "ring_reduce_scatter",
]


# ---------------------------------------------------------------------------
# functional lowerings (the shard_map programs the ops execute)
# ---------------------------------------------------------------------------


def _axis_size(axis_name: str) -> int:
    """Static mesh-axis size; ``lax.psum(1, axis)`` constant-folds on jax
    releases predating ``lax.axis_size``."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def _grid_groups(p: int, group_size: int) -> tuple[list[list[int]], list[list[int]]]:
    if p % group_size != 0:
        raise ValueError(f"axis size {p} not divisible by group size {group_size}")
    g = p // group_size
    intra = [[gg * group_size + h for h in range(group_size)] for gg in range(g)]
    inter = [[gg * group_size + h for gg in range(g)] for h in range(group_size)]
    return intra, inter


def flat_all_to_all(x: jax.Array, axis_name: str) -> jax.Array:
    """Baseline single-shot all-to-all. ``x``: [P, ...] chunks by destination."""
    return lax.all_to_all(x, axis_name, split_axis=0, concat_axis=0)


def _a2a_scale_up(x: jax.Array, axis_name: str, group_size: int) -> jax.Array:
    """Delegation stage 1 — intra-group exchange (the scale-up/NVSwitch
    analogue): split/concat the h-chunk dim.  ``[P, ...]`` in and out."""
    p = _axis_size(axis_name)
    h = group_size
    g = p // h
    intra, _ = _grid_groups(p, h)
    xr = x.reshape(g, h, *x.shape[1:])
    z = lax.all_to_all(xr, axis_name, split_axis=1, concat_axis=1, axis_index_groups=intra)
    return z.reshape(x.shape)


def _a2a_scale_out(x: jax.Array, axis_name: str, group_size: int) -> jax.Array:
    """Delegation stage 2 — inter-group exchange (the scale-out/OCS
    analogue): split/concat the g-chunk dim.  ``[P, ...]`` in and out."""
    p = _axis_size(axis_name)
    h = group_size
    g = p // h
    _, inter = _grid_groups(p, h)
    xr = x.reshape(g, h, *x.shape[1:])
    w = lax.all_to_all(xr, axis_name, split_axis=0, concat_axis=0, axis_index_groups=inter)
    return w.reshape(x.shape)


def hierarchical_all_to_all(
    x: jax.Array, axis_name: str, group_size: int
) -> jax.Array:
    """Two-stage (delegation) all-to-all over a factored axis.

    Args:
      x: ``[P, ...]`` local chunks ordered by destination device on
        ``axis_name`` (device index = g * group_size + h).
      axis_name: mesh axis of size P = G * group_size.
      group_size: size of the scale-up (intra-host analogue) stage H.

    Returns:
      ``[P, ...]`` chunks ordered by source device — identical to
      :func:`flat_all_to_all`.  The two halves are exposed separately
      through :meth:`AllToAll.stages` so the overlap scheduler can run
      another chunk's compute between them.
    """
    p = _axis_size(axis_name)
    h = group_size
    if p == 1 or h == 1 or h >= p:
        return flat_all_to_all(x, axis_name)
    return _a2a_scale_out(
        _a2a_scale_up(x, axis_name, h), axis_name, h
    )


def mixnet_all_to_all(
    x: jax.Array,
    axis_name: str,
    group_size: int,
    *,
    dest_perm: jax.Array | None = None,
    src_perm: jax.Array | None = None,
) -> jax.Array:
    """Hierarchical all-to-all with an expert-placement permutation.

    ``dest_perm`` re-addresses outgoing chunks (the chunk physically sent to
    device ``k`` is the one logically addressed to ``dest_perm[k]``);
    ``src_perm`` restores the logical ordering of received chunks.  This is
    how a runtime-reconfigured placement is realized on the wire without
    touching the collective itself — the analogue of pushing a new cross-map
    to the OCS.
    """
    if dest_perm is not None:
        x = x[dest_perm]
    y = hierarchical_all_to_all(x, axis_name, group_size)
    if src_perm is not None:
        y = y[src_perm]
    return y


def hierarchical_psum(
    x: jax.Array,
    inner_axis: str,
    outer_axis: str | None = None,
    *,
    scatter_dim: int = 0,
) -> jax.Array:
    """Paper §5.3 hierarchical all-reduce.

    reduce-scatter over ``inner_axis`` (intra-host reduction to the gateway
    shard) -> all-reduce over ``outer_axis`` (the global ring over EPS) ->
    all-gather over ``inner_axis`` (broadcast back).  Cross-region bytes drop
    by a factor of the inner axis size versus a flat all-reduce.  Scalars and
    shapes the inner axis does not divide fall back to the flat psum.
    """
    inner = _axis_size(inner_axis)
    if inner == 1 or x.ndim == 0 or x.shape[scatter_dim] % inner != 0:
        y = lax.psum(x, inner_axis)
        return lax.psum(y, outer_axis) if outer_axis else y
    part = lax.psum_scatter(x, inner_axis, scatter_dimension=scatter_dim, tiled=True)
    if outer_axis is not None:
        part = lax.psum(part, outer_axis)
    return lax.all_gather(part, inner_axis, axis=scatter_dim, tiled=True)


def ring_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """Explicit ring all-gather via collective_permute (comm/compute overlap
    building block for the perf path; semantically = lax.all_gather(tiled))."""
    p = _axis_size(axis_name)
    if p == 1:
        return x
    idx = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % p) for i in range(p)]

    def body(carry, _):
        block, rot = carry
        nxt = lax.ppermute(block, axis_name, perm)
        return (nxt, rot - 1), nxt

    (_, _), rest = lax.scan(body, (x, p - 1), None, length=p - 1)
    # rest[k] came from device (idx - 1 - k); roll into ascending device order.
    all_blocks = jnp.concatenate([x[None], rest], axis=0)  # [P, ...] by hop
    src = (idx - jnp.arange(p)) % p
    order = jnp.argsort(src)
    return all_blocks[order].reshape(p * x.shape[0], *x.shape[1:])


def ring_reduce_scatter(
    x: jax.Array, axis_name: str, *, scatter_dim: int = 0
) -> jax.Array:
    """Explicit ring reduce-scatter via collective_permute stepping.

    The partial destined for device ``d`` starts at ``d+1`` and rides the
    ring for P-1 hops, each holder adding its own chunk — the overlap
    building block (one :class:`Permute` hop per step interleaves with
    compute).  Numerically a sum of the same terms as
    ``lax.psum_scatter(tiled=True)`` in ring order (f32 summation order
    differs from XLA's tree, so equality is allclose, exact for ints).
    """
    p = _axis_size(axis_name)
    if p == 1:
        return x
    idx = lax.axis_index(axis_name)
    xm = jnp.moveaxis(x, scatter_dim, 0)
    if xm.shape[0] % p != 0:
        raise ValueError(
            f"dim {scatter_dim} ({x.shape[scatter_dim]}) not divisible by "
            f"axis size {p}"
        )
    chunks = xm.reshape(p, xm.shape[0] // p, *xm.shape[1:])
    perm = [(i, (i + 1) % p) for i in range(p)]
    # After t additions the partial this device holds is destined for
    # device (idx - t); take(t) is the local chunk for that destination.
    acc = chunks[(idx - 1) % p]
    for t in range(1, p):
        acc = lax.ppermute(acc, axis_name, perm) + chunks[(idx - 1 - t) % p]
    return jnp.moveaxis(acc, 0, scatter_dim) if scatter_dim else acc


# ---------------------------------------------------------------------------
# CommSpec — the declarative half of the runtime
# ---------------------------------------------------------------------------


def _as_tuple(perm) -> tuple[int, ...] | None:
    if perm is None:
        return None
    return tuple(int(i) for i in np.asarray(perm).tolist())


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Where a collective runs and how its axis factors into regions/groups.

    ``axis`` is the regional mesh axis the lowering runs over (``None`` for
    single-device or cost-only specs — the simulator prices transfers without
    a mesh).  ``axis_size = num_groups * group_size``: ``group_size`` is the
    scale-up stage width (the intra-host/NVSwitch analogue), groups exchange
    over the scale-out (OCS) stage.  ``outer_axis/outer_size`` name the
    cross-region domain hierarchical reductions ring over (the EPS fabric).

    ``dest_perm``/``src_perm`` are the runtime wire re-addressing state the
    ControlPlane installs: static tuples (hashable — specs can be jit
    constants) produced by :meth:`reconfigure`.
    """

    axis: str | None = None
    axis_size: int = 1
    group_size: int = 1
    outer_axis: str | None = None
    outer_size: int = 1
    dest_perm: tuple[int, ...] | None = None
    src_perm: tuple[int, ...] | None = None

    def __post_init__(self):
        if self.axis_size < 1 or self.group_size < 1 or self.outer_size < 1:
            raise ValueError(f"bad CommSpec sizes: {self}")
        if self.hierarchical and self.axis_size % self.group_size != 0:
            raise ValueError(
                f"axis size {self.axis_size} not divisible by group size "
                f"{self.group_size}"
            )
        for perm in (self.dest_perm, self.src_perm):
            if perm is not None and sorted(perm) != list(range(len(perm))):
                raise ValueError(f"not a permutation: {perm}")

    # -- factorization ------------------------------------------------------
    @property
    def hierarchical(self) -> bool:
        """True when the lowering runs the two-stage delegation grid."""
        return 1 < self.group_size < self.axis_size

    @property
    def num_groups(self) -> int:
        return self.axis_size // self.group_size if self.hierarchical else 1

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_plan(cls, plan, *, group_size: int = 1) -> "CommSpec":
        """Spec for the trainer's regional (``model``) axis from a
        :class:`repro.parallel.sharding.ShardingPlan`.

        A ``group_size`` spanning the whole axis degrades to the flat
        lowering (a one-group hierarchy IS flat); a group that does not
        divide the axis is a misconfiguration and raises (via the spec
        validator), exactly like the pre-runtime ``_grid_groups`` did."""
        if plan.model_axis is None or plan.model_size <= 1:
            return cls(axis=None, axis_size=1)
        g = 1 if group_size >= plan.model_size else group_size
        return cls(
            axis=plan.model_axis,
            axis_size=plan.model_size,
            group_size=g,
        )

    @classmethod
    def for_grad_reduce(cls, plan, mesh) -> "CommSpec":
        """Spec for DP gradient reduction over the plan's batch axes:
        innermost batch axis = the region (reduce-scatter stage), outer batch
        axis = the cross-region ring."""
        axes = plan.batch_axes
        if mesh is None or not axes:
            return cls(axis=None, axis_size=1)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        inner = axes[-1]
        outer = axes[0] if len(axes) > 1 else None
        return cls(
            axis=inner,
            axis_size=sizes[inner],
            group_size=sizes[inner],
            outer_axis=outer,
            outer_size=sizes[outer] if outer else 1,
        )

    @classmethod
    def from_fabric(
        cls, fabric, num_servers_region: int | None = None
    ) -> "CommSpec":
        """Cost-only spec whose region/group factorization comes from the
        fabric topology: groups = servers of the OCS region, group width =
        the intra-server scale-up domain (NVSwitch)."""
        cfg = fabric.cfg
        region = num_servers_region or cfg.num_servers
        gps = max(cfg.gpus_per_server, 1)
        return cls(
            axis=None,
            axis_size=region * gps,
            group_size=gps,
            outer_size=max(cfg.num_servers, 1),
        )

    # -- reconfiguration hook ----------------------------------------------
    def reconfigure(self, dest_perm=None, src_perm=None) -> "CommSpec":
        """New spec with updated wire re-addressing (a ControlPlane plan
        lands here; pass ``None`` to clear a side)."""
        return dataclasses.replace(
            self, dest_perm=_as_tuple(dest_perm), src_perm=_as_tuple(src_perm)
        )


# ---------------------------------------------------------------------------
# bytes-on-link accounting
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LinkBytes:
    """Per-device wire bytes of one collective phase, split by link class.

    ``scale_up``: intra-group traffic (NVSwitch / the delegation's stage 1).
    ``scale_out``: inter-group regional traffic (the OCS circuits / stage 2).
    ``cross_region``: global traffic (the EPS fabric — DP ring, PP hops).
    """

    scale_up: float = 0.0
    scale_out: float = 0.0
    cross_region: float = 0.0

    @property
    def total(self) -> float:
        return self.scale_up + self.scale_out + self.cross_region


_LB_COUNTERS: dict[tuple[str, str], metrics.Counter] = {}
_LB_GENERATION = -1


def record_link_bytes(op: str, lb: LinkBytes) -> None:
    """Fold one priced phase's wire bytes into the process metrics registry
    as ``comm.link_bytes{link=...,op=...}`` (DESIGN.md §14).

    Called from every op's ``cost`` — each priced phase is one wire phase in
    the simulated/accounted timeline.  Children are cached per (op, link)
    tuple so the inner netsim loops pay one dict hit + one float add; the
    cache is invalidated when the registry is reset (its generation bumps)."""
    global _LB_GENERATION
    reg = metrics.default()
    if reg.generation != _LB_GENERATION:
        _LB_GENERATION = reg.generation
        _LB_COUNTERS.clear()
    for link, v in (
        ("scale_up", lb.scale_up),
        ("scale_out", lb.scale_out),
        ("cross_region", lb.cross_region),
    ):
        if v:
            c = _LB_COUNTERS.get((op, link))
            if c is None:
                c = _LB_COUNTERS[(op, link)] = reg.counter(
                    "comm.link_bytes", op=op, link=link
                )
            c.inc(v)


def ep_alltoall_bytes(
    tokens: int, top_k: int, d_model: int, dtype_bytes: int
) -> float:
    """Payload bytes of ONE EP all-to-all phase (whole EP group): every routed
    token copy carries its d_model activation row."""
    return float(tokens) * top_k * d_model * dtype_bytes


def dp_gradient_bytes(
    param_count: float,
    gpus_per_replica: int,
    gpus_per_server: int,
    dtype_bytes: int,
) -> float:
    """Gradient bytes one server contributes to the DP ring: each GPU holds
    params / (gpus per model replica); a server aggregates its GPUs' shards
    through the gateway (hierarchical all-reduce, §5.3)."""
    per_gpu = float(param_count) / max(gpus_per_replica, 1)
    return per_gpu * gpus_per_server * dtype_bytes


def device_perm_from_slots(
    slot_perm: np.ndarray, slots_per_device: int
) -> np.ndarray | None:
    """Collapse an expert-slot permutation to a device-level wire permutation.

    A ControlPlane placement plan permutes virtual expert slots; when the
    permutation moves whole device blocks, the wire chunks themselves can be
    re-addressed (``CommSpec.reconfigure``).  Returns ``None`` when slots
    cross device boundaries — those plans are realized by the router-side
    re-addressing instead, and the wire layout stays put.
    """
    slot_perm = np.asarray(slot_perm)
    if slot_perm.size % slots_per_device != 0:
        return None
    blocks = slot_perm.reshape(-1, slots_per_device)
    devs = blocks // slots_per_device
    if not (devs == devs[:, :1]).all():
        return None  # a device's slots scatter across devices
    within = blocks % slots_per_device
    if not (within == np.arange(slots_per_device)[None, :]).all():
        return None  # reordered within the block: not a pure device move
    return devs[:, 0].astype(np.int64)


# ---------------------------------------------------------------------------
# CollectiveOp protocol + ops
# ---------------------------------------------------------------------------


@runtime_checkable
class CollectiveOp(Protocol):
    """What every runtime collective carries (DESIGN.md §7).

    ``__call__``   — the executable shard_map lowering (per-device view).
    ``bytes_on_link`` — analytic per-device wire bytes by link class.
    ``cost``       — phase latency priced against a Fabric's link rates
                     (the function netsim consumes).
    ``reconfigure`` — install a ControlPlane plan's wire re-addressing.
    """

    spec: CommSpec

    def __call__(self, x, **kwargs): ...

    def bytes_on_link(self, nbytes: float) -> LinkBytes: ...

    def cost(self, fabric, *args, **kwargs) -> float: ...

    def reconfigure(self, dest_perm=None, src_perm=None) -> "CollectiveOp": ...


@dataclasses.dataclass(frozen=True)
class _OpBase:
    spec: CommSpec

    def reconfigure(self, dest_perm=None, src_perm=None):
        """Reconfiguration hook: same op, re-addressed wire chunks."""
        return dataclasses.replace(
            self, spec=self.spec.reconfigure(dest_perm, src_perm)
        )

    def _perms(self, dest_perm, src_perm):
        if dest_perm is None and self.spec.dest_perm is not None:
            dest_perm = jnp.asarray(self.spec.dest_perm)
        if src_perm is None and self.spec.src_perm is not None:
            src_perm = jnp.asarray(self.spec.src_perm)
        return dest_perm, src_perm


def _ids_to_lanes(ids: jax.Array, dtype) -> jax.Array:
    """Encode int32 metadata into exact small-integer lanes of the payload
    dtype.  Deliberately numeric, NOT a bitcast: arbitrary id bit patterns
    form float NaNs (e.g. the -1 sentinel -> 0xFFFF) which XLA backends may
    canonicalize in transit.  Byte-sized lanes are exact in every >=8-bit
    significand float.  Ids must lie in [-1, 2**16 - 2] for 16-bit payload
    dtypes ([-1, 2**24 - 2] for 32-bit)."""
    dtype = jnp.dtype(dtype)
    enc = ids + 1  # shift the -1 sentinel into the unsigned range
    if dtype.itemsize == 4:
        return enc.astype(dtype)[..., None]
    lo = (enc & 0xFF).astype(dtype)
    hi = ((enc >> 8) & 0xFF).astype(dtype)
    return jnp.stack([lo, hi], axis=-1)


def _lanes_to_ids(lanes: jax.Array, dtype) -> jax.Array:
    dtype = jnp.dtype(dtype)
    if dtype.itemsize == 4:
        return lanes[..., 0].astype(jnp.int32) - 1
    lo = lanes[..., 0].astype(jnp.int32)
    hi = lanes[..., 1].astype(jnp.int32)
    return lo + (hi << 8) - 1


def fuse_pack(payload: jax.Array, ids: jax.Array) -> jax.Array | None:
    """Pack int32 metadata into trailing exact lanes of ``payload``'s dtype
    (one wire tensor for a staged transfer).  Returns ``None`` when the
    payload dtype has no exact lane encoding (itemsize not 2/4) — callers
    fall back to the unfused pair."""
    if jnp.dtype(payload.dtype).itemsize not in (2, 4):
        return None
    lanes = lax.stop_gradient(_ids_to_lanes(ids, payload.dtype))
    return jnp.concatenate([payload, lanes], axis=-1)


def fuse_unpack(packed: jax.Array, d: int) -> tuple[jax.Array, jax.Array]:
    """Inverse of :func:`fuse_pack`: split a packed wire tensor back into
    (payload ``[..., d]``, int32 ids)."""
    return packed[..., :d], _lanes_to_ids(packed[..., d:], packed.dtype)


@dataclasses.dataclass(frozen=True)
class AllToAll(_OpBase):
    """EP all-to-all: flat or hierarchical/delegation per the spec.

    Lowering: ``x`` is the per-device ``[P, ...]`` send layout, chunks
    ordered by destination; returns ``[P, ...]`` ordered by source.  The
    spec's wire perms (or per-call overrides, for traced runtime values)
    re-address chunks exactly like an OCS cross-map push.

    ``lowering`` selects the *priced* wire schedule (the analytic side the
    netsim and the autotuner search over — DESIGN.md §13):

    * ``"hier"``  — the delegation lowering (default): per-server
      aggregation amortizes per-message overheads, the server-level demand
      matrix is what the fabric schedules.  This is the lowering
      ``__call__`` executes and the historical ``cost``.
    * ``"flat"``  — no in-server delegation: the same bytes cross the
      scale-out fabric but as ``group_size``x more (and smaller)
      per-GPU messages, so every remote destination pays the per-message
      propagation latency the delegation would have amortized.
    * ``"ring"``  — store-and-forward ring: R-1 sequential neighbor hops,
      each carrying the residual full payload over one p2p link.  Only
      competitive when the payload is tiny and latency dominates; the
      autotuner is expected to reject it at training payloads.
    """

    lowering: str = "hier"

    def __post_init__(self):
        if self.lowering not in ("hier", "flat", "ring"):
            raise ValueError(
                f"unknown a2a lowering {self.lowering!r}; "
                "expected 'hier', 'flat', or 'ring'")

    def __call__(self, x, *, dest_perm=None, src_perm=None):
        dest_perm, src_perm = self._perms(dest_perm, src_perm)
        if self.spec.axis is None or self.spec.axis_size <= 1:
            if dest_perm is not None:
                x = x[dest_perm]
            if src_perm is not None:
                x = x[src_perm]
            return x
        return mixnet_all_to_all(
            x, self.spec.axis, self.spec.group_size,
            dest_perm=dest_perm, src_perm=src_perm,
        )

    def fused(self, payload, ids, *, dest_perm=None, src_perm=None):
        """ONE packed wire transfer for a payload + its int32 metadata.

        ``payload``: ``[P, C, D]`` activations; ``ids``: ``[P, C]`` int32
        (e.g. destination-expert ids riding the same a2a; range per
        :func:`_ids_to_lanes`).  The metadata travels as exact trailing
        payload-dtype lanes, so the payload bytes move bit-identically to
        the unfused pair of transfers (tested) while the wire sees a single
        phase.  Metadata lanes carry no gradient.
        """
        packed = fuse_pack(payload, ids)
        if packed is None:
            return (
                self(payload, dest_perm=dest_perm, src_perm=src_perm),
                self(ids[..., None], dest_perm=dest_perm, src_perm=src_perm)[..., 0],
            )
        out = self(packed, dest_perm=dest_perm, src_perm=src_perm)
        return fuse_unpack(out, payload.shape[-1])

    # -- staged execution (the overlap scheduler's surface) ------------------
    def stages(self) -> tuple["AllToAllStage", ...]:
        """The lowering's wire phases as separately-callable stages.

        Hierarchical/delegation specs expose the scale-up and scale-out
        halves (stage 0 applies the spec's ``dest_perm``, the last stage the
        ``src_perm``); flat/degenerate specs expose one stage that IS the
        whole op.  Composing the stages in order is bit-identical to
        ``__call__`` — the split exists so the overlap engine
        (:mod:`repro.core.overlap`) can run another chunk's compute between
        a chunk's phases.  Each stage carries its own ``bytes_on_link``;
        the stage byte totals sum to the op's.
        """
        if self.spec.hierarchical:
            return (AllToAllStage(self, 0, 2), AllToAllStage(self, 1, 2))
        return (AllToAllStage(self, 0, 1),)

    # -- analytic side ------------------------------------------------------
    def bytes_on_link(self, nbytes: float) -> LinkBytes:
        """Wire bytes for ``nbytes`` of per-device send payload."""
        p = self.spec.axis_size
        if p <= 1:
            return LinkBytes()
        if not self.spec.hierarchical:
            return LinkBytes(scale_out=nbytes * (p - 1) / p)
        h = self.spec.group_size
        g = self.spec.num_groups
        return LinkBytes(
            scale_up=nbytes * (h - 1) / h,      # stage 1: intra-group
            scale_out=nbytes * (g - 1) / g,     # stage 2: across groups
        )

    def route_demand(self, demand: np.ndarray) -> np.ndarray:
        """Physical inter-server demand after the spec's wire re-addressing:
        the chunk logically bound for ``j`` lands on ``dest_perm``'s image —
        the cost-model half of the reconfiguration hook (``src_perm`` is a
        local reorder after receipt; it moves no wire bytes)."""
        if self.spec.dest_perm is None:
            return demand
        demand = np.asarray(demand)
        perm = np.asarray(self.spec.dest_perm)
        if perm.shape[0] != demand.shape[1]:
            raise ValueError(
                f"dest_perm length {perm.shape[0]} != demand dim {demand.shape[1]}"
            )
        return demand[:, perm]

    def cost(self, fabric, demand: np.ndarray) -> float:
        """Completion seconds of one a2a phase with ``demand`` bytes between
        servers, priced on ``fabric``'s link rates under this op's
        ``lowering`` (see the class docstring)."""
        demand = np.asarray(self.route_demand(demand))
        r = demand.shape[0]
        # Wire bytes this phase moves between servers (the diagonal stays
        # local) — the per-op ledger of DESIGN.md §14.
        offdiag = float(demand.sum())
        if demand.ndim == 2 and demand.shape[0] == demand.shape[1]:
            offdiag -= float(np.trace(demand))
        record_link_bytes("a2a", LinkBytes(scale_out=max(offdiag, 0.0)))
        if self.lowering == "ring" and r > 1:
            per_hop = float(
                max(demand.sum(axis=1).max(), demand.sum(axis=0).max())
            )
            return (r - 1) * fabric.p2p_time(per_hop)
        base = fabric.alltoall_time(demand)
        if self.lowering == "flat" and r > 1:
            # Same wire bytes, group_size x more messages: each GPU pays the
            # per-message latency for every remote server it talks to.
            msgs = max(self.spec.group_size, 1) * (r - 1)
            return base + msgs * fabric.cfg.propagation_delay_s
        return base


@dataclasses.dataclass(frozen=True)
class AllToAllStage:
    """One wire phase of an :class:`AllToAll` lowering (see
    :meth:`AllToAll.stages`).

    ``index``/``count`` identify the phase: for a 2-stage delegation spec,
    stage 0 is the scale-up exchange (and applies ``dest_perm``), stage 1
    the scale-out exchange (and applies ``src_perm``).  A 1-stage tuple's
    only member runs the whole op.  Inputs and outputs keep the op's
    ``[P, ...]`` layout so stages chain without reshapes.
    """

    op: AllToAll
    index: int
    count: int

    @property
    def link_class(self) -> str:
        """Which LinkBytes class this stage's traffic rides."""
        if self.count == 2 and self.index == 0:
            return "scale_up"
        return "scale_out"

    def __call__(self, x, *, dest_perm=None, src_perm=None):
        s = self.op.spec
        if self.count == 1:
            return self.op(x, dest_perm=dest_perm, src_perm=src_perm)
        dperm, sperm = self.op._perms(dest_perm, src_perm)
        if self.index == 0:
            if dperm is not None:
                x = x[dperm]
            if s.axis is None:  # cost-only spec: no wire to exchange on
                return x
            return _a2a_scale_up(x, s.axis, s.group_size)
        y = x if s.axis is None else _a2a_scale_out(x, s.axis, s.group_size)
        if sperm is not None:
            y = y[sperm]
        return y

    def bytes_on_link(self, nbytes: float) -> LinkBytes:
        """This stage's share of the op's wire bytes — the SAME per-stage
        accounting both the trainer's overlap scheduler and netsim's event
        timeline consume."""
        full = self.op.bytes_on_link(nbytes)
        if self.count == 1:
            return full
        if self.index == 0:
            return LinkBytes(scale_up=full.scale_up)
        return LinkBytes(scale_out=full.scale_out)


@dataclasses.dataclass(frozen=True)
class AllReduce(_OpBase):
    """Hierarchical all-reduce (§5.3): reduce-scatter over the region,
    all-reduce across regions on the gateway shard, all-gather back.

    ``compress=True`` routes the reduction through the int8 codec of
    :mod:`repro.optim.compress` — quantize against a pmax-shared scale, sum
    exactly in int32 through the same reduce-scatter/ring/all-gather stages,
    one shared dequantization — cutting wire bytes by ``dtype_bytes``x
    (error feedback lives with the caller, which holds per-shard residual
    state; see ``repro.train.train_step``).  The matching
    ``compress_ratio`` on :meth:`bytes_on_link`/:meth:`cost` is how netsim
    prices the identical savings.
    """

    def __call__(
        self, x, *, scatter_dim: int = 0, mean: bool = False,
        compress: bool = False,
    ):
        s = self.spec
        if s.axis is None and s.axis_size > 1:
            # A cost-only spec (e.g. netsim's fabric-derived one) prices
            # phases but names no mesh axis to reduce over — executing it
            # would silently return unreduced (and mis-scaled) data.
            raise ValueError(
                "cost-only AllReduce spec (axis=None, axis_size>1) has no "
                "executable lowering"
            )
        if compress:
            from repro.optim.compress import compressed_hierarchical_psum

            y = compressed_hierarchical_psum(
                x, s.axis, s.outer_axis, scatter_dim=scatter_dim
            )
        elif s.axis is None:
            y = lax.psum(x, s.outer_axis) if s.outer_axis else x
        else:
            y = hierarchical_psum(x, s.axis, s.outer_axis, scatter_dim=scatter_dim)
        if mean:
            y = y / float(max(s.axis_size, 1) * max(s.outer_size, 1))
        return y

    def compressed(self, x, *, scatter_dim: int = 0, mean: bool = False):
        """Error-feedback-aware compressed reduction: returns
        ``(reduced, local_decoded)`` where ``local_decoded`` (f32) is this
        shard's own decoded contribution — what the caller's residual
        subtracts (``repro.train.train_step``'s ``dp_compress`` path)."""
        from repro.optim.compress import compressed_hierarchical_psum

        s = self.spec
        if s.axis is None and s.axis_size > 1:
            raise ValueError(
                "cost-only AllReduce spec (axis=None, axis_size>1) has no "
                "executable lowering"
            )
        total, local = compressed_hierarchical_psum(
            x, s.axis, s.outer_axis, scatter_dim=scatter_dim, with_local=True
        )
        if mean:
            total = total / float(max(s.axis_size, 1) * max(s.outer_size, 1))
        return total, local

    def bytes_on_link(
        self, nbytes: float, *, compress_ratio: float = 1.0
    ) -> LinkBytes:
        """Wire bytes for ``nbytes`` of per-device reduction payload.
        ``compress_ratio`` scales the payload for the int8 path (e.g.
        1/dtype_bytes) with the SAME accounting the trainer's compressed
        reduction realizes."""
        nbytes = nbytes * compress_ratio
        i, o = self.spec.axis_size, self.spec.outer_size
        if i <= 1 and o <= 1:
            return LinkBytes()
        if o > 1:
            inner = 2.0 * nbytes * (i - 1) / i if i > 1 else 0.0
            ring = 2.0 * (nbytes / max(i, 1)) * (o - 1) / o
            return LinkBytes(scale_up=inner, cross_region=ring)
        return LinkBytes(cross_region=2.0 * nbytes * (i - 1) / i)

    def cost(
        self, fabric, bytes_per_server: float, num_servers: int | None = None,
        *, compress_ratio: float = 1.0,
    ) -> float:
        n = num_servers or (self.spec.outer_size if self.spec.outer_size > 1 else None)
        record_link_bytes(
            "allreduce",
            self.bytes_on_link(bytes_per_server, compress_ratio=compress_ratio),
        )
        return fabric.allreduce_time(bytes_per_server * compress_ratio, n)


@dataclasses.dataclass(frozen=True)
class AllGather(_OpBase):
    """All-gather over the regional axis; ``impl='ring'`` runs the explicit
    collective_permute ring (the comm/compute-overlap building block),
    ``impl='flat'`` the single-shot ``lax.all_gather``."""

    impl: str = "ring"

    def __call__(self, x, *, axis: int = 0, tiled: bool = True):
        s = self.spec
        if s.axis is None or s.axis_size <= 1:
            return x if tiled else jnp.expand_dims(x, axis)
        if self.impl == "ring" and tiled:
            if axis == 0:
                return ring_all_gather(x, s.axis)
            return jnp.moveaxis(
                ring_all_gather(jnp.moveaxis(x, axis, 0), s.axis), 0, axis
            )
        return lax.all_gather(x, s.axis, axis=axis, tiled=tiled)

    def bytes_on_link(self, nbytes: float) -> LinkBytes:
        """Wire bytes for ``nbytes`` of local shard: the shard transits every
        ring hop once."""
        p = self.spec.axis_size
        return LinkBytes(scale_out=nbytes * max(p - 1, 0))

    def cost(self, fabric, shard_bytes: float) -> float:
        p = self.spec.axis_size
        if p <= 1:
            return 0.0
        record_link_bytes("allgather", self.bytes_on_link(shard_bytes))
        return (p - 1) * fabric.p2p_time(shard_bytes)


@dataclasses.dataclass(frozen=True)
class ReduceScatter(_OpBase):
    """Tiled reduce-scatter over the regional axis (the hierarchical
    all-reduce's first phase, exposed for overlap scheduling).
    ``impl='ring'`` runs the explicit Permute-ring stepping
    (:func:`ring_reduce_scatter` — one collective_permute hop per step, the
    overlap building block); ``impl='flat'`` the single-shot
    ``lax.psum_scatter``.  Ring summation order differs from XLA's tree, so
    cross-impl equality is allclose (exact for integer payloads)."""

    impl: str = "flat"

    def __call__(self, x, *, scatter_dim: int = 0):
        s = self.spec
        if s.axis is None or s.axis_size <= 1:
            return x
        if x.shape[scatter_dim] % s.axis_size != 0:
            raise ValueError(
                f"dim {scatter_dim} ({x.shape[scatter_dim]}) not divisible by "
                f"axis size {s.axis_size}"
            )
        if self.impl == "ring":
            return ring_reduce_scatter(x, s.axis, scatter_dim=scatter_dim)
        return lax.psum_scatter(x, s.axis, scatter_dimension=scatter_dim, tiled=True)

    def bytes_on_link(self, nbytes: float) -> LinkBytes:
        p = self.spec.axis_size
        if p <= 1:
            return LinkBytes()
        return LinkBytes(scale_out=nbytes * (p - 1) / p)

    def cost(self, fabric, nbytes: float) -> float:
        p = self.spec.axis_size
        if p <= 1:
            return 0.0
        record_link_bytes("reducescatter", self.bytes_on_link(nbytes))
        return (p - 1) * fabric.p2p_time(nbytes / p)


@dataclasses.dataclass(frozen=True)
class Permute(_OpBase):
    """Point-to-point wire re-address with the SAME gather semantics as
    :class:`AllToAll`: after the hop, device ``k`` holds the payload of
    device ``perm[k]`` (default: the previous ring neighbour, i.e. a +1 ring
    shift of the blocks).  This is the primitive a ControlPlane plan
    actuates when it relocates whole device payloads (PP hops and
    expert-state migration ride it) — one ``dest_perm`` means one routing
    across the whole op family."""

    def __call__(self, x, *, perm=None):
        s = self.spec
        if s.axis is None or s.axis_size <= 1:
            return x
        if perm is None:
            perm = (
                s.dest_perm
                if s.dest_perm is not None
                else tuple((i - 1) % s.axis_size for i in range(s.axis_size))
            )
        # ppermute pairs are (source, dest): device k receives from perm[k].
        pairs = [(int(srcdev), k) for k, srcdev in enumerate(perm)]
        return lax.ppermute(x, s.axis, pairs)

    def bytes_on_link(self, nbytes: float) -> LinkBytes:
        if self.spec.axis_size <= 1:
            return LinkBytes()
        return LinkBytes(scale_out=nbytes)

    def cost(self, fabric, nbytes: float) -> float:
        if self.spec.axis_size <= 1:
            return 0.0
        record_link_bytes("permute", self.bytes_on_link(nbytes))
        return fabric.p2p_time(nbytes)
