"""Dynamic expert placement — the TPU-native analogue of OCS circuit
allocation (DESIGN.md §2).

On a fixed-topology TPU mesh the reconfigurable degree of freedom is *which
expert lives on which device*.  The same greedy bottleneck logic as
Algorithm 1 drives a permutation of the expert->device assignment so that the
heaviest-communicating experts are co-located or placed on adjacent devices
of the ``model`` axis ring, shrinking the realized all-to-all bytes-on-wire.

The permutation is applied to the *stacked expert weight tensors* by a gather
on the expert axis — a cheap intra-region collective, charged like the
paper charges the 25 ms OCS blocking time.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "PlacementPlan",
    "solve_expert_placement",
    "placement_cost",
    "apply_placement",
    "inverse_permutation",
]


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """``perm[e]`` = new slot of expert ``e``; slots map onto devices
    round-robin (slot // experts_per_device = device)."""

    perm: np.ndarray
    cost_before: float
    cost_after: float

    @property
    def gain(self) -> float:
        return self.cost_before - self.cost_after


def placement_cost(
    token_demand: np.ndarray, perm: np.ndarray, experts_per_device: int
) -> float:
    """Bytes-on-wire of an all-to-all under an expert->slot permutation.

    ``token_demand[s, e]`` = bytes source-device ``s`` sends to expert ``e``.
    Traffic to an expert hosted on the sender's own device is free (rides the
    local VMEM/HBM path, like the paper's NVSwitch-local traffic); everything
    else crosses the region.  The region finishes when its busiest device
    (in or out) finishes, so cost = max over devices of crossing bytes.
    """
    token_demand = np.asarray(token_demand, dtype=np.float64)
    n_dev, n_exp = token_demand.shape[0], token_demand.shape[1]
    owner = perm // experts_per_device  # expert -> device
    dev_mat = np.zeros((n_dev, n_dev))
    for e in range(n_exp):
        dev_mat[:, owner[e]] += token_demand[:, e]
    cross = dev_mat.copy()
    np.fill_diagonal(cross, 0.0)
    return float(max(cross.sum(axis=1).max(initial=0), cross.sum(axis=0).max(initial=0)))


def solve_expert_placement(
    token_demand: np.ndarray,
    experts_per_device: int,
    *,
    sweeps: int = 2,
) -> PlacementPlan:
    """Greedy bottleneck-relief placement (Algorithm 1 adapted).

    Starts from the identity placement; repeatedly considers the device with
    the highest crossing traffic and tries swapping each of its experts with
    every other expert, keeping the best-improving swap (first-improvement
    over ``sweeps`` passes).  O(sweeps * E^2) with tiny constants — host-side
    control-plane code that runs every ``reconfig_every_n`` steps.
    """
    token_demand = np.asarray(token_demand, dtype=np.float64)
    n_exp = token_demand.shape[1]
    perm = np.arange(n_exp)
    before = placement_cost(token_demand, perm, experts_per_device)
    best = before
    for _ in range(sweeps):
        improved = False
        for a in range(n_exp):
            for b in range(a + 1, n_exp):
                if perm[a] // experts_per_device == perm[b] // experts_per_device:
                    continue
                perm[a], perm[b] = perm[b], perm[a]
                c = placement_cost(token_demand, perm, experts_per_device)
                if c < best - 1e-9:
                    best = c
                    improved = True
                else:
                    perm[a], perm[b] = perm[b], perm[a]
        if not improved:
            break
    return PlacementPlan(perm=perm, cost_before=before, cost_after=best)


def inverse_permutation(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.shape[0])
    return inv


def apply_placement(stacked_expert_weights, perm: np.ndarray):
    """Gather stacked ``[E, ...]`` expert tensors into their new slots.

    ``out[slot] = weights[expert_with_that_slot]`` so that device
    ``slot // experts_per_device`` now hosts the experts the plan assigned it.
    Works on any pytree of arrays whose leading axis is the expert axis.
    """
    import jax

    inv = inverse_permutation(np.asarray(perm))

    def gather(x):
        return x[inv]

    return jax.tree_util.tree_map(gather, stacked_expert_weights)
