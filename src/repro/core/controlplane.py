"""Unified runtime-reconfiguration control plane (paper Fig 20, DESIGN.md §3).

One engine drives the paper's monitor -> COPILOT -> solve -> reconfigure loop
for BOTH consumers of runtime reconfiguration in this repo:

  * the flow-level simulator (:mod:`repro.core.netsim`), where a decision is
    a per-layer OCS cross-map actuated through ``fabric.prepare`` with the
    hide-or-block semantics of §5.1, and
  * the trainer (:mod:`repro.train.trainer`), where a decision is a
    per-layer expert->slot permutation applied to the stacked expert weights
    and threaded to the router (the TPU-native analogue of pushing a new
    cross-map, DESIGN.md §2).

The lifecycle is explicit and identical in both modes:

    engine.observe(layer, load)   # every step, every MoE layer (monitor)
    engine.end_step()             # advance the window + batched COPILOT refit
    plan = engine.plan(layer)     # per-layer decision (solve + hysteresis)
    engine.apply(plan)            # actuate: OCS cross-map or weight permute

Failure handling (§5.4) is folded into the same engine: ``fail_device`` /
``fail_nic`` notifications flow through the identical decide/apply path —
in OCS mode the bound fabric masks the failed server's circuits, in
placement mode the engine emits failover plans (bounded remap permutations)
and subsequent routine plans keep only the coldest experts parked on failed
devices.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.copilot import CopilotPredictor
from repro.core.placement import (
    inverse_permutation,
    placement_cost,
    solve_expert_placement,
)
from repro.core.traffic import TrafficMonitor
from repro.obs import metrics, trace

__all__ = [
    "LayerPlan",
    "ControlPlane",
    "FailureHandler",
    "PlacementApplier",
    "RegionGateStats",
    "permute_expert_weights",
]


class RegionGateStats:
    """Region-conditioned expert-mix statistics (EWMA per traffic region).

    The paper's §3 measurement — gate load is *regionally* skewed — applied
    at the granularity a fleet steers on: for every traffic region ``r`` keep
    an exponentially weighted per-layer expert mix ``mix[r] : [L, E]`` plus a
    confidence weight (total observation mass).  Each serving tick a replica
    attributes its observed gate load to the regions of its live requests
    (weights = each region's share of live slots); with locality steering the
    replicas become region-pure and the per-region statistics sharpen — the
    self-reinforcing loop DESIGN.md §12 describes.

    Everything is plain numpy and JSON-serializable (``state_dict``) so the
    stats ride the same checkpoint path as the placement perms.
    """

    def __init__(self, num_regions: int, num_layers: int, num_experts: int,
                 *, alpha: float = 0.3):
        self.num_regions = num_regions
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.alpha = alpha
        self.mix = np.full(
            (num_regions, num_layers, num_experts), 1.0 / num_experts
        )
        self.weight = np.zeros(num_regions)

    def observe(self, region_weights: dict[int, float], load: np.ndarray) -> None:
        """Fold one tick's gate load ``[L, E]`` into each live region's EWMA,
        scaled by that region's share of the tick's live slots."""
        load = np.asarray(load, dtype=np.float64)
        s = load.sum(axis=-1, keepdims=True)
        norm = np.where(s > 0, load / np.maximum(s, 1e-12), 1.0 / load.shape[-1])
        for region, w in region_weights.items():
            if w <= 0 or not (0 <= region < self.num_regions):
                continue
            a = min(self.alpha * w, 1.0)
            self.mix[region] = (1.0 - a) * self.mix[region] + a * norm
            self.weight[region] += w

    def mix_for(self, region: int) -> np.ndarray | None:
        """``[L, E]`` mix estimate, or None while the region is still cold."""
        if not (0 <= region < self.num_regions) or self.weight[region] < 1.0:
            return None
        return self.mix[region]

    @staticmethod
    def merged(stats: list["RegionGateStats | None"]) -> "RegionGateStats | None":
        """Fleet-level view: confidence-weighted average across replicas."""
        live = [s for s in stats if s is not None]
        if not live:
            return None
        out = RegionGateStats(
            live[0].num_regions, live[0].num_layers, live[0].num_experts
        )
        for r in range(out.num_regions):
            w = np.array([s.weight[r] for s in live])
            out.weight[r] = w.sum()
            if out.weight[r] > 0:
                out.mix[r] = sum(
                    s.mix[r] * wr for s, wr in zip(live, w)
                ) / out.weight[r]
        return out

    def state_dict(self) -> dict:
        return {"mix": self.mix.tolist(), "weight": self.weight.tolist(),
                "alpha": self.alpha}

    def load_state_dict(self, state: dict) -> None:
        mix = np.asarray(state["mix"], dtype=np.float64)
        if mix.shape != self.mix.shape:
            raise ValueError(
                f"region stats shape {mix.shape} != {self.mix.shape}"
            )
        self.mix = mix
        self.weight = np.asarray(state["weight"], dtype=np.float64)
        self.alpha = float(state.get("alpha", self.alpha))


def permute_expert_weights(params, inv_stack: np.ndarray, num_virtual: int):
    """Gather every MoE block's stacked expert tensors into their new slots.

    ``inv_stack`` is ``[L, E_virtual]`` of per-layer *inverse* permutations
    (``inv[s]`` = the slot whose expert moves into slot ``s``); identity rows
    leave a layer untouched.  Applied to every ``[L, E_virtual, ...]`` leaf
    under ``params["blocks"][*]["moe"]`` — the weight-side half of a
    reconfiguration, mirrored by the router-side ``perm_stack`` composition
    in :meth:`ControlPlane.apply`.
    """
    import jax.numpy as jnp  # lazy: pure-simulation consumers stay jax-free

    reps = inv_stack.shape[0]
    rows = jnp.asarray(inv_stack)
    gather_idx = (jnp.arange(reps)[:, None], rows)

    def permute(leaf):
        if leaf.ndim >= 2 and leaf.shape[0] == reps and leaf.shape[1] == num_virtual:
            return leaf[gather_idx]
        return leaf

    for bparams in params["blocks"].values():
        if "moe" in bparams:
            for wname in ("w_in", "w_gate", "w_out"):
                bparams["moe"][wname] = permute(bparams["moe"][wname])
    return params


@dataclasses.dataclass
class LayerPlan:
    """One layer's reconfiguration decision.

    Exactly one of ``perm`` (placement mode: expert->slot permutation over
    the *current slot occupancy*) and ``demand`` (OCS mode: the demand
    matrix to actuate through ``fabric.prepare``) is set when
    ``reconfigure`` is True.
    """

    layer: int
    reconfigure: bool
    perm: np.ndarray | None = None
    demand: np.ndarray | None = None
    predicted: bool = False  # demand came from COPILOT, not observation
    gain_bytes: float = 0.0
    reason: str = ""


class FailureHandler:
    """§5.4 failure handling at the placement level.

    Devices are slots on the ``model`` axis.  A failed device's experts are
    re-homed onto backup slots spread over survivors, producing an expert
    permutation the runtime applies exactly like a routine reconfiguration.
    """

    def __init__(self, num_experts: int, num_devices: int):
        if num_experts % num_devices != 0:
            raise ValueError("experts must divide devices for slot bookkeeping")
        self.num_experts = num_experts
        self.num_devices = num_devices
        self.experts_per_device = num_experts // num_devices
        self.failed: set[int] = set()

    def fail_device(self, device: int) -> None:
        if device < 0 or device >= self.num_devices:
            raise ValueError("bad device id")
        self.failed.add(device)
        if len(self.failed) >= self.num_devices:
            raise RuntimeError("all devices failed — unrecoverable")

    def restore_device(self, device: int) -> None:
        self.failed.discard(device)

    def healthy_devices(self) -> list[int]:
        return [d for d in range(self.num_devices) if d not in self.failed]

    def remap(self) -> np.ndarray:
        """Expert -> slot map avoiding failed devices (elastic capacity).

        Experts originally on failed devices round-robin onto healthy ones;
        healthy experts keep their slots where possible (minimal movement,
        'minor topology adjustments' per §5.4).  Overflow slots live past the
        nominal range; ``device_of_slot`` translates slot -> device.
        """
        epd = self.experts_per_device
        healthy = self.healthy_devices()
        if not healthy:
            raise RuntimeError("no healthy devices")
        slots = np.full(self.num_experts, -1, dtype=np.int64)
        for e in range(self.num_experts):
            dev = e // epd
            if dev not in self.failed:
                slots[e] = e
        overflow = {d: 0 for d in healthy}
        cursor = 0
        for e in range(self.num_experts):
            if slots[e] >= 0:
                continue
            dev = healthy[cursor % len(healthy)]
            cursor += 1
            slots[e] = self.num_experts + dev * epd + overflow[dev]
            overflow[dev] += 1
        return slots

    def swap_remap(self) -> np.ndarray:
        """Bounded failover *permutation* over ``[0, E)``.

        Every expert homed on a failed device swaps slots with a round-robin
        chosen backup expert on a healthy device.  Unlike :meth:`remap` this
        stays within the nominal slot range, so stacked ``[L, E, ...]``
        weight tensors keep their shape — the TPU analogue of pre-provisioned
        backup slots.  The displaced (cold) backup experts are the ones
        parked on the failed device.
        """
        epd = self.experts_per_device
        healthy = self.healthy_devices()
        if not healthy:
            raise RuntimeError("no healthy devices")
        perm = np.arange(self.num_experts)
        cursor = 0
        for e in range(self.num_experts):
            if e // epd not in self.failed:
                continue
            dev = healthy[cursor % len(healthy)]
            backup = dev * epd + (cursor // len(healthy)) % epd
            perm[e], perm[backup] = perm[backup], perm[e]
            cursor += 1
        return perm

    def device_of_slot(self, slot: int) -> int:
        if slot < self.num_experts:
            return slot // self.experts_per_device
        return (slot - self.num_experts) // self.experts_per_device


class ControlPlane:
    """The shared reconfiguration engine (one per reconfigurable region).

    OCS mode (``fabric`` bound): plans carry demand matrices and ``apply``
    actuates them through ``fabric.prepare`` with hide-or-block accounting.
    Placement mode (no fabric): plans carry expert permutations over the
    current slot occupancy and ``apply`` composes them into the per-layer
    ``perm_stack`` the model's router consumes.
    """

    def __init__(
        self,
        num_layers: int,
        num_experts: int,
        *,
        num_devices: int = 1,
        replication: int = 1,
        fabric=None,
        window: int = 8,
        min_gain_fraction: float = 0.05,
        reconfig_cost_bytes: float = 0.0,
        use_copilot: bool = True,
        fit_steps: int = 150,
        batched_refit: bool = True,
        num_regions: int = 0,
    ):
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.num_devices = max(num_devices, 1)
        self.replication = max(replication, 1)
        self.num_virtual = num_experts * self.replication
        self.experts_per_device = max(self.num_virtual // self.num_devices, 1)
        self.fabric = fabric
        self.min_gain_fraction = min_gain_fraction
        self.reconfig_cost_bytes = reconfig_cost_bytes
        self.monitor = TrafficMonitor(num_layers, num_experts, window=window)
        self.copilot = (
            CopilotPredictor(
                num_layers, num_experts, fit_steps=fit_steps, batched_refit=batched_refit
            )
            if use_copilot and num_layers > 1
            else None
        )
        self.failures = (
            FailureHandler(self.num_virtual, self.num_devices)
            if self.num_devices > 1 and self.num_virtual % self.num_devices == 0
            else None
        )
        self.layer_perms = np.tile(
            np.arange(self.num_virtual, dtype=np.int64), (num_layers, 1)
        )
        self.reconfig_count = 0
        # Measurement plane (DESIGN.md §14): cached metric children so the
        # per-step observe path stays one float add.
        _m = metrics.default()
        self._m_steps = _m.counter("controlplane.steps")
        self._m_plan_go = _m.counter("controlplane.plans", verdict="reconfigure")
        self._m_plan_hold = _m.counter("controlplane.plans", verdict="hold")
        # Per-replica region-conditioned stats (fleet steering, DESIGN.md §12).
        self.region_stats = (
            RegionGateStats(num_regions, num_layers, num_experts)
            if num_regions > 0
            else None
        )

    @classmethod
    def for_simulation(
        cls,
        model,
        fabric,
        *,
        num_servers_region: int | None = None,
        gpus_per_server: int = 8,
        use_copilot: bool = True,
        fit_steps: int = 60,
    ) -> "ControlPlane":
        """Engine for one simulated PP stage's EP region (netsim consumer)."""
        region = num_servers_region or max(model.gpus_per_stage // gpus_per_server, 2)
        return cls(
            num_layers=model.layers_per_stage,
            num_experts=model.num_experts,
            num_devices=region,
            fabric=fabric,
            use_copilot=use_copilot,
            fit_steps=fit_steps,
        )

    # -- lifecycle: observe ---------------------------------------------------
    def observe(self, layer: int, expert_load, device_matrix=None) -> None:
        """Record one layer's realized expert load for this step."""
        self.monitor.record(layer, expert_load, device_matrix)

    def observe_regions(self, region_weights: dict[int, float],
                        load: np.ndarray) -> None:
        """Attribute one tick's ``[L, E]`` gate load to traffic regions
        (no-op unless the engine was built with ``num_regions > 0``)."""
        if self.region_stats is not None and region_weights:
            self.region_stats.observe(region_weights, load)

    def end_step(self) -> None:
        """Close the step: advance the monitor window, refit COPILOT (one
        batched vmapped call across all layers)."""
        self._m_steps.inc()
        self.monitor.advance()
        if self.copilot is not None:
            self.copilot.update(self.monitor)

    # -- lifecycle: predict ---------------------------------------------------
    def predict_load(self, layer: int) -> np.ndarray | None:
        """COPILOT forecast of ``layer``'s load from layer-1's latest
        observation (§B.1) — what the FP's first all-to-all must be planned
        from, before the gate of ``layer`` has run.  None when unavailable."""
        if self.copilot is None or layer < 1:
            return None
        prev = self.monitor.loads(layer - 1)
        if not len(prev):
            return None
        src = min(layer - 1, self.copilot.num_layers - 2)
        return self.copilot.predict(src, prev[-1])

    # -- lifecycle: plan ------------------------------------------------------
    def plan(
        self,
        layer: int,
        demand: np.ndarray | None = None,
        *,
        predicted: bool = False,
    ) -> LayerPlan:
        """Per-layer decision with its gain/hysteresis verdict journaled as
        a structured reconfiguration audit event (DESIGN.md §14)."""
        p = self._plan(layer, demand, predicted=predicted)
        (self._m_plan_go if p.reconfigure else self._m_plan_hold).inc()
        tr = trace.default()
        if tr.enabled:
            tr.audit("controlplane.plan", {
                "layer": p.layer,
                "reconfigure": p.reconfigure,
                "gain_bytes": float(p.gain_bytes),
                "reason": p.reason,
                "predicted": bool(p.predicted),
            }, cat="reconfig_audit")
        return p

    def _plan(
        self,
        layer: int,
        demand: np.ndarray | None = None,
        *,
        predicted: bool = False,
    ) -> LayerPlan:
        """Per-layer reconfiguration decision.

        OCS mode: ``demand`` is the region's ``[S, S]`` inter-server matrix
        (observed or COPILOT-predicted) and the plan simply carries it to the
        fabric — Algorithm 1 runs inside ``fabric.prepare``.

        Placement mode: ``demand`` is ``[D, E_virtual]`` bytes device->slot;
        when omitted it is proxied from the monitor's latest load for the
        layer, mapped through the current slot occupancy.  The plan passes
        the hide-or-block hysteresis only when the predicted byte gain beats
        the permutation's own cost.
        """
        if self.fabric is not None:
            if demand is None:
                raise ValueError("OCS mode requires an explicit demand matrix")
            return LayerPlan(
                layer, True, demand=np.asarray(demand, dtype=np.float64),
                predicted=predicted, reason="ocs cross-map",
            )
        if demand is None:
            demand = self._demand_proxy(layer)
        if demand is None:
            return LayerPlan(layer, False, reason="no traffic observed")
        demand = np.asarray(demand, dtype=np.float64)
        solved = solve_expert_placement(demand, self.experts_per_device)
        perm, cost_after = solved.perm, solved.cost_after
        if self.failures is not None and self.failures.failed:
            perm = self._park_coldest_on_failed(perm, demand.sum(axis=0))
            cost_after = placement_cost(demand, perm, self.experts_per_device)
        gain = solved.cost_before - cost_after
        threshold = self.min_gain_fraction * max(solved.cost_before, 1e-9)
        if gain <= max(threshold, 0.0) or gain <= self.reconfig_cost_bytes:
            return LayerPlan(
                layer, False, gain_bytes=gain, reason="gain below reconfig cost"
            )
        return LayerPlan(
            layer, True, perm=perm, gain_bytes=gain, reason="bottleneck relief"
        )

    def _demand_proxy(self, layer: int) -> np.ndarray | None:
        """``[D, E_virtual]`` demand proxy from the layer's latest load:
        every data shard contributes tokens proportional to the global load,
        expressed over the *current slot occupancy* so routine plans compose
        correctly after earlier reconfigurations."""
        loads = self.monitor.loads(layer)
        if not len(loads):
            return None
        vload = np.repeat(loads[-1], self.replication) / self.replication
        occupant = inverse_permutation(self.layer_perms[layer])
        slot_load = vload[occupant]
        return np.tile(slot_load[None, :], (self.num_devices, 1))

    def _park_coldest_on_failed(
        self, perm: np.ndarray, slot_load: np.ndarray
    ) -> np.ndarray:
        """Adjust a solved permutation so failed devices host only the
        coldest experts (their traffic is the §5.4 degradation we accept)."""
        epd = self.experts_per_device
        failed_slots = {
            s for s in range(self.num_virtual) if s // epd in self.failures.failed
        }
        if not failed_slots:
            return perm
        perm = perm.copy()
        k = len(failed_slots)
        cold = set(np.argsort(slot_load, kind="stable")[:k].tolist())
        hot_on_failed = [
            c for c in range(self.num_virtual)
            if perm[c] in failed_slots and c not in cold
        ]
        cold_elsewhere = [c for c in sorted(cold) if perm[c] not in failed_slots]
        for a, b in zip(hot_on_failed, cold_elsewhere):
            perm[a], perm[b] = perm[b], perm[a]
        return perm

    # -- lifecycle: apply -----------------------------------------------------
    def apply(self, plan: LayerPlan, *, hide_window: float = math.inf) -> float:
        """Actuate a plan; returns the *blocking* seconds (0 when hidden).

        OCS mode mirrors §5.1's hide-or-block: only the part of the
        reconfiguration delay that does not fit in ``hide_window`` (the
        pipelined compute between the phase's all-to-alls) stalls the pipe.
        Placement mode composes the layer's permutation into ``perm_stack``;
        the caller is responsible for gathering the expert weights with the
        matching inverse permutation (see ``repro.train.trainer``).
        """
        if not plan.reconfigure:
            return 0.0
        if self.fabric is not None:
            overflow = max(0.0, self.fabric.cfg.reconfig_delay_s - hide_window)
            blocked = self.fabric.prepare(plan.demand, can_hide=overflow <= 0.0)
            self.reconfig_count += 1
            metrics.counter("controlplane.reconfigs", mode="ocs").inc()
            return min(blocked, overflow)
        base = self.layer_perms[plan.layer]
        self.layer_perms[plan.layer] = plan.perm[base]
        self.reconfig_count += 1
        metrics.counter("controlplane.reconfigs", mode="placement").inc()
        return 0.0

    def perm_stack(self) -> np.ndarray:
        """``[L, E_virtual]`` per-layer expert->slot maps for the router."""
        return self.layer_perms.astype(np.int32).copy()

    # -- state round-trip (checkpointable placement, DESIGN.md §9) ------------
    def state_dict(self) -> dict:
        """JSON-serializable placement state: what a checkpoint must carry so
        a restored server resumes with the SAME expert placement (the perm
        stack composes against physically permuted weights — restoring one
        without the other would misroute every token)."""
        state = {
            "layer_perms": self.layer_perms.tolist(),
            "reconfig_count": int(self.reconfig_count),
        }
        if self.region_stats is not None:
            state["region_stats"] = self.region_stats.state_dict()
        return state

    def load_state_dict(self, state: dict) -> None:
        perms = np.asarray(state["layer_perms"], dtype=np.int64)
        if perms.shape != self.layer_perms.shape:
            raise ValueError(
                f"perm stack shape {perms.shape} does not match engine "
                f"{self.layer_perms.shape}"
            )
        for row in perms:
            if sorted(row.tolist()) != list(range(self.num_virtual)):
                raise ValueError(f"not a permutation row: {row}")
        self.layer_perms = perms
        self.reconfig_count = int(state.get("reconfig_count", 0))
        if self.region_stats is not None and "region_stats" in state:
            self.region_stats.load_state_dict(state["region_stats"])

    # -- failures (§5.4) ------------------------------------------------------
    def fail_device(self, device: int) -> list[LayerPlan]:
        """A server/device drops out of the region.

        OCS mode: the bound fabric loses the server's optical circuits (EPS
        fallback, ``MixNetFabric.fail_server_ocs``) and subsequent plans
        route around it — no placement plans needed.  Placement mode:
        returns per-layer failover plans (bounded remap permutations) for the
        consumer to apply through the standard decide/apply path.
        """
        if self.failures is not None:
            self.failures.fail_device(device)
        if self.fabric is not None:
            self.fabric.fail_server_ocs(device)
            return []
        if self.failures is None:
            raise ValueError("placement-mode failures need >= 2 devices")
        perm = self.failures.swap_remap()
        return [
            LayerPlan(l, True, perm=perm.copy(), reason="failover remap")
            for l in range(self.num_layers)
        ]

    def fail_nic(self, server: int, failed_nics: int = 1) -> None:
        """Partial NIC failure: the server keeps running with fewer optical
        links (OCS mode only — the fabric reroutes over the rest + EPS)."""
        if self.fabric is None:
            raise ValueError("NIC failures only exist in OCS (fabric) mode")
        self.fabric.fail_server_nic(server, failed_nics)

    def restore_device(self, device: int) -> None:
        if self.failures is not None:
            self.failures.restore_device(device)
        if self.fabric is not None:
            self.fabric.restore_server_ocs(device)

    def failover_slots(self) -> np.ndarray:
        """§5.4 elastic remap (overflow slots allowed) — exposed for
        consumers that relocate state rather than permute it."""
        if self.failures is None:
            raise ValueError("no failure bookkeeping for this region")
        return self.failures.remap()


class PlacementApplier:
    """Shared actuation of placement-mode :class:`LayerPlan` batches against
    stacked expert weights — the runtime half both the trainer and the
    serving engine drive (DESIGN.md §3/§9).

    A plan whose permutation moves whole device blocks is installed as a
    **wire re-address** (``device_perm_from_slots`` -> a per-layer ``[P]``
    device map threaded to the a2a's ``dest_perm``/``src_perm``) — the
    expert weights never move, exactly like pushing a new cross-map to the
    OCS.  Any other plan falls back to the weight gather
    (:func:`permute_expert_weights`), flushing the layer's pending wire perm
    into the same gather so the two realizations always compose.
    Router-side perms go through the engine either way (``perm[base]``
    ordering in :meth:`ControlPlane.apply`).
    """

    def __init__(self, cp: ControlPlane, *, model_size: int = 1, wire_capable: bool = False):
        self.cp = cp
        self.model_size = max(model_size, 1)
        # Wire re-addressing needs the mixnet data plane and a control-plane
        # device space that IS the model axis (one slot block per device).
        self.wire_capable = (
            wire_capable
            and self.model_size > 1
            and cp.num_devices == self.model_size
        )
        self.wire_perm: np.ndarray | None = None
        self.wire_reconfig_count = 0

    def apply(self, params, plans: list[LayerPlan]):
        """Actuate ``plans``; returns ``(params, changed)``."""
        from repro.core.commruntime import device_perm_from_slots

        cp = self.cp
        live = [p for p in plans if p.reconfigure]
        if not live:
            return params, False
        ev = cp.num_virtual
        epd = cp.experts_per_device
        p_axis = self.model_size
        inv_stack = np.tile(np.arange(ev, dtype=np.int64), (cp.num_layers, 1))
        gather_needed = False
        for p in live:
            devp = (
                device_perm_from_slots(np.asarray(p.perm), epd)
                if self.wire_capable
                else None
            )
            if devp is not None:
                # Wire path: the occupant of logical device a moves to device
                # devp[a]; physically nothing moves, so the layer's device
                # map composes as D'[k] = D[devp^-1[k]].
                if self.wire_perm is None:
                    self.wire_perm = np.tile(
                        np.arange(p_axis, dtype=np.int64), (cp.num_layers, 1)
                    )
                d_cur = self.wire_perm[p.layer]
                self.wire_perm[p.layer] = d_cur[inverse_permutation(devp)]
                self.wire_reconfig_count += 1
                metrics.counter("placement.applies", mode="wire").inc()
                continue
            inv = inverse_permutation(p.perm)
            if self.wire_perm is not None and (
                self.wire_perm[p.layer] != np.arange(p_axis)
            ).any():
                # Flush the pending wire perm into this gather: new physical
                # slot s receives Phi(perm^-1(s)) where Phi maps logical slot
                # -> physical slot under the current device map.
                d_cur = self.wire_perm[p.layer]
                slots = np.arange(ev)
                phi = d_cur[slots // epd] * epd + slots % epd
                inv = phi[inv]
                self.wire_perm[p.layer] = np.arange(p_axis)
            inv_stack[p.layer] = inv
            gather_needed = True
            metrics.counter("placement.applies", mode="weight_gather").inc()
        if gather_needed:
            params = permute_expert_weights(params, inv_stack, ev)
        for p in live:
            cp.apply(p)
        return params, True

    # -- state round-trip -----------------------------------------------------
    def state_dict(self) -> dict:
        return {
            "controlplane": self.cp.state_dict(),
            "wire_perm": None if self.wire_perm is None else self.wire_perm.tolist(),
            "wire_reconfig_count": int(self.wire_reconfig_count),
        }

    def load_state_dict(self, state: dict) -> None:
        self.cp.load_state_dict(state["controlplane"])
        wp = state.get("wire_perm")
        self.wire_perm = None if wp is None else np.asarray(wp, dtype=np.int64)
        self.wire_reconfig_count = int(state.get("wire_reconfig_count", 0))
