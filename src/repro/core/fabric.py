"""Interconnect fabric models (paper §7.1 "Simulated GPU interconnect fabrics").

Every fabric answers the same three questions for the flow-level simulator
(:mod:`repro.core.netsim`):

  * ``alltoall_time(demand)``   — completion time of an EP all-to-all given an
    inter-server demand matrix in bytes,
  * ``allreduce_time(bytes_)``  — completion time of a DP ring all-reduce,
  * ``p2p_time(bytes_)``        — PP stage-to-stage transfer time,

plus ``prepare(demand)`` which lets reconfigurable fabrics (MixNet, TopoOpt)
adapt — MixNet re-runs Algorithm 1 every call (runtime reconfiguration, maybe
blocking), TopoOpt only honours the first call (one-shot, pre-training).

All times are seconds; bandwidths are bytes/second per NIC.  The models are
flow-level: a transfer's rate is its allocated circuit/fallback bandwidth and
a phase completes when its slowest flow completes.  This reproduces the
paper's *relative* results (Figs 12-14, 26-28) without packet-level detail.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import topology as topo

__all__ = [
    "FabricConfig",
    "Fabric",
    "FatTree",
    "OverSubFatTree",
    "RailOptimized",
    "TopoOpt",
    "MixNetFabric",
    "make_fabric",
]

GBPS = 1e9 / 8.0  # bytes/sec per Gbps


@dataclasses.dataclass
class FabricConfig:
    num_servers: int = 128
    gpus_per_server: int = 8
    nics_per_server: int = 8
    link_gbps: float = 400.0
    # MixNet split (paper §7.1: 2 EPS + 6 OCS by default).
    eps_nics: int = 2
    ocs_nics: int = 6
    reconfig_delay_s: float = 0.025  # Polatis millisecond OCS (25 ms)
    nvlink_bytes_per_s: float = 900e9  # intra-server scale-up
    oversub_ratio: float = 3.0
    propagation_delay_s: float = 1e-6
    # Packet-switched fabrics lose a slice of line rate to ECMP hash
    # collisions / incast on skewed all-to-alls (the packet-level effect the
    # paper's htsim captures); layer-1 optical circuits do not contend.
    eps_a2a_efficiency: float = 0.90

    @property
    def nic_bw(self) -> float:
        return self.link_gbps * GBPS


class Fabric:
    """Base class: non-blocking full-bandwidth abstraction."""

    name = "abstract"

    def __init__(self, cfg: FabricConfig):
        self.cfg = cfg

    # -- reconfiguration hooks -------------------------------------------
    def prepare(self, demand: np.ndarray, *, can_hide: bool = True) -> float:
        """Adapt to the coming demand; return *blocking* seconds (not hidden)."""
        return 0.0

    # -- transfer primitives ----------------------------------------------
    def server_bandwidth(self) -> float:
        """Aggregate scale-out bandwidth of one server (bytes/s)."""
        return self.cfg.nics_per_server * self.cfg.nic_bw

    def alltoall_time(self, demand: np.ndarray) -> float:
        """Non-blocking fabrics: each server drains at its aggregate NIC bw.

        Completion = max over servers of (bytes in or out) / server bw,
        derated by the packet-fabric a2a efficiency.
        """
        demand = np.asarray(demand, dtype=np.float64)
        out_bytes = demand.sum(axis=1)
        in_bytes = demand.sum(axis=0)
        worst = max(out_bytes.max(initial=0.0), in_bytes.max(initial=0.0))
        bw = self.server_bandwidth() * self.cfg.eps_a2a_efficiency
        return worst / bw + self.cfg.propagation_delay_s

    def allreduce_time(self, bytes_per_server: float, num_servers: int | None = None) -> float:
        """Ring all-reduce: 2*(n-1)/n of the data crosses each server's NICs."""
        n = num_servers or self.cfg.num_servers
        if n <= 1:
            return 0.0
        wire = 2.0 * (n - 1) / n * bytes_per_server
        return wire / self.server_bandwidth() + n * self.cfg.propagation_delay_s

    def p2p_time(self, bytes_: float) -> float:
        return bytes_ / self.server_bandwidth() + self.cfg.propagation_delay_s

    def intra_host_time(self, bytes_: float) -> float:
        return bytes_ / self.cfg.nvlink_bytes_per_s


class FatTree(Fabric):
    """1:1 non-blocking fat-tree — the reference EPS fabric."""

    name = "fat-tree"


class OverSubFatTree(Fabric):
    """Fat-tree with 3:1 core over-subscription: inter-rack bw divided."""

    name = "oversub-fat-tree"

    def server_bandwidth(self) -> float:
        return self.cfg.nics_per_server * self.cfg.nic_bw / self.cfg.oversub_ratio


class RailOptimized(Fabric):
    """Nvidia rail-optimized topology.

    Same aggregate bandwidth as fat-tree; GPUs of the same rank share a rail
    switch, so same-rail flows take one hop while cross-rail flows first hop
    through NVSwitch (cheap).  Flow-level this is fat-tree performance with a
    small intra-host forwarding surcharge on the all-to-all (which is
    inherently cross-rail for a fraction (r-1)/r of the bytes).
    """

    name = "rail-optimized"

    def alltoall_time(self, demand: np.ndarray) -> float:
        base = super().alltoall_time(demand)
        demand = np.asarray(demand, dtype=np.float64)
        r = self.cfg.nics_per_server
        cross_rail = demand.sum() * (r - 1) / r / max(self.cfg.num_servers, 1)
        return base + self.intra_host_time(cross_rail)


class TopoOpt(Fabric):
    """TopoOpt-style one-shot optical topology (patch panel, §7.1).

    All NICs sit on a big static patch panel.  The topology is optimized once
    (first ``prepare`` call) for the demand it sees then; afterwards it never
    changes.  Traffic between pairs without a direct circuit relays through
    intermediate servers (halved effective bandwidth, one extra hop).
    """

    name = "topoopt"

    def __init__(self, cfg: FabricConfig):
        super().__init__(cfg)
        self._circuits: np.ndarray | None = None

    def prepare(self, demand: np.ndarray, *, can_hide: bool = True) -> float:
        if self._circuits is None or self._circuits.shape[0] != demand.shape[0]:
            # TopoOpt's degree-limited direct-connect topology serves DP ring
            # + PP chain + EP jointly (it co-optimizes all parallelisms over
            # one flat patch panel) — only the NICs left over from the DP/PP
            # circuits point at EP peers.
            ep_alpha = max(2, self.cfg.nics_per_server - 4)
            solved = topo.reconfigure_ocs(
                demand,
                alpha=ep_alpha,
                num_servers=demand.shape[0],
                experts_per_server=1,
            )
            self._circuits = solved.circuits
        return 0.0  # one-shot reconfig happens before training

    def alltoall_time(self, demand: np.ndarray) -> float:
        if self._circuits is None or self._circuits.shape[0] != demand.shape[0]:
            self._circuits = None
            self.prepare(demand)
        demand = np.asarray(demand, dtype=np.float64)
        # Circuits are full duplex: a pair's completion is driven by its
        # heavier direction.
        pair = np.triu(np.maximum(demand, demand.T), k=1)
        bw = self.cfg.nic_bw
        # Direct circuits at full bw; non-matching pairs relay through an
        # intermediate server, consuming two hops of somebody's circuits —
        # effectively half a link once shared.
        circ = self._circuits.astype(np.float64)
        direct_bw = np.triu(circ, k=1) * bw
        relay_bw = 0.5 * bw
        eff_bw = np.where(direct_bw > 0, direct_bw, relay_bw)
        with np.errstate(divide="ignore", invalid="ignore"):
            t = np.where(pair > 0, pair / eff_bw, 0.0)
        return float(t.max(initial=0.0)) + self.cfg.propagation_delay_s


class MixNetFabric(Fabric):
    """MixNet: EPS (2 NICs) + regionally reconfigurable OCS (6 NICs).

    ``prepare`` re-runs Algorithm 1 for every all-to-all phase.  When the
    reconfiguration can be hidden inside compute (the 2nd FP a2a and both BP
    a2as, §5.1) the returned blocking time is zero; for the 1st FP a2a either
    COPILOT predicted the demand in advance (hidden) or the fabric blocks for
    ``reconfig_delay_s``.
    """

    name = "mixnet"

    def __init__(self, cfg: FabricConfig):
        super().__init__(cfg)
        self._circuits: np.ndarray | None = None
        self.reconfig_count = 0
        self.blocked_seconds = 0.0
        self._failed_servers: set[int] = set()
        self._degree_caps: dict[int, int] = {}

    @staticmethod
    def demand_hint(demand: np.ndarray) -> np.ndarray:
        return np.maximum(demand, demand.T)

    def fail_server_nic(self, server: int, failed_nics: int = 1) -> None:
        """§5.4 NIC failure: the server keeps running with fewer optical
        links; traffic re-routes over its remaining circuits + EPS."""
        self._degree_caps[server] = max(self.cfg.ocs_nics - failed_nics, 0)

    # -- control plane -----------------------------------------------------
    # NOTE: the demand matrix a MixNet fabric sees is *regional* — one OCS
    # slice serves one EP group (§4.2).  Its shape defines the region size;
    # cfg.num_servers only matters for the global EPS (DP/PP) paths and cost.
    def prepare(self, demand: np.ndarray, *, can_hide: bool = True) -> float:
        region = demand.shape[0]
        solved = topo.reconfigure_ocs(
            demand,
            alpha=self.cfg.ocs_nics,
            num_servers=region,
            experts_per_server=1,
        )
        circuits = solved.circuits
        if self._failed_servers or self._degree_caps:
            circuits = circuits.copy()
            for s in self._failed_servers:
                circuits[s, :] = 0
                circuits[:, s] = 0
            # Partial NIC failures: cap a server's optical degree by dropping
            # its lightest circuits (the controller re-solves around them).
            for s, cap in self._degree_caps.items():
                if s >= region:
                    continue
                while circuits[s].sum() > cap:
                    nz = np.nonzero(circuits[s])[0]
                    j = nz[np.argmin(self.demand_hint(demand)[s, nz])]
                    circuits[s, j] -= 1
                    circuits[j, s] -= 1
        self._circuits = circuits
        self.reconfig_count += 1
        block = 0.0 if can_hide else self.cfg.reconfig_delay_s
        self.blocked_seconds += block
        return block

    def fail_server_ocs(self, server: int) -> None:
        """Full optical loss for a server: EPS fallback only (§5.4)."""
        self._failed_servers.add(server)
        if self._circuits is not None:
            self._circuits[server, :] = 0
            self._circuits[:, server] = 0

    def restore_server_ocs(self, server: int) -> None:
        self._failed_servers.discard(server)

    # -- data plane ----------------------------------------------------------
    def alltoall_time(self, demand: np.ndarray) -> float:
        """Completion of one EP all-to-all on the hybrid fabric.

        The delegation runtime (§5.3) splits traffic into two classes:
          * circuit-covered pairs drain over their dedicated duplex circuits
            (contention-free layer 1) — bounded per pair by its circuit count
            and per server by its optical degree;
          * uncovered pairs multiplex over the server's EPS NICs (the runtime
            steers flows across "NICs in both the EPS and OCS fabrics").
        Completion = the slowest of the three bottlenecks.
        """
        demand = np.asarray(demand, dtype=np.float64)
        if self._circuits is None or self._circuits.shape[0] != demand.shape[0]:
            self._circuits = topo.uniform_topology(demand.shape[0], self.cfg.ocs_nics)
        bw = self.cfg.nic_bw
        circ = self._circuits.astype(np.float64)
        eps_cap = self.cfg.eps_nics * bw * self.cfg.eps_a2a_efficiency

        # Fluid completion time: find the smallest T such that every directed
        # flow d[i,j] drains within T over (a) its pair's duplex circuits at
        # full rate and (b) an EPS allocation, subject to each server's EPS
        # egress/ingress capacity.  Feasibility is monotone in T -> bisection.
        def feasible(t: float) -> bool:
            resid = np.maximum(demand - circ * bw * t, 0.0)
            out_ok = resid.sum(axis=1) <= eps_cap * t + 1e-9
            in_ok = resid.sum(axis=0) <= eps_cap * t + 1e-9
            return bool(out_ok.all() and in_ok.all())

        hi = max(
            demand.sum(axis=1).max(initial=0.0), demand.sum(axis=0).max(initial=0.0)
        ) / eps_cap + 1e-12  # everything over EPS always feasible
        lo = 0.0
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            if feasible(mid):
                hi = mid
            else:
                lo = mid
        return hi + self.cfg.propagation_delay_s

    def allreduce_time(self, bytes_per_server: float, num_servers: int | None = None) -> float:
        # DP rides the EPS fabric (hierarchical all-reduce, §5.3): intra-host
        # reduce to the gateway GPU on NVSwitch, ring over EPS NICs, broadcast.
        n = num_servers or self.cfg.num_servers
        if n <= 1:
            return 0.0
        eps_bw = self.cfg.eps_nics * self.cfg.nic_bw
        wire = 2.0 * (n - 1) / n * bytes_per_server
        intra = 2.0 * self.intra_host_time(bytes_per_server)
        return wire / eps_bw + intra + n * self.cfg.propagation_delay_s

    def p2p_time(self, bytes_: float) -> float:
        eps_bw = self.cfg.eps_nics * self.cfg.nic_bw
        return bytes_ / eps_bw + self.cfg.propagation_delay_s


_FABRICS = {
    "mixnet": MixNetFabric,
    "fat-tree": FatTree,
    "oversub-fat-tree": OverSubFatTree,
    "rail-optimized": RailOptimized,
    "topoopt": TopoOpt,
}


def make_fabric(name: str, cfg: FabricConfig) -> Fabric:
    try:
        return _FABRICS[name](cfg)
    except KeyError:
        raise ValueError(f"unknown fabric {name!r}; options: {sorted(_FABRICS)}")
