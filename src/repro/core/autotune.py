"""Cached measured autotuner over the runtime's communication knobs
(DESIGN.md §13).

The repo grew four orthogonal comm knobs that were, until now, constants
picked per call site: ``overlap_chunks`` (the §8 chunked schedule depth),
the MoE dispatch mode (dropless vs capacity), the priced a2a lowering
(hier / flat / ring, :class:`repro.core.commruntime.AllToAll`), and
``dp_compress`` (int8 gradient wire).  None of them has a shape-independent
winner: chunking pays a latency tax per chunk, capacity dispatch trades
delivered tokens for wire/FFN time, the flat a2a amortizes nothing but
costs nothing to set up, and compressed gradients only matter when the DP
reduction is exposed.

:func:`tune` searches the full grid with the *measured* objective — the
flow-level netsim prices each candidate on the actual fabric with the same
gate trace, and the score is **delivered-token goodput**
(``kept_fraction * tokens / iteration_time``), so capacity dispatch is a
real tradeoff, not a free discount.  Results are cached on disk keyed by
(model shape, parallelism layout, fabric, link rate); both consumers read
the same cache:

* netsim / benchmarks: :func:`apply` stamps the winning knobs onto a
  :class:`~repro.core.netsim.SimModel`;
* the trainer: :func:`apply_to_trainer` maps them onto the execution-side
  config (``MoEConfig.overlap_chunks`` / ``MoEConfig.dispatch``,
  ``TrainerConfig.dp_compress`` where the mesh allows it) — see
  ``repro.train.trainer.TrainerConfig.autotune_cache``.
"""

from __future__ import annotations

import dataclasses
import itertools
import json
import os

import numpy as np

__all__ = [
    "SEARCH_SPACE",
    "TuneResult",
    "cache_key",
    "tune",
    "apply",
    "apply_to_trainer",
    "load_cached",
]

# The searched grid.  ``pp_overlap`` is not a knob: bubble-filling never
# hurts in the flow model, so the tuner measures every candidate with it on
# and the default baseline with it off (the pre-§13 accounting).
SEARCH_SPACE = {
    "overlap_chunks": (1, 2, 4, 8),
    "moe_dispatch": ("dropless", "capacity"),
    "a2a_lowering": ("hier", "flat", "ring"),
    "dp_compress": (False, True),
}


@dataclasses.dataclass(frozen=True)
class TuneResult:
    key: str
    knobs: dict
    goodput_tok_s: float
    default_goodput_tok_s: float

    @property
    def speedup(self) -> float:
        return self.goodput_tok_s / max(self.default_goodput_tok_s, 1e-12)

    def to_json(self) -> dict:
        return {
            "key": self.key,
            "knobs": self.knobs,
            "goodput_tok_s": self.goodput_tok_s,
            "default_goodput_tok_s": self.default_goodput_tok_s,
        }

    @classmethod
    def from_json(cls, d: dict) -> "TuneResult":
        return cls(
            key=d["key"],
            knobs=dict(d["knobs"]),
            goodput_tok_s=float(d["goodput_tok_s"]),
            default_goodput_tok_s=float(d["default_goodput_tok_s"]),
        )


def cache_key(model, fabric_name: str, link_gbps: int) -> str:
    """Stable identity of one tuning problem: model shape x layout x fabric."""
    return (
        f"{model.name}|ep{model.ep_degree}tp{model.tp_degree}"
        f"pp{model.pp_degree}mb{model.num_microbatches}"
        f"|{fabric_name}|{link_gbps}G"
    )


def _load_cache(path: str) -> dict:
    if path and os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def load_cached(path: str, key: str) -> TuneResult | None:
    """Cache lookup without measuring; None on miss."""
    entry = _load_cache(path).get(key)
    return TuneResult.from_json(entry) if entry else None


def _goodput(model, fabric_name, link_gbps, num_servers, iterations, seed):
    """Delivered tokens/s of ``model`` on a fresh fabric (same seed -> same
    gate trace across candidates, so the comparison is paired)."""
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_training

    fab = make_fabric(
        fabric_name, FabricConfig(num_servers=num_servers, link_gbps=link_gbps)
    )
    res = simulate_training(
        model, fab, iterations=iterations, seed=seed,
        use_copilot=(fabric_name == "mixnet"),
    )
    res = res[1:] if len(res) > 1 else res
    t = float(np.mean([r.total for r in res]))
    kept = float(np.mean([r.kept_fraction for r in res]))
    tokens = model.num_microbatches * model.tokens_per_microbatch
    return kept * tokens / max(t, 1e-12)


def tune(
    model,
    fabric_name: str = "mixnet",
    link_gbps: int = 400,
    *,
    num_servers: int | None = None,
    cache_path: str | None = None,
    iterations: int = 2,
    seed: int = 0,
    refresh: bool = False,
    space: dict | None = None,
) -> TuneResult:
    """Measured grid search; returns (and caches) the best knob setting.

    ``model`` enters with its *default* knobs — that configuration, priced
    with ``pp_overlap`` off, is the baseline every candidate must beat.
    The winner is the measured-goodput argmax with ``pp_overlap`` on.
    """
    key = cache_key(model, fabric_name, link_gbps)
    if cache_path and not refresh:
        hit = load_cached(cache_path, key)
        if hit is not None:
            return hit
    if num_servers is None:
        num_servers = max(
            (model.gpus_per_stage * model.pp_degree) // 8, 2
        )
    space = dict(SEARCH_SPACE if space is None else space)
    default_score = _goodput(
        model, fabric_name, link_gbps, num_servers, iterations, seed
    )
    best_knobs, best_score = None, -1.0
    names = sorted(space)
    for values in itertools.product(*(space[n] for n in names)):
        knobs = dict(zip(names, values))
        cand = dataclasses.replace(model, pp_overlap=True, **knobs)
        score = _goodput(
            cand, fabric_name, link_gbps, num_servers, iterations, seed
        )
        if score > best_score:
            best_knobs, best_score = dict(knobs, pp_overlap=True), score
    result = TuneResult(
        key=key,
        knobs=best_knobs,
        goodput_tok_s=best_score,
        default_goodput_tok_s=default_score,
    )
    if cache_path:
        cache = _load_cache(cache_path)
        cache[key] = result.to_json()
        os.makedirs(os.path.dirname(os.path.abspath(cache_path)), exist_ok=True)
        with open(cache_path, "w") as f:
            json.dump(cache, f, indent=2, sort_keys=True)
    return result


def apply(model, result: TuneResult):
    """Stamp a tuning result onto a netsim :class:`SimModel`."""
    return dataclasses.replace(model, **result.knobs)


def apply_to_trainer(cfg, tcfg, result: TuneResult):
    """Map a tuning result onto the execution-side configs.

    * ``overlap_chunks`` / dispatch mode -> ``cfg.moe`` (chunk_count degrades
      non-divisors gracefully at run time);
    * ``dp_compress`` -> ``tcfg`` ONLY when the trainer runs the runtime DP
      reduction (``dp_comm='runtime'`` and no PP) — elsewhere the knob has
      no execution path and is dropped rather than raising.

    The a2a lowering and ``pp_overlap`` are pricing-side knobs with no
    separate execution lowering (the data plane always runs the delegation
    a2a), so they do not map.  Returns ``(cfg, tcfg)`` replaced copies.
    """
    k = result.knobs
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe,
            overlap_chunks=int(k.get("overlap_chunks", cfg.moe.overlap_chunks)),
            dispatch=k.get("moe_dispatch", cfg.moe.dispatch),
        )
        cfg = dataclasses.replace(cfg, moe=moe)
    want_compress = bool(k.get("dp_compress", False))
    if want_compress and tcfg.dp_comm == "runtime" and tcfg.pp_stages <= 1:
        tcfg = dataclasses.replace(tcfg, dp_compress=True)
    return cfg, tcfg
