"""MIXNET-COPILOT: traffic demand prediction for the FP's first all-to-all
(paper Appendix B.1).

The first forward all-to-all of layer ``l+1`` cannot be characterized before
the gate of layer ``l+1`` runs — but it *can* be predicted: COPILOT models
the conditional probability ``P[j, i] = Pr(token -> expert j in layer l+1 |
token -> expert i in layer l)`` and predicts the next layer's load as
``P @ x_l``.  ``P`` is fit per layer by weighted least squares over a rolling
window of realized load pairs, constrained to the column-stochastic polytope:

    min_P   sum_i w_i * || y_i - P x_i ||^2
    s.t.    P >= 0,  1^T P = 1^T          (each column a distribution)

The paper uses scipy SLSQP; we solve the identical program with projected
gradient descent in JAX (jit-compiled, deterministic, no scipy dependency in
the hot path) — tests cross-check against scipy on small instances.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "fit_transition_matrix",
    "predict_next_load",
    "topk_accuracy",
    "CopilotPredictor",
]


def _project_columns_to_simplex(p: jax.Array) -> jax.Array:
    """Euclidean projection of every column of ``p`` onto the simplex.

    Duchi et al. (2008) sort-based projection, vmapped over columns.
    """

    def proj(v):
        n = v.shape[0]
        u = jnp.sort(v)[::-1]
        css = jnp.cumsum(u)
        idx = jnp.arange(1, n + 1)
        cond = u - (css - 1.0) / idx > 0
        rho = jnp.max(jnp.where(cond, idx, 0))
        theta = (css[rho - 1] - 1.0) / rho
        return jnp.maximum(v - theta, 0.0)

    return jax.vmap(proj, in_axes=1, out_axes=1)(p)


@partial(jax.jit, static_argnames=("steps",))
def fit_transition_matrix(
    x: jax.Array,
    y: jax.Array,
    weights: jax.Array,
    p_init: jax.Array,
    steps: int = 200,
    lr: float = 0.5,
) -> jax.Array:
    """Fit column-stochastic ``P`` minimizing ``sum_i w_i ||y_i - P x_i||^2``.

    Args:
      x: ``[k, E]`` previous-layer load distributions (rows sum to 1).
      y: ``[k, E]`` next-layer load distributions.
      weights: ``[k]`` window weights (newest-heaviest).
      p_init: ``[E, E]`` warm start (e.g. previous fit or uniform).
      steps: projected-gradient iterations.
    """
    w = weights / (weights.sum() + 1e-12)

    def loss_fn(p):
        pred = x @ p.T  # [k, E]
        return jnp.sum(w[:, None] * (y - pred) ** 2)

    # Lipschitz-ish step size from the data scale.
    scale = jnp.maximum(jnp.sum(w[:, None] * x**2), 1e-6)
    step = lr / scale

    def body(p, _):
        g = jax.grad(loss_fn)(p)
        p = _project_columns_to_simplex(p - step * g)
        return p, ()

    p, _ = jax.lax.scan(body, p_init, None, length=steps)
    return p


def predict_next_load(p: jax.Array, x: jax.Array) -> jax.Array:
    """Predicted next-layer load distribution ``P @ x``."""
    return p @ x


def topk_accuracy(pred: np.ndarray, truth: np.ndarray, k: int) -> float:
    """Fraction of the true top-k experts recovered in the predicted top-k."""
    pred_top = set(np.argsort(-np.asarray(pred))[:k].tolist())
    true_top = set(np.argsort(-np.asarray(truth))[:k].tolist())
    return len(pred_top & true_top) / max(k, 1)


@dataclasses.dataclass
class CopilotState:
    """Per-layer transition matrices ``[L-1, E, E]`` plus the fit window."""

    transitions: np.ndarray
    fitted_steps: int = 0


class CopilotPredictor:
    """Online COPILOT: consume a :class:`TrafficMonitor`, emit predictions.

    Workflow per iteration (mirrors Fig. 20):
      1. ``update(monitor)`` — refit transition matrices from the window.
      2. ``predict(layer, observed_load)`` — forecast layer+1's load from
         layer's realized load, ahead of layer+1's gate.
    """

    def __init__(
        self,
        num_layers: int,
        num_experts: int,
        *,
        window: int = 8,
        decay: float = 0.7,
        fit_steps: int = 150,
    ):
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.window = window
        self.decay = decay
        self.fit_steps = fit_steps
        eye_mix = np.full((num_experts, num_experts), 1.0 / num_experts)
        self.state = CopilotState(
            transitions=np.tile(eye_mix, (max(num_layers - 1, 1), 1, 1))
        )

    def _window_weights(self, k: int) -> np.ndarray:
        # Newest-heaviest exponential decay, as in Eq. (1)'s weighted average.
        w = self.decay ** np.arange(k - 1, -1, -1)
        return w / w.sum()

    @staticmethod
    def _normalize(loads: np.ndarray) -> np.ndarray:
        s = loads.sum(axis=-1, keepdims=True)
        return np.where(s > 0, loads / np.maximum(s, 1e-12), 1.0 / loads.shape[-1])

    def update(self, monitor) -> None:
        """Refit every layer's transition matrix from the monitor window."""
        for layer, x_raw, y_raw in monitor.layer_pairs():
            if len(x_raw) < 2:
                continue
            x = self._normalize(x_raw)
            y = self._normalize(y_raw)
            w = self._window_weights(len(x))
            p0 = jnp.asarray(self.state.transitions[layer])
            p = fit_transition_matrix(
                jnp.asarray(x), jnp.asarray(y), jnp.asarray(w), p0, steps=self.fit_steps
            )
            self.state.transitions[layer] = np.asarray(p)
        self.state.fitted_steps += 1

    def predict(self, layer: int, observed_load: np.ndarray) -> np.ndarray:
        """Forecast layer+1's load distribution from layer's realized load."""
        if layer >= self.num_layers - 1:
            raise ValueError("no next layer to predict")
        x = self._normalize(np.asarray(observed_load, dtype=np.float64))
        return np.asarray(self.state.transitions[layer] @ x)

    # Baselines from Fig. 19 -------------------------------------------------
    @staticmethod
    def baseline_unchanged(observed_load: np.ndarray) -> np.ndarray:
        """'Unchanged topology': assume layer l+1 loads == layer l loads."""
        x = np.asarray(observed_load, dtype=np.float64)
        return x / max(x.sum(), 1e-12)

    def baseline_random(self, rng: np.random.Generator) -> np.ndarray:
        """'Uniform bandwidth allocation': random/uniform expectation."""
        p = rng.random(self.num_experts)
        return p / p.sum()
