"""MIXNET-COPILOT: traffic demand prediction for the FP's first all-to-all
(paper Appendix B.1).

The first forward all-to-all of layer ``l+1`` cannot be characterized before
the gate of layer ``l+1`` runs — but it *can* be predicted: COPILOT models
the conditional probability ``P[j, i] = Pr(token -> expert j in layer l+1 |
token -> expert i in layer l)`` and predicts the next layer's load as
``P @ x_l``.  ``P`` is fit per layer by weighted least squares over a rolling
window of realized load pairs, constrained to the column-stochastic polytope:

    min_P   sum_i w_i * || y_i - P x_i ||^2
    s.t.    P >= 0,  1^T P = 1^T          (each column a distribution)

The paper uses scipy SLSQP; we solve the identical program with projected
gradient descent in JAX (jit-compiled, deterministic, no scipy dependency in
the hot path) — tests cross-check against scipy on small instances.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "fit_transition_matrix",
    "fit_transition_matrices",
    "predict_next_load",
    "topk_accuracy",
    "CopilotPredictor",
]


def _project_columns_to_simplex(p: jax.Array, iters: int = 50) -> jax.Array:
    """Euclidean projection of every column of ``p`` onto the simplex.

    The water-filling threshold ``theta`` (Duchi et al. 2008) solves the
    monotone scalar equation ``sum(max(v - theta, 0)) == 1`` per column; we
    find it by bisection instead of the classical sort.  Elementwise-only,
    so it vectorizes over columns and any leading batch dims — XLA's sort is
    the serial bottleneck of the batched ``[L, E, E]`` refit, while ``iters``
    bisection halvings reach f32 resolution and keep every step a fused
    max/sum over the whole stack.  Accuracy ~1e-7, well inside the fit's
    1e-5 tolerance.
    """
    lo = jnp.min(p, axis=-2, keepdims=True) - 1.0  # sum(max(v-lo,0)) >= 1
    hi = jnp.max(p, axis=-2, keepdims=True)  # sum(max(v-hi,0)) == 0

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        s = jnp.sum(jnp.maximum(p - mid, 0.0), axis=-2, keepdims=True)
        lo = jnp.where(s > 1.0, mid, lo)
        hi = jnp.where(s > 1.0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    theta = 0.5 * (lo + hi)
    return jnp.maximum(p - theta, 0.0)


def _fit_transition(
    x: jax.Array,
    y: jax.Array,
    weights: jax.Array,
    p_init: jax.Array,
    steps: int,
    lr: float,
) -> jax.Array:
    """Projected-gradient core shared by the single and batched entry points.

    Zero-weight rows contribute nothing to the loss, the gradient, or the
    step-size scale, so callers may pad ragged windows with ``w == 0`` rows
    and recover results identical to an unpadded fit.
    """
    w = weights / (weights.sum() + 1e-12)
    # Lipschitz-ish step size from the data scale.
    scale = jnp.maximum(jnp.sum(w[:, None] * x**2), 1e-6)
    step = lr / scale
    xw = x * w[:, None]

    def body(p, _):
        # Analytic gradient of sum_i w_i ||y_i - P x_i||^2 (identical to
        # jax.grad of the quadratic, without the transpose-heavy VJP graph).
        pred = x @ p.T  # [k, E]
        g = 2.0 * (pred - y).T @ xw
        p = _project_columns_to_simplex(p - step * g)
        return p, ()

    p, _ = jax.lax.scan(body, p_init, None, length=steps)
    return p


@partial(jax.jit, static_argnames=("steps",))
def fit_transition_matrix(
    x: jax.Array,
    y: jax.Array,
    weights: jax.Array,
    p_init: jax.Array,
    steps: int = 200,
    lr: float = 0.5,
) -> jax.Array:
    """Fit column-stochastic ``P`` minimizing ``sum_i w_i ||y_i - P x_i||^2``.

    Args:
      x: ``[k, E]`` previous-layer load distributions (rows sum to 1).
      y: ``[k, E]`` next-layer load distributions.
      weights: ``[k]`` window weights (newest-heaviest).
      p_init: ``[E, E]`` warm start (e.g. previous fit or uniform).
      steps: projected-gradient iterations.
    """
    return _fit_transition(x, y, weights, p_init, steps, lr)


@partial(jax.jit, static_argnames=("steps",))
def fit_transition_matrices(
    x: jax.Array,
    y: jax.Array,
    weights: jax.Array,
    p_init: jax.Array,
    steps: int = 200,
    lr: float = 0.5,
) -> jax.Array:
    """Batched refit: every layer's transition in ONE compiled call.

    ``x``/``y`` are ``[L, k, E]`` stacked windows, ``weights`` ``[L, k]``,
    ``p_init`` ``[L, E, E]``.  vmapping the projected-gradient solve across
    layers replaces the per-layer jit-call Python loop the predictor used to
    run — one dispatch instead of L, and XLA fuses the whole batch (see the
    ``copilot_refit`` benchmark for the measured speedup).  Ragged windows
    are handled by zero-weight padding rows, which leave the per-layer
    solutions bit-for-bit unaffected.
    """
    return jax.vmap(
        lambda xl, yl, wl, pl: _fit_transition(xl, yl, wl, pl, steps, lr)
    )(x, y, weights, p_init)


def predict_next_load(p: jax.Array, x: jax.Array) -> jax.Array:
    """Predicted next-layer load distribution ``P @ x``."""
    return p @ x


def topk_accuracy(pred: np.ndarray, truth: np.ndarray, k: int) -> float:
    """Fraction of the true top-k experts recovered in the predicted top-k."""
    pred_top = set(np.argsort(-np.asarray(pred))[:k].tolist())
    true_top = set(np.argsort(-np.asarray(truth))[:k].tolist())
    return len(pred_top & true_top) / max(k, 1)


@dataclasses.dataclass
class CopilotState:
    """Per-layer transition matrices ``[L-1, E, E]`` plus the fit window."""

    transitions: np.ndarray
    fitted_steps: int = 0


class CopilotPredictor:
    """Online COPILOT: consume a :class:`TrafficMonitor`, emit predictions.

    Workflow per iteration (mirrors Fig. 20):
      1. ``update(monitor)`` — refit transition matrices from the window.
      2. ``predict(layer, observed_load)`` — forecast layer+1's load from
         layer's realized load, ahead of layer+1's gate.
    """

    def __init__(
        self,
        num_layers: int,
        num_experts: int,
        *,
        window: int = 8,
        decay: float = 0.7,
        fit_steps: int = 150,
        batched_refit: bool = True,
    ):
        self.num_layers = num_layers
        self.num_experts = num_experts
        self.window = window
        self.decay = decay
        self.fit_steps = fit_steps
        self.batched_refit = batched_refit
        eye_mix = np.full((num_experts, num_experts), 1.0 / num_experts)
        self.state = CopilotState(
            transitions=np.tile(eye_mix, (max(num_layers - 1, 1), 1, 1))
        )

    def _window_weights(self, k: int) -> np.ndarray:
        # Newest-heaviest exponential decay, as in Eq. (1)'s weighted average.
        w = self.decay ** np.arange(k - 1, -1, -1)
        return w / w.sum()

    @staticmethod
    def _normalize(loads: np.ndarray) -> np.ndarray:
        s = loads.sum(axis=-1, keepdims=True)
        return np.where(s > 0, loads / np.maximum(s, 1e-12), 1.0 / loads.shape[-1])

    def update(self, monitor) -> None:
        """Refit every layer's transition matrix from the monitor window.

        With ``batched_refit`` (the default) all layers are fit in one
        vmapped :func:`fit_transition_matrices` call; the per-layer loop is
        kept (``batched_refit=False``) as the reference implementation the
        ``copilot_refit`` benchmark compares against.
        """
        pairs = [
            (layer, x, y) for layer, x, y in monitor.layer_pairs() if len(x) >= 2
        ]
        if pairs:
            if self.batched_refit:
                self._refit_batched(pairs)
            else:
                self._refit_looped(pairs)
        self.state.fitted_steps += 1

    def _refit_looped(self, pairs) -> None:
        for layer, x_raw, y_raw in pairs:
            x = self._normalize(x_raw)
            y = self._normalize(y_raw)
            w = self._window_weights(len(x))
            p0 = jnp.asarray(self.state.transitions[layer])
            p = fit_transition_matrix(
                jnp.asarray(x), jnp.asarray(y), jnp.asarray(w), p0, steps=self.fit_steps
            )
            self.state.transitions[layer] = np.asarray(p)

    def _refit_batched(self, pairs) -> None:
        # Stack ragged per-layer windows into [Lp, kmax, E] with zero-weight
        # padding rows (numerically inert — see _fit_transition).
        e = self.num_experts
        kmax = max(len(x) for _, x, _ in pairs)
        xs = np.zeros((len(pairs), kmax, e))
        ys = np.zeros((len(pairs), kmax, e))
        ws = np.zeros((len(pairs), kmax))
        p0 = np.stack([self.state.transitions[layer] for layer, _, _ in pairs])
        for i, (_, x_raw, y_raw) in enumerate(pairs):
            k = len(x_raw)
            xs[i, :k] = self._normalize(x_raw)
            ys[i, :k] = self._normalize(y_raw)
            ws[i, :k] = self._window_weights(k)
        fitted = np.asarray(
            fit_transition_matrices(
                jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(ws), jnp.asarray(p0),
                steps=self.fit_steps,
            )
        )
        for i, (layer, _, _) in enumerate(pairs):
            self.state.transitions[layer] = fitted[i]

    def predict(self, layer: int, observed_load: np.ndarray) -> np.ndarray:
        """Forecast layer+1's load distribution from layer's realized load."""
        if layer >= self.num_layers - 1:
            raise ValueError("no next layer to predict")
        x = self._normalize(np.asarray(observed_load, dtype=np.float64))
        return np.asarray(self.state.transitions[layer] @ x)

    def rollout(self, entry_load: np.ndarray) -> np.ndarray:
        """Forecast every layer's expert mix from an entry-layer mix.

        Chains the fitted per-layer transition matrices: ``mix[0]`` is the
        normalized entry load and ``mix[l+1] = P_l @ mix[l]`` (renormalized
        against drift from the simplex projection's tolerance).  Returns
        ``[num_layers, num_experts]``.

        This is the fleet steering predictor (DESIGN.md §12): a request's
        region determines its *entry* mix (region-conditioned gate stats),
        and the rollout turns that into the full per-layer mix the locality
        score compares against each replica's resident placement.
        """
        x = self._normalize(np.asarray(entry_load, dtype=np.float64))
        mixes = [x]
        for layer in range(self.num_layers - 1):
            x = self.state.transitions[layer] @ x
            x = x / max(float(x.sum()), 1e-12)
            mixes.append(x)
        return np.stack(mixes)

    # Baselines from Fig. 19 -------------------------------------------------
    @staticmethod
    def baseline_unchanged(observed_load: np.ndarray) -> np.ndarray:
        """'Unchanged topology': assume layer l+1 loads == layer l loads."""
        x = np.asarray(observed_load, dtype=np.float64)
        return x / max(x.sum(), 1e-12)

    def baseline_random(self, rng: np.random.Generator) -> np.ndarray:
        """'Uniform bandwidth allocation': random/uniform expectation."""
        p = rng.random(self.num_experts)
        return p / p.sum()
