"""MixNet (SIGCOMM'25) on JAX/TPU.

The paper's runtime-reconfigurable MoE training fabric as a production-grade
framework: control plane (`repro.core`), substrate model zoo
(`repro.models`), Pallas TPU kernels (`repro.kernels`), distribution rules
(`repro.parallel`), training/serving runtimes (`repro.train`, `repro.serve`)
and the multi-pod launcher (`repro.launch`).  See DESIGN.md for the paper ->
TPU adaptation map and EXPERIMENTS.md for dry-run/roofline/perf records.
"""
