"""Pallas TPU kernels for the compute hot spots (+ jnp oracles).

``grouped_matmul`` — per-expert GEMM (MoE FFN); ``topk_gating`` — fused
router; ``flash_attention`` — blockwise attention with GQA / sliding window /
softcap.  Use :mod:`repro.kernels.ops` as the entry point.
"""

from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
