"""Grouped (per-expert) matmul Pallas kernel — the MoE expert-FFN hot spot.

Two layouts, one accumulation scheme:

* capacity dispatch leaves ``x[E_local, C, D]`` per-expert buffers next to
  stacked weights ``w[E_local, D, F]`` (``grouped_matmul_pallas``);
* dropless sort-based dispatch (:mod:`repro.models.routing`) leaves a
  block-padded ``x[n, B, D]`` row-tile layout plus a block->expert map
  (``grouped_matmul_blocks_pallas``, scalar-prefetched weight indexing).

Either way the kernel tiles output blocks into VMEM with a ``D``-step
accumulation loop so the MXU sees aligned ``(bc x bd) @ (bd x bf)`` tiles and
the working set (``bc*bd + bd*bf + bc*bf`` elements) stays inside the ~16 MB
VMEM budget.

TPU is the target; CPU validation runs in ``interpret=True`` mode against
:func:`repro.kernels.ref.grouped_matmul`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["grouped_matmul_pallas", "grouped_matmul_blocks_pallas", "pick_block"]


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= ``target`` (hardware-aligned
    blocks when the caller passes multiples of 128)."""
    if dim <= target:
        return dim
    for b in range(target, 0, -1):
        if dim % b == 0:
            return b
    return dim


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    """One (expert, c-block, f-block) output tile; grid axis 3 walks D."""

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bc", "bf", "bd", "interpret")
)
def grouped_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    bc: int = 128,
    bf: int = 128,
    bd: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """``[E, C, D] @ [E, D, F] -> [E, C, F]`` with per-expert tiling."""
    e, c, d = x.shape
    e2, d2, f = w.shape
    if (e, d) != (e2, d2):
        raise ValueError(f"shape mismatch {x.shape} @ {w.shape}")
    bc = pick_block(c, bc)
    bf = pick_block(f, bf)
    bd = pick_block(d, bd)
    k_steps = d // bd
    grid = (e, c // bc, f // bf, k_steps)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda ei, ci, fi, ki: (ei, ci, ki)),
            pl.BlockSpec((1, bd, bf), lambda ei, ci, fi, ki: (ei, ki, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda ei, ci, fi, ki: (ei, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)


def _gmm_blocks_kernel(be_ref, x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    """One (row-block, f-block) output tile; grid axis 2 walks D.  The weight
    block is addressed by the scalar-prefetched block->expert map, so each
    row tile multiplies against *its own* expert's weights — the MegaBlocks
    dropless layout with no per-expert padding to a common capacity."""
    del be_ref  # consumed by the index maps

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bf", "bd", "interpret"))
def grouped_matmul_blocks_pallas(
    x: jax.Array,
    w: jax.Array,
    block_experts: jax.Array,
    *,
    bf: int = 128,
    bd: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """``[n, B, D] @ w[block_experts[n], D, F] -> [n, B, F]``.

    ``x`` is the block-padded dropless token layout from
    :func:`repro.models.routing.dropless_plan`: ``n`` row tiles of ``B``
    tokens, tile ``i`` owned entirely by expert ``block_experts[i]``.
    """
    n, b, d = x.shape
    e, d2, f = w.shape
    if d != d2:
        raise ValueError(f"shape mismatch {x.shape} @ {w.shape}")
    bf = pick_block(f, bf)
    bd = pick_block(d, bd)
    k_steps = d // bd
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n, f // bf, k_steps),
        in_specs=[
            pl.BlockSpec((1, b, bd), lambda ni, fi, ki, be: (ni, 0, ki)),
            pl.BlockSpec((1, bd, bf), lambda ni, fi, ki, be: (be[ni], ki, fi)),
        ],
        out_specs=pl.BlockSpec((1, b, bf), lambda ni, fi, ki, be: (ni, 0, fi)),
        scratch_shapes=[pltpu.VMEM((b, bf), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_gmm_blocks_kernel, k_steps=k_steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, b, f), x.dtype),
        interpret=interpret,
    )(block_experts.astype(jnp.int32), x, w)
