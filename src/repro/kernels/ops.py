"""Public kernel entry points.

Each op dispatches between the Pallas TPU kernel and the pure-jnp reference:

  * ``backend="auto"``  — Pallas on TPU, reference elsewhere (CPU containers
    validate kernels in interpret mode through the tests, but run models on
    the reference path for speed).
  * ``backend="pallas"`` — force the kernel (interpret=True off-TPU).
  * ``backend="ref"``   — force the oracle.
"""

from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.topk_gating import topk_gating_pallas

__all__ = [
    "grouped_matmul", "topk_gating", "flash_attention", "rmsnorm",
    "ssd_chunk", "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if on_tpu() else "chunked"
    return backend


def _resolve_simple(backend: str) -> str:
    """For ops with no chunked variant: auto -> pallas on TPU, ref off it."""
    mode = _resolve(backend)
    return "ref" if mode == "chunked" else mode


def grouped_matmul(x, w, *, backend: str = "auto"):
    mode = _resolve_simple(backend)
    if mode == "pallas":
        return grouped_matmul_pallas(x, w, interpret=not on_tpu())
    return ref.grouped_matmul(x, w)


def topk_gating(logits, k: int, *, backend: str = "auto"):
    mode = _resolve_simple(backend)
    if mode == "pallas":
        return topk_gating_pallas(logits, k, interpret=not on_tpu())
    return ref.topk_gating(logits, k)


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    backend: str = "auto",
):
    mode = _resolve(backend)
    if mode == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            interpret=not on_tpu(),
        )
    if mode == "ref":
        return ref.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap
        )
    # auto off-TPU: chunked memory-efficient path so big-S graphs lower with
    # bounded buffers (semantically identical to ref; tested).
    return ref.flash_attention_chunked(
        q, k, v, causal=causal, window=window, softcap=softcap
    )


def rmsnorm(x, w, *, eps: float = 1e-6, backend: str = "auto"):
    """Fused RMSNorm over [T, D] tokens (TPU kernel; jnp path elsewhere)."""
    mode = _resolve_simple(backend)
    if mode == "pallas":
        from repro.kernels.rmsnorm import rmsnorm_pallas

        return rmsnorm_pallas(x, w, eps=eps, interpret=not on_tpu())
    from repro.models.layers import rms_norm

    return rms_norm(x[None], w, eps)[0]


def ssd_chunk(x, da, bmat, cmat, *, backend: str = "auto"):
    """Mamba-2 SSD intra-chunk compute: (y_intra, chunk_state)."""
    mode = _resolve_simple(backend)
    if mode == "pallas":
        from repro.kernels.ssd_chunk import ssd_chunk_pallas

        return ssd_chunk_pallas(x, da, bmat, cmat, interpret=not on_tpu())
    import jax.numpy as jnp
    import numpy as np

    l = x.shape[1]
    cum = jnp.cumsum(da, axis=1)
    cb = jnp.einsum("gln,gsn->gls", cmat, bmat)
    gate = jnp.exp(cum[:, :, None] - cum[:, None, :])
    mask = np.tril(np.ones((l, l), bool))
    y = jnp.einsum("gls,gls,gsp->glp", cb, jnp.where(mask, gate, 0.0), x)
    st = jnp.einsum("gsn,gs,gsp->gnp", bmat, jnp.exp(cum[:, -1:] - cum), x)
    return y, st
