"""Public kernel entry points.

Each op dispatches between the Pallas TPU kernel and the pure-jnp reference:

  * ``backend="auto"``  — Pallas on TPU, reference elsewhere (CPU containers
    validate kernels in interpret mode through the tests, but run models on
    the reference path for speed).
  * ``backend="pallas"`` — force the kernel (interpret=True off-TPU).
  * ``backend="ref"``   — force the oracle.
"""

from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import (
    flash_attention_pallas,
    paged_flash_decode_pallas,
)
from repro.kernels.grouped_matmul import (
    grouped_matmul_blocks_pallas,
    grouped_matmul_pallas,
)
from repro.kernels.moe_dispatch import moe_combine_pallas, moe_dispatch_pallas
from repro.kernels.topk_gating import topk_gating_pallas

__all__ = [
    "grouped_matmul", "topk_gating", "moe_dispatch", "moe_combine",
    "flash_attention", "paged_flash_decode", "rmsnorm", "ssd_chunk", "on_tpu",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if on_tpu() else "chunked"
    return backend


def _resolve_simple(backend: str) -> str:
    """For ops with no chunked variant: auto -> pallas on TPU, ref off it."""
    mode = _resolve(backend)
    return "ref" if mode == "chunked" else mode


def grouped_matmul(x, w, *, block_experts=None, backend: str = "auto"):
    """Per-expert GEMM.  ``block_experts=None``: capacity layout ``[E, C, D]``
    against ``w [E, D, F]``; with a ``[n]`` block->expert map: dropless block
    layout ``[n, B, D]`` (rows of tile ``i`` use ``w[block_experts[i]]``)."""
    mode = _resolve_simple(backend)
    if block_experts is None:
        if mode == "pallas":
            return grouped_matmul_pallas(x, w, interpret=not on_tpu())
        return ref.grouped_matmul(x, w)
    if mode == "pallas":
        return grouped_matmul_blocks_pallas(
            x, w, block_experts, interpret=not on_tpu()
        )
    return ref.grouped_matmul_blocks(x, w, block_experts)


def moe_dispatch(x, src, *, backend: str = "auto"):
    """Gather token rows ``x [T, D]`` into a packed layout by ``src [P]``
    (i32 source row per slot, -1 = empty -> zeros)."""
    mode = _resolve_simple(backend)
    if mode == "pallas":
        return moe_dispatch_pallas(x, src, interpret=not on_tpu())
    return ref.moe_dispatch(x, src)


def moe_combine(y, slot, weights, *, backend: str = "auto"):
    """Weighted combine of packed rows ``y [P, D]`` back to token order via
    ``slot``/``weights [T, S]`` (f32 result; ``slot < 0`` terms skipped)."""
    mode = _resolve_simple(backend)
    if mode == "pallas":
        return moe_combine_pallas(y, slot, weights, interpret=not on_tpu())
    return ref.moe_combine(y, slot, weights)


def topk_gating(logits, k: int, *, backend: str = "auto"):
    mode = _resolve_simple(backend)
    if mode == "pallas":
        return topk_gating_pallas(logits, k, interpret=not on_tpu())
    return ref.topk_gating(logits, k)


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    backend: str = "auto",
):
    mode = _resolve(backend)
    if mode == "pallas":
        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, softcap=softcap,
            interpret=not on_tpu(),
        )
    if mode == "ref":
        return ref.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap
        )
    # auto off-TPU: chunked memory-efficient path so big-S graphs lower with
    # bounded buffers (semantically identical to ref; tested).
    return ref.flash_attention_chunked(
        q, k, v, causal=causal, window=window, softcap=softcap
    )


def paged_flash_decode(
    q,
    k_pool,
    v_pool,
    page_table,
    lengths,
    *,
    window: int | None = None,
    softcap: float | None = None,
    backend: str = "auto",
):
    """Decode / chunked-continuation attention through a paged KV cache:
    ``q [B, C, Hq, D]`` against pools ``[N, page, Hkv, D]`` gathered via
    ``page_table [B, P]`` (i32 page ids, -1 = unallocated) with per-sequence
    ``lengths [B]``.  Both paths run the same streaming-softmax schedule, so
    pallas-vs-ref is bit-exact (tested in interpret mode).  ``C > 1`` also
    carries speculative verify spans (K drafted tokens + 1): bit-exactness
    across C is what lets verify's rescoring reproduce the serial decode
    rounding token for token (DESIGN.md §11), including the padded C=2
    tile."""
    mode = _resolve_simple(backend)
    if mode == "pallas":
        return paged_flash_decode_pallas(
            q, k_pool, v_pool, page_table, lengths,
            window=window, softcap=softcap, interpret=not on_tpu(),
        )
    return ref.paged_flash_decode(
        q, k_pool, v_pool, page_table, lengths, window=window, softcap=softcap
    )


def rmsnorm(x, w, *, eps: float = 1e-6, backend: str = "auto"):
    """Fused RMSNorm over [T, D] tokens (TPU kernel; jnp path elsewhere)."""
    mode = _resolve_simple(backend)
    if mode == "pallas":
        from repro.kernels.rmsnorm import rmsnorm_pallas

        return rmsnorm_pallas(x, w, eps=eps, interpret=not on_tpu())
    from repro.models.layers import rms_norm

    return rms_norm(x[None], w, eps)[0]


def ssd_chunk(x, da, bmat, cmat, *, backend: str = "auto"):
    """Mamba-2 SSD intra-chunk compute: (y_intra, chunk_state)."""
    mode = _resolve_simple(backend)
    if mode == "pallas":
        from repro.kernels.ssd_chunk import ssd_chunk_pallas

        return ssd_chunk_pallas(x, da, bmat, cmat, interpret=not on_tpu())
    import jax.numpy as jnp
    import numpy as np

    l = x.shape[1]
    cum = jnp.cumsum(da, axis=1)
    cb = jnp.einsum("gln,gsn->gls", cmat, bmat)
    gate = jnp.exp(cum[:, :, None] - cum[:, None, :])
    mask = np.tril(np.ones((l, l), bool))
    y = jnp.einsum("gls,gls,gsp->glp", cb, jnp.where(mask, gate, 0.0), x)
    st = jnp.einsum("gsn,gs,gsp->gnp", bmat, jnp.exp(cum[:, -1:] - cum), x)
    return y, st
