"""Fused softmax + top-k router Pallas kernel (the MoE gate unit).

The gate is tiny FLOP-wise but sits on the critical path before the dispatch
all-to-all (§5.1: its output *is* the traffic matrix), so fusing the softmax,
the k iterative arg-max passes and the probability normalization into one
VMEM-resident pass over the ``[T, E]`` logits removes several HBM round
trips.  Token blocks ride the grid; the expert axis stays whole (E <= a few
hundred fits VMEM trivially).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.grouped_matmul import pick_block

__all__ = ["topk_gating_pallas"]


def _gating_kernel(logits_ref, w_ref, i_ref, *, k: int):
    x = logits_ref[...].astype(jnp.float32)  # [bt, E]
    m = jnp.max(x, axis=-1, keepdims=True)
    ex = jnp.exp(x - m)
    probs = ex / jnp.sum(ex, axis=-1, keepdims=True)
    cur = probs
    ws, ids = [], []
    for _ in range(k):
        idx = jnp.argmax(cur, axis=-1)
        val = jnp.max(cur, axis=-1)
        ws.append(val)
        ids.append(idx.astype(jnp.int32))
        # Mask the chosen expert out for the next pass.
        onehot = jax.nn.one_hot(idx, cur.shape[-1], dtype=cur.dtype)
        cur = cur - onehot * val[:, None]
    w_ref[...] = jnp.stack(ws, axis=-1)
    i_ref[...] = jnp.stack(ids, axis=-1)


@functools.partial(jax.jit, static_argnames=("k", "bt", "interpret"))
def topk_gating_pallas(
    logits: jax.Array,
    k: int,
    *,
    bt: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """``[T, E]`` logits -> (``[T, k]`` f32 weights, ``[T, k]`` i32 indices)."""
    t, e = logits.shape
    bt = pick_block(t, bt)
    grid = (t // bt,)
    w, i = pl.pallas_call(
        functools.partial(_gating_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((bt, e), lambda ti: (ti, 0))],
        out_specs=[
            pl.BlockSpec((bt, k), lambda ti: (ti, 0)),
            pl.BlockSpec((bt, k), lambda ti: (ti, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, k), jnp.float32),
            jax.ShapeDtypeStruct((t, k), jnp.int32),
        ],
        interpret=interpret,
    )(logits)
    return w, i
