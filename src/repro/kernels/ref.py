"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's test sweeps shapes/dtypes
and asserts allclose against these functions, and on non-TPU backends the
``ops`` wrappers route here (interpret-mode Pallas is for validation, not
speed).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "grouped_matmul",
    "grouped_matmul_blocks",
    "moe_dispatch",
    "moe_combine",
    "topk_gating",
    "flash_attention",
    "flash_attention_chunked",
    "paged_flash_decode",
    "paged_gather_kv",
]


def grouped_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Per-expert GEMM: ``[E, C, D] @ [E, D, F] -> [E, C, F]`` (f32 accum)."""
    out = jnp.einsum(
        "ecd,edf->ecf", x.astype(jnp.float32), w.astype(jnp.float32)
    )
    return out.astype(x.dtype)


def grouped_matmul_blocks(
    x: jax.Array, w: jax.Array, block_experts: jax.Array
) -> jax.Array:
    """Block-wise grouped GEMM: ``[n, B, D] @ w[block_experts[n], D, F]``.

    Oracle for the dropless (MegaBlocks) layout: a ``lax.scan`` over row
    tiles gathers ONE expert's ``[D, F]`` weights per step, so peak memory
    stays O(D·F) instead of materializing the ``[n, D, F]`` weight gather.
    """

    def step(_, xs):
        xb, be = xs
        yb = xb.astype(jnp.float32) @ w[be].astype(jnp.float32)
        return _, yb.astype(x.dtype)

    _, out = jax.lax.scan(step, None, (x, block_experts))
    return out


def moe_dispatch(x: jax.Array, src: jax.Array) -> jax.Array:
    """Gather token rows into a packed dispatch layout.

    Args:
      x: ``[T, D]`` token rows.
      src: ``[P]`` i32 source row per packed slot, -1 for empty/padding.
    Returns:
      ``[P, D]``: ``x[src[p]]`` where ``src[p] >= 0``, zeros elsewhere.
    """
    rows = jnp.take(x, jnp.clip(src, 0, x.shape[0] - 1), axis=0)
    return jnp.where(src[:, None] >= 0, rows, 0).astype(x.dtype)


def moe_combine(y: jax.Array, slot: jax.Array, weights: jax.Array) -> jax.Array:
    """Weighted combine back to token order (f32 accumulation).

    Args:
      y: ``[P, D]`` packed expert outputs.
      slot: ``[T, S]`` i32 packed row per (token, choice), -1 if dropped.
      weights: ``[T, S]`` combine weights.
    Returns:
      ``[T, D]`` f32: ``out[t] = Σ_s w[t,s] · y[slot[t,s]]`` over kept terms.
    """
    rows = jnp.take(y, jnp.clip(slot, 0, y.shape[0] - 1), axis=0)  # [T, S, D]
    w = jnp.where(slot >= 0, weights.astype(jnp.float32), 0.0)
    return jnp.sum(rows.astype(jnp.float32) * w[..., None], axis=1)


def topk_gating(logits: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Softmax over experts then top-k.

    Args:
      logits: ``[T, E]`` router logits.
    Returns:
      (weights ``[T, k]`` f32 softmax probabilities of the chosen experts,
       indices ``[T, k]`` i32, descending by probability).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, k)
    return weights, idx.astype(jnp.int32)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """Reference attention with GQA, causal/sliding-window mask, logit softcap.

    Shapes: q ``[B, Hq, S, D]``, k/v ``[B, Hkv, S, D]`` with Hq % Hkv == 0.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx.astype(jnp.float32))
    logits = logits * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    bq: int = 512,
) -> jax.Array:
    """Memory-efficient attention: ``lax.scan`` over query chunks —
    O(S * bq) score memory instead of O(S^2).

    Same semantics as :func:`flash_attention`; this is the pure-jnp path the
    *models* use off-TPU so that 32k+ prefill graphs lower with bounded
    buffers (the Pallas kernel covers the TPU target).  Partitioner-friendly
    by construction: every tensor keeps the ``[B, H, S, D]`` layout (chunks
    via dynamic slices on the seq dim, output accumulated in place), and the
    dots take bf16 operands with f32 accumulation — the heads dim shards
    cleanly with zero collectives.
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    while s % bq:
        bq //= 2
    nq = s // bq
    scale = 1.0 / float(d) ** 0.5
    kpos = jnp.arange(s)

    def body(out, qstart):
        qc = jax.lax.dynamic_slice_in_dim(q, qstart, bq, axis=2)  # [B,H,bq,D]
        logits = jnp.einsum(
            "bhqd,bhkd->bhqk", qc, k, preferred_element_type=jnp.float32
        )
        logits = logits * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        qpos = qstart + jnp.arange(bq)
        mask = jnp.ones((bq, s), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        logits = jnp.where(mask[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        oc = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        out = jax.lax.dynamic_update_slice_in_dim(out, oc, qstart, axis=2)
        return out, None

    starts = jnp.arange(nq) * bq
    out, _ = jax.lax.scan(body, jnp.zeros_like(q), starts)
    return out


def paged_gather_kv(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """Gather a paged pool into a contiguous per-sequence view.

    Args:
      pool: ``[N, page, ...]`` page pool (K, V, or any per-position leaf).
      page_table: ``[B, P]`` i32 page ids, -1 = unallocated (gathered as page
        0 — callers mask positions past the sequence length).
    Returns:
      ``[B, P * page, ...]``: position ``pos`` of sequence ``b`` at view
      index ``pos`` (page ``pos // page``, offset ``pos % page``).
    """
    gathered = jnp.take(pool, jnp.maximum(page_table, 0), axis=0)  # [B,P,page,...]
    b, p, page = gathered.shape[:3]
    return gathered.reshape(b, p * page, *gathered.shape[3:])


@functools.partial(jax.jit, static_argnames=("window", "softcap"))
def paged_flash_decode(
    q: jax.Array,  # [B, C, Hq, D]
    k_pool: jax.Array,  # [N, page, Hkv, D]
    v_pool: jax.Array,
    page_table: jax.Array,  # [B, P] i32, -1 = unallocated
    lengths: jax.Array,  # [B] i32 — chunk row c attends positions <= t + c
    *,
    window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """Oracle for :func:`..flash_attention.paged_flash_decode_pallas`.

    Mirrors the kernel's exact execution structure: one ``fori_loop`` over
    the flattened ``(B, Hq, pages)`` grid (pages innermost, like the TPU
    grid), the same ``[C, D] x [page, D]`` 2D tile dots, select-based scratch
    init at ``p == 0``, and the identical streaming-softmax recurrence.
    Deliberately NOT a batched einsum formulation and jitted at the
    definition: batching the dots or unrolling the page loop changes XLA's
    contraction/FMA-fusion choices and drifts from the interpret-mode kernel
    by ~1 ulp per page, while this loop form is bit-exact (asserted
    ``== 0.0`` in the tests — including the C in {1, 2, 4} speculative
    verify-span widths; the kernel pads its C=2 tile to 4 because a 2-row
    dot picks a different XLA contraction strategy).  Models read paged
    caches off-TPU through a dense gathered view instead (see
    ``models/layers.py``); this function is the kernel's semantics of
    record.
    """
    b, c, hq, d = q.shape
    _, page, hkv, _ = k_pool.shape
    pages = page_table.shape[1]
    group = hq // hkv
    scale = 1.0 / (d**0.5)
    table = page_table.astype(jnp.int32)
    lens = jnp.broadcast_to(jnp.asarray(lengths, jnp.int32), (b,))

    def body(i, carry):
        m, l, acc, out = carry
        bi = i // (hq * pages)
        hi = (i // pages) % hq
        p = i % pages
        m = jnp.where(p == 0, jnp.full_like(m, -1e30), m)
        l = jnp.where(p == 0, jnp.zeros_like(l), l)
        acc = jnp.where(p == 0, jnp.zeros_like(acc), acc)
        qt = jax.lax.dynamic_slice(q, (bi, 0, hi, 0), (1, c, 1, d))[0, :, 0, :]
        pid = jnp.maximum(table[bi, p], 0)
        kh = hi // group
        k = jax.lax.dynamic_slice(
            k_pool, (pid, 0, kh, 0), (1, page, 1, d)
        )[0, :, 0, :]
        v = jax.lax.dynamic_slice(
            v_pool, (pid, 0, kh, 0), (1, page, 1, d)
        )[0, :, 0, :]
        s = jnp.dot(
            qt.astype(jnp.float32) * scale,
            k.astype(jnp.float32).T,
            preferred_element_type=jnp.float32,
        )  # [C, page]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = lens[bi] + jax.lax.broadcasted_iota(jnp.int32, (c, page), 0)
        k_pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (c, page), 1)
        mask = k_pos <= q_pos
        if window is not None:
            mask &= q_pos - k_pos < window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p_tile = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p_tile, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(
            p_tile, v.astype(jnp.float32), preferred_element_type=jnp.float32
        )
        # The same (bi, hi) output block is revisited for every p; the last
        # visit (p == pages - 1) leaves the final normalized tile in place.
        denom = jnp.where(l > 0.0, l, 1.0)
        o = (acc / denom).astype(q.dtype)
        out = jax.lax.dynamic_update_slice(out, o[None, :, None, :], (bi, 0, hi, 0))
        return (m_new, l, acc, out)

    init = (
        jnp.full((c, 1), -1e30, jnp.float32),
        jnp.zeros((c, 1), jnp.float32),
        jnp.zeros((c, d), jnp.float32),
        jnp.zeros(q.shape, q.dtype),
    )
    _, _, _, out = jax.lax.fori_loop(0, b * hq * pages, body, init)
    return out
