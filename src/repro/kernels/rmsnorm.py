"""Fused RMSNorm Pallas kernel.

Every block applies 2-3 RMSNorms per layer; unfused, each costs three HBM
round trips (read x, write mean-square, read+scale).  The kernel keeps a
``(bt x D)`` token tile VMEM-resident and fuses the square-mean, rsqrt and
scale into one pass — one read + one write of x per norm.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.grouped_matmul import pick_block

__all__ = ["rmsnorm_pallas"]


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [bt, D]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "bt", "interpret"))
def rmsnorm_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-6,
    bt: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """``x [T, D] * rsqrt(mean(x^2)) * (1 + w)`` — token tiles in VMEM."""
    t, d = x.shape
    bt = pick_block(t, bt)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=interpret,
    )(x, w)
