"""Mamba-2 SSD intra-chunk Pallas kernel.

The SSD dual form's dominant compute is the per-chunk quadratic part:

    y[l] = sum_{s<=l} (C_l . B_s) * exp(cum_a[l] - cum_a[s]) * x_s
    state = sum_s B_s^T (exp(cum_a[L-1] - cum_a[s]) * x_s)

One grid cell = one (batch*head, chunk): the [L, N] B/C tiles, the [L, P]
dt-weighted inputs and the [L] decay prefix all live in VMEM; the kernel
fuses the C@B^T GEMM, the causal decay gating, the gated [L,L]@[L,P] GEMM
and the chunk-state GEMM into one pass (the jnp path materializes the
[L, L, H] gate tensor in HBM).  The sequential inter-chunk recurrence stays
outside (it is O(chunks) tiny GEMMs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_chunk_pallas"]


def _ssd_kernel(x_ref, da_ref, b_ref, c_ref, y_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)  # [L, P]
    cum = jnp.cumsum(da_ref[0].astype(jnp.float32))  # [L]
    bmat = b_ref[0].astype(jnp.float32)  # [L, N]
    cmat = c_ref[0].astype(jnp.float32)  # [L, N]
    l = x.shape[0]

    cb = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32)  # [L, L]
    decay = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1) <= jax.lax.broadcasted_iota(
        jnp.int32, (l, l), 0
    )
    gate = jnp.where(mask, jnp.exp(decay), 0.0)
    y_ref[0] = jnp.dot(cb * gate, x, preferred_element_type=jnp.float32).astype(
        y_ref.dtype
    )
    decay_to_end = jnp.exp(cum[-1] - cum)  # [L]
    s_ref[0] = jnp.dot(
        bmat.T, x * decay_to_end[:, None], preferred_element_type=jnp.float32
    ).astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(
    x: jax.Array,  # [G, L, P] dt-weighted inputs (G = batch*heads*chunks)
    da: jax.Array,  # [G, L] per-step log-decay (dt * A)
    bmat: jax.Array,  # [G, L, N]
    cmat: jax.Array,  # [G, L, N]
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y_intra [G, L, P], chunk_state [G, N, P])."""
    g, l, p = x.shape
    n = bmat.shape[-1]
    return pl.pallas_call(
        _ssd_kernel,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, l, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, l), lambda i: (i, 0)),
            pl.BlockSpec((1, l, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, l, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, l, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, p), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, l, p), jnp.float32),
            jax.ShapeDtypeStruct((g, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(x, da, bmat, cmat)
