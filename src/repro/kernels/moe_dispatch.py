"""Fused MoE dispatch/combine Pallas kernels (the sort-based data movers).

After :mod:`repro.models.routing` has computed the argsort-by-expert token
permutation, the remaining hot-path work is pure data movement:

* ``moe_dispatch`` — gather token rows into the packed expert layout:
  ``out[p] = x[src[p]]`` (zeros where ``src[p] < 0``, i.e. padding/drops).
* ``moe_combine`` — weighted gather-sum back to token order:
  ``out[t] = Σ_s w[t, s] · y[slot[t, s]]`` (terms with ``slot < 0`` skipped).

Both kernels drive the gather with **scalar-prefetched** index arrays
(``pltpu.PrefetchScalarGridSpec``): the index map of the data input reads the
packed-row/source-row id from SMEM before the block DMA is issued, so the
pipeline streams exactly the rows it needs from HBM — no one-hot matrices,
no host-side reordering.  Row blocks are single token rows ``(1, D)``; the
grid walks packed rows (dispatch) or (token, choice) pairs (combine), and
the combine accumulates its S terms in a VMEM scratch like the grouped GEMM
accumulates its K steps.

TPU is the target; CPU validation runs in ``interpret=True`` mode against
:func:`repro.kernels.ref.moe_dispatch` / :func:`repro.kernels.ref.moe_combine`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["moe_dispatch_pallas", "moe_combine_pallas"]


def _dispatch_kernel(src_ref, x_ref, o_ref):
    p = pl.program_id(0)

    @pl.when(src_ref[p] >= 0)
    def _copy():
        o_ref[...] = x_ref[...]

    @pl.when(src_ref[p] < 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_dispatch_pallas(
    x: jax.Array, src: jax.Array, *, interpret: bool = False
) -> jax.Array:
    """``x [T, D]`` gathered by ``src [P]`` (i32, -1 = empty) -> ``[P, D]``."""
    t, d = x.shape
    p = src.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, src_ref: (jnp.maximum(src_ref[i], 0), 0))
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, src_ref: (i, 0)),
    )
    return pl.pallas_call(
        _dispatch_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((p, d), x.dtype),
        interpret=interpret,
    )(src.astype(jnp.int32), x)


def _combine_kernel(slot_ref, w_ref, y_ref, o_ref, acc_ref, *, s_steps: int):
    ti = pl.program_id(0)
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = ti * s_steps + si
    w = jnp.where(slot_ref[i] >= 0, w_ref[i], 0.0)
    acc_ref[...] += w * y_ref[...].astype(jnp.float32)

    @pl.when(si == s_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_combine_pallas(
    y: jax.Array,
    slot: jax.Array,
    weights: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """``y [P, D]`` combined by ``slot/weights [T, S]`` -> ``[T, D]`` f32."""
    p, d = y.shape
    t, s = slot.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(t, s),
        in_specs=[
            pl.BlockSpec(
                (1, d),
                lambda ti, si, slot_ref, w_ref: (
                    jnp.maximum(slot_ref[ti * s + si], 0),
                    0,
                ),
            )
        ],
        out_specs=pl.BlockSpec((1, d), lambda ti, si, slot_ref, w_ref: (ti, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_combine_kernel, s_steps=s),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, d), jnp.float32),
        interpret=interpret,
    )(
        slot.reshape(-1).astype(jnp.int32),
        weights.reshape(-1).astype(jnp.float32),
        y,
    )
