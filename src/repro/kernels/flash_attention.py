"""Blockwise (flash) attention Pallas kernels — the prefill hot spot plus
the paged decode path.

:func:`flash_attention_pallas` — streaming-softmax attention tiled for the
TPU memory hierarchy: a ``(bq x D)`` query tile stays VMEM-resident while
``(bk x D)`` key/value tiles stream through the innermost grid axis; running
max / sum / output accumulators live in VMEM scratch and persist across the
kv axis (TPU grids iterate the last axis innermost, revisiting the same
output block).

:func:`paged_flash_decode_pallas` — the decode / chunked-continuation
variant over a block-paged KV cache (DESIGN.md §10): K/V live in a page
pool ``[N, page, Hkv, D]`` shared by every slot, and each sequence's pages
are gathered through a scalar-prefetched page table — the same
``PrefetchScalarGridSpec`` index-map idiom :mod:`repro.kernels.moe_dispatch`
uses for token gathers, so the DMA for page ``p+1`` is issued from an SMEM
lookup while page ``p``'s tile computes.

Supports GQA (kv-head picked by index map — no materialized repeat), causal
masking, sliding windows (gemma2 / recurrentgemma local attention) and logit
soft-capping (gemma2).  Validated in interpret mode against
:func:`repro.kernels.ref.flash_attention` /
:func:`repro.kernels.ref.paged_flash_decode` (the paged oracle mirrors the
page-at-a-time streaming schedule, so the check is bit-exact).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.grouped_matmul import pick_block

__all__ = ["flash_attention_pallas", "paged_flash_decode_pallas"]

_NEG_INF = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    kv_steps: int,
    bq: int,
    bk: int,
    scale: float,
    causal: bool,
    window: int | None,
    softcap: float | None,
):
    kv_i = pl.program_id(3)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)  # [bk, D]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [bq, bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = pl.program_id(2) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kv_i == kv_steps - 1)
    def _store():
        # Fully-masked rows (can happen for non-causal windows) get zeros.
        denom = jnp.where(l_ref[...] > 0.0, l_ref[...], 1.0)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Attention ``q[B,Hq,S,D], k/v[B,Hkv,S,D] -> [B,Hq,S,D]``."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if hq % hkv != 0:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    bq = pick_block(s, bq)
    bk = pick_block(s, bk)
    kv_steps = s // bk
    grid = (b, hq, s // bq, kv_steps)
    scale = 1.0 / (d**0.5)

    kernel = functools.partial(
        _flash_kernel,
        kv_steps=kv_steps,
        bq=bq,
        bk=bk,
        scale=scale,
        causal=causal,
        window=window,
        softcap=softcap,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec(
                (1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, d), lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _paged_decode_kernel(
    table_ref,  # SMEM [B, P] page id per (seq, logical page), -1 = unallocated
    len_ref,  # SMEM [B] first chunk position t (row c attends pos <= t + c)
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_ref,
    l_ref,
    acc_ref,
    *,
    pages: int,
    page: int,
    chunk: int,
    scale: float,
    window: int | None,
    softcap: float | None,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # [C, D]
    k = k_ref[0, :, 0, :]  # [page, D]
    v = v_ref[0, :, 0, :]
    s = jnp.dot(q, k.astype(jnp.float32).T, preferred_element_type=jnp.float32)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    # Row c of the chunk sits at absolute position t + c; page p covers key
    # positions [p*page, (p+1)*page).  Unallocated pages (table -1, gathered
    # as page 0) only cover positions past the sequence end, so the causal
    # mask alone discards them.
    q_pos = len_ref[b] + jax.lax.broadcasted_iota(jnp.int32, (chunk, page), 0)
    k_pos = p * page + jax.lax.broadcasted_iota(jnp.int32, (chunk, page), 1)
    mask = k_pos <= q_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p_tile = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p_tile, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p_tile, v.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(p == pages - 1)
    def _store():
        denom = jnp.where(l_ref[...] > 0.0, l_ref[...], 1.0)
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "interpret")
)
def paged_flash_decode_pallas(
    q: jax.Array,  # [B, C, Hq, D] — C=1 decode, C>1 chunked continuation
    k_pool: jax.Array,  # [N, page, Hkv, D] shared page pool
    v_pool: jax.Array,
    page_table: jax.Array,  # [B, P] i32 page ids, -1 = unallocated
    lengths: jax.Array,  # [B] i32 — chunk row c attends positions <= t + c
    *,
    window: int | None = None,
    softcap: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Decode attention through a paged KV cache: ``-> [B, C, Hq, D]``.

    The pools must already hold the chunk's own K/V (positions
    ``t .. t+C-1``), matching the write-then-attend order of
    :func:`repro.models.layers._decode_attention`.  The page table and
    per-sequence lengths ride the scalar-prefetch channel: the K/V BlockSpec
    index maps read ``table[b, p]`` from SMEM to aim each page's DMA, so an
    arbitrary slot-length mix streams through one static grid
    ``(B, Hq, P)`` with no gather materialized in HBM.
    """
    b, c, hq, d = q.shape
    n_pages, page, hkv, _ = k_pool.shape
    pages = page_table.shape[1]
    if hq % hkv != 0:
        raise ValueError(f"Hq={hq} not a multiple of Hkv={hkv}")
    group = hq // hkv
    scale = 1.0 / (d**0.5)

    # Chunk rows are independent (per-row softmax, per-row accumulator), so
    # padding the chunk never changes live rows — but a 2-row tile DOES
    # change which contraction strategy XLA picks for the [C, D] x [D, page]
    # dot, drifting 1 ulp from every other chunk width (and from the ref.py
    # oracle's fori_loop form).  K=1 draft/verify spans are exactly C=2, so
    # pad that one width up to 4 and slice; bit-exact acceptance depends on
    # verify rescoring positions with the same rounding the serial path saw.
    c_in = c
    if c == 2:
        q = jnp.concatenate(
            [q, jnp.zeros((b, 2, hq, d), q.dtype)], axis=1
        )
        c = 4

    kernel = functools.partial(
        _paged_decode_kernel,
        pages=pages,
        page=page,
        chunk=c,
        scale=scale,
        window=window,
        softcap=softcap,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hq, pages),
        in_specs=[
            pl.BlockSpec((1, c, 1, d), lambda bi, hi, pi, tref, lref: (bi, 0, hi, 0)),
            pl.BlockSpec(
                (1, page, 1, d),
                lambda bi, hi, pi, tref, lref: (
                    jnp.maximum(tref[bi, pi], 0), 0, hi // group, 0
                ),
            ),
            pl.BlockSpec(
                (1, page, 1, d),
                lambda bi, hi, pi, tref, lref: (
                    jnp.maximum(tref[bi, pi], 0), 0, hi // group, 0
                ),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, c, 1, d), lambda bi, hi, pi, tref, lref: (bi, 0, hi, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((c, 1), jnp.float32),
            pltpu.VMEM((c, 1), jnp.float32),
            pltpu.VMEM((c, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(
        page_table.astype(jnp.int32),
        lengths.astype(jnp.int32),
        q,
        k_pool,
        v_pool,
    )
    return out[:, :c_in] if c_in != c else out
