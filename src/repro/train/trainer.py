"""Training loop wiring the whole system together:

  data -> jit(train_step) -> MoE telemetry -> MixNet control plane
  (observe -> end_step -> plan -> apply, repro.core.controlplane)
  -> checkpoint/restart -> straggler watchdog.

The control loop is the paper's runtime reconfiguration (Fig 20) at the
framework level, driven through the shared :class:`ControlPlane` engine:
every step the trainer feeds the realized per-layer expert loads to the
engine's monitor; every ``reconfig_every`` steps it asks for a *per-layer*
placement plan (the regional per-layer OCS cross-maps of §5.2, DESIGN.md
§3) and — only for layers whose predicted gain clears the permute cost —
gathers that layer's stacked expert weights into their new slots and
updates the router's per-layer slot map.  Training math is unchanged (the
paper: "MixNet does not alter the parallelization strategies... and does
not affect training accuracy").
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controlplane import (
    ControlPlane,
    LayerPlan,
    PlacementApplier,
    permute_expert_weights,
)
from repro.obs import metrics, trace
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import ShardingPlan, virtual_experts
from repro.train import checkpoint as ckpt
from repro.train.pp_step import make_pp_train_step
from repro.train.train_step import init_all, init_ef_residual, make_train_step

# permute_expert_weights moved to repro.core.controlplane (it is shared with
# the serving engine, DESIGN.md §9); re-exported here for API stability.
__all__ = ["TrainerConfig", "Trainer", "permute_expert_weights"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0  # 0 = disabled
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    ckpt_async: bool = True
    # MixNet runtime reconfiguration
    reconfig_every: int = 0  # 0 = disabled (paper-faithful needs >0)
    reconfig_min_gain: float = 0.05
    # DP gradient reduction: "auto" (XLA sharding propagation) or "runtime"
    # (explicit CommRuntime hierarchical all-reduce inside shard_map over the
    # batch axes — requires a DP-only mesh and an fsdp=False plan; see the
    # repro.train.train_step module docstring).
    dp_comm: str = "auto"
    # int8 + error-feedback gradient compression through the runtime
    # reduction (requires dp_comm="runtime"); the trainer carries the
    # per-shard residual state across steps.
    dp_compress: bool = False
    # Pipeline parallelism (DESIGN.md §13): pp_stages > 1 stacks block
    # repeats on a 'stage' mesh axis and runs the GPipe schedule
    # (repro.train.pp_step) with the MoE data plane live inside each stage;
    # params/checkpoints/placement stay in the canonical [repeats, ...]
    # layout.  num_microbatches also drives gradient accumulation for the
    # non-PP step.
    pp_stages: int = 1
    num_microbatches: int = 1
    # Cached autotuner (repro.core.autotune, DESIGN.md §13): when both are
    # set and the key is present in the cache file, the trainer replaces the
    # constant comm knobs (MoE overlap_chunks / dispatch mode, dp_compress
    # where the mesh allows) with the tuned winners before building the
    # step.  A cache miss keeps the configured constants and is surfaced as
    # a one-line warning plus an ``autotune.cache_miss`` counter — tuning is
    # done offline by the benchmark/netsim side, which shares the cache file.
    autotune_cache: str = ""
    autotune_key: str = ""
    # Straggler watchdog: warn when a step exceeds ema * factor.
    straggler_factor: float = 3.0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        tcfg: TrainerConfig,
        plan: ShardingPlan,
        *,
        mesh=None,
        seed: int = 0,
    ):
        if tcfg.autotune_cache and tcfg.autotune_key:
            from repro.core import autotune

            tuned = autotune.load_cached(tcfg.autotune_cache, tcfg.autotune_key)
            if tuned is not None:
                cfg, tcfg = autotune.apply_to_trainer(cfg, tcfg, tuned)
            else:
                metrics.counter("autotune.cache_miss").inc()
                print(
                    f"[trainer] autotune cache miss: key {tcfg.autotune_key!r} "
                    f"not in {tcfg.autotune_cache} — using configured constants"
                )
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.plan = plan
        self.mesh = mesh
        key = jax.random.PRNGKey(seed)
        self.params, self.specs, self.opt_state = init_all(key, cfg, plan, opt_cfg)
        if tcfg.pp_stages > 1:
            if tcfg.dp_comm != "auto" or tcfg.dp_compress:
                raise ValueError(
                    "pp_stages > 1 composes with dp_comm='auto' only (the "
                    "runtime DP reduction needs a DP-only mesh)"
                )
            step = make_pp_train_step(
                cfg, plan, opt_cfg, mesh,
                pp_stages=tcfg.pp_stages,
                microbatches=tcfg.num_microbatches,
                block_specs=self.specs["blocks"],
            )
        else:
            step = make_train_step(
                cfg, plan, opt_cfg, mesh=mesh,
                microbatches=tcfg.num_microbatches, dp_comm=tcfg.dp_comm,
                dp_compress=tcfg.dp_compress,
            )
        self.step_fn = jax.jit(step, donate_argnums=(0, 1))
        self.ef_residual = (
            init_ef_residual(self.params, plan) if tcfg.dp_compress else None
        )
        self.step = 0
        self.metrics_log: list[dict] = []
        self._ema_step_time: float | None = None
        self.straggler_events = 0
        self._tr = trace.default()
        self._tid: int | None = None
        _m = metrics.default()
        self._m_steps = _m.counter("train.steps")
        self._m_tokens = _m.counter("train.tokens")
        self._m_stragglers = _m.counter("train.stragglers")
        self._m_step_time = _m.histogram("train.step_time_s")

        # MixNet control plane (only meaningful for MoE archs).
        self.controlplane: ControlPlane | None = None
        self._applier: PlacementApplier | None = None
        self.expert_perm = None
        if cfg.is_moe and tcfg.reconfig_every:
            ev, r = virtual_experts(cfg.moe.num_experts, plan.model_size)
            self.controlplane = ControlPlane(
                num_layers=cfg.pattern_repeats,
                num_experts=cfg.moe.num_experts,
                num_devices=max(plan.model_size, 1),
                replication=r,
                min_gain_fraction=tcfg.reconfig_min_gain,
            )
            self._applier = PlacementApplier(
                self.controlplane, model_size=max(plan.model_size, 1)
            )
            self.expert_perm = self.controlplane.perm_stack()
        self.reconfig_count = 0

    # Wire-level re-addressing state: [L, P] per-layer device maps for plans
    # realized on the a2a wire instead of by weight gathers (lives on the
    # shared PlacementApplier, DESIGN.md §3/§9).
    @property
    def wire_perm(self) -> np.ndarray | None:
        return self._applier.wire_perm if self._applier is not None else None

    @property
    def wire_reconfig_count(self) -> int:
        return self._applier.wire_reconfig_count if self._applier is not None else 0

    # -- checkpoint/restart ---------------------------------------------------
    def maybe_restore(self) -> bool:
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return False
        state = ckpt.restore(
            self.tcfg.ckpt_dir, last, {"params": self.params, "opt": self.opt_state}
        )
        self.params, self.opt_state = state["params"], state["opt"]
        # Placement state rides the manifest: restore it WITH the weights or
        # the router would address pre-reconfiguration slots (DESIGN.md §9).
        extra = ckpt.load_extra(self.tcfg.ckpt_dir, last)
        if self._applier is not None and "placement" in extra:
            self._applier.load_state_dict(extra["placement"])
            self.expert_perm = self.controlplane.perm_stack()
            self.reconfig_count = self.controlplane.reconfig_count
        self.step = last
        return True

    def _checkpoint(self):
        tree = {"params": self.params, "opt": self.opt_state}
        extra = (
            {"placement": self._applier.state_dict()}
            if self._applier is not None
            else None
        )
        if self.tcfg.ckpt_async:
            ckpt.save_async(
                self.tcfg.ckpt_dir, self.step, tree, keep=self.tcfg.ckpt_keep,
                extra=extra,
            )
        else:
            ckpt.save(
                self.tcfg.ckpt_dir, self.step, tree, keep=self.tcfg.ckpt_keep,
                extra=extra,
            )

    # -- observability ---------------------------------------------------------
    def _track_id(self) -> int:
        if self._tid is None:
            self._tid = self._tr.track("trainer")
        return self._tid

    # -- MixNet reconfiguration ------------------------------------------------
    def _wire_capable(self) -> bool:
        """Wire re-addressing needs the mixnet data plane and a control-plane
        device space that IS the model axis (one slot block per device)."""
        cp = self.controlplane
        p = max(self.plan.model_size, 1)
        return (
            self.cfg.moe is not None
            and self.cfg.moe.backend == "mixnet"
            and p > 1
            and cp is not None
            and cp.num_devices == p
        )

    def _apply_layer_plans(self, plans: list[LayerPlan]) -> bool:
        """Actuate per-layer placement plans through the shared
        :class:`PlacementApplier` (wire re-address for whole-device-block
        plans, weight gather otherwise — DESIGN.md §3)."""
        # Rebind when the engine was swapped after construction (tests inject
        # custom-region ControlPlanes directly onto the trainer).
        if self._applier is None or self._applier.cp is not self.controlplane:
            self._applier = PlacementApplier(
                self.controlplane, model_size=max(self.plan.model_size, 1)
            )
        ap = self._applier
        # Re-evaluated per call: tests toggle _wire_capable on the instance.
        ap.wire_capable = self._wire_capable()
        tid = self._track_id() if self._tr.enabled else None
        with self._tr.span(
            "train.reconfig", tid=tid, cat="reconfig", step=self.step
        ) as sp:
            self.params, changed = ap.apply(self.params, plans)
            sp.set(
                applied=bool(changed),
                plans=sum(1 for p in plans if p.reconfigure),
            )
        if changed:
            self.expert_perm = self.controlplane.perm_stack()
            self.reconfig_count = self.controlplane.reconfig_count
        return changed

    def _reconfigure_step(self, expert_load: np.ndarray):
        """Drive one step of the Fig 20 loop through the shared engine.

        ``expert_load``: [repeats, E] realized loads from the last step.
        """
        cp = self.controlplane
        for layer in range(expert_load.shape[0]):
            cp.observe(layer, expert_load[layer])
        cp.end_step()
        if self.step % self.tcfg.reconfig_every:
            return
        self._apply_layer_plans([cp.plan(layer) for layer in range(cp.num_layers)])

    def fail_device(self, device: int) -> None:
        """§5.4 failover: re-home the failed device's experts onto backup
        slots through the identical decide/apply path as a routine
        reconfiguration; subsequent plans keep only cold experts there."""
        if self.controlplane is None:
            raise RuntimeError(
                "no control plane configured (MoE arch + reconfig_every > 0 required)"
            )
        self._apply_layer_plans(self.controlplane.fail_device(device))

    def restore_device(self, device: int) -> None:
        if self.controlplane is None:
            raise RuntimeError(
                "no control plane configured (MoE arch + reconfig_every > 0 required)"
            )
        self.controlplane.restore_device(device)

    # -- main loop ---------------------------------------------------------------
    def train(self, data_iter) -> list[dict]:
        t = self.tcfg
        while self.step < t.total_steps:
            batch_np = next(data_iter)
            batch = {
                "tokens": jnp.asarray(batch_np.tokens),
                "labels": jnp.asarray(batch_np.labels),
            }
            perm = (
                jnp.asarray(self.expert_perm)
                if self.expert_perm is not None
                else None
            )
            wire = (
                jnp.asarray(self.wire_perm, jnp.int32)
                if self.wire_perm is not None
                else None
            )
            tid = self._track_id() if self._tr.enabled else None
            t0 = time.perf_counter()
            with self._tr.span(
                "train.step", tid=tid, cat="train", step=self.step + 1
            ) as sp:
                if self.tcfg.dp_compress:
                    self.params, self.opt_state, step_metrics, self.ef_residual = (
                        self.step_fn(
                            self.params, self.opt_state, batch, perm, wire,
                            self.ef_residual,
                        )
                    )
                else:
                    self.params, self.opt_state, step_metrics = self.step_fn(
                        self.params, self.opt_state, batch, perm, wire
                    )
                step_metrics = {
                    k: np.asarray(v) for k, v in step_metrics.items()
                }
                sp.set(loss=float(step_metrics.get("loss", 0.0)))
            dt = time.perf_counter() - t0
            self._m_steps.inc()
            self._m_tokens.inc(float(batch_np.tokens.size))
            self._m_step_time.observe(dt)
            if self._tr.enabled:
                self._tr.counter("train.step_time_s", dt, tid=tid)
            # Straggler watchdog (mitigation = flag + report; a real cluster
            # deployment feeds this to the job scheduler for hot-sparing).
            if self._ema_step_time is not None and dt > t.straggler_factor * self._ema_step_time:
                self.straggler_events += 1
                self._m_stragglers.inc()
                if self._tr.enabled:
                    self._tr.instant(
                        "train.straggler", tid=tid, cat="train",
                        step=self.step + 1, dt_s=dt, ema_s=self._ema_step_time,
                    )
            self._ema_step_time = (
                dt if self._ema_step_time is None else 0.9 * self._ema_step_time + 0.1 * dt
            )
            self.step += 1
            step_metrics["step"] = self.step
            step_metrics["step_time_s"] = dt
            self.metrics_log.append(step_metrics)

            if self.controlplane is not None and "expert_load" in step_metrics:
                self._reconfigure_step(np.asarray(step_metrics["expert_load"]))
            if t.ckpt_every and self.step % t.ckpt_every == 0:
                self._checkpoint()
        ckpt.wait_pending()
        return self.metrics_log
