"""Training loop wiring the whole system together:

  data -> jit(train_step) -> MoE telemetry -> MixNet control loop
  (traffic monitor -> COPILOT -> placement solver -> expert-weight permute)
  -> checkpoint/restart -> straggler watchdog.

The control loop is the paper's runtime reconfiguration (Fig 20) at the
framework level: every ``reconfig_every`` steps the controller folds the
observed per-layer expert loads into a device demand matrix, solves the
greedy placement (Algorithm 1's TPU analogue), and — only when the
predicted gain clears the permute cost — gathers the stacked expert weights
into their new slots and updates the router's slot map.  Training math is
unchanged (the paper: "MixNet does not alter the parallelization
strategies... and does not affect training accuracy").
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import inverse_permutation
from repro.core.reconfig import ReconfigController
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import ShardingPlan, virtual_experts
from repro.train import checkpoint as ckpt
from repro.train.train_step import init_all, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0  # 0 = disabled
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    ckpt_async: bool = True
    # MixNet runtime reconfiguration
    reconfig_every: int = 0  # 0 = disabled (paper-faithful needs >0)
    reconfig_min_gain: float = 0.05
    # Straggler watchdog: warn when a step exceeds ema * factor.
    straggler_factor: float = 3.0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: AdamWConfig,
        tcfg: TrainerConfig,
        plan: ShardingPlan,
        *,
        mesh=None,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.plan = plan
        self.mesh = mesh
        key = jax.random.PRNGKey(seed)
        self.params, self.specs, self.opt_state = init_all(key, cfg, plan, opt_cfg)
        self.step_fn = jax.jit(
            make_train_step(cfg, plan, opt_cfg, mesh=mesh), donate_argnums=(0, 1)
        )
        self.step = 0
        self.metrics_log: list[dict] = []
        self._ema_step_time: float | None = None
        self.straggler_events = 0

        # MixNet control plane (only meaningful for MoE archs).
        self.controller = None
        self.expert_perm = None
        if cfg.is_moe and tcfg.reconfig_every:
            ev, r = virtual_experts(cfg.moe.num_experts, plan.model_size)
            self.controller = ReconfigController(
                num_layers=cfg.pattern_repeats,
                num_experts=cfg.moe.num_experts,
                experts_per_device=max(ev // max(plan.model_size, 1), 1),
                min_gain_fraction=tcfg.reconfig_min_gain,
            )
            self._virtual = (ev, r)
            self.expert_perm = np.tile(
                np.arange(ev, dtype=np.int32), (cfg.pattern_repeats, 1)
            )
        self.reconfig_count = 0

    # -- checkpoint/restart ---------------------------------------------------
    def maybe_restore(self) -> bool:
        last = ckpt.latest_step(self.tcfg.ckpt_dir)
        if last is None:
            return False
        state = ckpt.restore(
            self.tcfg.ckpt_dir, last, {"params": self.params, "opt": self.opt_state}
        )
        self.params, self.opt_state = state["params"], state["opt"]
        self.step = last
        return True

    def _checkpoint(self):
        tree = {"params": self.params, "opt": self.opt_state}
        if self.tcfg.ckpt_async:
            ckpt.save_async(
                self.tcfg.ckpt_dir, self.step, tree, keep=self.tcfg.ckpt_keep
            )
        else:
            ckpt.save(self.tcfg.ckpt_dir, self.step, tree, keep=self.tcfg.ckpt_keep)

    # -- MixNet reconfiguration ------------------------------------------------
    def _maybe_reconfigure(self, expert_load: np.ndarray):
        """expert_load: [repeats, E] realized loads from the last step."""
        c = self.controller
        for layer in range(expert_load.shape[0]):
            c.observe(layer, expert_load[layer])
        c.end_step()
        if self.step % self.tcfg.reconfig_every:
            return
        ev, r = self._virtual
        p = max(self.plan.model_size, 1)
        epd = max(ev // p, 1)
        # Fold the mean load into a [devices, E_virtual] demand proxy: every
        # data shard contributes tokens proportional to the global load.
        load = expert_load.mean(axis=0)
        vload = np.repeat(load, r) / max(r, 1)
        demand = np.tile(vload[None, :], (p, 1))
        decision = c.decide(demand)
        if not decision.reconfigure:
            return
        perm = decision.plan.perm.astype(np.int32)  # virtual slot permutation
        inv = inverse_permutation(perm)
        # Permute stacked expert weights of every MoE block: slot s must hold
        # the expert whose new slot is s.
        def permute(leaf):
            return leaf[:, inv] if leaf.ndim >= 2 and leaf.shape[1] == ev else leaf

        for bname, bparams in self.params["blocks"].items():
            if "moe" in bparams:
                for wname in ("w_in", "w_gate", "w_out"):
                    bparams["moe"][wname] = permute(bparams["moe"][wname])
        base = self.expert_perm
        self.expert_perm = perm[base] if base is not None else np.tile(
            perm, (self.cfg.pattern_repeats, 1)
        )
        self.reconfig_count += 1

    # -- main loop ---------------------------------------------------------------
    def train(self, data_iter) -> list[dict]:
        t = self.tcfg
        while self.step < t.total_steps:
            batch_np = next(data_iter)
            batch = {
                "tokens": jnp.asarray(batch_np.tokens),
                "labels": jnp.asarray(batch_np.labels),
            }
            perm = (
                jnp.asarray(self.expert_perm)
                if self.expert_perm is not None
                else None
            )
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch, perm
            )
            metrics = {
                k: np.asarray(v) for k, v in metrics.items()
            }
            dt = time.perf_counter() - t0
            # Straggler watchdog (mitigation = flag + report; a real cluster
            # deployment feeds this to the job scheduler for hot-sparing).
            if self._ema_step_time is not None and dt > t.straggler_factor * self._ema_step_time:
                self.straggler_events += 1
            self._ema_step_time = (
                dt if self._ema_step_time is None else 0.9 * self._ema_step_time + 0.1 * dt
            )
            self.step += 1
            metrics["step"] = self.step
            metrics["step_time_s"] = dt
            self.metrics_log.append(metrics)

            if self.controller is not None and "expert_load" in metrics:
                self._maybe_reconfigure(np.asarray(metrics["expert_load"]))
            if t.ckpt_every and self.step % t.ckpt_every == 0:
                self._checkpoint()
        ckpt.wait_pending()
        return self.metrics_log
