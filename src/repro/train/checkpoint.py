"""Checkpointing with async save, keep-last-k GC and elastic restore.

Layout per step:  ``<dir>/step_<N>/arrays.npz`` + ``manifest.json``
(tree structure, shapes, dtypes, mesh shape it was saved under).

* **Atomicity**: written to ``step_<N>.tmp`` then renamed — a crashed save
  never corrupts the latest checkpoint.
* **Async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes on a worker thread, overlapping I/O with the next steps.
* **Elastic restore**: arrays are stored as *global* logical arrays; restore
  device_puts them under whatever mesh/sharding the *new* job uses, so a
  job can restart on a different device count (checkpoint resharding).
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = [
    "save",
    "save_async",
    "restore",
    "load_extra",
    "latest_step",
    "wait_pending",
]

_SEP = "/"
_pending: list[threading.Thread] = []


def _flatten(tree):
    flat = {}

    def walk(path, node):
        if isinstance(node, dict):
            # SORTED keys: must match jax's dict-flattening order so
            # _unflatten zips leaves back against the right treedef slots.
            for k in sorted(node, key=str):
                walk(path + [str(k)], node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(path + [str(i)], v)
        else:
            flat[_SEP.join(path)] = node

    walk([], tree)
    return flat


def _unflatten(flat: dict, skeleton):
    leaves, treedef = jax.tree.flatten(skeleton)
    keys = _flatten(skeleton)
    out = {k: flat[k] for k in keys}
    return jax.tree.unflatten(treedef, [out[k] for k in keys])


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3, extra: dict | None = None):
    """Synchronous checkpoint write (atomic)."""
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)


def save_async(ckpt_dir: str, step: int, tree, *, keep: int = 3, extra=None):
    """Snapshot now, write on a background thread (overlaps training I/O)."""
    snapshot = {k: np.asarray(v) for k, v in _flatten(tree).items()}

    def work():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **snapshot)
        manifest = {
            "step": step,
            "keys": sorted(snapshot),
            "shapes": {k: list(v.shape) for k, v in snapshot.items()},
            "dtypes": {k: str(v.dtype) for k, v in snapshot.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    t = threading.Thread(target=work, daemon=True)
    t.start()
    _pending.append(t)
    return t


def wait_pending():
    for t in _pending:
        t.join()
    _pending.clear()


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def load_extra(ckpt_dir: str, step: int) -> dict:
    """The ``extra`` dict a checkpoint was saved with (empty if none).

    This is where non-array runtime state rides — notably the ControlPlane
    placement state (perm stack + wire perms, DESIGN.md §9): a server
    restored with permuted expert weights but a fresh perm stack would
    misroute every token, so the two must round-trip together.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    return manifest.get("extra") or {}


def restore(ckpt_dir: str, step: int, skeleton, *, shardings=None):
    """Load a checkpoint into the skeleton's tree structure.

    ``shardings`` (same tree shape, NamedSharding leaves) re-shards onto the
    *current* mesh — elastic restart across different device counts.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(flat, skeleton)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    else:
        tree = jax.tree.map(lambda x: jax.numpy.asarray(x), tree)
    return tree
