"""Pipeline parallelism: GPipe-schedule microbatch pipeline as a jax-native
``shard_map`` program over a ``stage`` mesh axis.

The paper's training configurations all pipeline MoE blocks across stages
(Table 1), and its locality insight — EP all-to-all never crosses PP stages
— is what makes regional reconfigurable domains possible in the first
place.  This module provides that axis for the framework: stages hold
disjoint layer slices (params stacked on a leading stage dim, sharded over
``stage``), activations flow stage-to-stage with ``ppermute``, and the
schedule is a ``lax.scan`` over M + S - 1 ticks.  Differentiating through
the scan yields the reverse pipeline automatically, so one definition
serves forward and backward.

This module is the bare schedule; the composition with the real training
stack lives in :mod:`repro.train.pp_step` (``make_pp_train_step``): there the
stage body runs the actual transformer blocks with the MoE mixnet data plane
(dispatch a2a, ``overlap_chunks`` chunking, per-layer expert/wire perms) on
the ``model`` mesh axis *inside* each stage, and the Trainer drives it via
``TrainerConfig.pp_stages`` / ``num_microbatches`` (DESIGN.md §13).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import shard_map

__all__ = ["pipeline_apply", "num_ticks"]


def num_ticks(num_microbatches: int, num_stages: int) -> int:
    return num_microbatches + num_stages - 1


def pipeline_apply(
    stage_fn,
    stage_params,
    microbatches: jax.Array,
    mesh,
    *,
    axis: str = "stage",
    extra_specs: P | None = None,
):
    """Run ``microbatches [M, mb, ...]`` through a GPipe pipeline.

    Args:
      stage_fn: ``(params_for_stage, x) -> y`` applied by every stage to its
        resident activation.  Stages are homogeneous (the usual transformer
        case: each stage = L/S blocks).
      stage_params: pytree with leading stage dim on every leaf
        (``[S, ...]``), sharded ``P(axis, ...)``.
      microbatches: ``[M, mb, ...]`` inputs (replicated across stages).
      mesh: mesh containing ``axis`` of size S.

    Returns:
      ``[M, mb, ...]`` outputs of the last stage, in microbatch order.
    """
    s = mesh.shape[axis]
    m = microbatches.shape[0]
    ticks = num_ticks(m, s)
    perm = [(i, i + 1) for i in range(s - 1)]

    def per_stage(params_local, mbs):
        # params_local: stage slice [1, ...] -> squeeze; mbs replicated [M,...]
        params_here = jax.tree.map(lambda p: p[0], params_local)
        stage_idx = lax.axis_index(axis)
        buf0 = jnp.zeros_like(mbs[0])

        def tick(carry, t):
            buf = carry
            # Stage 0 ingests microbatch t (when one is due); other stages
            # work on whatever arrived from the previous stage last tick.
            # Past the last microbatch (drain ticks — every tick >= M when
            # M < S) stage 0 feeds zeros, so the garbage riding the pipe is
            # a fixed point of well-behaved stage fns instead of a stale
            # re-fed microbatch; those ticks' outputs are discarded by the
            # final slice either way.
            feed = jnp.where(t < m, mbs[jnp.minimum(t, m - 1)], 0)
            x = jnp.where(stage_idx == 0, feed, buf)
            y = stage_fn(params_here, x)
            # Shift the pipe: stage i's output becomes stage i+1's input.
            nxt = lax.ppermute(y, axis, perm)
            # The last stage emits its result this tick (valid for ticks
            # >= S-1); gather on the host side below.
            return nxt, y

        _, outs = lax.scan(tick, buf0, jnp.arange(ticks))
        # outs: [ticks, mb, ...] = every stage's per-tick output; only the
        # last stage's outputs at ticks S-1 .. S-1+M-1 are the model outputs.
        return outs[None]  # [1, ticks, ...] stage-major for the out_spec

    in_specs = (
        jax.tree.map(lambda _: P(axis), stage_params,
                     is_leaf=lambda x: hasattr(x, "shape")),
        P(),  # microbatches replicated
    )
    fn = shard_map(
        per_stage,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=P(axis),
        check_vma=False,
    )
    all_outs = fn(stage_params, microbatches)  # [S, ticks, mb, ...]
    return all_outs[s - 1, s - 1 : s - 1 + m]
