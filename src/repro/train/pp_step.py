"""Pipeline-parallel train step: the GPipe schedule of
:mod:`repro.train.pipeline` composed with the real MoE data plane.

One flat ``shard_map`` over ``('stage', [batch axes], 'model')`` runs the
whole block stack (DESIGN.md §13):

* **Blocks stack on the stage axis.**  The canonical ``[repeats, ...]``
  block params are reshaped to ``[S, repeats/S, ...]`` inside the jitted
  step; each stage owns a contiguous slice of layers.  Trainer-side state
  (checkpoints, :func:`permute_expert_weights`, the PlacementApplier) keeps
  the canonical layout — PP is invisible to everything outside the step.

* **The carrier stays sequence-sharded.**  The residual microbatch rides
  the pipe as the local ``[mb, T/P, D]`` shard (the same layout the non-PP
  step's activation spec pins), so the MoE body below sees exactly the
  token shard the non-PP ``shard_map`` region sees.  Attention gathers the
  full sequence (``all_gather`` over ``model``), computes redundantly per
  device, and slices its shard back — per-output-element math identical to
  the single-device program.

* **The MoE data plane runs unchanged inside the stage.**  Each MoE block
  calls :func:`repro.models.moe._moe_mixnet_local` — dropless/capacity
  dispatch, the fused hierarchical a2a, ``overlap_chunks`` software
  pipelining, per-layer expert/wire perms — with the same
  ``axis_names``/``token_axes`` the non-PP region uses, so per-device MoE
  numerics are bit-identical to the non-PP step.

* **Schedule = ``lax.scan`` over M + S - 1 ticks**, activations shifted
  stage-to-stage with ``lax.ppermute``; differentiating the scan yields the
  reverse pipeline.  Warmup/drain ticks feed zeros and their telemetry is
  masked (``valid = sidx <= t < sidx + M``), so bubble ticks never reach
  the ControlPlane's gate-load observations.

* **Embedding, final norm, and the chunked CE run OUTSIDE the stage
  region** under pjit — the identical program the non-PP step runs, which
  is what makes end-to-end gradient parity exact: the only difference
  between PP(S) and PP(1) is the schedule, and the loss is ONE
  ``value_and_grad`` over the full pipeline (full-batch CE + microbatch-
  averaged aux losses), so no gradient-accumulation reassociation sneaks
  in.

Gate-load telemetry accumulates per stage over valid ticks and is emitted
``[S, repeats/S, E]`` -> reshaped to the canonical ``[repeats, E]``, so
``Trainer._reconfigure_step`` (observe -> plan -> apply) works under PP
without modification.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.commruntime import AllGather, CommSpec
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import routing
from repro.models import transformer as tfm
from repro.models.transformer import _FFN_PREFETCH_DIMS
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.parallel.sharding import (
    ShardingPlan,
    constrain,
    shard_map,
    virtual_experts,
)
from repro.train.pipeline import num_ticks

__all__ = ["make_pp_train_step"]


def _validate(cfg, plan, mesh, pp_stages, stage_axis):
    if mesh is None or stage_axis not in mesh.axis_names:
        raise ValueError(
            f"pp_stages={pp_stages} needs a mesh with a {stage_axis!r} axis"
        )
    if mesh.shape[stage_axis] != pp_stages:
        raise ValueError(
            f"mesh {stage_axis!r} axis is {mesh.shape[stage_axis]}, "
            f"pp_stages is {pp_stages}"
        )
    if cfg.pattern_repeats % pp_stages:
        raise ValueError(
            f"{cfg.pattern_repeats} block repeats not divisible by "
            f"{pp_stages} stages"
        )
    bad = [k for k in cfg.block_pattern if k not in ("global", "local")]
    if bad or cfg.tail_pattern or cfg.encoder_layers or cfg.vision_patches:
        raise NotImplementedError(
            "pipeline-parallel stages support attention(+MLP/MoE) block "
            f"patterns only (got pattern={cfg.block_pattern}, "
            f"tail={cfg.tail_pattern})"
        )
    if cfg.is_moe:
        if cfg.moe.backend != "mixnet":
            raise NotImplementedError(
                "PP composes with the mixnet MoE data plane only "
                f"(backend={cfg.moe.backend!r})"
            )
        if cfg.moe.num_shared_experts:
            raise NotImplementedError(
                "shared experts are not wired through the PP stage body yet"
            )
        p = max(plan.model_size, 1)
        if p > 1 and cfg.moe.num_experts % p:
            raise NotImplementedError(
                f"PP stage specs shard the expert dim over the model axis; "
                f"{cfg.moe.num_experts} experts do not divide over {p} "
                "devices (virtual-expert replication is not wired through "
                "the stage body)"
            )


def _stage_leaf_spec(plan, stage_axis, sub, leafname, spec, prefetch):
    """in_spec for one stacked block leaf inside the flat stage shard_map.

    ``spec`` is the canonical ``P(None, *rest)`` (leading repeats dim).  The
    repeats dim splits over the stage axis; the expert dim keeps its EP
    sharding (``_moe_mixnet_local`` consumes the local shard); FFN leaves
    keep their FSDP sharding when the in-stage ring prefetch gathers them;
    every other axis is dropped so shard_map feeds the full leaf (TP
    attention inside stages is future work — attention computes replicated
    on the gathered sequence).
    """
    rest = list(spec)[1:]
    out = [None] * len(rest)
    dims = _FFN_PREFETCH_DIMS.get(sub or "", {})
    if sub == "moe" and leafname in dims:
        out[dims[leafname][1]] = rest[dims[leafname][1]]  # expert dim (EP)
    if prefetch and sub in ("moe", "mlp") and leafname in dims:
        fdim = dims[leafname][0]
        if rest[fdim] == plan.fsdp_axis:
            out[fdim] = rest[fdim]
    return P(stage_axis, None, *out)


def make_pp_train_step(
    cfg,
    plan: ShardingPlan,
    opt_cfg: AdamWConfig,
    mesh,
    *,
    pp_stages: int,
    microbatches: int = 1,
    block_specs=None,
    stage_axis: str = "stage",
):
    """jit-able ``(params, opt_state, batch, expert_perm, wire_perm) ->
    (params, opt_state, metrics)`` with blocks pipelined over ``pp_stages``.

    ``params`` stay in the canonical ``[repeats, ...]`` layout; ``plan`` is
    the usual :func:`make_plan` of the mesh (the ``stage`` axis is invisible
    to it — batch/model semantics inside a stage match the non-PP step).
    ``block_specs``: the init-time ``specs["blocks"]`` tree (derived from a
    throwaway init when omitted).
    """
    _validate(cfg, plan, mesh, pp_stages, stage_axis)
    s = pp_stages
    m = microbatches
    reps = cfg.pattern_repeats
    reps_local = reps // s
    names = [f"{i}_{k}" for i, k in enumerate(cfg.block_pattern)]
    pattern = cfg.block_pattern
    p_model = max(plan.model_size, 1)
    ticks = num_ticks(m, s)
    pperm = [(i, i + 1) for i in range(s - 1)]
    ev, _ = virtual_experts(cfg.moe.num_experts, p_model) if cfg.is_moe else (1, 1)

    if block_specs is None:
        key = jax.random.PRNGKey(0)
        block_specs = {}
        for i, kind in enumerate(pattern):
            _, spec1 = tfm._block_init(key, kind, cfg, plan)
            block_specs[names[i]] = jax.tree.map(
                lambda sp: P(None, *sp), spec1,
                is_leaf=lambda sp: isinstance(sp, P),
            )

    prefetch = bool(cfg.fsdp_prefetch and plan.fsdp_axis is not None)
    fsdp_ag = (
        AllGather(
            CommSpec(axis=plan.fsdp_axis, axis_size=max(plan.data_size, 1)),
            impl="ring",
        )
        if prefetch
        else None
    )
    axis_names = tuple(a for a in (plan.batch_axes or ()) if a) + (
        (plan.model_axis,) if plan.model_axis else ()
    )

    def staged_in_specs():
        out = {}
        for name in names:
            sub_specs = block_specs[name]
            staged = {}
            for sub, tree in sub_specs.items():
                if isinstance(tree, P):
                    staged[sub] = _stage_leaf_spec(
                        plan, stage_axis, None, sub, tree, prefetch
                    )
                else:
                    staged[sub] = {
                        leaf: _stage_leaf_spec(
                            plan, stage_axis, sub, leaf, sp, prefetch
                        )
                        for leaf, sp in tree.items()
                    }
            out[name] = staged
        return out

    blocks_in_specs = staged_in_specs()

    def _gather_ffn(sub_name, sub_params, sub_specs):
        """Ring-gather FSDP-sharded FFN leaves inside the stage region."""
        if not prefetch or sub_name not in _FFN_PREFETCH_DIMS:
            return sub_params
        out = dict(sub_params)
        for wname, (fdim, _) in _FFN_PREFETCH_DIMS[sub_name].items():
            if wname not in out:
                continue
            sp = sub_specs[wname]
            if len(sp) > 2 + fdim and sp[2 + fdim] == plan.fsdp_axis:
                out[wname] = fsdp_ag(out[wname], axis=fdim)
        return out

    def _apply_block_local(kind, p, x, perm_row, wire_row, midx, token_axes):
        """One transformer block on the local ``[mb, T/P, D]`` shard."""
        sl = x.shape[1]
        h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
        if p_model > 1:
            hg = lax.all_gather(h, plan.model_axis, axis=1, tiled=True)
        else:
            hg = h
        y, _ = L.attention_apply(p["attn"], hg, cfg, kind=kind, mode="train")
        if p_model > 1:
            y = lax.dynamic_slice_in_dim(y, midx * sl, sl, axis=1)
        x = x + y
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        stats = None
        if cfg.is_moe:
            mp = p["moe"]
            perm_row = routing.resolve_perm(perm_row, ev)
            y, load, bal, z, _ = moe_mod._moe_mixnet_local(
                (mp["router"], mp["w_in"], mp["w_gate"], mp["w_out"]),
                h2, cfg, plan, perm_row, axis_names,
                wire_perm=wire_row, token_axes=token_axes,
            )
            stats = (load, bal, z)
        else:
            y = L.mlp_apply(p["mlp"], h2, cfg)
        x = x + y
        return x, stats

    def train_step(
        params, opt_state, batch, expert_perm=None, wire_perm=None,
    ):
        tokens, labels = batch["tokens"], batch["labels"]
        b, t_len = tokens.shape
        if b % m:
            raise ValueError(f"batch {b} not divisible by {m} microbatches")
        mb = b // m
        seq_ok = t_len % p_model == 0 and p_model > 1
        batch_ok = mb % max(plan.data_size, 1) == 0
        if p_model > 1 and not seq_ok:
            raise ValueError(
                f"seq {t_len} must divide the model axis {p_model} for the "
                "sequence-sharded PP carrier"
            )
        batch_ax = (plan.batch_axes or None) if batch_ok else None
        seq_ax = plan.model_axis if seq_ok else None
        token_axes = tuple(a for a in (batch_ax or ()) if a) + (
            (seq_ax,) if seq_ax else ()
        )
        mb_spec = P(None, batch_ax, seq_ax, None)

        if expert_perm is None and cfg.is_moe:
            expert_perm = jnp.broadcast_to(
                jnp.arange(ev, dtype=jnp.int32), (reps, ev)
            )

        def per_device(blocks_local, mbs, perm_local, wire_local):
            blocks_here = jax.tree.map(lambda p: p[0], blocks_local)
            perm_here = perm_local[0] if perm_local is not None else None
            wire_here = wire_local[0] if wire_local is not None else None
            sidx = lax.axis_index(stage_axis)
            midx = lax.axis_index(plan.model_axis) if p_model > 1 else 0
            zero = jnp.zeros_like(mbs[0])

            def rep_body(x, xs):
                gp = xs["blocks"]
                prow = xs.get("perm")
                wrow = xs.get("wire")
                stats_list = []
                for i, kind in enumerate(pattern):
                    bp = dict(gp[names[i]])
                    for fk in ("moe", "mlp"):
                        if fk in bp:
                            bp[fk] = _gather_ffn(
                                fk, bp[fk], blocks_in_specs[names[i]][fk]
                            )
                    x, st = _apply_block_local(
                        kind, bp, x, prow, wrow, midx, token_axes
                    )
                    if st is not None:
                        stats_list.append(st)
                nstat = max(len(stats_list), 1)
                load = (
                    stats_list[0][0]
                    if stats_list
                    else jnp.zeros((1,), jnp.float32)
                )
                bal = sum(st[1] for st in stats_list) / nstat if stats_list \
                    else jnp.zeros((), jnp.float32)
                z = sum(st[2] for st in stats_list) / nstat if stats_list \
                    else jnp.zeros((), jnp.float32)
                return x, (load, bal, z)

            def run_stage(x):
                xs = {"blocks": blocks_here}
                if perm_here is not None:
                    xs["perm"] = perm_here
                if wire_here is not None:
                    xs["wire"] = wire_here
                return lax.scan(rep_body, x, xs)

            if cfg.remat != "none":
                run_stage = jax.checkpoint(run_stage)

            e_dim = cfg.moe.num_experts if cfg.is_moe else 1

            def tick(carry, t):
                buf, lacc, bacc, zacc = carry
                feed = jnp.where(t < m, mbs[jnp.minimum(t, m - 1)], 0)
                xin = jnp.where(sidx == 0, feed, buf)
                y, (loads, bals, zs) = run_stage(xin)
                nxt = lax.ppermute(y, stage_axis, pperm) if s > 1 else y
                # Bubble ticks (warmup on stage i: t < i; drain: t >= i + M)
                # carry zeros and MUST NOT pollute the gate telemetry.
                valid = (t >= sidx) & (t - sidx < m)
                w = jnp.where(valid, 1.0, 0.0).astype(jnp.float32)
                lacc = lacc + w * loads
                bacc = bacc + w * bals / m
                zacc = zacc + w * zs / m
                return (nxt, lacc, bacc, zacc), y

            init = (
                zero,
                jnp.zeros((reps_local, e_dim), jnp.float32),
                jnp.zeros((reps_local,), jnp.float32),
                jnp.zeros((reps_local,), jnp.float32),
            )
            (_, lacc, bacc, zacc), outs = lax.scan(
                tick, init, jnp.arange(ticks)
            )
            return outs[None], lacc[None], bacc[None], zacc[None]

        def loss_fn(params):
            x = jnp.take(params["embed"], tokens, axis=0).astype(
                jnp.dtype(cfg.dtype)
            )
            x = x * (cfg.d_model**0.5)
            mbs = x.reshape(m, mb, t_len, cfg.d_model)
            staged_blocks = jax.tree.map(
                lambda p: p.reshape(s, reps_local, *p.shape[1:]),
                params["blocks"],
            )
            args = [staged_blocks, mbs]
            in_specs = [blocks_in_specs, mb_spec]
            has_perm = expert_perm is not None
            has_wire = wire_perm is not None
            if has_perm:
                args.append(expert_perm.reshape(s, reps_local, -1))
                in_specs.append(P(stage_axis, None, None))
            if has_wire:
                args.append(
                    jnp.asarray(wire_perm, jnp.int32).reshape(s, reps_local, -1)
                )
                in_specs.append(P(stage_axis, None, None))
            out_specs = (
                P(stage_axis, None, batch_ax, seq_ax, None),
                P(stage_axis, None, None),
                P(stage_axis, None),
                P(stage_axis, None),
            )

            def wrapped(*a):
                rest = list(a[2:])
                perm_l = rest.pop(0) if has_perm else None
                wire_l = rest.pop(0) if has_wire else None
                return per_device(a[0], a[1], perm_l, wire_l)

            fn = shard_map(
                wrapped, mesh=mesh, in_specs=tuple(in_specs),
                out_specs=out_specs, check_vma=False,
            )
            outs, loads, bal, zl = fn(*args)
            # outs [S, ticks, mb, T, D]: the last stage's emissions at ticks
            # S-1 .. S-1+M-1 are the model outputs, in microbatch order.
            feats = outs[s - 1, s - 1 : s - 1 + m].reshape(
                b, t_len, cfg.d_model
            )
            feats = L.rms_norm(feats, params["final_norm"], cfg.norm_eps)
            feats = constrain(feats, mesh, plan.activation_spec())
            ce = tfm.chunked_cross_entropy(params, feats, labels, cfg)
            bal_mean = jnp.mean(bal.reshape(reps))
            z_mean = jnp.mean(zl.reshape(reps))
            loss = ce
            if cfg.is_moe:
                loss = loss + cfg.moe.balance_loss * bal_mean
                loss = loss + cfg.moe.router_z_loss * z_mean
            return loss, (ce, bal_mean, z_mean, loads.reshape(reps, -1))

        (loss, (ce, bal, zl, loads)), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg
        )
        metrics = {
            "loss": loss,
            "ce": ce,
            "balance_loss": bal,
            "z_loss": zl,
            **opt_metrics,
        }
        if cfg.is_moe:
            metrics["expert_load"] = loads
        return params, opt_state, metrics

    return train_step
