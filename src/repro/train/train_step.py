"""jit-able training / serving step factories.

These are the functions the launcher pjits and the dry-run lowers: pure
(params, opt_state, batch) -> (params, opt_state, metrics) with all
distribution expressed through param/activation shardings (plus the MoE
``mixnet`` shard_map region inside the model).

Gradient reduction has two modes (``dp_comm``): ``"auto"`` leaves the DP
reduction to XLA's sharding propagation (the pjit baseline), while
``"runtime"`` computes per-shard gradients inside an explicit ``shard_map``
over the batch axes and reduces them through the CommRuntime's hierarchical
:class:`~repro.core.commruntime.AllReduce` (reduce-scatter inside the
region, ring across regions, all-gather back — paper §5.3).  The runtime
mode requires a DP-only mesh (no model axis) with FSDP disabled
(``make_plan(mesh, fsdp=False)`` — params ride the shard_map replicated)
and evaluates the MoE aux losses per shard (averaged), the standard
per-group GShard semantics.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.commruntime import AllReduce, CommSpec
from repro.models import transformer as tfm
from repro.optim.adamw import AdamWConfig, adamw_update, init_adamw
from repro.parallel.sharding import ShardingPlan, constrain, shard_map

__all__ = [
    "make_train_step",
    "make_prefill_step",
    "make_prefill_chunk_step",
    "make_serve_step",
    "make_verify_step",
    "make_draft_loop_step",
    "init_ef_residual",
    "loss_fn",
    "step_shardings",
]


def _sample_rows(logits, keys):
    """Per-row Gumbel-argmax sample: ``[B, V]`` logits × ``[B, 2]`` keys.

    Equivalent to ``categorical`` per row, but each row consumes its OWN key
    — the serving engine's sampling-key discipline (DESIGN.md §11): one key
    per (slot, emitted-token index), so speculative and serial decode draw
    the same token from the same logits, and draft passes can reuse the
    verify keys (common random numbers) for free extra acceptance.
    """
    l32 = logits.astype(jnp.float32)
    g = jax.vmap(lambda k: jax.random.gumbel(k, l32.shape[-1:]))(keys)
    return jnp.argmax(l32 + g, axis=-1)


def loss_fn(params, batch, cfg, plan, mesh=None, expert_perm=None, wire_perm=None):
    """``expert_perm``: ``[repeats, E_virtual]`` per-layer expert->slot maps
    from the control plane (distinct rows per layer after regional
    reconfiguration); the transformer scan slices one row per repeat.
    ``wire_perm``: optional ``[repeats, P]`` device maps for layers whose
    plan was installed as a wire re-address instead of a weight gather."""
    feats, aux, _ = tfm.model_apply(
        params, batch, cfg, plan, mesh=mesh, mode="train", expert_perm=expert_perm,
        wire_perm=wire_perm,
    )
    feats = constrain(feats, mesh, plan.activation_spec())
    ce = tfm.chunked_cross_entropy(params, feats, batch["labels"], cfg)
    loss = ce
    if cfg.is_moe:
        loss = loss + cfg.moe.balance_loss * aux.balance_loss
        loss = loss + cfg.moe.router_z_loss * aux.z_loss
    return loss, (ce, aux)


def init_ef_residual(params, plan: ShardingPlan):
    """Per-shard error-feedback residuals for ``dp_compress=True``: one f32
    copy of every gradient leaf per DP shard, leading dim = the DP degree
    (sharded over the batch axes inside the runtime shard_map)."""
    d = max(plan.data_size, 1)
    return jax.tree.map(
        lambda p: jnp.zeros((d, *p.shape), jnp.float32), params
    )


def _make_runtime_grad_fn(cfg, plan: ShardingPlan, mesh, compress: bool = False):
    """Per-shard gradients inside shard_map over the batch axes, reduced with
    the CommRuntime hierarchical AllReduce (``dp_comm="runtime"``).

    ``compress=True`` routes the reduction through the int8 codec
    (:mod:`repro.optim.compress`) riding the op's reduce-scatter stage, with
    per-shard error-feedback residuals threaded by the caller — quantization
    noise does not accumulate across steps, and the wire bytes drop by the
    gradient dtype's width (the same ``compress_ratio`` netsim prices)."""
    if mesh is None or not plan.batch_axes or plan.model_size > 1:
        raise ValueError(
            "dp_comm='runtime' requires a data-parallel mesh without a model "
            f"axis (got mesh={mesh is not None}, plan={plan})"
        )
    if plan.fsdp_axis is not None:
        # Params enter the shard_map replicated (in_specs P()) and the full
        # gradient tree leaves it replicated — ZeRO-3 sharding would be
        # silently gathered away.  Fail loudly instead of OOMing at scale.
        raise ValueError(
            "dp_comm='runtime' replicates parameters inside the shard_map and "
            "is incompatible with FSDP sharding; build the plan with "
            "make_plan(mesh, fsdp=False)"
        )
    local_plan = ShardingPlan((), None, 1, None, 1)
    reduce_op = AllReduce(CommSpec.for_grad_reduce(plan, mesh))
    tok_spec = P(plan.batch_axes, None)

    def body(params, tokens, labels, expert_perm, residual):
        (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, {"tokens": tokens, "labels": labels}, cfg, local_plan,
            None, expert_perm,
        )
        new_residual = None
        if compress:
            # Error feedback (Seide et al.): compress (grad + residual), keep
            # this shard's own quantization error for the next step.  The
            # int32 sum through the RS/ring/AG stages is exact, so the only
            # noise is the shared quantization the residual absorbs.
            flat_g, treedef = jax.tree.flatten(grads)
            flat_r = treedef.flatten_up_to(residual)
            red, res = [], []
            for g, r in zip(flat_g, flat_r):
                target = g.astype(jnp.float32) + r[0]
                total, local = reduce_op.compressed(target, mean=True)
                red.append(total.astype(g.dtype))
                res.append((target - local)[None])
            grads = jax.tree.unflatten(treedef, red)
            new_residual = jax.tree.unflatten(treedef, res)
        else:
            grads = jax.tree.map(lambda g: reduce_op(g, mean=True), grads)
        stats = aux.moe_stats
        aux = dataclasses.replace(
            aux,
            # Expert-load telemetry is a count -> SUM over shards; the aux
            # losses are per-shard means -> averaged.
            moe_stats=None if stats is None else reduce_op(stats),
            balance_loss=reduce_op(aux.balance_loss, mean=True),
            z_loss=reduce_op(aux.z_loss, mean=True),
        )
        out = (reduce_op(loss, mean=True), reduce_op(ce, mean=True), aux, grads)
        return out + ((new_residual,) if compress else ())

    def grad_fn(params, batch, expert_perm, residual=None):
        args = [params, batch["tokens"], batch["labels"]]
        in_specs = [P(), tok_spec, tok_spec]
        if expert_perm is not None:
            args.append(expert_perm)
            in_specs.append(P())
        if compress:
            args.append(residual)
            in_specs.append(P(plan.batch_axes))
        out_specs = (P(), P(), P(), P()) + (
            (P(plan.batch_axes),) if compress else ()
        )
        has_perm = expert_perm is not None

        def wrapped(*a):
            pm = a[3] if has_perm else None
            res = a[-1] if compress else None
            return body(a[0], a[1], a[2], pm, res)

        f = shard_map(
            wrapped, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs,
            check_vma=False,
        )
        return f(*args)

    return grad_fn


def make_train_step(
    cfg,
    plan: ShardingPlan,
    opt_cfg: AdamWConfig,
    mesh=None,
    microbatches: int = 1,
    dp_comm: str = "auto",
    dp_compress: bool = False,
):
    """jit-able train step; ``microbatches > 1`` scans gradient accumulation
    over batch slices — activation live-set (and its reshard collectives per
    slice) shrink by the microbatch factor at the cost of re-gathering FSDP
    weights per slice (the classic trade; see EXPERIMENTS.md §Perf).

    ``dp_comm="runtime"`` routes the DP gradient reduction through the
    CommRuntime's hierarchical all-reduce (see module docstring);
    ``dp_compress=True`` additionally runs it through the int8 +
    error-feedback codec (``repro.optim.compress``) — the step then takes
    an extra ``ef_residual`` pytree (:func:`init_ef_residual`) and returns
    the updated one as a 4th output."""
    if dp_comm not in ("auto", "runtime"):
        raise ValueError(f"unknown dp_comm mode {dp_comm!r}")
    if dp_compress and dp_comm != "runtime":
        raise ValueError("dp_compress=True requires dp_comm='runtime'")
    if dp_compress and microbatches > 1:
        raise ValueError(
            "dp_compress=True supports microbatches=1 only (the error-feedback "
            "residual is a per-step state, not a per-slice one)"
        )
    runtime_grads = (
        _make_runtime_grad_fn(cfg, plan, mesh, compress=dp_compress)
        if dp_comm == "runtime"
        else None
    )

    def grad_once(params, batch, expert_perm, wire_perm, residual=None):
        if runtime_grads is not None:
            if wire_perm is not None:
                raise ValueError(
                    "wire_perm needs a model axis; dp_comm='runtime' runs on a "
                    "DP-only mesh"
                )
            out = runtime_grads(params, batch, expert_perm, residual)
            return out if dp_compress else (*out, None)
        (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg, plan, mesh, expert_perm, wire_perm
        )
        return loss, ce, aux, grads, None

    def train_step(
        params, opt_state, batch, expert_perm=None, wire_perm=None,
        ef_residual=None,
    ):
        if microbatches <= 1:
            loss, ce, aux, grads, new_residual = grad_once(
                params, batch, expert_perm, wire_perm, ef_residual
            )
        else:
            b = batch["tokens"].shape[0]
            m = microbatches
            assert b % m == 0, (b, m)

            def mb_body(acc, xs):
                tok, lab = xs
                l, c, a, g, _ = grad_once(
                    params, {"tokens": tok, "labels": lab}, expert_perm, wire_perm
                )
                acc = (
                    acc[0] + l / m,
                    acc[1] + c / m,
                    jax.tree.map(lambda p, q: p + q / m, acc[2], a),
                    jax.tree.map(lambda p, q: p + q / m, acc[3], g),
                )
                return acc, ()

            toks = batch["tokens"].reshape(m, b // m, -1)
            labs = batch["labels"].reshape(m, b // m, -1)
            zero_aux = jax.tree.map(
                jnp.zeros_like,
                jax.eval_shape(
                    lambda: grad_once(
                        params, {"tokens": toks[0], "labels": labs[0]},
                        expert_perm, wire_perm,
                    )[2]
                ),
            )
            zeros = (
                jnp.zeros((), jnp.float32),
                jnp.zeros((), jnp.float32),
                zero_aux,
                jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params),
            )
            (loss, ce, aux, grads), _ = jax.lax.scan(mb_body, zeros, (toks, labs))
            new_residual = None
        params, opt_state, opt_metrics = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {
            "loss": loss,
            "ce": ce,
            "balance_loss": aux.balance_loss,
            "z_loss": aux.z_loss,
            **opt_metrics,
        }
        if cfg.is_moe:
            metrics["expert_load"] = aux.moe_stats  # [repeats, E]
        if dp_compress:
            return params, opt_state, metrics, new_residual
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, plan: ShardingPlan, mesh=None, *, with_stats: bool = False):
    """``expert_perm``/``wire_perm`` thread the serving engine's runtime
    placement state into prefill (DESIGN.md §9); ``with_stats`` additionally
    returns the per-layer gate-load telemetry the control plane observes."""

    def prefill_step(params, batch, expert_perm=None, wire_perm=None):
        feats, aux, caches = tfm.model_apply(
            params, batch, cfg, plan, mesh=mesh, mode="prefill",
            expert_perm=expert_perm, wire_perm=wire_perm,
        )
        logits = tfm.logits_from_features(params, feats[:, -1:], cfg)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if with_stats:
            return next_tok, caches, aux.moe_stats
        return next_tok, caches

    return prefill_step


def make_prefill_chunk_step(cfg, plan: ShardingPlan, mesh=None, *, with_stats: bool = False):
    """Chunked-prefill continuation step (DESIGN.md §9).

    Runs a ``[B, C]`` slice of prompt tokens against EXISTING caches in
    decode mode: attention writes positions ``t .. t+C-1`` and attends
    causally over the cached prefix plus the chunk, so a long prompt streams
    through the decode tick loop ``C`` tokens at a time instead of stalling
    every live slot behind one monolithic prefill.  Returns the next-token
    prediction after the chunk's last token (the request's first output when
    the chunk completes the prompt) and the updated caches.

    Only attention block kinds support the multi-token continuation; the
    recurrent kinds (rglru/ssm) advance their state token-by-token.
    """
    bad = [
        k for k in (*cfg.block_pattern, *cfg.tail_pattern)
        if k not in ("global", "local")
    ]
    if bad:
        raise ValueError(
            f"chunked prefill needs attention-only block patterns, got {bad}"
        )

    def chunk_step(
        params, caches, tokens, t, expert_perm=None, wire_perm=None,
        gate_weights=None, page_table=None,
    ):
        feats, aux, caches = tfm.model_apply(
            params, {"tokens": tokens}, cfg, plan, mesh=mesh, mode="decode",
            caches=caches, t=t, expert_perm=expert_perm, wire_perm=wire_perm,
            gate_weights=gate_weights, page_table=page_table,
        )
        logits = tfm.logits_from_features(params, feats[:, -1:], cfg)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if with_stats:
            return next_tok, caches, aux.moe_stats
        return next_tok, caches

    return chunk_step


def make_serve_step(
    cfg, plan: ShardingPlan, mesh=None, *, sample: bool = False,
    with_stats: bool = False,
):
    def serve_step(
        params, caches, tokens, t, rng=None, expert_perm=None, wire_perm=None,
        gate_weights=None, page_table=None,
    ):
        """One decode step: tokens [B,1] + caches -> next token [B,1].

        ``expert_perm``/``wire_perm`` are the runtime placement state the
        serving engine threads per tick; ``gate_weights`` its live-slot mask
        for the exported gate-load telemetry (``with_stats``);
        ``page_table`` switches the caches onto the paged KV pool
        (DESIGN.md §10)."""
        feats, aux, caches = tfm.model_apply(
            params, {"tokens": tokens}, cfg, plan, mesh=mesh, mode="decode",
            caches=caches, t=t, expert_perm=expert_perm, wire_perm=wire_perm,
            gate_weights=gate_weights, page_table=page_table,
        )
        logits = tfm.logits_from_features(params, feats, cfg)[:, -1]
        if sample and rng is not None:
            if rng.ndim == 2:  # [B, 2] per-slot keys (DESIGN.md §11)
                next_tok = _sample_rows(logits, rng)
            else:  # legacy single key for the whole batch (decode.generate)
                next_tok = jax.random.categorical(rng, logits.astype(jnp.float32))
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        next_tok = next_tok.astype(jnp.int32)[:, None]
        if with_stats:
            return next_tok, caches, aux.moe_stats
        return next_tok, caches

    return serve_step


def make_verify_step(
    cfg, plan: ShardingPlan, mesh=None, *, sample: bool = False,
    with_stats: bool = False,
):
    """Speculative VERIFY step (DESIGN.md §11): score a ``[B, C]`` causal
    continuation in one pass and emit the target model's token at EVERY
    position.

    Generalizes ``make_prefill_chunk_step`` from last-position-only to
    all-position outputs: position ``j`` of the chunk consumes token ``j``
    (the previous accepted token at ``j=0``, draft token ``j`` otherwise),
    attention overwrites cache positions ``t .. t+C-1`` with FULL-model K/V
    (the draft's approximate K/V at those positions is never read again),
    and ``tokens[:, j]`` of the result is what serial decode would emit
    after consuming the chunk prefix ``.. j`` — so the longest prefix where
    draft and verify agree, plus verify's first disagreeing token, is
    bit-exact serial decode.  ``rng``: ``[B, C, 2]`` per-(slot, position)
    sample keys under the per-verified-token key discipline.
    """
    bad = [
        k for k in (*cfg.block_pattern, *cfg.tail_pattern)
        if k not in ("global", "local")
    ]
    if bad:
        raise ValueError(
            f"speculative verify needs attention-only block patterns, got {bad}"
        )

    def verify_step(
        params, caches, tokens, t, rng=None, expert_perm=None, wire_perm=None,
        gate_weights=None, page_table=None,
    ):
        feats, aux, caches = tfm.model_apply(
            params, {"tokens": tokens}, cfg, plan, mesh=mesh, mode="decode",
            caches=caches, t=t, expert_perm=expert_perm, wire_perm=wire_perm,
            gate_weights=gate_weights, page_table=page_table,
        )
        logits = tfm.logits_from_features(params, feats, cfg)  # [B, C, V]
        if sample and rng is not None:
            b, c, _ = logits.shape
            flat = _sample_rows(logits.reshape(b * c, -1), rng.reshape(b * c, 2))
            toks = flat.reshape(b, c)
        else:
            toks = jnp.argmax(logits, axis=-1)
        toks = toks.astype(jnp.int32)
        if with_stats:
            return toks, caches, aux.moe_stats
        return toks, caches

    return verify_step


def make_draft_loop_step(
    cfg, plan: ShardingPlan, mesh=None, *, k: int, sample: bool = False,
):
    """Speculative DRAFT loop (DESIGN.md §11): ``k`` greedy/sampled decode
    steps of the (cheap) draft config FUSED into one jitted ``lax.scan``.

    Fusion is the perf point: a serial host loop pays one dispatch per draft
    token, which on launch-overhead-bound decode erases the speculative win;
    the scan makes the whole k-token draft ONE program launch, so a
    draft+verify round is 2 launches for up to k+1 emitted tokens.  The
    draft writes its approximate K/V into the SAME paged pool at positions
    ``t .. t+k-1`` (read only by its own later iterations); verify then
    overwrites those positions with full-model K/V.  ``cfg`` here is the
    DRAFT config (``models.moe.draft_config``).  ``rng``: ``[B, k, 2]``
    keys — the same per-verified-token keys verify uses, so draft samples
    are coupled to verify samples (common random numbers).
    """
    bad = [
        k_ for k_ in (*cfg.block_pattern, *cfg.tail_pattern)
        if k_ not in ("global", "local")
    ]
    if bad:
        raise ValueError(
            f"speculative drafting needs attention-only block patterns, got {bad}"
        )

    def draft_loop(
        params, caches, tokens, t, rng=None, expert_perm=None, wire_perm=None,
        gate_weights=None, page_table=None,
    ):
        b = tokens.shape[0]
        if rng is None:
            keys = jnp.zeros((k, b, 2), jnp.uint32)
        else:
            keys = jnp.swapaxes(rng, 0, 1)  # [k, B, 2]

        def body(carry, xs):
            caches, tok = carry
            i, kk = xs
            feats, _, caches = tfm.model_apply(
                params, {"tokens": tok}, cfg, plan, mesh=mesh, mode="decode",
                caches=caches, t=t + i, expert_perm=expert_perm,
                wire_perm=wire_perm, gate_weights=gate_weights,
                page_table=page_table,
            )
            logits = tfm.logits_from_features(params, feats, cfg)[:, -1]
            if sample:
                nxt = _sample_rows(logits, kk)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32)[:, None]
            return (caches, nxt), nxt[:, 0]

        (caches, _), drafts = jax.lax.scan(
            body, (caches, tokens), (jnp.arange(k, dtype=jnp.int32), keys)
        )
        return jnp.swapaxes(drafts, 0, 1), caches  # [B, k]

    return draft_loop


# ---------------------------------------------------------------------------
# shardings for pjit
# ---------------------------------------------------------------------------


def step_shardings(cfg, plan: ShardingPlan, mesh, param_specs):
    """NamedShardings for (params, opt_state, batch) under the given mesh."""
    ns = lambda spec: NamedSharding(mesh, spec)
    p_sh = jax.tree.map(ns, param_specs, is_leaf=lambda s: isinstance(s, P))
    opt_sh = {
        "mu": p_sh,
        "nu": p_sh,
        "step": ns(P()),
    }
    batch_sh = {
        "tokens": ns(plan.tokens_spec()),
        "labels": ns(plan.tokens_spec()),
    }
    return p_sh, opt_sh, batch_sh


def init_all(key, cfg, plan, opt_cfg):
    """(params, specs, opt_state) convenience initializer."""
    from repro.models.transformer import init_model

    params, specs = init_model(key, cfg, plan)
    opt_state = init_adamw(params, opt_cfg)
    return params, specs, opt_state
