"""Metrics registry: named counters / gauges / histograms with labeled
series and a JSON snapshot (DESIGN.md §14).

The structured replacement for ad-hoc dict telemetry: every layer registers
its series against ONE process-wide registry (``default()``), so a run's
quantitative story — serve ticks, tokens, allocator churn, comm bytes by
link class, control-plane verdicts, netsim hidden/exposed seconds — is a
single ``snapshot()`` away, keyed by a stable ``name{label=value}`` schema.

Emission is deliberately cheap: a labeled child is resolved once and cached
(``registry.counter("comm.link_bytes", op="a2a")``), after which ``inc`` is
one float add under the GIL — safe to call from serve/train tick loops and
netsim inner loops.  Nothing here imports jax or numpy.
"""

from __future__ import annotations

import json
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default",
    "counter",
    "gauge",
    "histogram",
    "snapshot",
    "reset",
]


def _series_key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing value (events, bytes, tokens)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v

    def to_json(self) -> dict:
        return {"labels": self.labels, "value": self.value}


class Gauge:
    """Last-written value (resident pages, loss, EMA step time)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def to_json(self) -> dict:
        return {"labels": self.labels, "value": self.value}


class Histogram:
    """Power-of-two bucketed distribution (latencies, span lengths).

    Buckets are upper bounds ``2^k`` for ``k`` in [min_exp, max_exp]; one
    overflow bucket catches the rest.  Tracks count/sum/min/max exactly.
    """

    __slots__ = ("name", "labels", "count", "sum", "min", "max", "buckets",
                 "_bounds")

    def __init__(self, name: str, labels: dict, *, min_exp: int = -20,
                 max_exp: int = 30):
        self.name = name
        self.labels = labels
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self._bounds = [2.0 ** k for k in range(min_exp, max_exp + 1)]
        self.buckets = [0] * (len(self._bounds) + 1)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        lo, hi = 0, len(self._bounds)
        while lo < hi:  # first bound >= v (bisect, but dependency-free)
            mid = (lo + hi) // 2
            if self._bounds[mid] >= v:
                hi = mid
            else:
                lo = mid + 1
        self.buckets[lo] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_json(self) -> dict:
        nonzero = {
            (f"le_{self._bounds[i]:g}" if i < len(self._bounds) else "overflow"): n
            for i, n in enumerate(self.buckets)
            if n
        }
        return {
            "labels": self.labels,
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "buckets": nonzero,
        }


class MetricsRegistry:
    def __init__(self):
        self._lock = threading.Lock()
        self._series: dict[str, object] = {}
        # Bumped by reset(): long-lived caches of child handles (e.g.
        # commruntime's link-bytes cache) key on this to drop orphans.
        self.generation = 0

    def _get(self, cls, name: str, labels: dict):
        key = _series_key(name, labels)
        s = self._series.get(key)
        if s is None:
            with self._lock:
                s = self._series.get(key)
                if s is None:
                    s = cls(name, labels)
                    self._series[key] = s
        if not isinstance(s, cls):
            raise TypeError(
                f"metric {key!r} already registered as {type(s).__name__}"
            )
        return s

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name, labels)

    def snapshot(self) -> dict:
        """JSON-able view: ``{"counters": {key: {...}}, "gauges": ...}``."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        kind = {Counter: "counters", Gauge: "gauges", Histogram: "histograms"}
        with self._lock:
            items = list(self._series.items())
        for key, s in sorted(items):
            out[kind[type(s)]][key] = s.to_json()
        return out

    def value(self, name: str, **labels) -> float:
        """Current value of a counter/gauge series (0.0 if never written)."""
        key = _series_key(name, labels)
        s = self._series.get(key)
        return getattr(s, "value", 0.0) if s is not None else 0.0

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)

    def reset(self) -> None:
        with self._lock:
            self._series = {}
            self.generation += 1


_DEFAULT = MetricsRegistry()


def default() -> MetricsRegistry:
    return _DEFAULT


def counter(name: str, **labels) -> Counter:
    return _DEFAULT.counter(name, **labels)


def gauge(name: str, **labels) -> Gauge:
    return _DEFAULT.gauge(name, **labels)


def histogram(name: str, **labels) -> Histogram:
    return _DEFAULT.histogram(name, **labels)


def snapshot() -> dict:
    return _DEFAULT.snapshot()


def reset() -> None:
    _DEFAULT.reset()
