"""repro.obs — the measurement plane (DESIGN.md §14).

One schema from kernel-adjacent tick loops up to fleet steering: the paper's
entire design is justified by a *measurement study* (§3's per-iteration
expert traffic matrices), so the repo must be able to produce that study
about its own runs.  Three zero-dependency pieces:

* :mod:`repro.obs.trace` — spans + counters + typed audit events in Chrome
  ``trace_event`` JSON (a whole serve/train/fleet run opens in
  ``chrome://tracing`` / Perfetto).  Disabled is a no-op.
* :mod:`repro.obs.metrics` — a registry of named counters / gauges /
  histograms with labeled series and a JSON snapshot, replacing ad-hoc
  dict telemetry.
* :mod:`repro.obs.traffic` — the §3 observatory: per-layer expert→device
  traffic matrices accumulated from live gate loads, with the locality /
  regional-concentration statistics the paper measures.

This package must stay importable without jax (netsim and the pure-python
consumers are jax-free), and the instrumented hot paths only ever pay one
attribute check when tracing is disabled.
"""

from repro.obs import metrics, trace, traffic

__all__ = ["trace", "metrics", "traffic"]
