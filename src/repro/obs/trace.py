"""Zero-dependency tracer: spans, counters, and typed audit events in the
Chrome ``trace_event`` format (DESIGN.md §14).

One process-wide :class:`Tracer` (``default()``) is shared by every layer —
Trainer steps, ServeEngine ticks, FleetEngine steering, ControlPlane plan
verdicts, netsim scenario runs — so a whole run exports as ONE merged
timeline that opens directly in ``chrome://tracing`` or
https://ui.perfetto.dev.  Components that share a host thread (fleet
replicas, netsim scenarios) get their own *track* (a synthetic ``tid`` with
a ``thread_name`` metadata record) via :meth:`Tracer.track`.

Design constraints, asserted by ``tests/test_obs.py``:

* **Disabled is a no-op.**  The tracer ships disabled; every emit path
  starts with one attribute check and returns a shared null object, so the
  instrumented hot loops (serve ticks, train steps, netsim inner loops) pay
  near-zero overhead by default.  The benchmark gate
  (``benchmarks/run.py::observability``) bounds the *enabled* serve-tick
  overhead too (< 3%).
* **Thread-safe, ring-buffered.**  Events land in a bounded deque (oldest
  events drop first); concurrent emitters never block each other beyond a
  short append lock.
* **Schema.**  Every exported event carries ``name``/``ph``/``ts``/``pid``/
  ``tid``; spans are complete (``ph="X"``) events whose intervals nest,
  counters are ``ph="C"``, typed audit events are instants (``ph="i"``)
  whose payload rides ``args``.  :func:`validate_events` is the shared
  schema check used by tests, CI and ``scripts/measure_run.py``.
"""

from __future__ import annotations

import json
import os
import threading
import time

__all__ = [
    "Tracer",
    "default",
    "enable",
    "disable",
    "export",
    "validate_events",
    "validate_file",
]


class _NullSpan:
    """Shared no-op context manager — the disabled tracer's span."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    """A live span: records a complete (``ph="X"``) event on exit."""

    __slots__ = ("_tracer", "name", "cat", "tid", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str, tid: int, args: dict):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.tid = tid
        self.args = args

    def set(self, **args):
        """Attach result fields discovered while the span runs."""
        self.args.update(args)
        return self

    def __enter__(self):
        self._t0 = self._tracer._now_us()
        return self

    def __exit__(self, *exc):
        # Hot path: one timestamp, one dict, one locked append — kept flat
        # (no helper calls) because the serve/train tick overhead gate in
        # benchmarks/run.py::observability charges every interpreter cycle
        # spent here against the < 3% budget.
        tr = self._tracer
        t1 = (tr._clock() - tr._epoch) * 1e6
        ev = {
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": self._t0,
            "dur": t1 - self._t0,
            "pid": tr.pid,
            "tid": self.tid,
            "args": self.args,
        }
        lock = tr._lock
        lock.acquire()
        events = tr._events
        if len(events) >= tr.capacity:
            keep = tr.capacity // 2
            tr._dropped += len(events) - keep
            tr._events = events = events[-keep:]
        events.append(ev)
        lock.release()
        return False


class Tracer:
    def __init__(self, capacity: int = 262_144, clock=time.perf_counter):
        self.enabled = False
        self.pid = os.getpid()
        self.capacity = int(capacity)
        self._clock = clock
        self._epoch = clock()
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._dropped = 0
        # Synthetic tracks for components sharing a host thread; real thread
        # ids collide with nothing in this range (ids start at 1).
        self._tracks: dict[str, int] = {}
        self._next_track = 1

    # -- time / track bookkeeping -------------------------------------------
    def _now_us(self) -> float:
        return (self._clock() - self._epoch) * 1e6

    def _default_tid(self) -> int:
        return threading.get_ident() & 0x7FFFFFFF

    def track(self, name: str) -> int:
        """Register (or look up) a named track; returns its ``tid``.

        Pass the returned id as ``tid=`` to span/instant/counter so one
        component's events form their own row in the viewer even when many
        components tick on the same host thread (fleet replicas)."""
        with self._lock:
            tid = self._tracks.get(name)
            if tid is None:
                tid = self._next_track
                self._next_track += 1
                self._tracks[name] = tid
            return tid

    def _append(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) >= self.capacity:
                # Ring semantics: drop the oldest half in one O(n) slice
                # instead of an O(n) pop per event.
                keep = self.capacity // 2
                self._dropped += len(self._events) - keep
                self._events = self._events[-keep:]
            self._events.append(ev)

    # -- emitters ------------------------------------------------------------
    def span(self, name: str, *, cat: str = "span", tid: int | None = None, **args):
        """Context manager timing a region: ``with tracer.span("serve.tick")``.

        Returns the shared null span when disabled — the no-op fast path."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, self._default_tid() if tid is None else tid, args)

    def instant(self, name: str, *, cat: str = "event", tid: int | None = None, **args):
        """A point-in-time typed event (``ph="i"``); payload rides ``args``."""
        if not self.enabled:
            return
        self._append({
            "name": name,
            "cat": cat,
            "ph": "i",
            "s": "t",
            "ts": self._now_us(),
            "pid": self.pid,
            "tid": self._default_tid() if tid is None else tid,
            "args": args,
        })

    def counter(self, name: str, values, *, tid: int | None = None):
        """A counter sample (``ph="C"``): ``values`` is a float or a dict of
        named series (Perfetto stacks the series of one counter name)."""
        if not self.enabled:
            return
        # Flat hot path (see _Span.__exit__): per-tick counters ride the
        # same < 3% overhead budget as spans.
        if isinstance(values, dict):
            args = {k: float(v) for k, v in values.items()}
        else:
            args = {"value": float(values)}
        ev = {
            "name": name,
            "cat": "counter",
            "ph": "C",
            "ts": (self._clock() - self._epoch) * 1e6,
            "pid": self.pid,
            "tid": self._default_tid() if tid is None else tid,
            "args": args,
        }
        lock = self._lock
        lock.acquire()
        events = self._events
        if len(events) >= self.capacity:
            keep = self.capacity // 2
            self._dropped += len(events) - keep
            self._events = events = events[-keep:]
        events.append(ev)
        lock.release()

    def audit(self, name: str, payload: dict, *, cat: str = "audit", tid: int | None = None):
        """A structured audit record (reconfiguration verdicts, steering
        decisions) — an instant whose args ARE the typed event's fields."""
        if not self.enabled:
            return
        self.instant(name, cat=cat, tid=tid, **payload)

    # -- snapshot / export ---------------------------------------------------
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events = []
            self._dropped = 0

    def _metadata_events(self) -> list[dict]:
        meta = [{
            "name": "process_name", "ph": "M", "ts": 0.0,
            "pid": self.pid, "tid": 0, "args": {"name": "repro"},
        }]
        with self._lock:
            tracks = dict(self._tracks)
        for name, tid in sorted(tracks.items(), key=lambda kv: kv[1]):
            meta.append({
                "name": "thread_name", "ph": "M", "ts": 0.0,
                "pid": self.pid, "tid": tid, "args": {"name": name},
            })
        return meta

    def export(self, path: str) -> int:
        """Write the Chrome/Perfetto JSON; returns the number of events."""
        events = self._metadata_events() + self.events()
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        d = os.path.dirname(os.path.abspath(path))
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(events)


# -- process-wide default tracer (the merged-timeline contract) --------------
_DEFAULT = Tracer()


def default() -> Tracer:
    return _DEFAULT


def enable() -> Tracer:
    _DEFAULT.enabled = True
    return _DEFAULT


def disable() -> Tracer:
    _DEFAULT.enabled = False
    return _DEFAULT


def export(path: str) -> int:
    return _DEFAULT.export(path)


# -- the shared schema check -------------------------------------------------
_REQUIRED = ("name", "ph", "ts", "pid", "tid")


def validate_events(events: list) -> list[str]:
    """Validate trace events against the §14 schema; returns human-readable
    failures (empty = valid).  Checks: every event carries
    ``name``/``ph``/``ts``/``pid``/``tid``; complete spans carry a
    non-negative ``dur`` and, per track, nest properly (two spans either
    disjoint or one containing the other); counter samples carry numeric
    series; the whole list JSON round-trips."""
    failures: list[str] = []
    if not isinstance(events, list):
        return ["trace is not a list of events"]
    spans_by_track: dict[tuple, list[tuple[float, float, str]]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            failures.append(f"event[{i}] is not an object")
            continue
        missing = [k for k in _REQUIRED if k not in ev]
        if missing:
            failures.append(f"event[{i}] ({ev.get('name')!r}) missing {missing}")
            continue
        if not isinstance(ev["ts"], (int, float)):
            failures.append(f"event[{i}] ({ev['name']!r}) non-numeric ts")
        ph = ev["ph"]
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                failures.append(f"event[{i}] ({ev['name']!r}) span without dur")
            else:
                spans_by_track.setdefault((ev["pid"], ev["tid"]), []).append(
                    (float(ev["ts"]), float(ev["ts"]) + float(dur), ev["name"])
                )
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                isinstance(v, (int, float)) for v in args.values()
            ):
                failures.append(
                    f"event[{i}] ({ev['name']!r}) counter without numeric series"
                )
        elif ph not in ("i", "I", "M", "B", "E"):
            failures.append(f"event[{i}] ({ev['name']!r}) unknown phase {ph!r}")
    for track, spans in spans_by_track.items():
        # Parent-before-child order: ascending start, DESCENDING end, so a
        # span starting with its parent sorts after it.
        spans.sort(key=lambda t: (t[0], -t[1]))
        stack: list[tuple[float, float, str]] = []
        for s0, s1, name in spans:
            while stack and stack[-1][1] <= s0:
                stack.pop()
            if stack and s1 > stack[-1][1]:
                failures.append(
                    f"track {track}: span {name!r} [{s0:.1f}, {s1:.1f}] "
                    f"partially overlaps {stack[-1][2]!r} "
                    f"[{stack[-1][0]:.1f}, {stack[-1][1]:.1f}]"
                )
                continue
            stack.append((s0, s1, name))
    try:
        json.loads(json.dumps(events))
    except (TypeError, ValueError) as e:  # pragma: no cover - defensive
        failures.append(f"trace does not JSON round-trip: {e}")
    return failures


def validate_file(path: str) -> list[str]:
    """Schema-check an exported trace file (the CI step's entry point)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"cannot load {path}: {e}"]
    events = doc.get("traceEvents") if isinstance(doc, dict) else doc
    if events is None:
        return [f"{path}: no traceEvents array"]
    return validate_events(events)
