"""Traffic-matrix observatory — the paper's §3 measurement study applied to
this repo's own runs (DESIGN.md §14).

MixNet's design is licensed by a production measurement: per-iteration
expert traffic matrices show strong *locality* (a few experts/devices take
most of the traffic) and strong *regional* skew (different request regions
activate different expert mixes) — Figs 7–12.  The observatory reproduces
that study live: consumers stream per-tick/step gate loads
(``record(load, perm_stack, region_weights)``) and it accumulates

* ``expert_traffic [L, E]`` — routed token mass per layer per expert;
* ``device_traffic [L, D]`` — the same mass mapped through the *current*
  expert→slot permutation onto devices (the expert→device traffic matrix,
  under whatever placement the control plane has actuated so far);
* per-region expert mixes, when the caller attributes ticks to traffic
  regions (the fleet-steering statistics, DESIGN.md §12).

and computes the statistics the paper measures: a normalized-HHI
**locality score** per layer (0 = uniform, 1 = single-expert), the
**regional concentration** (share of a layer's traffic on the hottest
device block), the **effective expert count** ``1/Σ mix²`` (netsim's
expert-residency floor uses the same quantity), and the **regional skew**
(mean Bhattacharyya miss between each region's mix and the global mix —
the signal that makes gate-locality steering win).

Everything is plain numpy and JSON round-trippable (``report`` /
``from_report``), so a run's observatory rides the trace file as one typed
event and ``scripts/measure_run.py`` can rebuild the study offline.
"""

from __future__ import annotations

import numpy as np

__all__ = ["TrafficObservatory"]


def _bhattacharyya(a: np.ndarray, b: np.ndarray) -> float:
    a = a / max(float(a.sum()), 1e-12)
    b = b / max(float(b.sum()), 1e-12)
    return float(np.sqrt(a * b).sum())


class TrafficObservatory:
    def __init__(
        self,
        num_layers: int,
        num_experts: int,
        *,
        num_devices: int = 1,
        replication: int = 1,
        num_regions: int = 0,
    ):
        self.num_layers = int(num_layers)
        self.num_experts = int(num_experts)
        self.num_devices = max(int(num_devices), 1)
        self.replication = max(int(replication), 1)
        self.num_virtual = self.num_experts * self.replication
        self.experts_per_device = max(self.num_virtual // self.num_devices, 1)
        self.num_regions = int(num_regions)
        self.expert_traffic = np.zeros((self.num_layers, self.num_experts))
        self.device_traffic = np.zeros((self.num_layers, self.num_devices))
        self.region_traffic = np.zeros(
            (max(self.num_regions, 1), self.num_layers, self.num_experts)
        )
        self.ticks = 0

    # -- ingestion ------------------------------------------------------------
    def record(
        self,
        load,
        perm_stack=None,
        region_weights: dict[int, float] | None = None,
    ) -> None:
        """Fold one tick/step's realized gate load into the matrices.

        ``load``: ``[L, E]`` routed token mass (rows may be all-zero).
        ``perm_stack``: ``[L, E_virtual]`` expert→slot maps (the control
        plane's ``perm_stack()``); identity when omitted.  ``region_weights``
        attributes the tick's mass to traffic regions (each region's share
        of the live slots, as :meth:`ServeEngine.live_region_weights`)."""
        load = np.asarray(load, dtype=np.float64)
        if load.ndim != 2:
            raise ValueError(f"load must be [L, E], got shape {load.shape}")
        layers = min(load.shape[0], self.num_layers)
        load = load[:layers, : self.num_experts]
        self.expert_traffic[:layers] += load
        # Expert→device: each expert's mass splits evenly over its replicas;
        # a virtual slot s lives on device s // experts_per_device.
        vload = (
            np.repeat(load, self.replication, axis=1) / self.replication
        )  # [layers, E_virtual]
        if perm_stack is None:
            slots = np.tile(np.arange(self.num_virtual), (layers, 1))
        else:
            slots = np.asarray(perm_stack)[:layers, : self.num_virtual]
        devs = np.clip(slots // self.experts_per_device, 0, self.num_devices - 1)
        for l in range(layers):
            np.add.at(self.device_traffic[l], devs[l], vload[l])
        if region_weights and self.num_regions:
            for region, w in region_weights.items():
                if w > 0 and 0 <= region < self.num_regions:
                    self.region_traffic[region, :layers] += w * load
        self.ticks += 1

    # -- §3 statistics --------------------------------------------------------
    @staticmethod
    def _normalized_hhi(mass: np.ndarray) -> np.ndarray:
        """Per-row concentration in [0, 1]: 0 = uniform, 1 = single bin."""
        n = mass.shape[-1]
        s = mass.sum(axis=-1, keepdims=True)
        share = np.where(s > 0, mass / np.maximum(s, 1e-12), 1.0 / n)
        hhi = (share**2).sum(axis=-1)
        if n <= 1:
            return np.zeros_like(hhi)
        return (hhi - 1.0 / n) / (1.0 - 1.0 / n)

    def locality_per_layer(self) -> np.ndarray:
        """``[L]`` expert-traffic concentration (normalized HHI) — the §3
        'a small set of experts receives most traffic' statistic."""
        return self._normalized_hhi(self.expert_traffic)

    def locality_score(self) -> float:
        return float(self.locality_per_layer().mean())

    def device_concentration(self) -> np.ndarray:
        """``[L]`` share of each layer's traffic on its hottest device —
        the regional-concentration statistic (traffic a regional fabric can
        keep local instead of crossing regions)."""
        s = self.device_traffic.sum(axis=-1)
        top = self.device_traffic.max(axis=-1)
        return np.where(s > 0, top / np.maximum(s, 1e-12), 1.0 / self.num_devices)

    def effective_experts(self) -> np.ndarray:
        """``[L]`` effective number of experts ``1/Σ mix²`` — what the
        fleet netsim's expert-residency HBM floor streams."""
        s = self.expert_traffic.sum(axis=-1, keepdims=True)
        mix = np.where(
            s > 0,
            self.expert_traffic / np.maximum(s, 1e-12),
            1.0 / self.num_experts,
        )
        return 1.0 / np.maximum((mix**2).sum(axis=-1), 1e-12)

    def regional_skew(self) -> float:
        """Mass-weighted mean Bhattacharyya *miss* between each region's
        expert mix and the global mix, over layers — 0 when every region
        routes identically, →1 as regions activate disjoint experts."""
        if not self.num_regions:
            return 0.0
        glob = self.expert_traffic
        weights, misses = [], []
        for r in range(self.num_regions):
            mass = float(self.region_traffic[r].sum())
            if mass <= 0:
                continue
            per_layer = [
                1.0 - _bhattacharyya(self.region_traffic[r, l], glob[l])
                for l in range(self.num_layers)
                if glob[l].sum() > 0
            ]
            if per_layer:
                weights.append(mass)
                misses.append(float(np.mean(per_layer)))
        if not weights:
            return 0.0
        w = np.asarray(weights)
        return float((w * np.asarray(misses)).sum() / w.sum())

    # -- round-trip (the trace-event payload) ---------------------------------
    def report(self) -> dict:
        """The §3-style study as one JSON-able document."""
        total = self.expert_traffic.sum()
        return {
            "ticks": self.ticks,
            "num_layers": self.num_layers,
            "num_experts": self.num_experts,
            "num_devices": self.num_devices,
            "replication": self.replication,
            "num_regions": self.num_regions,
            "total_routed": float(total),
            "locality_score": self.locality_score(),
            "locality_per_layer": self.locality_per_layer().tolist(),
            "device_concentration": self.device_concentration().tolist(),
            "effective_experts": self.effective_experts().tolist(),
            "regional_skew": self.regional_skew(),
            "expert_traffic": self.expert_traffic.tolist(),
            "device_traffic": self.device_traffic.tolist(),
            "region_traffic": (
                self.region_traffic.tolist() if self.num_regions else []
            ),
        }

    @classmethod
    def from_report(cls, rep: dict) -> "TrafficObservatory":
        obs = cls(
            rep["num_layers"],
            rep["num_experts"],
            num_devices=rep.get("num_devices", 1),
            replication=rep.get("replication", 1),
            num_regions=rep.get("num_regions", 0),
        )
        obs.expert_traffic = np.asarray(rep["expert_traffic"], dtype=np.float64)
        obs.device_traffic = np.asarray(rep["device_traffic"], dtype=np.float64)
        if rep.get("region_traffic"):
            obs.region_traffic = np.asarray(
                rep["region_traffic"], dtype=np.float64
            )
        obs.ticks = int(rep.get("ticks", 0))
        return obs

    def merge(self, other: "TrafficObservatory") -> "TrafficObservatory":
        """Sum another observatory's matrices into this one (fleet view)."""
        if (self.num_layers, self.num_experts) != (
            other.num_layers, other.num_experts,
        ):
            raise ValueError("observatory shapes differ")
        self.expert_traffic += other.expert_traffic
        if self.device_traffic.shape == other.device_traffic.shape:
            self.device_traffic += other.device_traffic
        if self.region_traffic.shape == other.region_traffic.shape:
            self.region_traffic += other.region_traffic
        self.ticks += other.ticks
        return self
