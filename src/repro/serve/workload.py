"""Synthetic serving workloads (DESIGN.md §9).

One generator feeds BOTH serving consumers: the real engine
(:mod:`repro.serve.engine`, which materializes prompt tokens and drives the
jitted tick loop) and the flow-level simulator
(:func:`repro.core.netsim.simulate_serving`, which only needs arrival times,
lengths and regions) — so the priced scenario and the executed one see the
same traffic, the same way netsim and the trainer share the CommRuntime's
byte accounting.

A :class:`TrafficMix` describes one request population:

* **arrivals** — Poisson (independent exponential gaps) or bursty (a two
  state on/off modulated Poisson process, the production "thundering herd"
  shape);
* **lengths** — prompt and output lengths drawn from bounded Zipf
  (power-law) distributions, the documented long-tail of production traces
  (most requests short, a heavy tail of huge prompts / long generations);
* **regions** — each request originates in one of ``num_regions`` traffic
  regions with Zipf-skewed popularity.  Regional origin is what makes
  decode-time gate load *regionally* skewed — the locality a reconfigurable
  fabric exploits (paper §3) — and drives the per-region demand matrices of
  the netsim serving scenario.

Everything is deterministic in ``seed``: the engine's generation-parity
tests replay the identical request stream with reconfiguration on and off.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TrafficMix", "MIXES", "SyntheticRequest", "WorkloadGenerator",
           "clamp_requests", "SLOClass", "SLO_CLASSES", "slo_for"]


@dataclasses.dataclass(frozen=True)
class TrafficMix:
    """One named request population (arrival process + length laws)."""

    name: str
    rate_rps: float  # mean arrival rate (requests / second)
    arrival: str = "poisson"  # "poisson" | "bursty"
    burst_factor: float = 4.0  # on-state rate multiplier (bursty only)
    burst_on_s: float = 2.0  # mean on-period length (seconds)
    burst_off_s: float = 6.0  # mean off-period length (seconds)
    prompt_min: int = 8
    prompt_max: int = 128
    prompt_zipf_a: float = 1.2  # power-law exponent over [min, max]
    out_min: int = 4
    out_max: int = 64
    out_zipf_a: float = 1.1
    num_regions: int = 4
    region_zipf_a: float = 0.8  # request-origin skew across regions
    # Agentic traces re-send the same system prompt / tool schema on every
    # self-loop call: requests from one region share a common prompt prefix.
    # ``shared_prefix_tokens`` is the per-region prefix length and
    # ``shared_prefix_ratio`` the fraction of requests that carry it — the
    # traffic shape the paged KV cache's prefix registry (DESIGN.md §10)
    # turns into page reuse instead of recomputed prefill.
    shared_prefix_tokens: int = 0
    shared_prefix_ratio: float = 0.0
    # Replica-affinity churn: every ``region_churn_every_s`` seconds the
    # region popularity ranking rotates by ``region_churn_rot`` positions, so
    # the *hot* region migrates mid-stream.  This is the drift that makes a
    # fleet's gate-locality steering eventually lose — the resident mix a
    # replica reconfigured for stops matching its arrivals — and is the
    # trigger for the steer-vs-reconfigure decision rule (DESIGN.md §12).
    # Zero disables churn; mixes without it generate byte-identical streams
    # to earlier versions.
    region_churn_every_s: float = 0.0
    region_churn_rot: int = 1


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One admission-priority class (fleet scheduling, DESIGN.md §12).

    ``priority`` orders the global admission queue (lower dispatches first);
    ``ttft_target_s`` is the class's time-to-first-token objective, the
    attainment denominator :func:`repro.core.netsim.simulate_fleet` reports.
    """

    name: str
    priority: int
    ttft_target_s: float


# Priority classes for the named mixes.  Interactive chat is latency-critical,
# agentic loops tolerate moderate queueing (the caller is a program), batch
# jobs only care about completion.
SLO_CLASSES: dict[str, SLOClass] = {
    "chat": SLOClass("chat", priority=0, ttft_target_s=1.0),
    "agentic": SLOClass("agentic", priority=1, ttft_target_s=4.0),
    "agentic_shared": SLOClass("agentic_shared", priority=1, ttft_target_s=4.0),
    "agentic_churn": SLOClass("agentic_churn", priority=1, ttft_target_s=4.0),
    "batch_summarize": SLOClass("batch_summarize", priority=2, ttft_target_s=30.0),
}

_DEFAULT_SLO = SLOClass("default", priority=1, ttft_target_s=4.0)


def slo_for(mix_name: str) -> SLOClass:
    """SLO class for a mix name (unknown mixes get the mid-priority default)."""
    return SLO_CLASSES.get(mix_name, _DEFAULT_SLO)


# Named mixes the examples/benchmarks reference.  The shapes follow the
# production archetypes: chat = short prompts / medium outputs at steady
# Poisson rate; batch_summarize = long prompts / short outputs arriving in
# bursts (cron-fired document batches); agentic = medium prompts with LONG
# tool-call transcripts and bursty self-loops.
MIXES: dict[str, TrafficMix] = {
    "chat": TrafficMix(
        "chat", rate_rps=8.0, arrival="poisson",
        prompt_min=8, prompt_max=96, prompt_zipf_a=1.4,
        out_min=8, out_max=64, out_zipf_a=1.2,
    ),
    "batch_summarize": TrafficMix(
        "batch_summarize", rate_rps=4.0, arrival="bursty", burst_factor=6.0,
        prompt_min=64, prompt_max=512, prompt_zipf_a=0.8,
        out_min=4, out_max=24, out_zipf_a=1.5,
    ),
    "agentic": TrafficMix(
        "agentic", rate_rps=6.0, arrival="bursty", burst_factor=3.0,
        prompt_min=16, prompt_max=256, prompt_zipf_a=1.0,
        out_min=16, out_max=128, out_zipf_a=0.9,
        num_regions=4, region_zipf_a=1.2,
    ),
    # The agentic mix with the self-loop structure made explicit: ~90% of
    # requests re-send their region's 64-token system prompt verbatim.
    "agentic_shared": TrafficMix(
        "agentic_shared", rate_rps=6.0, arrival="bursty", burst_factor=3.0,
        prompt_min=80, prompt_max=256, prompt_zipf_a=1.0,
        out_min=16, out_max=128, out_zipf_a=0.9,
        num_regions=4, region_zipf_a=1.2,
        shared_prefix_tokens=64, shared_prefix_ratio=0.9,
    ),
    # Region-skewed agentic traffic whose hot region migrates every few
    # seconds — the fleet-steering stress mix: locality steering must either
    # follow the drift or trigger a replica reconfiguration.
    "agentic_churn": TrafficMix(
        "agentic_churn", rate_rps=6.0, arrival="bursty", burst_factor=3.0,
        prompt_min=16, prompt_max=256, prompt_zipf_a=1.0,
        out_min=16, out_max=128, out_zipf_a=0.9,
        num_regions=4, region_zipf_a=1.6,
        region_churn_every_s=8.0, region_churn_rot=1,
    ),
}


@dataclasses.dataclass(frozen=True)
class SyntheticRequest:
    """One generated request (framework-free: netsim consumes it as-is)."""

    rid: int
    arrival_s: float
    prompt_len: int
    max_new_tokens: int
    region: int
    prefix_len: int = 0  # leading tokens shared with the region's prefix


def clamp_requests(
    reqs: list["SyntheticRequest"],
    *,
    prompt_max: int | None = None,
    max_new: int | None = None,
    arrival_s: float | None = None,
) -> list["SyntheticRequest"]:
    """Benchmark-shape a generated stream: cap prompt lengths, pin output
    budgets and/or arrivals, preserving every distributional property the
    caps don't touch.

    The decode benchmarks (``paged_decode``, ``spec_decode``) want the mix's
    region/prefix structure but a decode-heavy shape (short prompts, fixed
    generation budget, no arrival gaps).  Clamping ``prompt_len`` keeps the
    materialized tokens a PREFIX of the unclamped prompt
    (:meth:`WorkloadGenerator.prompt_tokens` draws sequentially from the
    same streams), and ``prefix_len`` is re-clamped so the shared-prefix
    invariant ``prefix_len <= prompt_len`` survives aggressive caps."""
    out = []
    for r in reqs:
        plen = min(r.prompt_len, prompt_max) if prompt_max else r.prompt_len
        out.append(dataclasses.replace(
            r,
            prompt_len=plen,
            prefix_len=min(r.prefix_len, plen),
            max_new_tokens=max_new if max_new is not None else r.max_new_tokens,
            arrival_s=arrival_s if arrival_s is not None else r.arrival_s,
        ))
    return out


def _bounded_zipf(rng: np.random.Generator, a: float, lo: int, hi: int, n: int):
    """Discrete power-law sample over [lo, hi]: p(k) ∝ (k - lo + 1)^-a."""
    support = np.arange(lo, hi + 1)
    p = (support - lo + 1.0) ** -a
    p /= p.sum()
    return rng.choice(support, size=n, p=p)


class WorkloadGenerator:
    """Deterministic request-stream generator for one :class:`TrafficMix`."""

    def __init__(self, mix: TrafficMix | str, *, seed: int = 0, vocab_size: int = 256):
        self.mix = MIXES[mix] if isinstance(mix, str) else mix
        self.seed = seed
        self.vocab_size = vocab_size

    def generate(self, num_requests: int) -> list[SyntheticRequest]:
        m = self.mix
        rng = np.random.default_rng(self.seed)
        # -- arrival process --------------------------------------------------
        if m.arrival == "poisson":
            gaps = rng.exponential(1.0 / m.rate_rps, size=num_requests)
            arrivals = np.cumsum(gaps)
        elif m.arrival == "bursty":
            # Two-state MMPP: on-periods run at rate*burst_factor, off-periods
            # at a trickle; state dwell times are exponential.
            arrivals = np.empty(num_requests)
            t, state, state_left = 0.0, 1, rng.exponential(m.burst_on_s)
            for i in range(num_requests):
                rate = m.rate_rps * (m.burst_factor if state else 0.2)
                gap = rng.exponential(1.0 / rate)
                while gap > state_left:
                    t += state_left
                    gap = (gap - state_left) * (
                        (m.burst_factor if state else 0.2)
                        / (0.2 if state else m.burst_factor)
                    )
                    state = 1 - state
                    state_left = rng.exponential(
                        m.burst_on_s if state else m.burst_off_s
                    )
                t += gap
                state_left -= gap
                arrivals[i] = t
        else:
            raise ValueError(f"unknown arrival process {m.arrival!r}")
        # -- lengths + regions ------------------------------------------------
        plens = _bounded_zipf(rng, m.prompt_zipf_a, m.prompt_min, m.prompt_max,
                              num_requests)
        olens = _bounded_zipf(rng, m.out_zipf_a, m.out_min, m.out_max,
                              num_requests)
        rp = (np.arange(1, m.num_regions + 1) ** -m.region_zipf_a).astype(float)
        rp /= rp.sum()
        regions = rng.choice(m.num_regions, size=num_requests, p=rp)
        if m.region_churn_every_s > 0:
            # Rotate the popularity ranking over time: the region drawn at
            # Zipf rank k at time t is (k + rot * floor(t / every)) mod R, so
            # the hot region walks around the ring deterministically.
            shift = (arrivals // m.region_churn_every_s).astype(np.int64)
            regions = (regions + m.region_churn_rot * shift) % m.num_regions
        # Shared prefixes (drawn only when configured, so mixes without them
        # generate byte-identical streams to earlier versions).
        if m.shared_prefix_tokens > 0:
            carries = rng.random(num_requests) < m.shared_prefix_ratio
            prefix_lens = np.where(
                carries, np.minimum(m.shared_prefix_tokens, plens), 0
            )
        else:
            prefix_lens = np.zeros(num_requests, np.int64)
        return [
            SyntheticRequest(
                rid=i,
                arrival_s=float(arrivals[i]),
                prompt_len=int(plens[i]),
                max_new_tokens=int(olens[i]),
                region=int(regions[i]),
                prefix_len=int(prefix_lens[i]),
            )
            for i in range(num_requests)
        ]

    def prompt_tokens(self, req: SyntheticRequest) -> np.ndarray:
        """Materialize the request's prompt (deterministic in (seed, rid)).

        The leading token encodes the region so requests from the same region
        share a prefix — the correlation that concentrates gate load
        per-region (paper §3's semantic locality, at toy scale).  When the mix
        assigns the request a shared prefix (``req.prefix_len > 0``), the
        first ``prefix_len`` tokens come from a region-seeded stream instead:
        every carrying request from that region sends the identical system
        prompt, which is what the paged cache's prefix registry deduplicates.
        """
        rng = np.random.default_rng((self.seed << 20) ^ req.rid)
        toks = rng.integers(0, self.vocab_size, size=req.prompt_len)
        if req.prefix_len > 0:
            prng = np.random.default_rng((self.seed << 20) ^ 0x5AFE ^ req.region)
            toks[: req.prefix_len] = prng.integers(
                0, self.vocab_size, size=req.prefix_len
            )
        toks[0] = req.region % self.vocab_size
        return toks.astype(np.int32)
