"""ServeEngine — the reconfigurable expert-parallel serving engine
(DESIGN.md §9).

Owns the request lifecycle end to end:

    workload (repro.serve.workload) -> admission -> chunked prefill
    interleaved into decode ticks (repro.serve.batching) -> EP-sharded
    decode -> per-tick gate-load observation -> ControlPlane.observe /
    end_step -> placement plans applied BETWEEN ticks (weight permutation,
    or wire re-address for whole-device-block plans) -> checkpoint.

This is the serving half of the paper's runtime-reconfiguration story: the
decode-time expert load is skewed and drifts with the request mix (§3's
locality, which the workload generator's regional skew reproduces), so the
same monitor -> solve -> actuate loop the trainer runs
(:class:`repro.core.controlplane.ControlPlane` +
:class:`~repro.core.controlplane.PlacementApplier`) migrates hot experts
toward the regions generating their traffic while the server keeps serving.

**Generation-consistency guarantee**: with identical seeds and request
streams, the generated tokens are bit-identical with reconfiguration on and
off.  A placement plan moves expert *weights* (or wire addresses) and
re-addresses the router through ``expert_perm`` in the same transaction —
and every decode-path combine sums choices in gate order, never slot order
(:mod:`repro.models.moe`), so no float association moves with the
permutation.  The parity sweep in ``tests/test_serve.py`` asserts this for
P ∈ {1,2,4,8} × dropless/capacity.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import commruntime as comm
from repro.core.controlplane import ControlPlane, LayerPlan, PlacementApplier
from repro.models import routing
from repro.obs import metrics, trace
from repro.obs.traffic import TrafficObservatory
from repro.parallel.sharding import ShardingPlan, virtual_experts
from repro.serve import events as sev
from repro.serve.batching import ContinuousBatcher, Request, TickStats
from repro.serve.workload import SyntheticRequest, WorkloadGenerator
from repro.train import checkpoint as ckpt

__all__ = ["ServeConfig", "ServeReport", "ServeEngine"]


@dataclasses.dataclass
class ServeConfig:
    slots: int = 4
    max_len: int = 128
    # Chunked prefill budget per tick (tokens); 0 = whole-prompt prefill at
    # admission (the pre-engine behaviour).
    prefill_chunk: int = 0
    # Virtual seconds one tick represents — maps workload arrival times onto
    # the tick clock deterministically (parity runs replay identically).
    tick_s: float = 0.05
    # Decode-time reconfiguration: every N ticks the engine asks the control
    # plane for per-layer placement plans and applies them between ticks.
    # 0 disables the control loop entirely.
    reconfig_every: int = 0
    reconfig_min_gain: float = 0.0
    # Control-plane device space (expert slots per device = Ev / num_devices).
    # 0 = the sharding plan's model-axis size.  A logical region larger than
    # the physical axis is legal — placement-mode perms are pure router/weight
    # re-addressing (DESIGN.md §2).
    num_devices: int = 0
    use_copilot: bool = False
    sample: bool = False
    max_ticks: int = 10_000
    # Paged KV cache (DESIGN.md §10).  None = auto (paged whenever the model
    # supports it); False forces the dense per-slot ring buffer.
    paged: bool | None = None
    page_size: int = 16
    num_pages: int = 0  # 0 = slots * ceil(max_len / page_size)
    prefix_cache: bool = True
    # Speculative decoding (DESIGN.md §11): draft up to spec_k tokens per
    # slot per tick with the cheap same-weights pass and verify them in one
    # chunked full-model step.  0 = off.  Requires the paged KV cache.
    spec_k: int = 0
    # Draft pass: "auto" (shared_only when the model has shared experts,
    # else topk1), or an explicit MoEConfig.draft_mode value.
    spec_draft_mode: str = "auto"
    # Base seed for the per-(request, emitted-token) sampling keys (only
    # used when sample=True).
    sample_seed: int = 0
    # Fleet hooks (DESIGN.md §12).  ``external_control`` builds the control
    # plane even with reconfig_every=0 — an external controller (FleetEngine)
    # decides WHEN to reconfigure and drives ``apply_plans`` itself.
    # ``num_regions`` > 0 turns on region-conditioned gate statistics: each
    # tick's observed gate load is attributed to the traffic regions of the
    # live requests (the per-replica statistics fleet steering merges).
    external_control: bool = False
    num_regions: int = 0


@dataclasses.dataclass
class ServeReport:
    """End-of-run serving metrics (ticks are the deterministic clock; wall
    seconds measure this host's actual throughput)."""

    requests: int
    completed: int
    rejected: int
    ticks: int
    tokens_out: int
    wall_s: float
    tokens_per_s: float
    ttft_ticks_p50: float
    ttft_ticks_p99: float
    ttft_s_p50: float  # virtual (tick_s-scaled) TTFT
    ttft_s_p99: float
    tpot_ticks_mean: float
    reconfig_count: int
    wire_reconfig_count: int
    # Decode-path EP all-to-all payload bytes, accounted through the SAME
    # CommRuntime formula netsim prices (ep_alltoall_bytes) — the serving
    # cross-check in tests/test_serve.py.
    a2a_bytes: float
    gate_load_total: np.ndarray | None
    # Paged-KV telemetry (zeros when running the dense ring buffer).
    kv_paged: bool = False
    kv_resident_pages_peak: int = 0
    kv_pool_pages: int = 0
    kv_prefix_hit_pages: int = 0
    kv_cow_forks: int = 0
    kv_evictions: int = 0
    # Speculative-decoding telemetry (DESIGN.md §11; zeros when spec_k=0).
    spec_k: int = 0
    spec_drafted: int = 0  # draft tokens proposed across the run
    spec_accepted: int = 0  # draft tokens accepted and emitted
    spec_acceptance: float = 0.0  # accepted / drafted
    draft_truncations: int = 0  # rejected-tail truncations applied
    pages_reclaimed: int = 0  # whole pages freed immediately by truncation


class ServeEngine:
    """Reconfigurable EP serving engine over a :class:`ContinuousBatcher`."""

    def __init__(
        self,
        params,
        cfg,
        plan: ShardingPlan,
        scfg: ServeConfig | None = None,
        *,
        mesh=None,
        name: str | None = None,
    ):
        self.cfg = cfg
        self.plan = plan
        self.scfg = scfg or ServeConfig()
        s = self.scfg
        self.batcher = ContinuousBatcher(
            params, cfg, plan, slots=s.slots, max_len=s.max_len, mesh=mesh,
            prefill_chunk=s.prefill_chunk, sample=s.sample,
            paged=s.paged, page_size=s.page_size, num_pages=s.num_pages,
            prefix_cache=s.prefix_cache, spec_k=s.spec_k,
            spec_draft_mode=s.spec_draft_mode, sample_seed=s.sample_seed,
        )
        # Draft tokens pay a narrower routed fan-out on the wire (0 for
        # shared_only drafts — no dispatch a2a at all).
        self._draft_top_k = (
            routing.effective_top_k(cfg.moe.top_k, self.batcher.draft_mode)
            if cfg.is_moe and s.spec_k > 0
            else 0
        )
        self.controlplane: ControlPlane | None = None
        self.applier: PlacementApplier | None = None
        if cfg.is_moe and (s.reconfig_every or s.external_control):
            ev, r = virtual_experts(cfg.moe.num_experts, plan.model_size)
            ndev = s.num_devices or max(plan.model_size, 1)
            self.controlplane = ControlPlane(
                num_layers=cfg.pattern_repeats,
                num_experts=cfg.moe.num_experts,
                num_devices=ndev,
                replication=r,
                min_gain_fraction=s.reconfig_min_gain,
                use_copilot=s.use_copilot,
                num_regions=s.num_regions,
            )
            # Wire re-addressing is only realizable when the decode path
            # actually runs the mixnet a2a (sparse decode on a model axis).
            self.applier = PlacementApplier(
                self.controlplane,
                model_size=max(plan.model_size, 1),
                wire_capable=(
                    cfg.moe.backend == "mixnet"
                    and cfg.moe.decode_backend == "sparse"
                ),
            )
            self.batcher.expert_perm = self.controlplane.perm_stack()
        # Per-tick decode a2a payload accounting (one EP a2a phase per MoE
        # layer per tick), through the CommRuntime byte formula.
        self._moe_layers = (
            cfg.pattern_repeats
            * sum(1 for k in cfg.block_pattern if k in ("global", "local"))
            if cfg.is_moe
            else 0
        )
        self._dtype_bytes = np.dtype(cfg.dtype).itemsize
        self.a2a_bytes = 0.0
        self.gate_load_total: np.ndarray | None = None
        self.tick_log: list[TickStats] = []
        # Fleet/lifecycle state (DESIGN.md §12).
        self.draining = False
        self.decisions: list[sev.DecisionEvent] = []
        self._resident_mix: np.ndarray | None = None  # [L, E] EWMA gate mix
        # Measurement plane (DESIGN.md §14): the process tracer, this
        # engine's viewer track (lazy — fleet renames replicas before the
        # first tick), cached metric children, and the §3 observatory fed
        # every observed tick's gate load through the live perm stack.
        self.name = name or "serve"
        self._tr = trace.default()
        self._tid: int | None = None
        self._kv_last: tuple | None = None
        _m = metrics.default()
        self._m_ticks = _m.counter("serve.ticks")
        self._m_tokens = _m.counter("serve.tokens_served")
        self._m_a2a = _m.counter("serve.a2a_bytes")
        self.observatory = (
            TrafficObservatory(
                cfg.pattern_repeats,
                cfg.moe.num_experts,
                num_devices=(
                    self.controlplane.num_devices if self.controlplane else 1
                ),
                replication=(
                    self.controlplane.replication if self.controlplane else 1
                ),
                num_regions=s.num_regions,
            )
            if cfg.is_moe
            else None
        )

    # -- request intake -------------------------------------------------------
    @property
    def params(self):
        return self.batcher.params

    @property
    def tick(self) -> int:
        return self.batcher.tick

    # -- measurement plane (DESIGN.md §14) ------------------------------------
    @property
    def decision_log(self) -> list[dict]:
        """Backward-compat dict view of the typed ``decisions`` journal —
        same keys, same order as the legacy raw-dict log."""
        return [e.as_dict() for e in self.decisions]

    def _track_id(self) -> int:
        if self._tid is None:
            self._tid = self._tr.track(self.name)
            # Batcher spans (prefill/decode/spec) share this engine's row.
            self.batcher.trace_tid = self._tid
        return self._tid

    def _decide(self, ev: sev.DecisionEvent) -> None:
        """Journal a typed lifecycle decision and mirror it onto the trace
        as a structured audit event."""
        self.decisions.append(ev)
        metrics.counter("serve.decisions", kind=ev.kind).inc()
        if self._tr.enabled:
            self._tr.audit(
                f"serve.{ev.kind}", ev.as_dict(), cat="decision",
                tid=self._track_id(),
            )

    def submit(self, req: Request) -> None:
        if self.draining:
            raise RuntimeError("engine is draining; admissions refused")
        self.batcher.submit(req)

    # -- drain / restore lifecycle (DESIGN.md §12) ----------------------------
    def drain(self) -> list[Request]:
        """Stop accepting work: refuse new admissions, hand back every
        queued-but-not-started request (the fleet re-steers them), and let
        in-flight requests finish normally.  After draining idles the engine
        (``batcher.busy`` false), ``save_checkpoint`` exports a complete
        resumable state: params + placement + paged pool + prefix registry."""
        self.draining = True
        handed = [r for r in self.batcher.queue]
        self.batcher.queue.clear()
        for r in handed:
            r.submit_tick = -1
        self._decide(sev.DrainDecision(tick=self.tick, handed_back=len(handed)))
        return handed

    def restore(self) -> None:
        """Re-open admissions after a drain."""
        self.draining = False
        self._decide(sev.RestoreDecision(tick=self.tick))

    def unfinished_requests(self) -> list[Request]:
        """Every admitted-but-unfinished request (queued, prefilling or
        decoding) — what a fleet must re-admit elsewhere when this replica
        fails hard (as opposed to a graceful drain)."""
        live = [r for r in self.batcher.active if r is not None]
        live += [p.req for p in self.batcher.prefilling]
        live += list(self.batcher.queue)
        return live

    # -- the decode-time control loop ----------------------------------------
    def _observe(self, stats: TickStats) -> None:
        if stats.gate_load is None:
            return
        load = np.asarray(stats.gate_load, dtype=np.float64)
        self.gate_load_total = (
            load if self.gate_load_total is None else self.gate_load_total + load
        )
        if load.sum() > 0:
            norm = load / np.maximum(load.sum(axis=-1, keepdims=True), 1e-12)
            self._resident_mix = (
                norm if self._resident_mix is None
                else 0.8 * self._resident_mix + 0.2 * norm
            )
        regions = self.live_region_weights()
        if self.observatory is not None:
            # §3 observatory: fold the tick's realized gate load through the
            # CURRENT perm stack so the expert→device matrix reflects the
            # placement actually serving it (DESIGN.md §14).
            self.observatory.record(
                load,
                self.controlplane.perm_stack() if self.controlplane else None,
                regions,
            )
        if self.controlplane is not None:
            for layer in range(load.shape[0]):
                self.controlplane.observe(layer, load[layer])
            self.controlplane.observe_regions(regions, load)
            self.controlplane.end_step()

    # -- exported gate statistics (fleet steering inputs, DESIGN.md §12) ------
    def live_region_weights(self) -> dict[int, float]:
        """Each traffic region's share of the currently live requests."""
        regs = [r.region for r in self.batcher.active
                if r is not None and r.region is not None]
        regs += [p.req.region for p in self.batcher.prefilling
                 if p.req.region is not None]
        if not regs:
            return {}
        out: dict[int, float] = {}
        for rg in regs:
            out[rg] = out.get(rg, 0.0) + 1.0 / len(regs)
        return out

    def resident_mix(self) -> np.ndarray | None:
        """``[L, E]`` EWMA of the recently served gate mix — what "the expert
        mix this replica is currently keeping resident" means for the fleet's
        locality score."""
        return self._resident_mix

    def region_stats(self):
        """Per-replica region-conditioned gate stats (None unless the engine
        was built with ``num_regions > 0`` and a control plane)."""
        return self.controlplane.region_stats if self.controlplane else None

    def placement_cost_of(self, mixes: np.ndarray) -> float:
        """Bottleneck crossing cost of serving per-layer expert mixes
        ``[L, E]`` under THIS replica's current placement, normalized to the
        per-layer demand mass — the placement-fit term of the fleet locality
        score.  Engines without a control plane score 0 (no placement state
        to mismatch)."""
        cp = self.controlplane
        if cp is None:
            return 0.0
        from repro.core.placement import placement_cost

        mixes = np.asarray(mixes, dtype=np.float64)
        total = 0.0
        for layer in range(min(mixes.shape[0], cp.num_layers)):
            mix = mixes[layer]
            s = mix.sum()
            if s <= 0:
                continue
            vload = np.repeat(mix / s, cp.replication) / cp.replication
            demand = np.tile(vload[None, :], (cp.num_devices, 1))
            total += placement_cost(
                demand, cp.layer_perms[layer], cp.experts_per_device
            ) / cp.num_devices
        return float(total)

    def apply_plans(self, plans: list[LayerPlan]) -> bool:
        """Actuate placement plans BETWEEN ticks: expert weights are gathered
        into their new slots (or wire-re-addressed for whole-device-block
        plans) and the router's perm stack updates in the same transaction —
        in-flight slot caches are position-addressed, so live requests
        continue bit-identically (the §9 consistency guarantee)."""
        if self.applier is None:
            raise RuntimeError("no control plane configured (reconfig_every=0?)")
        params, changed = self.applier.apply(self.batcher.params, plans)
        if changed:
            self.batcher.params = params
            self.batcher.expert_perm = self.controlplane.perm_stack()
            self.batcher.wire_perm = self.applier.wire_perm
        return changed

    def _maybe_reconfigure(self) -> None:
        cp = self.controlplane
        if (cp is None or not self.scfg.reconfig_every or self.tick == 0
                or self.tick % self.scfg.reconfig_every):
            return
        with self._tr.span("serve.reconfig", tid=self._track_id(),
                           tick=self.tick) as sp:
            plans = [cp.plan(layer) for layer in range(cp.num_layers)]
            applied = self.apply_plans(plans)
            sp.set(applied=applied)
        self._decide(sev.ReconfigDecision(
            tick=self.tick,
            applied=applied,
            layers=[p.layer for p in plans if p.reconfigure],
            gain_bytes=float(sum(p.gain_bytes for p in plans
                                 if p.reconfigure)),
            reasons=sorted({p.reason for p in plans}),
        ))

    def step(self) -> TickStats:
        """One engine tick: decode + interleaved prefill chunk, stream the
        realized gate loads into the control plane, and (on cadence) apply
        placement plans before the next tick."""
        tr = self._tr
        if not tr.enabled:
            return self._step_inner()
        tid = self._track_id()
        with tr.span("serve.tick", tid=tid, tick=self.tick) as sp:
            stats = self._step_inner()
            sp.set(live=stats.live, prefill_tokens=stats.prefill_tokens,
                   admitted=stats.admitted, finished=stats.finished)
        tr.counter("serve.a2a_bytes", self.a2a_bytes, tid=tid)
        if self.batcher.paged:
            alloc = self.batcher.alloc
            kv = (alloc.resident_pages(), alloc.prefix_hit_pages,
                  alloc.evictions, alloc.cow_forks)
            # Counters render as step functions — only emit on change, so
            # steady-state decode ticks pay one less event.
            if kv != self._kv_last:
                self._kv_last = kv
                tr.counter("serve.kv", {
                    "resident_pages": float(kv[0]),
                    "prefix_hit_pages": float(kv[1]),
                    "evictions": float(kv[2]),
                    "cow_forks": float(kv[3]),
                }, tid=tid)
        return stats

    def _step_inner(self) -> TickStats:
        a2a0 = self.a2a_bytes
        stats = self.batcher.step()
        # Full-model routed positions: one per live slot on plain ticks, the
        # whole verify span on speculative ticks (the a2a launch amortizes
        # over the span, but its payload still scales with positions).
        decode_routed = stats.spec_verified if stats.spec_verified else stats.live
        served = decode_routed + stats.prefill_tokens
        if served and self._moe_layers:
            self.a2a_bytes += self._moe_layers * comm.ep_alltoall_bytes(
                served, self.cfg.moe.top_k, self.cfg.d_model, self._dtype_bytes
            )
        if stats.spec_drafted and self._moe_layers and self._draft_top_k:
            self.a2a_bytes += self._moe_layers * comm.ep_alltoall_bytes(
                stats.spec_drafted, self._draft_top_k, self.cfg.d_model,
                self._dtype_bytes,
            )
        self._m_ticks.inc()
        self._m_tokens.inc(served)
        self._m_a2a.inc(self.a2a_bytes - a2a0)
        self._observe(stats)
        self._maybe_reconfigure()
        self.tick_log.append(stats)
        return stats

    # -- driving a workload ---------------------------------------------------
    def run(
        self,
        requests: list[SyntheticRequest] | None = None,
        generator: WorkloadGenerator | None = None,
        *,
        eos_id: int | None = None,
        drain: bool = True,
    ) -> ServeReport:
        """Serve a workload to completion.

        ``requests`` (from ``generator.generate``) are admitted when the
        tick clock passes their arrival time; with ``drain`` the engine runs
        until every request completes (or ``max_ticks``)."""
        t0 = time.perf_counter()
        pending = sorted(requests or [], key=lambda r: r.arrival_s)
        cursor = 0
        while self.tick < self.scfg.max_ticks:
            now_s = self.tick * self.scfg.tick_s
            while cursor < len(pending) and pending[cursor].arrival_s <= now_s:
                sr = pending[cursor]
                self.submit(Request(
                    rid=sr.rid,
                    prompt=generator.prompt_tokens(sr),
                    max_new_tokens=sr.max_new_tokens,
                    eos_id=eos_id,
                    region=sr.region,
                ))
                cursor += 1
            if cursor >= len(pending) and not self.batcher.busy:
                break
            if not self.batcher.busy and cursor < len(pending):
                # Idle gap before the next arrival: jump the clock straight
                # to the arrival tick (mirrors netsim's clock jump) instead
                # of burning max_ticks on empty ticks.
                import math

                nxt = math.ceil(pending[cursor].arrival_s / self.scfg.tick_s)
                self.batcher.tick = max(self.tick + 1, nxt)
                continue
            self.step()
            if not drain and cursor >= len(pending):
                break
        return self.report(time.perf_counter() - t0)

    def report(self, wall_s: float) -> ServeReport:
        done = self.batcher.finished
        ok = [r for r in done if r.error is None]
        ttft = np.array(
            [r.first_token_tick - r.submit_tick for r in ok if r.first_token_tick >= 0],
            dtype=np.float64,
        )
        tpot = np.array(
            [
                (r.finish_tick - r.first_token_tick) / max(len(r.out) - 1, 1)
                for r in ok
                if len(r.out) > 1 and r.finish_tick >= 0
            ],
            dtype=np.float64,
        )
        tokens_out = sum(len(r.out) for r in ok)
        pct = lambda a, q: float(np.percentile(a, q)) if len(a) else 0.0
        ts = self.scfg.tick_s
        if (self._tr.enabled and self.observatory is not None
                and self.observatory.ticks):
            # The run's §3 study rides the trace as ONE typed event —
            # scripts/measure_run.py rebuilds the observatory from it.
            self._tr.audit(
                "traffic.report",
                {"scope": self.name, "report": self.observatory.report()},
                cat="traffic", tid=self._track_id(),
            )
        return ServeReport(
            requests=len(done),
            completed=len(ok),
            rejected=len(done) - len(ok),
            ticks=self.tick,
            tokens_out=tokens_out,
            wall_s=wall_s,
            tokens_per_s=tokens_out / max(wall_s, 1e-9),
            ttft_ticks_p50=pct(ttft, 50),
            ttft_ticks_p99=pct(ttft, 99),
            ttft_s_p50=pct(ttft, 50) * ts,
            ttft_s_p99=pct(ttft, 99) * ts,
            tpot_ticks_mean=float(tpot.mean()) if len(tpot) else 0.0,
            reconfig_count=(
                self.controlplane.reconfig_count if self.controlplane else 0
            ),
            wire_reconfig_count=(
                self.applier.wire_reconfig_count if self.applier else 0
            ),
            a2a_bytes=self.a2a_bytes,
            gate_load_total=self.gate_load_total,
            kv_paged=self.batcher.paged,
            kv_resident_pages_peak=self.batcher.kv_resident_pages_peak,
            kv_pool_pages=(
                self.batcher.num_pages if self.batcher.paged else 0
            ),
            kv_prefix_hit_pages=(
                self.batcher.alloc.prefix_hit_pages if self.batcher.paged else 0
            ),
            kv_cow_forks=(
                self.batcher.alloc.cow_forks if self.batcher.paged else 0
            ),
            kv_evictions=(
                self.batcher.alloc.evictions if self.batcher.paged else 0
            ),
            spec_k=self.batcher.spec_k,
            spec_drafted=self.batcher.spec_drafted,
            spec_accepted=self.batcher.spec_accepted,
            spec_acceptance=(
                self.batcher.spec_accepted / max(self.batcher.spec_drafted, 1)
            ),
            draft_truncations=(
                self.batcher.alloc.draft_truncations if self.batcher.paged else 0
            ),
            pages_reclaimed=(
                self.batcher.alloc.pages_reclaimed if self.batcher.paged else 0
            ),
        )

    # -- checkpoint round-trip (DESIGN.md §9, §12) ----------------------------
    def save_checkpoint(self, ckpt_dir: str, step: int | None = None) -> int:
        """Persist params WITH the placement state: the perm stack composes
        against the physically permuted weights, so restoring one without
        the other would misroute every token.

        Paged engines additionally export the KV pools and the allocator's
        page table / prefix registry (the drain checkpoint, DESIGN.md §12):
        a drained-and-restored replica keeps its warm prefix pages, so
        re-admitted shared-prefix requests hit the registry bit-identically
        instead of re-prefilling."""
        step = self.tick if step is None else step
        extra = {
            "placement": self.applier.state_dict() if self.applier else None,
            "serve": {"tick": self.tick},
        }
        tree = {"params": self.batcher.params}
        if self.batcher.paged:
            extra["kv_alloc"] = self.batcher.alloc.state_dict()
            tree["kv"] = self.batcher.caches
        ckpt.save(ckpt_dir, step, tree, extra=extra)
        return step

    def restore_checkpoint(self, ckpt_dir: str, step: int | None = None) -> int:
        step = ckpt.latest_step(ckpt_dir) if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
        extra = ckpt.load_extra(ckpt_dir, step)
        skeleton = {"params": self.batcher.params}
        kv_alloc = extra.get("kv_alloc")
        if kv_alloc is not None and self.batcher.paged:
            skeleton["kv"] = self.batcher.caches
        state = ckpt.restore(ckpt_dir, step, skeleton)
        self.batcher.params = state["params"]
        if kv_alloc is not None and self.batcher.paged:
            self.batcher.caches = state["kv"]
            self.batcher.alloc.load_state_dict(kv_alloc)
        serve = extra.get("serve") or {}
        if "tick" in serve:
            self.batcher.tick = int(serve["tick"])
        placement = extra.get("placement")
        if placement is not None:
            if self.applier is None:
                raise RuntimeError(
                    "checkpoint carries placement state but this engine has "
                    "no control plane (set reconfig_every > 0)"
                )
            self.applier.load_state_dict(placement)
            self.batcher.expert_perm = self.controlplane.perm_stack()
            self.batcher.wire_perm = self.applier.wire_perm
        return step
