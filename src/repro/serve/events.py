"""Typed serving decision events (DESIGN.md §14).

`ServeEngine` and `FleetEngine` used to journal lifecycle decisions as raw
dicts in ``decision_log``.  These dataclasses are the typed replacement:
each decision is one event object that (a) renders back to the exact legacy
dict via :meth:`as_dict` — the ``decision_log`` property view keeps every
existing consumer byte-identical — and (b) doubles as the payload of a
structured trace audit event (``tracer.audit("serve.decision", ...)``), so
a run's decision trail rides the exported Perfetto timeline.

Field order matters: ``as_dict`` iterates dataclass fields, and the legacy
dict literals put ``tick`` then ``kind`` first — consumers like
``examples/serve.py`` print ``{k: v for k, v in d.items() if ...}`` and
rely on that insertion order.
"""

from __future__ import annotations

from dataclasses import dataclass, fields

__all__ = [
    "DecisionEvent",
    "DrainDecision",
    "RestoreDecision",
    "ReconfigDecision",
    "FleetDrainDecision",
    "FleetRestoreDecision",
    "FleetFailDecision",
    "SteerDecision",
    "FleetReconfigDecision",
]


@dataclass(kw_only=True)
class DecisionEvent:
    tick: int
    kind: str = "?"

    def as_dict(self) -> dict:
        """The legacy ``decision_log`` dict — same keys, same order."""
        return {f.name: getattr(self, f.name) for f in fields(self)}


# -- single-engine lifecycle (ServeEngine) ------------------------------------
@dataclass(kw_only=True)
class DrainDecision(DecisionEvent):
    kind: str = "drain"
    handed_back: int = 0


@dataclass(kw_only=True)
class RestoreDecision(DecisionEvent):
    kind: str = "restore"


@dataclass(kw_only=True)
class ReconfigDecision(DecisionEvent):
    kind: str = "reconfig"
    applied: bool = False
    layers: list[int] = None
    gain_bytes: float = 0.0
    reasons: list[str] = None


# -- fleet lifecycle (FleetEngine) --------------------------------------------
@dataclass(kw_only=True)
class FleetDrainDecision(DecisionEvent):
    kind: str = "drain"
    replica: int = 0
    resteered: int = 0


@dataclass(kw_only=True)
class FleetRestoreDecision(DecisionEvent):
    kind: str = "restore"
    replica: int = 0


@dataclass(kw_only=True)
class FleetFailDecision(DecisionEvent):
    kind: str = "fail"
    replica: int = 0
    resteered: int = 0


@dataclass(kw_only=True)
class SteerDecision(DecisionEvent):
    kind: str = "steer"
    rid: int = 0
    region: int | None = None
    slo: str = ""
    replica: int = 0
    reason: str = ""


@dataclass(kw_only=True)
class FleetReconfigDecision(DecisionEvent):
    kind: str = "reconfig"
    replica: int = 0
    layers: list[int] = None
    gain_bytes: float = 0.0
