"""FleetEngine — multi-replica serving with gate-locality steering
(DESIGN.md §12).

The paper's §3 measurement — MoE gate traffic is *regionally* skewed — is
why regionally reconfigurable domains beat global reconfiguration.  This
module applies the same argument one level up, where a "region" is a whole
:class:`~repro.serve.engine.ServeEngine` replica: a fleet behind one
admission queue can exploit locality by *steering* (send a request where
its predicted expert mix is already resident) before it ever has to
*reconfigure* (rewrite a replica's expert placement).  TA-MoE adapts
dispatch to a fixed hierarchy; a fleet can do both, and the decision rule
is explicit here.

Three layers:

* **Admission** — one global queue ordered by SLO class priority
  (:data:`repro.serve.workload.SLO_CLASSES`: chat > agentic > batch), then
  arrival.  Dispatch is strict-priority work-conserving: the head request
  goes to any replica with backlog headroom; if every replica is full the
  queue simply waits a tick (in-flight work frees capacity, so admission
  cannot deadlock).

* **Steering** — policy ``locality`` scores each candidate replica with
  :func:`locality_score`: how far the request's *predicted* per-layer
  expert mix (region-conditioned gate stats merged across replicas, with
  the replica COPILOT's :meth:`~repro.core.copilot.CopilotPredictor.rollout`
  as the forecast refinement) sits from the replica's *resident* mix and
  current expert placement, plus a small load term so locality never
  dogpiles one replica.  Cold regions (no statistics yet) fall back to
  least-loaded, and policies ``least_loaded`` / ``round_robin`` are the
  steering baselines the benchmarks compare against.

* **Steer-vs-reconfigure** — steering keeps each replica region-pure, so
  its resident mix keeps matching its placement and no reconfiguration is
  needed.  When the workload churns (the hot region migrates,
  ``TrafficMix.region_churn_every_s``), the mix a replica serves drifts
  off its placement; on the fleet cadence each replica's own
  :meth:`ControlPlane.plan` hysteresis re-tests its *served* (steered)
  traffic — a plan that passes the min-gain threshold is exactly the
  signal that steering alone no longer keeps the mix resident, and the
  fleet applies it replica-locally (weights or wire perms, between ticks).

**Bit-exactness**: a request's tokens are a function of (prompt, params,
sampling keys) only — per-request prefill, dense per-token decode and
per-(rid, position) sampling keys are independent of co-batched traffic
under dropless dispatch — so the same request produces identical tokens
regardless of which replica serves it, or whether it was steered, drained
and re-admitted, or restarted after a replica failure.
``tests/test_fleet.py`` asserts this across policies × fleet sizes.
(Capacity-mode dispatch drops tokens based on co-batched demand and
voids the cross-replica guarantee; the fleet layer does not forbid it,
but the bit-exactness bar only holds for dropless — the default.)
"""

from __future__ import annotations

import dataclasses
import heapq
import math

import numpy as np

from repro.core.controlplane import RegionGateStats
from repro.obs import metrics, trace
from repro.obs.traffic import TrafficObservatory
from repro.serve import events as sev
from repro.serve.batching import Request
from repro.serve.engine import ServeEngine, ServeReport
from repro.serve.workload import (
    SLOClass,
    SyntheticRequest,
    WorkloadGenerator,
    slo_for,
)

__all__ = [
    "FleetConfig",
    "FleetRequest",
    "FleetReport",
    "FleetEngine",
    "locality_score",
    "fleet_requests",
]


@dataclasses.dataclass(frozen=True)
class FleetRequest:
    """One request as the fleet sees it: prompt already materialized (the
    fleet may steer it to any replica, or to several after a failure)."""

    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_s: float = 0.0
    region: int | None = None
    slo: SLOClass = dataclasses.field(default_factory=lambda: slo_for("default"))
    eos_id: int | None = None


@dataclasses.dataclass
class FleetConfig:
    policy: str = "locality"  # "locality" | "least_loaded" | "round_robin"
    tick_s: float = 0.05
    # Per-replica admitted backlog cap (live + prefilling + queued) — the
    # steering horizon: beyond it a request waits in the global queue where
    # it can still be re-steered.
    queue_cap: int = 8
    max_ticks: int = 20_000
    # Fleet-cadence steer-vs-reconfigure check (0 = steering only).  Each
    # replica's own ControlPlane hysteresis (min_gain_fraction) decides; the
    # fleet only sets the cadence and actuates replica-locally.
    reconfig_every: int = 0
    # Locality-score mixing: placement-fit weight and load-penalty weight.
    locality_gamma: float = 0.5
    steer_load_beta: float = 0.25


@dataclasses.dataclass
class FleetReport:
    requests: int
    completed: int
    ticks: int
    tokens_out: int
    policy: str
    steer_reasons: dict
    reconfig_events: int  # fleet-triggered replica reconfigurations
    ttft_ticks_p50: float
    ttft_ticks_p99: float
    slo_attainment: dict  # class name -> fraction meeting its TTFT target
    outputs: dict  # rid -> list of generated token ids
    per_replica: list[ServeReport]


def _bhattacharyya(a: np.ndarray, b: np.ndarray) -> float:
    """Overlap of two mix distributions in [0, 1] (1 = identical)."""
    a = np.asarray(a, dtype=np.float64).reshape(-1)
    b = np.asarray(b, dtype=np.float64).reshape(-1)
    a = a / max(float(a.sum()), 1e-12)
    b = b / max(float(b.sum()), 1e-12)
    return float(np.sqrt(a * b).sum())


def locality_score(
    predicted_mix: np.ndarray,
    resident_mix: np.ndarray | None,
    *,
    placement_fit: float = 0.0,
    backlog: int = 0,
    slots: int = 1,
    gamma: float = 0.5,
    beta: float = 0.25,
) -> float:
    """Steering score of one replica for one request — LOWER is better.

    ``1 - BC(predicted, resident)`` is the residency miss (how much of the
    request's predicted expert mix the replica is not already serving),
    ``placement_fit`` the normalized bottleneck cost of the predicted mix
    under the replica's current placement
    (:meth:`ServeEngine.placement_cost_of`), and the load term keeps
    locality from dogpiling the single best replica.
    """
    miss = 1.0 if resident_mix is None else 1.0 - _bhattacharyya(
        np.asarray(predicted_mix).mean(axis=0)
        if np.asarray(predicted_mix).ndim > 1 else predicted_mix,
        np.asarray(resident_mix).mean(axis=0)
        if np.asarray(resident_mix).ndim > 1 else resident_mix,
    )
    return miss + gamma * placement_fit + beta * backlog / max(slots, 1)


def fleet_requests(
    requests: list[SyntheticRequest],
    generator: WorkloadGenerator,
    *,
    slo: SLOClass | None = None,
    eos_id: int | None = None,
) -> list[FleetRequest]:
    """Materialize one mix's synthetic stream into steerable fleet requests
    (the SLO class defaults to the generator mix's)."""
    cls = slo or slo_for(generator.mix.name)
    return [
        FleetRequest(
            rid=sr.rid,
            prompt=generator.prompt_tokens(sr),
            max_new_tokens=sr.max_new_tokens,
            arrival_s=sr.arrival_s,
            region=sr.region,
            slo=cls,
            eos_id=eos_id,
        )
        for sr in requests
    ]


class FleetEngine:
    """N ServeEngine replicas behind one SLO-aware steering queue.

    Replicas may be heterogeneous (different slot counts, device regions or
    placement state) but must serve the SAME weights — steering assumes any
    replica produces the same tokens for a request (the bit-exactness bar).
    """

    def __init__(self, engines: list[ServeEngine], fcfg: FleetConfig | None = None):
        if not engines:
            raise ValueError("a fleet needs at least one replica")
        self.engines = engines
        self.fcfg = fcfg or FleetConfig()
        if self.fcfg.policy not in ("locality", "least_loaded", "round_robin"):
            raise ValueError(f"unknown steering policy {self.fcfg.policy!r}")
        self.alive = [True] * len(engines)
        self.tick = 0
        self.decisions: list[sev.DecisionEvent] = []
        # Measurement plane (DESIGN.md §14): every replica gets its own
        # viewer track so the merged trace shows one row per replica plus
        # one for fleet-level steering/lifecycle decisions.
        self._tr = trace.default()
        self._tid: int | None = None
        for j, e in enumerate(engines):
            if e.name == "serve":  # default name -> stable replica track
                e.name = f"replica{j}"
        self._queue: list[tuple[int, float, int, FleetRequest]] = []
        self._seq = 0
        self._rr = 0
        self.records: dict[int, FleetRequest] = {}
        self.assignment: dict[int, int] = {}  # rid -> replica currently serving
        self._done: dict[int, Request] = {}
        self._arrival_tick: dict[int, int] = {}
        self._first_out_tick: dict[int, int] = {}
        self._finish_tick: dict[int, int] = {}
        self._polled: list[int] = [0] * len(engines)  # finished-list cursors
        self._steer_reasons: dict[str, int] = {}
        self.reconfig_events = 0

    # -- measurement plane (DESIGN.md §14) ------------------------------------
    @property
    def decision_log(self) -> list[dict]:
        """Backward-compat dict view of the typed ``decisions`` journal."""
        return [e.as_dict() for e in self.decisions]

    def _track_id(self) -> int:
        if self._tid is None:
            self._tid = self._tr.track("fleet")
        return self._tid

    def _decide(self, ev: sev.DecisionEvent) -> None:
        self.decisions.append(ev)
        metrics.counter("fleet.decisions", kind=ev.kind).inc()
        if self._tr.enabled:
            self._tr.audit(
                f"fleet.{ev.kind}", ev.as_dict(), cat="decision",
                tid=self._track_id(),
            )

    # -- intake ---------------------------------------------------------------
    def submit(self, freq: FleetRequest) -> None:
        self.records[freq.rid] = freq
        self._arrival_tick.setdefault(freq.rid, self.tick)
        heapq.heappush(
            self._queue, (freq.slo.priority, freq.arrival_s, self._seq, freq)
        )
        self._seq += 1

    # -- replica lifecycle ----------------------------------------------------
    def drain_replica(self, j: int) -> int:
        """Graceful drain: replica ``j`` refuses admissions and finishes its
        in-flight work; its queued-but-unstarted requests are re-steered."""
        handed = self.engines[j].drain()
        for r in handed:
            self.assignment.pop(r.rid, None)
            self.submit(self.records[r.rid])
        self._decide(sev.FleetDrainDecision(
            tick=self.tick, replica=j, resteered=len(handed)
        ))
        return len(handed)

    def restore_replica(self, j: int) -> None:
        self.engines[j].restore()
        self._decide(sev.FleetRestoreDecision(tick=self.tick, replica=j))

    def fail_replica(self, j: int) -> int:
        """Hard failure: everything unfinished on ``j`` (including partially
        generated requests) restarts from scratch elsewhere.  Tokens stay
        bit-identical because generation is a pure function of the request,
        not of the replica or its co-batched traffic."""
        self._poll(j)  # keep whatever finished before the failure
        self.alive[j] = False
        lost = self.engines[j].unfinished_requests()
        for r in lost:
            self.assignment.pop(r.rid, None)
            self.submit(self.records[r.rid])
        self._decide(sev.FleetFailDecision(
            tick=self.tick, replica=j, resteered=len(lost)
        ))
        return len(lost)

    # -- steering -------------------------------------------------------------
    def _backlog(self, j: int) -> int:
        b = self.engines[j].batcher
        return (
            len(b.queue)
            + len(b.prefilling)
            + sum(1 for r in b.active if r is not None)
        )

    def _candidates(self) -> list[int]:
        return [
            j
            for j, e in enumerate(self.engines)
            if self.alive[j]
            and not e.draining
            and self._backlog(j) < self.fcfg.queue_cap
        ]

    def _predicted_mixes(self, region: int | None) -> np.ndarray | None:
        """Fleet-level mix forecast for a region: merge every replica's
        region-conditioned stats, then refine layers > 0 through the first
        fitted COPILOT's transition rollout."""
        if region is None:
            return None
        merged = RegionGateStats.merged(
            [e.region_stats() for j, e in enumerate(self.engines) if self.alive[j]]
        )
        if merged is None:
            return None
        base = merged.mix_for(region)
        if base is None:
            return None
        for j, e in enumerate(self.engines):
            cp = e.controlplane
            if (
                self.alive[j]
                and cp is not None
                and cp.copilot is not None
                and cp.copilot.state.fitted_steps > 0
            ):
                rolled = cp.copilot.rollout(base[0])
                n = min(len(rolled), len(base))
                return 0.5 * base[:n] + 0.5 * rolled[:n]
        return base

    def _pick(self, freq: FleetRequest, cands: list[int]) -> tuple[int, str]:
        by_load = lambda: min(cands, key=lambda j: (self._backlog(j), j))
        if self.fcfg.policy == "round_robin":
            j = cands[self._rr % len(cands)]
            self._rr += 1
            return j, "round-robin"
        if self.fcfg.policy == "least_loaded":
            return by_load(), "least-loaded"
        mixes = self._predicted_mixes(freq.region)
        if mixes is None:
            return by_load(), "cold-region-fallback"
        f = self.fcfg
        scored = sorted(
            (
                locality_score(
                    mixes,
                    self.engines[j].resident_mix(),
                    placement_fit=self.engines[j].placement_cost_of(mixes),
                    backlog=self._backlog(j),
                    slots=self.engines[j].scfg.slots,
                    gamma=f.locality_gamma,
                    beta=f.steer_load_beta,
                ),
                j,
            )
            for j in cands
        )
        return scored[0][1], "locality"

    def _dispatch(self) -> None:
        while self._queue:
            cands = self._candidates()
            if not cands:
                return  # every replica full — wait for in-flight work
            prio, arr, seq, freq = heapq.heappop(self._queue)
            j, reason = self._pick(freq, cands)
            self.assignment[freq.rid] = j
            self._steer_reasons[reason] = self._steer_reasons.get(reason, 0) + 1
            self.engines[j].submit(Request(
                rid=freq.rid,
                prompt=freq.prompt,
                max_new_tokens=freq.max_new_tokens,
                eos_id=freq.eos_id,
                region=freq.region,
            ))
            self._decide(sev.SteerDecision(
                tick=self.tick, rid=freq.rid, region=freq.region,
                slo=freq.slo.name, replica=j, reason=reason,
            ))

    # -- steer-vs-reconfigure (fleet cadence) ---------------------------------
    def _maybe_reconfigure(self) -> None:
        f = self.fcfg
        if (
            f.policy != "locality"
            or not f.reconfig_every
            or self.tick == 0
            or self.tick % f.reconfig_every
        ):
            return
        for j, e in enumerate(self.engines):
            cp = e.controlplane
            if not self.alive[j] or cp is None or e.applier is None:
                continue
            # The replica's own hysteresis over its *served* (post-steering)
            # traffic is the decision rule: a plan clearing min_gain means
            # steering alone no longer keeps this replica's mix resident.
            plans = [cp.plan(layer) for layer in range(cp.num_layers)]
            if not any(p.reconfigure for p in plans):
                continue
            e.apply_plans(plans)
            self.reconfig_events += 1
            self._decide(sev.FleetReconfigDecision(
                tick=self.tick, replica=j,
                layers=[p.layer for p in plans if p.reconfigure],
                gain_bytes=float(sum(
                    p.gain_bytes for p in plans if p.reconfigure
                )),
            ))

    # -- progress tracking ----------------------------------------------------
    def _poll(self, j: int) -> None:
        e = self.engines[j]
        for r in e.batcher.finished[self._polled[j]:]:
            if r.error is None and r.rid not in self._done:
                self._done[r.rid] = r
                self._finish_tick[r.rid] = self.tick
        self._polled[j] = len(e.batcher.finished)
        for r in e.batcher.active:
            if r is not None and r.out and r.rid not in self._first_out_tick:
                self._first_out_tick[r.rid] = self.tick
        for r in e.batcher.finished:
            if r.error is None and r.out and r.rid not in self._first_out_tick:
                self._first_out_tick[r.rid] = self.tick

    @property
    def busy(self) -> bool:
        return bool(self._queue) or any(
            self.alive[j] and e.batcher.busy for j, e in enumerate(self.engines)
        )

    def step(self) -> None:
        """One fleet tick: dispatch from the global queue, tick every busy
        replica, poll completions, run the fleet-cadence reconfigure check."""
        metrics.counter("fleet.ticks").inc()
        tid = self._track_id() if self._tr.enabled else None
        with self._tr.span("fleet.tick", tid=tid, tick=self.tick):
            self._dispatch()
            for j, e in enumerate(self.engines):
                if not self.alive[j]:
                    continue  # failed replicas were polled once at failure
                if e.batcher.busy:
                    e.step()
                self._poll(j)
            self._maybe_reconfigure()
        self.tick += 1

    # -- driving a workload ---------------------------------------------------
    def run(
        self,
        requests: list[FleetRequest],
        *,
        drain_at: dict[int, int] | None = None,
        fail_at: dict[int, int] | None = None,
        restore_at: dict[int, int] | None = None,
    ) -> FleetReport:
        """Serve fleet requests to completion.

        ``drain_at`` / ``fail_at`` / ``restore_at`` map replica index ->
        fleet tick, for scripted degradation scenarios (the fleet keeps
        serving: handed-back work is re-steered the same tick)."""
        drain_at = drain_at or {}
        fail_at = fail_at or {}
        restore_at = restore_at or {}
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        cursor = 0
        event_ticks = sorted(
            set(drain_at.values()) | set(fail_at.values())
            | set(restore_at.values())
        )
        while self.tick < self.fcfg.max_ticks:
            for j, t in drain_at.items():
                if t == self.tick:
                    self.drain_replica(j)
            for j, t in fail_at.items():
                if t == self.tick:
                    self.fail_replica(j)
            for j, t in restore_at.items():
                if t == self.tick:
                    self.restore_replica(j)
            now_s = self.tick * self.fcfg.tick_s
            while cursor < len(pending) and pending[cursor].arrival_s <= now_s:
                self.submit(pending[cursor])
                cursor += 1
            if cursor >= len(pending) and not self.busy:
                break
            if not self.busy and cursor < len(pending):
                # Idle gap: jump the clock to the next arrival, but never
                # past a scheduled drain/fail/restore event.
                nxt = math.ceil(
                    pending[cursor].arrival_s / self.fcfg.tick_s
                )
                for et in event_ticks:
                    if self.tick < et < nxt:
                        nxt = et
                        break
                self.tick = max(self.tick + 1, nxt)
                continue
            self.step()
        return self.report()

    def observatory(self):
        """Fleet-wide §3 observatory: the replicas' matrices summed (the
        per-replica matrices stay available on each engine)."""
        merged = None
        for e in self.engines:
            if e.observatory is None or not e.observatory.ticks:
                continue
            if merged is None:
                merged = TrafficObservatory.from_report(e.observatory.report())
            else:
                merged.merge(e.observatory)
        return merged

    def report(self) -> FleetReport:
        obs = self.observatory()
        if self._tr.enabled and obs is not None:
            self._tr.audit(
                "traffic.report", {"scope": "fleet", "report": obs.report()},
                cat="traffic", tid=self._track_id(),
            )
        ok = list(self._done.values())
        ttft = np.array(
            [
                self._first_out_tick[rid] - self._arrival_tick[rid]
                for rid in self._done
                if rid in self._first_out_tick
            ],
            dtype=np.float64,
        )
        pct = lambda a, q: float(np.percentile(a, q)) if len(a) else 0.0
        attain: dict[str, list[int]] = {}
        for rid in self._done:
            freq = self.records[rid]
            hit = (
                rid in self._first_out_tick
                and (self._first_out_tick[rid] - self._arrival_tick[rid])
                * self.fcfg.tick_s
                <= freq.slo.ttft_target_s
            )
            attain.setdefault(freq.slo.name, []).append(int(hit))
        return FleetReport(
            requests=len(self.records),
            completed=len(ok),
            ticks=self.tick,
            tokens_out=sum(len(r.out) for r in ok),
            policy=self.fcfg.policy,
            steer_reasons=dict(self._steer_reasons),
            reconfig_events=self.reconfig_events,
            ttft_ticks_p50=pct(ttft, 50),
            ttft_ticks_p99=pct(ttft, 99),
            slo_attainment={
                k: float(np.mean(v)) for k, v in sorted(attain.items())
            },
            outputs={rid: list(r.out) for rid, r in self._done.items()},
            per_replica=[e.report(0.0) for e in self.engines],
        )
