"""Host-side page allocator for the paged KV cache (DESIGN.md §10).

The device side is dumb on purpose — pools are flat ``[N, page, Hkv, dh]``
buffers and the model just scatters/gathers through a ``[slots, P]`` table —
so all policy lives here, in plain numpy/python that runs between ticks:

* **free list** — retired pages go back verbatim; stale contents are safe
  because every read is masked by the owner's sequence length.
* **refcounts** — a page is held by each slot whose table row maps it, plus
  one reference while the prefix registry caches it.  A slot may write a
  page only while it is the sole holder.
* **prefix registry** — chained hashes of page-aligned prompt blocks map to
  resident pages, so a request admitted with a known system-prompt prefix
  maps those pages read-only instead of recomputing prefill for them.
  Registry-only pages (refcount 1) are the eviction victims, oldest first.
* **copy-on-write** — before a step writes positions ``[start, end)``,
  :meth:`PageAllocator.ensure` forks any shared page in that range: a fresh
  page is allocated, the device copies the contents (the batcher applies the
  returned ``(src, dst)`` pairs), and the table row is repointed.
* **reservations** — admission reserves every page the request can touch
  (``ceil(min(prompt + max_new, max_len) / page)``, minus reused prefix
  pages, plus one for the full-reuse CoW fork), so an admitted request can
  never deadlock mid-decode on an exhausted pool.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

from repro.obs import metrics

__all__ = ["AdmitPlan", "PageAllocator"]


@dataclasses.dataclass(frozen=True)
class AdmitPlan:
    """What admission decided for one request."""

    reuse_len: int  # prompt positions covered by reused prefix pages
    start: int  # prefill continuation start (min(reuse_len, L-1))
    reused_pages: tuple  # page ids mapped read-only from the registry


class PageAllocator:
    def __init__(
        self,
        *,
        slots: int,
        page_size: int,
        max_pages: int,
        num_pages: int,
        prefix_cache: bool = True,
    ):
        if num_pages < max_pages:
            raise ValueError(
                f"pool of {num_pages} pages cannot hold one full sequence "
                f"({max_pages} pages)"
            )
        self.slots = slots
        self.page = page_size
        self.max_pages = max_pages
        self.num_pages = num_pages
        self.prefix_cache = prefix_cache
        self.table = np.full((slots, max_pages), -1, np.int32)
        self.refcount = np.zeros(num_pages, np.int32)
        self._free = list(range(num_pages - 1, -1, -1))  # pop() -> page 0 first
        self._registry: dict[int, int] = {}  # chained prefix hash -> page id
        self._page_hash: dict[int, int] = {}  # page id -> its registry hash
        self._lru: OrderedDict[int, None] = OrderedDict()  # hashes, oldest first
        self._reserved = np.zeros(slots, np.int64)
        # telemetry — the local ints stay (per-allocator reports); the
        # registry children mirror them process-wide (DESIGN.md §14)
        self.prefix_hit_pages = 0
        self.cow_forks = 0
        self.evictions = 0
        self.allocs = 0
        self.draft_truncations = 0
        self.pages_reclaimed = 0
        _m = metrics.default()
        self._m_prefix_hits = _m.counter("kv.prefix_hit_pages")
        self._m_cow = _m.counter("kv.cow_forks")
        self._m_evict = _m.counter("kv.evictions")
        self._m_alloc = _m.counter("kv.allocs")
        self._m_trunc = _m.counter("kv.draft_truncations")
        self._m_reclaim = _m.counter("kv.pages_reclaimed")

    # -- capacity --------------------------------------------------------

    def _evictable(self) -> int:
        return sum(1 for h in self._lru if self.refcount[self._registry[h]] == 1)

    def available(self) -> int:
        """Pages obtainable right now: free list + evictable registry pages."""
        return len(self._free) + self._evictable()

    def resident_pages(self) -> int:
        """Pages holding live K/V (slot-held or registry-cached)."""
        return self.num_pages - len(self._free)

    def pages_for(self, prompt_len: int, max_new: int, max_len: int) -> int:
        total = min(prompt_len + max_new, max_len)
        return -(-total // self.page)

    # -- page supply -----------------------------------------------------

    def _alloc(self) -> int:
        if self._free:
            self.allocs += 1
            self._m_alloc.inc()
            return self._free.pop()
        for h in list(self._lru):  # oldest first
            pid = self._registry[h]
            if self.refcount[pid] == 1:  # held only by the registry
                del self._registry[h]
                del self._page_hash[pid]
                del self._lru[h]
                self.refcount[pid] = 0
                self.evictions += 1
                self.allocs += 1
                self._m_evict.inc()
                self._m_alloc.inc()
                return pid
        raise RuntimeError(
            "page pool exhausted despite reservations (allocator bug)"
        )

    def _decref(self, pid: int) -> None:
        self.refcount[pid] -= 1
        if self.refcount[pid] == 0:
            # Dirty page straight back to the free list — no clearing; reads
            # are masked by the next owner's sequence length.
            self._free.append(pid)

    # -- prefix hashing --------------------------------------------------

    def _block_hashes(self, prompt) -> list[int]:
        """Chained hash per FULL prompt page (page j depends on 0..j)."""
        hashes = []
        h = 0
        for j in range(len(prompt) // self.page):
            block = tuple(int(tok) for tok in prompt[j * self.page:(j + 1) * self.page])
            h = hash((h, block))
            hashes.append(h)
        return hashes

    # -- lifecycle -------------------------------------------------------

    def admit(self, slot: int, prompt, max_new: int, max_len: int):
        """Map reusable prefix pages and reserve the rest.

        Returns an :class:`AdmitPlan`, or None when the pool cannot cover the
        request right now (caller keeps it queued).  Only FULL prompt pages
        are ever shared — decode never writes them (the first decode write
        lands at position ``len(prompt)``), except the full-reuse case where
        the last prompt token is re-run to produce the first output; that
        write CoW-forks, which the reservation accounts for.
        """
        assert (self.table[slot] == -1).all(), f"slot {slot} not released"
        prompt_len = len(prompt)
        total = self.pages_for(prompt_len, max_new, max_len)
        reused: list[int] = []
        if self.prefix_cache:
            for h in self._block_hashes(prompt)[:total]:
                pid = self._registry.get(h)
                if pid is None:
                    break
                reused.append(pid)
                self._lru.move_to_end(h)
        reuse_len = len(reused) * self.page
        need = total - len(reused)
        if reuse_len >= prompt_len:
            need += 1  # the last-token re-run CoW-forks one shared page
        for j, pid in enumerate(reused):
            self.table[slot, j] = pid
            self.refcount[pid] += 1
        if self.available() - int(self._reserved.sum()) < need:
            for j, pid in enumerate(reused):  # roll back
                self.table[slot, j] = -1
                self.refcount[pid] -= 1
            return None
        self._reserved[slot] = need
        self.prefix_hit_pages += len(reused)
        self._m_prefix_hits.inc(len(reused))
        return AdmitPlan(
            reuse_len=reuse_len,
            start=min(reuse_len, prompt_len - 1),
            reused_pages=tuple(reused),
        )

    def ensure(self, slot: int, start: int, end: int) -> list[tuple[int, int]]:
        """Make positions ``[start, end)`` of ``slot`` privately writable.

        Allocates unmapped pages and CoW-forks shared ones; returns the
        ``(src_page, dst_page)`` copies the caller must apply to the device
        pools before running the step.
        """
        forks: list[tuple[int, int]] = []
        for j in range(start // self.page, -(-end // self.page)):
            pid = int(self.table[slot, j])
            if pid < 0:
                npid = self._alloc()
                self.refcount[npid] = 1
                self.table[slot, j] = npid
            elif self.refcount[pid] > 1:
                npid = self._alloc()
                self.refcount[npid] = 1
                self._decref(pid)
                self.table[slot, j] = npid
                forks.append((pid, npid))
                self.cow_forks += 1
                self._m_cow.inc()
            else:
                continue
            if self._reserved[slot] > 0:
                self._reserved[slot] -= 1
        return forks

    def register_prefix(self, slot: int, prompt) -> None:
        """Publish this slot's full prompt pages to the prefix registry."""
        if not self.prefix_cache:
            return
        for j, h in enumerate(self._block_hashes(prompt)):
            if j >= self.max_pages:
                break
            pid = int(self.table[slot, j])
            if pid < 0:
                break
            if h in self._registry:
                self._lru.move_to_end(h)
                continue
            if pid in self._page_hash:
                continue  # page already published under another hash
            self._registry[h] = pid
            self._page_hash[pid] = h
            self.refcount[pid] += 1
            self._lru[h] = None

    def release(self, slot: int) -> None:
        """Retire a slot: drop its page references and reservation.  Pages the
        registry still caches stay resident for future prefix hits."""
        for j in range(self.max_pages):
            pid = int(self.table[slot, j])
            if pid >= 0:
                self._decref(pid)
                self.table[slot, j] = -1
        self._reserved[slot] = 0

    def truncate(self, slot: int, new_len: int) -> int:
        """Shrink ``slot`` to ``new_len`` positions, freeing orphaned pages
        IMMEDIATELY (DESIGN.md §11).

        Speculative rejection is just this truncation: pages are append-only
        per owner, so a rejected draft tail leaves whole now-unused pages
        past ``ceil(new_len / page)`` — they go straight back to the free
        list (not through LRU; they hold garbage K/V nobody can ever read,
        masked by the owner's length).  Each unmapped page restores one unit
        of the slot's admission reservation: the slot will allocate that
        page again as decode advances, and the reservation invariant (an
        admitted request never deadlocks on an exhausted pool) must survive
        truncation.  Returns the number of pages returned to the free list.
        """
        freed = 0
        for j in range(-(-new_len // self.page), self.max_pages):
            pid = int(self.table[slot, j])
            if pid < 0:
                continue
            before = len(self._free)
            self._decref(pid)
            self.table[slot, j] = -1
            self._reserved[slot] += 1
            if len(self._free) > before:
                freed += 1
        self.draft_truncations += 1
        self.pages_reclaimed += freed
        self._m_trunc.inc()
        self._m_reclaim.inc(freed)
        return freed

    # -- state round-trip (drain checkpoints, DESIGN.md §12) -------------

    def state_dict(self) -> dict:
        """JSON-serializable allocator state.

        A drained engine's checkpoint must carry the page table, refcounts
        and the prefix registry alongside the KV pools: a restored server
        that kept the pools but lost the registry would re-prefill every
        shared prefix (correct but slow), and one that lost refcounts would
        free registry-held pages (corrupt).  Chained block hashes are Python
        ints over tuples of ints — deterministic across processes (only str
        hashing is seed-randomized), so the registry round-trips as plain
        JSON.
        """
        return {
            "table": self.table.tolist(),
            "refcount": self.refcount.tolist(),
            "free": list(self._free),
            "registry": [[int(h), int(pid)] for h, pid in self._registry.items()],
            "lru": [int(h) for h in self._lru],
            "reserved": self._reserved.tolist(),
        }

    def load_state_dict(self, state: dict) -> None:
        table = np.asarray(state["table"], np.int32)
        if table.shape != self.table.shape:
            raise ValueError(
                f"page table shape {table.shape} != {self.table.shape}"
            )
        self.table = table
        self.refcount = np.asarray(state["refcount"], np.int32)
        self._free = [int(p) for p in state["free"]]
        self._registry = {int(h): int(pid) for h, pid in state["registry"]}
        self._page_hash = {pid: h for h, pid in self._registry.items()}
        self._lru = OrderedDict((int(h), None) for h in state["lru"])
        self._reserved = np.asarray(state["reserved"], np.int64)
        self.check_leaks()

    # -- invariants ------------------------------------------------------

    def check_leaks(self) -> None:
        """Assert the pool is exactly partitioned: every page is either on
        the free list (refcount 0, unmapped) or resident with a refcount
        equal to its holder count (slot table rows + registry entry).  The
        speculative tick loop calls this in tests after EVERY tick — a
        truncation that forgot a decref, or freed a page a table row still
        maps, fails here immediately."""
        held = np.zeros(self.num_pages, np.int64)
        for s in range(self.slots):
            for j in range(self.max_pages):
                pid = int(self.table[s, j])
                if pid >= 0:
                    held[pid] += 1
        for pid in self._page_hash:
            held[pid] += 1
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate pages on the free list"
        for pid in range(self.num_pages):
            if pid in free:
                assert held[pid] == 0 and self.refcount[pid] == 0, (
                    f"page {pid} on the free list but held/referenced"
                )
            else:
                assert held[pid] == int(self.refcount[pid]) and held[pid] > 0, (
                    f"page {pid}: refcount {int(self.refcount[pid])} != "
                    f"{int(held[pid])} holders"
                )
        assert len(self._free) + self.resident_pages() == self.num_pages
