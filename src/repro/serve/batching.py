"""Continuous batching: a fixed-slot decode batch where every slot runs at
its own position, finished sequences are evicted between steps and queued
prompts are admitted into the freed slots (vLLM-style scheduling on static
shapes — slot caches are scattered in place, never reshaped).

Decode attention supports per-slot ``t`` vectors natively
(:mod:`repro.models.layers`), so one jitted ``serve_step`` serves the whole
heterogeneous batch.  Two admission paths (DESIGN.md §9):

* **whole-prompt prefill** (default) — the prompt runs through a batch-1
  prefill and the resulting caches scatter into the freed slot;
* **chunked prefill** (``prefill_chunk > 0``) — the prompt streams through
  the decode tick loop ``prefill_chunk`` tokens at a time
  (:func:`repro.train.train_step.make_prefill_chunk_step`), so a long prompt
  never stalls the live decode slots behind one monolithic prefill — the
  chunk rides the same tick the decode step does, which is also what lets
  the netsim serving scenario hide the tick's all-to-all under the combined
  decode + prefill compute window.

Slot lifecycle hardening (regression-tested in ``tests/test_batching.py``):
prompts longer than the slot cache are rejected at admission (``req.error``)
instead of corrupting the ring buffer; a prompt that exactly fills the cache
emits its prefill token and finishes (no decode room); EOS on the final
allowed token finishes the request exactly like an early EOS; and an evicted
slot's dirty cache may be re-admitted into without clearing — every decode
read is masked to ``pos <= t``, so stale tail entries are never attended.

The serving engine (:mod:`repro.serve.engine`) threads runtime placement
state through the ``expert_perm`` / ``wire_perm`` attributes and reads
per-tick gate loads from :class:`TickStats` — the decode-time control-plane
contract.

**Paged KV cache** (DESIGN.md §10, auto-on when the model supports it): the
per-slot ring buffer is replaced by flat page pools plus a
``[slots, max_pages]`` table managed by :class:`repro.serve.paged.PageAllocator`.
Admission reserves pages up front (so a live slot never deadlocks on an
exhausted pool), prompt prefill scatters K/V into freshly allocated pages,
full prompt pages are published to a prefix registry for copy-on-write reuse
by later requests with the same system-prompt prefix, and slot retirement
returns pages to the free list.  HBM residency follows the *live token*
footprint instead of ``slots x max_len``, which is what lets the same pool
bytes serve more concurrent slots — the page-table indirection itself is
priced in the serving scenario of :mod:`repro.core.netsim`.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moe_mod
from repro.models import transformer as tfm
from repro.obs import trace
from repro.serve.paged import PageAllocator
from repro.train.train_step import (
    make_draft_loop_step,
    make_prefill_chunk_step,
    make_prefill_step,
    make_serve_step,
    make_verify_step,
)

__all__ = ["ContinuousBatcher", "Request", "TickStats"]


@jax.jit
def _fold_slot_keys(base, rids, counts):
    """Per-slot sample keys: ``fold_in(fold_in(base, rid), emitted_index)``.

    The per-VERIFIED-token key discipline (DESIGN.md §11): the key stream is
    a pure function of (request, output position), so speculative and serial
    decode consume identical keys regardless of how many draft attempts were
    burned getting there.
    """
    k1 = jax.vmap(jax.random.fold_in, (None, 0))(base, rids)
    return jax.vmap(jax.random.fold_in)(k1, counts)  # [B, 2]


@jax.jit
def _fold_span_keys(base, rids, starts, offsets):
    """[B, C, 2] keys for a C-token span starting at each slot's next
    emitted-token index (one jit specialization per span length C)."""
    k1 = jax.vmap(jax.random.fold_in, (None, 0))(base, rids)

    def row(k, s):
        return jax.vmap(lambda o: jax.random.fold_in(k, s + o))(offsets)

    return jax.vmap(row)(k1, starts)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_slot_caches(caches, one, slot):
    """Write ONE slot's column of every dense cache leaf.

    The donated input is the fix for the admission-path copy bug: an undonated
    ``full.at[:, slot].set(...)`` outside jit materializes a fresh
    ``slots x max_len`` copy of every leaf per admitted request; donated under
    jit it lowers to an aliased dynamic-update-slice that touches only the
    target column.  ``slot`` is traced, so all slots share one compile."""

    def sc(full, new):
        return jax.lax.dynamic_update_slice_in_dim(
            full, new.astype(full.dtype), slot, axis=1
        )

    return jax.tree.map(sc, caches, one)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_prompt_pages(caches, one, page_ids):
    """Scatter a batch-1 prefill's K/V into the page pool.

    ``one`` leaves are ``[reps, 1, P*page, Hkv, dh]`` (padded to the table's
    span); ``page_ids [P]`` maps logical page j to its pool slot, -1 entries
    (past the prompt, or reused prefix pages that must not be overwritten)
    scatter out of bounds and drop."""

    def sc(pool, new):
        reps, n_pages, page = pool.shape[0], pool.shape[1], pool.shape[2]
        maxp = page_ids.shape[0]
        r = new[:, 0].reshape(reps, maxp, page, *new.shape[3:])
        pid = jnp.where(page_ids >= 0, page_ids, n_pages)
        return pool.at[:, pid].set(r.astype(pool.dtype), mode="drop")

    return jax.tree.map(sc, caches, one)


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_pages(caches, src, dst):
    """Copy-on-write fork: duplicate pages ``src -> dst`` in every pool."""
    return jax.tree.map(lambda pool: pool.at[:, dst].set(pool[:, src]), caches)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S_prompt] int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    # Traffic-region tag (workload regions; None = untagged).  The engine
    # attributes each tick's gate load to its live requests' regions — the
    # region-conditioned statistics fleet steering scores replicas with.
    region: int | None = None
    out: list = dataclasses.field(default_factory=list)
    error: str | None = None
    submit_tick: int = -1  # tick the request entered the queue
    first_token_tick: int = -1  # tick its first output token was emitted
    finish_tick: int = -1


@dataclasses.dataclass
class _Prefill:
    """Chunked-prefill progress of one admitted-but-not-yet-live request."""

    req: Request
    slot: int
    pos: int = 0


@dataclasses.dataclass
class TickStats:
    """What one tick did — the serving engine's observation surface."""

    live: int  # decode slots served
    prefill_tokens: int  # chunked-prefill tokens advanced this tick
    admitted: int
    finished: int
    gate_load: np.ndarray | None  # [repeats, E] live-slot expert loads
    # Speculative round telemetry (DESIGN.md §11) — all zero on plain ticks.
    spec_drafted: int = 0  # draft tokens proposed (live slots x span k)
    spec_accepted: int = 0  # draft tokens accepted AND emitted
    spec_verified: int = 0  # positions the FULL model scored (live x (k+1))


class ContinuousBatcher:
    """Slot-based continuous batching over a jitted decode step."""

    def __init__(
        self,
        params,
        cfg,
        plan,
        *,
        slots: int = 4,
        max_len: int = 128,
        mesh=None,
        prefill_chunk: int = 0,
        sample: bool = False,
        paged: bool | None = None,
        page_size: int = 16,
        num_pages: int = 0,
        prefix_cache: bool = True,
        spec_k: int = 0,
        spec_draft_mode: str = "auto",
        sample_seed: int = 0,
    ):
        self.params = params
        self.cfg = cfg
        self.plan = plan
        self.mesh = mesh
        self.slots = slots
        self.max_len = max_len
        self.prefill_chunk = int(prefill_chunk)
        self.sample = bool(sample)
        self.sample_seed = int(sample_seed)
        self._base_key = jax.random.PRNGKey(self.sample_seed) if self.sample else None
        self.spec_k = int(spec_k)
        # Measurement plane (DESIGN.md §14): the owning engine points
        # ``trace_tid`` at its viewer track so batcher spans (prefill chunks,
        # decode, spec draft/verify) land on the same timeline row.
        self._tr = trace.default()
        self.trace_tid: int | None = None
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self.t = np.zeros(slots, np.int32)  # next write position per slot
        self.tokens = np.zeros((slots, 1), np.int32)
        # Paged KV cache (DESIGN.md §10): auto-on for attention-only models;
        # `paged=False` keeps the dense ring buffer (the bit-parity reference
        # and the fallback for MLA / recurrent / audio cache layouts).
        self.paged = tfm.paged_supported(cfg) if paged is None else bool(paged)
        self.alloc: PageAllocator | None = None
        if self.paged:
            self.page_size = int(page_size)
            self.max_pages = -(-max_len // self.page_size)
            self.num_pages = int(num_pages) or slots * self.max_pages
            self.caches = tfm.init_paged_caches(
                cfg, self.num_pages, self.page_size
            )
            self.alloc = PageAllocator(
                slots=slots,
                page_size=self.page_size,
                max_pages=self.max_pages,
                num_pages=self.num_pages,
                prefix_cache=prefix_cache,
            )
        else:
            self.caches = tfm.init_caches(cfg, slots, max_len)
        # Caches are donated into the decode/chunk steps so the slot (or page
        # pool) updates lower to in-place dynamic-update-slices instead of a
        # full-cache copy per tick.
        self._step = jax.jit(
            make_serve_step(cfg, plan, mesh=mesh, sample=sample, with_stats=True),
            donate_argnums=(1,),
        )
        self._prefill_fn = jax.jit(
            make_prefill_step(cfg, plan, mesh=mesh, with_stats=True)
        )
        # Paged mode always builds the chunk step: a prefix-cache hit resumes
        # the prompt mid-way as a decode-mode continuation even when chunked
        # prefill is off.
        self._chunk_fn = (
            jax.jit(
                make_prefill_chunk_step(cfg, plan, mesh=mesh, with_stats=True),
                donate_argnums=(1,),
            )
            if self.prefill_chunk > 0 or self.paged
            else None
        )
        # Speculative decoding (DESIGN.md §11): draft k tokens with the cheap
        # same-weights config, verify all k+1 positions in ONE chunked step.
        # Drafts append into the SAME paged pool, so rejection is a length
        # truncation — the dense ring buffer has no such invariant, hence the
        # paged requirement.
        self.draft_mode = "off"
        self._verify_fn = None
        self._draft_cfg = None
        self._draft_fns: dict[int, object] = {}  # span k -> jitted draft loop
        if self.spec_k > 0:
            if not self.paged:
                raise ValueError(
                    "speculative decoding requires the paged KV cache "
                    "(pass paged=True / a paged-capable model)"
                )
            self.draft_mode = moe_mod.resolve_draft_mode(cfg, spec_draft_mode)
            self._draft_cfg = moe_mod.draft_config(cfg, spec_draft_mode)
            self._verify_fn = jax.jit(
                make_verify_step(
                    cfg, plan, mesh=mesh, sample=sample, with_stats=True
                ),
                donate_argnums=(1,),
            )
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_rounds = 0
        self.prefilling: deque[_Prefill] = deque()
        self.finished: list[Request] = []
        self.tick = 0
        self.kv_resident_pages_peak = 0
        # Runtime placement state, threaded by the serving engine (identity
        # when no control plane drives this batcher).  Stored as numpy; the
        # jitted steps receive them as traced values, so a reconfiguration
        # never recompiles.
        self.expert_perm: np.ndarray | None = None
        self.wire_perm: np.ndarray | None = None

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.submit_tick = self.tick
        self.queue.append(req)

    def _perm_args(self):
        perm = (
            jnp.asarray(self.expert_perm, jnp.int32)
            if self.expert_perm is not None
            else None
        )
        wire = (
            jnp.asarray(self.wire_perm, jnp.int32)
            if self.wire_perm is not None
            else None
        )
        return perm, wire

    def _finish(self, req: Request) -> None:
        req.finish_tick = self.tick
        self.finished.append(req)

    def _emit_first(self, req: Request, first: int) -> bool:
        """Record the prefill's next-token; True if the request is done."""
        req.out.append(first)
        req.first_token_tick = self.tick
        prompt_full = len(req.prompt) + 1 > self.max_len
        done = (
            len(req.out) >= req.max_new_tokens
            or (req.eos_id is not None and first == req.eos_id)
            or prompt_full  # no cache room to decode further
        )
        if done:
            self._finish(req)
        return done

    def _admit(self) -> tuple[int, np.ndarray | None]:
        admitted = 0
        load = None
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            if any(p.slot == slot for p in self.prefilling):
                continue  # slot reserved by an in-flight chunked prefill
            req = self.queue.popleft()
            if len(req.prompt) > self.max_len:
                # Reject instead of writing past the ring buffer: a prompt
                # longer than the cache would wrap and overwrite itself.
                req.error = "prompt_too_long"
                self._finish(req)
                continue
            plan_a = None
            if self.paged:
                # Speculative spans may overshoot max_new by up to spec_k
                # draft positions before the rejected tail is truncated, so
                # admission reserves that headroom too (usually 0-1 pages).
                plan_a = self.alloc.admit(
                    slot, req.prompt, req.max_new_tokens + self.spec_k,
                    self.max_len,
                )
                if plan_a is None:
                    # Pool cannot cover the request yet; keep FIFO order and
                    # wait for retiring slots to release pages.
                    self.queue.appendleft(req)
                    break
            admitted += 1
            if self.prefill_chunk > 0:
                # Chunked prefill: reserve the slot, stream the prompt
                # through the tick loop (see _advance_prefill) — starting
                # past any prefix-cache hit.
                start = plan_a.start if plan_a is not None else 0
                self.prefilling.append(_Prefill(req, slot, pos=start))
                continue
            if self.paged:
                load = self._admit_paged(req, slot, plan_a, load)
            else:
                load = self._admit_whole(req, slot, load)
        return admitted, load

    def _admit_whole(self, req: Request, slot: int, load):
        """Per-slot prefill: run the prompt through a batch-1 prefill, emit
        the prefill's next-token (the request's first output) and scatter the
        resulting caches into this slot."""
        perm, wire = self._perm_args()
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        next_tok, one, stats = self._prefill_fn(self.params, batch, perm, wire)
        first = int(next_tok[0, 0])
        one = tfm.pad_caches(one, self.max_len)
        self.caches = _scatter_slot_caches(
            self.caches, one, jnp.asarray(slot, jnp.int32)
        )
        if stats is not None:
            s = np.asarray(stats)
            load = s if load is None else load + s
        if self._emit_first(req, first):
            return load
        self.active[slot] = req
        self.t[slot] = len(req.prompt)
        self.tokens[slot, 0] = first
        return load

    def _admit_paged(self, req: Request, slot: int, plan_a, load):
        """Paged admission: whole-prompt prefill scatters K/V into freshly
        allocated pages; a prefix-cache hit skips the reused pages and runs
        only the remainder as a decode-mode continuation chunk."""
        prompt = np.asarray(req.prompt)
        n = len(prompt)
        perm, wire = self._perm_args()
        if plan_a.start == 0:
            self._apply_forks(self.alloc.ensure(slot, 0, n))
            batch = {"tokens": jnp.asarray(prompt[None, :])}
            next_tok, one, stats = self._prefill_fn(self.params, batch, perm, wire)
            one = tfm.pad_caches(one, self.max_pages * self.page_size)
            self.caches = _scatter_prompt_pages(
                self.caches, one, jnp.asarray(self.alloc.table[slot])
            )
        else:
            self._apply_forks(self.alloc.ensure(slot, plan_a.start, n))
            next_tok, stats = self._run_chunk(
                slot, prompt[plan_a.start :], plan_a.start
            )
        first = int(next_tok[0, 0])
        self.alloc.register_prefix(slot, prompt)
        if stats is not None:
            s = np.asarray(stats)
            load = s if load is None else load + s
        if self._emit_first(req, first):
            self.alloc.release(slot)
            return load
        self.active[slot] = req
        self.t[slot] = n
        self.tokens[slot, 0] = first
        return load

    def _apply_forks(self, forks) -> None:
        if forks:
            src = jnp.asarray([f[0] for f in forks], jnp.int32)
            dst = jnp.asarray([f[1] for f in forks], jnp.int32)
            self.caches = _copy_pages(self.caches, src, dst)

    def _run_chunk(self, slot: int, chunk: np.ndarray, pos: int):
        """Run a decode-mode continuation chunk for one slot.  Paged mode
        runs batch-1 against the shared pools through the slot's table row;
        dense mode gathers/scatters the slot column."""
        perm, wire = self._perm_args()
        if self.paged:
            next_tok, self.caches, stats = self._chunk_fn(
                self.params,
                self.caches,
                jnp.asarray(chunk[None, :]),
                jnp.asarray(pos, jnp.int32),
                perm,
                wire,
                None,
                jnp.asarray(self.alloc.table[slot : slot + 1]),
            )
            return next_tok, stats
        next_tok, new, stats = self._chunk_fn(
            self.params,
            self._slot_caches(slot),
            jnp.asarray(chunk[None, :]),
            jnp.asarray(pos, jnp.int32),
            perm,
            wire,
        )
        self._scatter_slot(slot, new)
        return next_tok, stats

    def _slot_caches(self, slot: int):
        return jax.tree.map(lambda c: c[:, slot : slot + 1], self.caches)

    def _scatter_slot(self, slot: int, new) -> None:
        self.caches = _scatter_slot_caches(
            self.caches, new, jnp.asarray(slot, jnp.int32)
        )

    def _advance_prefill(self) -> tuple[int, np.ndarray | None]:
        """Advance ONE pending prompt by up to ``prefill_chunk`` tokens —
        the chunk rides the same tick the decode step does."""
        if not self.prefilling:
            return 0, None
        pf = self.prefilling[0]
        chunk = pf.req.prompt[pf.pos : pf.pos + self.prefill_chunk]
        with self._tr.span("serve.prefill_chunk", tid=self.trace_tid,
                           rid=pf.req.rid, tokens=len(chunk)):
            if self.paged:
                self._apply_forks(
                    self.alloc.ensure(pf.slot, pf.pos, pf.pos + len(chunk))
                )
            next_tok, stats = self._run_chunk(pf.slot, np.asarray(chunk), pf.pos)
        pf.pos += len(chunk)
        load = None if stats is None else np.asarray(stats)
        if pf.pos >= len(pf.req.prompt):
            self.prefilling.popleft()
            if self.paged:
                self.alloc.register_prefix(pf.slot, np.asarray(pf.req.prompt))
            first = int(next_tok[0, 0])
            if self._emit_first(pf.req, first):
                if self.paged:
                    self.alloc.release(pf.slot)
            else:
                self.active[pf.slot] = pf.req
                self.t[pf.slot] = len(pf.req.prompt)
                self.tokens[pf.slot, 0] = first
        return len(chunk), load

    def _span_keys(self, c: int):
        """[slots, c, 2] sample keys for a c-token span: slot s's key j is
        ``fold(fold(base, rid), len(out) + j)`` — the per-verified-token
        discipline that makes speculative and serial sampling identical."""
        rids = np.zeros(self.slots, np.int32)
        starts = np.zeros(self.slots, np.int32)
        for s in range(self.slots):
            req = self.active[s]
            if req is not None:
                rids[s] = req.rid
                starts[s] = len(req.out)
        return _fold_span_keys(
            self._base_key,
            jnp.asarray(rids),
            jnp.asarray(starts),
            jnp.arange(c, dtype=jnp.int32),
        )

    # -- one decode tick -------------------------------------------------------
    def step(self) -> TickStats:
        """Admit, advance one prefill chunk, decode one token (or one
        speculative draft/verify round) for every active slot, evict
        finished.  Returns the tick's observations."""
        admitted, pre_load = self._admit()
        prefill_tokens, chunk_load = self._advance_prefill()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        finished = 0
        gate_load = None
        spec_drafted = spec_accepted = spec_verified = 0
        if live and self.spec_k > 0:
            finished, gate_load, spec_drafted, spec_accepted, spec_verified = (
                self._spec_tick(live)
            )
        elif live:
            perm, wire = self._perm_args()
            live_mask = np.zeros((self.slots, 1), np.float32)
            live_mask[live] = 1.0
            page_table = None
            if self.paged:
                # Every live slot writes position t[s] this tick; fork any
                # shared page in range and allocate fresh pages on demand.
                for s in live:
                    self._apply_forks(
                        self.alloc.ensure(s, int(self.t[s]), int(self.t[s]) + 1)
                    )
                page_table = jnp.asarray(self.alloc.table)
            rng = self._span_keys(1)[:, 0] if self.sample else None
            # The live mask serves two jobs (DESIGN.md §9): it weights the
            # exported MoE gate telemetry, and it suppresses K/V writes for
            # dead slots — without it the decode step would stomp a stale
            # position of a slot that is empty or still mid-chunked-prefill.
            with self._tr.span("serve.decode", tid=self.trace_tid,
                               live=len(live)):
                next_tok, self.caches, stats = self._step(
                    self.params,
                    self.caches,
                    jnp.asarray(self.tokens),
                    jnp.asarray(self.t),
                    rng,
                    perm,
                    wire,
                    jnp.asarray(live_mask),
                    page_table,
                )
            if stats is not None:
                gate_load = np.asarray(stats)
            next_np = np.asarray(next_tok)
            for s in live:
                req = self.active[s]
                tok = int(next_np[s, 0])
                req.out.append(tok)
                self.t[s] += 1
                self.tokens[s, 0] = tok
                done = (
                    len(req.out) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or self.t[s] >= self.max_len
                )
                if done:
                    finished += 1
                    self._finish(req)
                    self.active[s] = None
                    if self.paged:
                        self.alloc.release(s)
        if self.paged:
            self.kv_resident_pages_peak = max(
                self.kv_resident_pages_peak, self.alloc.resident_pages()
            )
        for extra in (pre_load, chunk_load):
            if extra is not None:
                gate_load = extra if gate_load is None else gate_load + extra
        self.tick += 1
        return TickStats(
            live=len(live),
            prefill_tokens=prefill_tokens,
            admitted=admitted,
            finished=finished,
            gate_load=gate_load,
            spec_drafted=spec_drafted,
            spec_accepted=spec_accepted,
            spec_verified=spec_verified,
        )

    def _spec_tick(self, live):
        """One speculative draft/verify round (DESIGN.md §11).

        Draft the next k tokens per live slot with the cheap config (one
        fused ``lax.scan`` launch), then score all k+1 continuation
        positions with the FULL model in one chunked verify launch.  The
        accepted prefix — the longest run where draft and verify agree,
        plus verify's token at the first disagreement (serial decode's
        correction; a bonus token when everything matched) — is bit-exact
        what non-speculative decode would have emitted, greedy or sampled
        (verify samples with the same per-verified-token keys serial decode
        would have used).  Rejected tail positions hold orphaned K/V: the
        slot's length simply doesn't advance over them, and whole now-unused
        pages go straight back to the allocator's free list.
        """
        perm, wire = self._perm_args()
        # Uniform span: clamp k so every live slot's k+1 writes stay inside
        # the page table.  One compiled program per span length (Kossmann et
        # al.: bucket specializations); steady-state ticks all use k=spec_k.
        k = self.spec_k
        for s in live:
            k = min(k, self.max_len - 1 - int(self.t[s]))
        k = max(k, 0)
        c = k + 1
        # Draft writes t..t+k-1, verify rewrites t..t+k: make the whole span
        # privately writable up front (CoW forks + fresh pages, drawing on
        # the spec_k admission headroom).
        for s in live:
            self._apply_forks(
                self.alloc.ensure(s, int(self.t[s]), int(self.t[s]) + c)
            )
        page_table = jnp.asarray(self.alloc.table)
        t_vec = jnp.asarray(self.t)
        live_mask = np.zeros((self.slots, c), np.float32)
        live_mask[live] = 1.0
        span_keys = self._span_keys(c) if self.sample else None
        tokens = np.zeros((self.slots, c), np.int32)
        tokens[:, 0] = self.tokens[:, 0]
        draft_np = None
        if k > 0:
            draft_fn = self._draft_fns.get(k)
            if draft_fn is None:
                draft_fn = jax.jit(
                    make_draft_loop_step(
                        self._draft_cfg, self.plan, mesh=self.mesh, k=k,
                        sample=self.sample,
                    ),
                    donate_argnums=(1,),
                )
                self._draft_fns[k] = draft_fn
            with self._tr.span("serve.spec_draft", tid=self.trace_tid,
                               k=k, live=len(live)):
                drafts, self.caches = draft_fn(
                    self.params,
                    self.caches,
                    jnp.asarray(self.tokens),
                    t_vec,
                    None if span_keys is None else span_keys[:, :k],
                    perm,
                    wire,
                    jnp.asarray(live_mask[:, :1]),
                    page_table,
                )
            draft_np = np.asarray(drafts)
            tokens[:, 1:] = draft_np
        with self._tr.span("serve.spec_verify", tid=self.trace_tid,
                           span=c, live=len(live)):
            toks, self.caches, stats = self._verify_fn(
                self.params,
                self.caches,
                jnp.asarray(tokens),
                t_vec,
                span_keys,
                perm,
                wire,
                jnp.asarray(live_mask),
                page_table,
            )
        gate_load = None if stats is None else np.asarray(stats)
        v = np.asarray(toks)
        finished = 0
        drafted = k * len(live)
        accepted = 0
        for s in live:
            req = self.active[s]
            a = 0
            while a < k and draft_np[s, a] == v[s, a]:
                a += 1
            emit = [int(x) for x in v[s, : a + 1]]
            # EOS inside the accepted span: stop AT the EOS and discard the
            # tail — post-EOS positions were verified but must not be
            # emitted (they'd never exist in serial decode).
            if req.eos_id is not None and req.eos_id in emit:
                emit = emit[: emit.index(req.eos_id) + 1]
            emit = emit[: req.max_new_tokens - len(req.out)]
            accepted += min(len(emit), a)
            req.out.extend(emit)
            self.t[s] += len(emit)
            self.tokens[s, 0] = emit[-1]
            done = (
                len(req.out) >= req.max_new_tokens
                or (req.eos_id is not None and emit[-1] == req.eos_id)
                or self.t[s] >= self.max_len
            )
            if done:
                finished += 1
                self._finish(req)
                self.active[s] = None
                self.alloc.release(s)
            elif len(emit) < c:
                # Rejected/cut tail: whole pages past the accepted length go
                # straight back to the free list and the slot's reservation
                # is restored (PageAllocator.truncate).
                self.alloc.truncate(s, int(self.t[s]))
        self.spec_drafted += drafted
        self.spec_accepted += accepted
        self.spec_rounds += 1
        return finished, gate_load, drafted, accepted, c * len(live)

    @property
    def busy(self) -> bool:
        return bool(
            self.queue or self.prefilling or any(a is not None for a in self.active)
        )

    def run(self, max_ticks: int = 1000) -> list[Request]:
        for _ in range(max_ticks):
            if not self.busy:
                break
            self.step()
        return self.finished
