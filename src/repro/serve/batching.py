"""Continuous batching: a fixed-slot decode batch where every slot runs at
its own position, finished sequences are evicted between steps and queued
prompts are admitted into the freed slots (vLLM-style scheduling on static
shapes — slot caches are scattered in, never reshaped).

Decode attention supports per-slot ``t`` vectors natively
(:mod:`repro.models.layers`), so one jitted ``serve_step`` serves the whole
heterogeneous batch.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.train.train_step import make_serve_step

__all__ = ["ContinuousBatcher", "Request"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S_prompt] int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    out: list = dataclasses.field(default_factory=list)


class ContinuousBatcher:
    """Slot-based continuous batching over a jitted decode step."""

    def __init__(self, params, cfg, plan, *, slots: int = 4, max_len: int = 128,
                 mesh=None):
        self.params = params
        self.cfg = cfg
        self.plan = plan
        self.slots = slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self.t = np.zeros(slots, np.int32)  # next write position per slot
        self.tokens = np.zeros((slots, 1), np.int32)
        self.caches = tfm.init_caches(cfg, slots, max_len)
        self._step = jax.jit(make_serve_step(cfg, plan, mesh=mesh))
        self.finished: list[Request] = []

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            # Per-slot prefill: run the prompt through a batch-1 prefill,
            # emit the prefill's next-token (the request's first output) and
            # scatter the resulting caches into this slot.
            batch = {"tokens": jnp.asarray(req.prompt[None, :])}
            feats, _, one = tfm.model_apply(
                self.params, batch, self.cfg, self.plan, mode="prefill"
            )
            logits = tfm.logits_from_features(self.params, feats[:, -1:], self.cfg)
            first = int(jnp.argmax(logits, axis=-1)[0, 0])
            one = tfm.pad_caches(one, self.max_len)

            def scatter(full, new):
                # full: [reps, slots, ...]; new: [reps, 1, ...]
                return full.at[:, slot].set(new[:, 0].astype(full.dtype))

            self.caches = jax.tree.map(scatter, self.caches, one)
            req.out.append(first)
            if len(req.out) >= req.max_new_tokens or (
                req.eos_id is not None and first == req.eos_id
            ):
                self.finished.append(req)
                continue
            self.active[slot] = req
            self.t[slot] = len(req.prompt)
            self.tokens[slot, 0] = first

    # -- one decode tick -------------------------------------------------------
    def step(self) -> int:
        """Admit, decode one token for every active slot, evict finished.
        Returns the number of active slots served."""
        self._admit()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return 0
        next_tok, self.caches = self._step(
            self.params, self.caches, jnp.asarray(self.tokens),
            jnp.asarray(self.t),
        )
        next_np = np.asarray(next_tok)
        for s in live:
            req = self.active[s]
            tok = int(next_np[s, 0])
            req.out.append(tok)
            self.t[s] += 1
            self.tokens[s, 0] = tok
            done = len(req.out) >= req.max_new_tokens or (
                req.eos_id is not None and tok == req.eos_id
            ) or self.t[s] >= self.max_len
            if done:
                self.finished.append(req)
                self.active[s] = None
        return len(live)

    def run(self, max_ticks: int = 1000) -> list[Request]:
        for _ in range(max_ticks):
            if not self.queue and all(a is None for a in self.active):
                break
            self.step()
        return self.finished
