"""Continuous batching: a fixed-slot decode batch where every slot runs at
its own position, finished sequences are evicted between steps and queued
prompts are admitted into the freed slots (vLLM-style scheduling on static
shapes — slot caches are scattered in place, never reshaped).

Decode attention supports per-slot ``t`` vectors natively
(:mod:`repro.models.layers`), so one jitted ``serve_step`` serves the whole
heterogeneous batch.  Two admission paths (DESIGN.md §9):

* **whole-prompt prefill** (default) — the prompt runs through a batch-1
  prefill and the resulting caches scatter into the freed slot;
* **chunked prefill** (``prefill_chunk > 0``) — the prompt streams through
  the decode tick loop ``prefill_chunk`` tokens at a time
  (:func:`repro.train.train_step.make_prefill_chunk_step`), so a long prompt
  never stalls the live decode slots behind one monolithic prefill — the
  chunk rides the same tick the decode step does, which is also what lets
  the netsim serving scenario hide the tick's all-to-all under the combined
  decode + prefill compute window.

Slot lifecycle hardening (regression-tested in ``tests/test_batching.py``):
prompts longer than the slot cache are rejected at admission (``req.error``)
instead of corrupting the ring buffer; a prompt that exactly fills the cache
emits its prefill token and finishes (no decode room); EOS on the final
allowed token finishes the request exactly like an early EOS; and an evicted
slot's dirty cache may be re-admitted into without clearing — every decode
read is masked to ``pos <= t``, so stale tail entries are never attended.

The serving engine (:mod:`repro.serve.engine`) threads runtime placement
state through the ``expert_perm`` / ``wire_perm`` attributes and reads
per-tick gate loads from :class:`TickStats` — the decode-time control-plane
contract.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tfm
from repro.train.train_step import (
    make_prefill_chunk_step,
    make_prefill_step,
    make_serve_step,
)

__all__ = ["ContinuousBatcher", "Request", "TickStats"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S_prompt] int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    out: list = dataclasses.field(default_factory=list)
    error: str | None = None
    submit_tick: int = -1  # tick the request entered the queue
    first_token_tick: int = -1  # tick its first output token was emitted
    finish_tick: int = -1


@dataclasses.dataclass
class _Prefill:
    """Chunked-prefill progress of one admitted-but-not-yet-live request."""

    req: Request
    slot: int
    pos: int = 0


@dataclasses.dataclass
class TickStats:
    """What one tick did — the serving engine's observation surface."""

    live: int  # decode slots served
    prefill_tokens: int  # chunked-prefill tokens advanced this tick
    admitted: int
    finished: int
    gate_load: np.ndarray | None  # [repeats, E] live-slot expert loads


class ContinuousBatcher:
    """Slot-based continuous batching over a jitted decode step."""

    def __init__(
        self,
        params,
        cfg,
        plan,
        *,
        slots: int = 4,
        max_len: int = 128,
        mesh=None,
        prefill_chunk: int = 0,
        sample: bool = False,
    ):
        self.params = params
        self.cfg = cfg
        self.plan = plan
        self.mesh = mesh
        self.slots = slots
        self.max_len = max_len
        self.prefill_chunk = int(prefill_chunk)
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self.t = np.zeros(slots, np.int32)  # next write position per slot
        self.tokens = np.zeros((slots, 1), np.int32)
        self.caches = tfm.init_caches(cfg, slots, max_len)
        self._step = jax.jit(
            make_serve_step(cfg, plan, mesh=mesh, sample=sample, with_stats=True)
        )
        self._prefill_fn = jax.jit(
            make_prefill_step(cfg, plan, mesh=mesh, with_stats=True)
        )
        self._chunk_fn = (
            jax.jit(make_prefill_chunk_step(cfg, plan, mesh=mesh, with_stats=True))
            if self.prefill_chunk > 0
            else None
        )
        self.prefilling: deque[_Prefill] = deque()
        self.finished: list[Request] = []
        self.tick = 0
        # Runtime placement state, threaded by the serving engine (identity
        # when no control plane drives this batcher).  Stored as numpy; the
        # jitted steps receive them as traced values, so a reconfiguration
        # never recompiles.
        self.expert_perm: np.ndarray | None = None
        self.wire_perm: np.ndarray | None = None

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request) -> None:
        req.submit_tick = self.tick
        self.queue.append(req)

    def _perm_args(self):
        perm = (
            jnp.asarray(self.expert_perm, jnp.int32)
            if self.expert_perm is not None
            else None
        )
        wire = (
            jnp.asarray(self.wire_perm, jnp.int32)
            if self.wire_perm is not None
            else None
        )
        return perm, wire

    def _finish(self, req: Request) -> None:
        req.finish_tick = self.tick
        self.finished.append(req)

    def _emit_first(self, req: Request, first: int) -> bool:
        """Record the prefill's next-token; True if the request is done."""
        req.out.append(first)
        req.first_token_tick = self.tick
        prompt_full = len(req.prompt) + 1 > self.max_len
        done = (
            len(req.out) >= req.max_new_tokens
            or (req.eos_id is not None and first == req.eos_id)
            or prompt_full  # no cache room to decode further
        )
        if done:
            self._finish(req)
        return done

    def _admit(self) -> tuple[int, np.ndarray | None]:
        admitted = 0
        load = None
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            if any(p.slot == slot for p in self.prefilling):
                continue  # slot reserved by an in-flight chunked prefill
            req = self.queue.popleft()
            if len(req.prompt) > self.max_len:
                # Reject instead of writing past the ring buffer: a prompt
                # longer than the cache would wrap and overwrite itself.
                req.error = "prompt_too_long"
                self._finish(req)
                continue
            admitted += 1
            if self._chunk_fn is not None:
                # Chunked prefill: reserve the slot, stream the prompt
                # through the tick loop (see _advance_prefill).
                self.prefilling.append(_Prefill(req, slot))
                continue
            load = self._admit_whole(req, slot, load)
        return admitted, load

    def _admit_whole(self, req: Request, slot: int, load):
        """Per-slot prefill: run the prompt through a batch-1 prefill, emit
        the prefill's next-token (the request's first output) and scatter the
        resulting caches into this slot."""
        perm, wire = self._perm_args()
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        next_tok, one, stats = self._prefill_fn(self.params, batch, perm, wire)
        first = int(next_tok[0, 0])
        one = tfm.pad_caches(one, self.max_len)

        def scatter(full, new):
            # full: [reps, slots, ...]; new: [reps, 1, ...]
            return full.at[:, slot].set(new[:, 0].astype(full.dtype))

        self.caches = jax.tree.map(scatter, self.caches, one)
        if stats is not None:
            s = np.asarray(stats)
            load = s if load is None else load + s
        if self._emit_first(req, first):
            return load
        self.active[slot] = req
        self.t[slot] = len(req.prompt)
        self.tokens[slot, 0] = first
        return load

    def _slot_caches(self, slot: int):
        return jax.tree.map(lambda c: c[:, slot : slot + 1], self.caches)

    def _scatter_slot(self, slot: int, new) -> None:
        self.caches = jax.tree.map(
            lambda full, n: full.at[:, slot].set(n[:, 0].astype(full.dtype)),
            self.caches,
            new,
        )

    def _advance_prefill(self) -> tuple[int, np.ndarray | None]:
        """Advance ONE pending prompt by up to ``prefill_chunk`` tokens —
        the chunk rides the same tick the decode step does."""
        if not self.prefilling:
            return 0, None
        pf = self.prefilling[0]
        perm, wire = self._perm_args()
        chunk = pf.req.prompt[pf.pos : pf.pos + self.prefill_chunk]
        next_tok, new, stats = self._chunk_fn(
            self.params,
            self._slot_caches(pf.slot),
            jnp.asarray(chunk[None, :]),
            jnp.asarray(pf.pos, jnp.int32),
            perm,
            wire,
        )
        self._scatter_slot(pf.slot, new)
        pf.pos += len(chunk)
        load = None if stats is None else np.asarray(stats)
        if pf.pos >= len(pf.req.prompt):
            self.prefilling.popleft()
            first = int(next_tok[0, 0])
            if not self._emit_first(pf.req, first):
                self.active[pf.slot] = pf.req
                self.t[pf.slot] = len(pf.req.prompt)
                self.tokens[pf.slot, 0] = first
        return len(chunk), load

    # -- one decode tick -------------------------------------------------------
    def step(self) -> TickStats:
        """Admit, advance one prefill chunk, decode one token for every
        active slot, evict finished.  Returns the tick's observations."""
        admitted, pre_load = self._admit()
        prefill_tokens, chunk_load = self._advance_prefill()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        finished = 0
        gate_load = None
        if live:
            perm, wire = self._perm_args()
            live_mask = np.zeros((self.slots, 1), np.float32)
            live_mask[live] = 1.0
            # The live mask serves two jobs (DESIGN.md §9): it weights the
            # exported MoE gate telemetry, and it suppresses K/V writes for
            # dead slots — without it the decode step would stomp a stale
            # position of a slot that is empty or still mid-chunked-prefill.
            next_tok, self.caches, stats = self._step(
                self.params,
                self.caches,
                jnp.asarray(self.tokens),
                jnp.asarray(self.t),
                None,
                perm,
                wire,
                jnp.asarray(live_mask),
            )
            if stats is not None:
                gate_load = np.asarray(stats)
            next_np = np.asarray(next_tok)
            for s in live:
                req = self.active[s]
                tok = int(next_np[s, 0])
                req.out.append(tok)
                self.t[s] += 1
                self.tokens[s, 0] = tok
                done = (
                    len(req.out) >= req.max_new_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or self.t[s] >= self.max_len
                )
                if done:
                    finished += 1
                    self._finish(req)
                    self.active[s] = None
        for extra in (pre_load, chunk_load):
            if extra is not None:
                gate_load = extra if gate_load is None else gate_load + extra
        self.tick += 1
        return TickStats(
            live=len(live),
            prefill_tokens=prefill_tokens,
            admitted=admitted,
            finished=finished,
            gate_load=gate_load,
        )

    @property
    def busy(self) -> bool:
        return bool(
            self.queue or self.prefilling or any(a is not None for a in self.active)
        )

    def run(self, max_ticks: int = 1000) -> list[Request]:
        for _ in range(max_ticks):
            if not self.busy:
                break
            self.step()
        return self.finished
