"""Batched serving: prefill + greedy/sampled decode against the KV caches.

``generate`` is the driver the serving example uses; ``serve_step`` /
``prefill_step`` (from :mod:`repro.train.train_step`) are what the dry-run
lowers for the decode_32k / long_500k cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.train.train_step import make_prefill_step, make_serve_step

__all__ = ["generate"]

# Compiled prefill/decode steps, keyed by everything that changes the traced
# program.  ``generate`` used to re-``jax.jit`` both steps per call, so every
# generation paid tracing + compilation again even for identical configs —
# with this cache, repeat calls (benchmark loops, tests, serving restarts)
# reuse the jitted callables and only shape changes retrace.
_STEP_CACHE: dict = {}


def _compiled_steps(cfg, plan, mesh, sample):
    key = (cfg, plan, mesh, bool(sample))
    hit = _STEP_CACHE.get(key)
    if hit is None:
        hit = (
            jax.jit(make_prefill_step(cfg, plan, mesh=mesh)),
            jax.jit(make_serve_step(cfg, plan, mesh=mesh, sample=sample)),
        )
        _STEP_CACHE[key] = hit
    return hit


def generate(
    params,
    cfg,
    plan,
    prompt_tokens: jax.Array,  # [B, S_prompt]
    *,
    max_new_tokens: int = 32,
    mesh=None,
    sample: bool = False,
    seed: int = 0,
    extra_batch: dict | None = None,
):
    """Prefill the prompt then decode ``max_new_tokens`` greedily/sampled."""
    b, s_prompt = prompt_tokens.shape
    prefill, step = _compiled_steps(cfg, plan, mesh, sample)

    batch = {"tokens": prompt_tokens, **(extra_batch or {})}
    next_tok, caches = prefill(params, batch)
    caches = tfm.pad_caches(caches, s_prompt + max_new_tokens)

    out = [next_tok]
    # Per-(row, emitted-index) keys — the same sampling-key discipline the
    # serving engine uses (DESIGN.md §11): key = fold(fold(base, row), n).
    # A pure function of position, so any decode schedule (serial here,
    # speculative in the engine) draws identical tokens.
    base = jax.random.PRNGKey(seed)
    row_keys = jax.vmap(jax.random.fold_in, (None, 0))(
        base, jnp.arange(b, dtype=jnp.int32)
    )
    tok = next_tok
    for i in range(max_new_tokens - 1):
        sub = jax.vmap(jax.random.fold_in)(
            row_keys, jnp.full((b,), i + 1, jnp.int32)
        )
        tok, caches = step(params, caches, tok, jnp.asarray(s_prompt + i), sub)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
