"""Batched serving: prefill + greedy/sampled decode against the KV caches.

``generate`` is the driver the serving example uses; ``serve_step`` /
``prefill_step`` (from :mod:`repro.train.train_step`) are what the dry-run
lowers for the decode_32k / long_500k cells.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.train.train_step import make_prefill_step, make_serve_step

__all__ = ["generate"]


def generate(
    params,
    cfg,
    plan,
    prompt_tokens: jax.Array,  # [B, S_prompt]
    *,
    max_new_tokens: int = 32,
    mesh=None,
    sample: bool = False,
    seed: int = 0,
    extra_batch: dict | None = None,
):
    """Prefill the prompt then decode ``max_new_tokens`` greedily/sampled."""
    b, s_prompt = prompt_tokens.shape
    prefill = jax.jit(make_prefill_step(cfg, plan, mesh=mesh))
    step = jax.jit(make_serve_step(cfg, plan, mesh=mesh, sample=sample))

    batch = {"tokens": prompt_tokens, **(extra_batch or {})}
    next_tok, caches = prefill(params, batch)
    caches = tfm.pad_caches(caches, s_prompt + max_new_tokens)

    out = [next_tok]
    rng = jax.random.PRNGKey(seed)
    tok = next_tok
    for i in range(max_new_tokens - 1):
        rng, sub = jax.random.split(rng)
        tok, caches = step(params, caches, tok, jnp.asarray(s_prompt + i), sub)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
