"""Deterministic data pipeline.

``SyntheticLM`` generates reproducible pseudo-text token streams (a mixture
of Zipfian unigrams and short repeated motifs so the loss actually has
structure to learn), sharded by (host, step) so every host reads a disjoint
stream — the standard multi-host input pattern, degenerate on one host.
``FileLM`` byte-tokenizes a local file for the end-to-end examples.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticLM", "FileLM", "Batch"]


@dataclasses.dataclass
class Batch:
    tokens: np.ndarray  # [B, S] int32
    labels: np.ndarray  # [B, S] int32 (next-token)


class SyntheticLM:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        *,
        seed: int = 0,
        host_id: int = 0,
        num_hosts: int = 1,
    ):
        if global_batch % num_hosts:
            raise ValueError("global batch must divide hosts")
        self.vocab = vocab_size
        self.seq = seq_len
        self.host_batch = global_batch // num_hosts
        self.seed = seed
        self.host_id = host_id
        self.step = 0
        # Zipfian unigram table (deterministic).
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def __iter__(self):
        return self

    def __next__(self) -> Batch:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + self.host_id) * 7_919 + self.step
        )
        b, s = self.host_batch, self.seq
        toks = rng.choice(self.vocab, size=(b, s + 1), p=self._probs).astype(np.int32)
        # Inject learnable motifs: short repeats at random offsets.
        for i in range(b):
            motif = rng.integers(0, self.vocab, size=8)
            for _ in range(max(s // 64, 1)):
                off = int(rng.integers(0, s - 8))
                toks[i, off : off + 8] = motif
        self.step += 1
        return Batch(tokens=toks[:, :-1], labels=toks[:, 1:])


class FileLM:
    """Byte-level tokens from a file, chunked into fixed windows."""

    def __init__(self, path: str, seq_len: int, global_batch: int, *, vocab_size: int = 256):
        data = np.frombuffer(open(path, "rb").read(), dtype=np.uint8)
        if vocab_size < 256:
            data = data % vocab_size
        self.data = data.astype(np.int32)
        self.seq = seq_len
        self.batch = global_batch
        self.pos = 0

    def __iter__(self):
        return self

    def __next__(self) -> Batch:
        need = self.batch * (self.seq + 1)
        if self.pos + need > len(self.data):
            self.pos = 0
        chunk = self.data[self.pos : self.pos + need].reshape(self.batch, self.seq + 1)
        self.pos += need
        return Batch(tokens=chunk[:, :-1], labels=chunk[:, 1:])
