"""Sharding rules: how each architecture maps onto the
``("pod", "data", "model")`` production mesh (DESIGN.md §5).

* batch            -> ("pod", "data")            (DP)
* params/opt-state -> "data" (+"pod")            (FSDP / ZeRO-3)
* "model" axis     -> the regional high-bandwidth domain:
    - dense layers: Megatron TP (heads / d_ff) when divisible,
      sequence-parallel activations between layers;
    - MoE layers: EP over (virtual) experts — the MixNet domain;
    - attention for head counts not divisible by the axis: sequence-sharded
      queries with gathered KV;
    - decode KV caches: sequence-sharded (flash-decoding style).
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = ["ShardingPlan", "make_plan", "virtual_experts", "shard_map"]


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    batch_axes: tuple  # axes sharding the batch dim, e.g. ("pod", "data")
    model_axis: str | None  # TP/EP axis name (None on single-device)
    model_size: int
    fsdp_axis: str | None  # params sharded over this axis too (ZeRO-3)
    data_size: int = 1

    # -- helpers used by layer init --------------------------------------
    def heads_axis(self, num_heads: int) -> str | None:
        """Shard a heads dim over the model axis only when divisible."""
        if self.model_axis and num_heads % max(self.model_size, 1) == 0:
            return self.model_axis
        return None

    def dim_axis(self, dim: int) -> str | None:
        if self.model_axis and dim % max(self.model_size, 1) == 0:
            return self.model_axis
        return None

    def fsdp_for(self, dim: int) -> str | None:
        if self.fsdp_axis and dim % max(self.data_size, 1) == 0:
            return self.fsdp_axis
        return None

    # -- activation specs ---------------------------------------------------
    def activation_spec(self, seq_shardable: bool = True) -> P:
        """Residual-stream spec [B, S, D]: batch over DP axes, seq over model
        (sequence parallelism) when the model axis exists."""
        seq = self.model_axis if seq_shardable else None
        return P(self.batch_axes or None, seq, None)

    def batch_spec(self) -> P:
        return P(self.batch_axes or None)

    def tokens_spec(self) -> P:
        return P(self.batch_axes or None, self.model_axis)

    def kv_cache_spec(self) -> P:
        """[B, S, Hkv, dh] — S sharded for flash-decoding."""
        return P(self.batch_axes or None, self.model_axis, None, None)

    def logits_spec(self) -> P:
        return P(self.batch_axes or None, None, self.model_axis)


def make_plan(mesh: Mesh | None, *, fsdp: bool = True) -> ShardingPlan:
    """Derive the plan from a mesh's named axes (or a no-op plan for None)."""
    if mesh is None or not mesh.axis_names:
        return ShardingPlan((), None, 1, None, 1)
    names = mesh.axis_names
    sizes = dict(zip(names, mesh.devices.shape))
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    model_axis = "model" if "model" in names else None
    data_size = 1
    for a in batch_axes:
        data_size *= sizes[a]
    return ShardingPlan(
        batch_axes=batch_axes,
        model_axis=model_axis,
        model_size=sizes.get("model", 1),
        fsdp_axis=("data" if (fsdp and "data" in names) else None),
        data_size=sizes.get("data", 1),
    )


def virtual_experts(num_experts: int, model_size: int) -> tuple[int, int]:
    """(virtual expert count, replication factor r).

    When E < model axis size, each expert is split into r = axis/E tensor
    shards ("virtual experts") so the expert dim shards exactly; tokens are
    dispatched to all r shards and the combine sums the partial products
    (row-split matmul identity).  When E >= axis, r = 1.
    """
    if model_size <= 1 or num_experts >= model_size:
        return num_experts, 1
    if model_size % num_experts != 0:
        raise ValueError(
            f"cannot factor {num_experts} experts over model axis {model_size}"
        )
    r = model_size // num_experts
    return num_experts * r, r


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def constrain(x, mesh: Mesh | None, spec: P):
    """with_sharding_constraint that degrades to identity without a mesh."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-compatible ``jax.shard_map``.

    Older jax releases only ship ``jax.experimental.shard_map`` and call the
    replication check ``check_rep`` instead of ``check_vma``.  The default
    mirrors jax's own (checking ON); call sites opt out explicitly.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check_vma
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_vma
    )
