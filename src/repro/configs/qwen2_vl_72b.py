"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution.  [arXiv:2409.12191]

Transformer BACKBONE only: the vision frontend is a STUB
(``input_specs`` provides precomputed patch embeddings spliced into the
first positions; text tokens use equal (t,h,w) positions = plain RoPE).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    vision_patches=1024,
    act="silu",
    dtype="bfloat16",
    opt_moment_dtype="bfloat16",
    remat="full",
)
