"""whisper-base [audio] — 6L d_model=512 8H d_ff=2048 vocab=51865 —
encoder-decoder; conv/mel frontend is a STUB (``input_specs`` provides
1500 precomputed frame embeddings).  [arXiv:2212.04356]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,  # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51872,  # 51865 padded to a multiple of 16 for vocab sharding
    head_dim=64,
    encoder_layers=6,
    encoder_seq=1500,
    act="gelu",
    dtype="bfloat16",
    remat="full",
)
