"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1)
d_ff=12288 vocab=256000 — RG-LRU + local attention at 2:1.
[arXiv:2402.19427]

38 layers = 12 x (rglru, rglru, local) + 2 tail RG-LRU blocks.
Sub-quadratic (local window 2048) -> runs the long_500k cell.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rglru", "rglru", "local"),
    tail_pattern=("rglru", "rglru"),
    window_size=2048,
    act="geglu",
    tie_embeddings=True,
    dtype="bfloat16",
    remat="full",
)
