"""Architecture registry: the 10 assigned architectures (``--arch <id>``)
plus the paper's own models.  ``get_config(name)`` returns the full-size
:class:`repro.models.config.ModelConfig`; ``get_reduced(name)`` the CPU
smoke-test variant of the same family.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced as _reduced

# arch id -> module (one file per assigned architecture).
_MODULES = {
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "mamba2-1.3b": "repro.configs.mamba2_1p3b",
    "whisper-base": "repro.configs.whisper_base",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    try:
        mod = importlib.import_module(_MODULES[name])
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; options: {sorted(_MODULES)}")
    return mod.CONFIG


def get_reduced(name: str, **overrides) -> ModelConfig:
    return _reduced(get_config(name), **overrides)


def all_configs() -> dict[str, ModelConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}
