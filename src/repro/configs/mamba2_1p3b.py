"""mamba2-1.3b [ssm] — 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060]

Attention-free and O(1)-state decode -> runs the long_500k cell.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=64,  # SSD heads = expand*d_model / head_dim
    num_kv_heads=1,
    d_ff=0,
    vocab_size=50288,  # 50280 padded to a multiple of 16 for vocab sharding
    head_dim=64,
    block_pattern=("ssm",),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True,
    dtype="bfloat16",
    remat="full",
)
