"""Assigned input shapes (the x-axis of the 40-cell matrix) and the
skip rules from DESIGN.md §4.

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache of ``seq_len``), not ``train_step``.  ``long_500k`` requires
sub-quadratic attention: it runs for SSM / hybrid / local-attention archs
and is skipped (documented) for pure full-attention archs.
"""

from __future__ import annotations

import dataclasses

__all__ = ["ShapeSpec", "SHAPES", "cell_supported"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Archs allowed to run long_500k: any arch with no *global* full-attention
# block (SSM / hybrid / pure-local), plus gemma2 (alternating local/global:
# the decode step is linear-time; flagged in DESIGN.md §4).
_LONG_OK = {"mamba2-1.3b", "recurrentgemma-9b", "gemma2-2b"}


def cell_supported(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """(supported, reason-if-not) for an (arch, shape) cell."""
    if shape.name == "long_500k":
        if cfg.name in _LONG_OK or cfg.sub_quadratic:
            return True, ""
        return False, (
            "long_500k skipped: pure full-attention arch (quadratic global "
            "attention over 524k context; DESIGN.md §4)"
        )
    if cfg.family == "audio" and shape.name == "long_500k":
        return False, "enc-dec decoder context does not extend to 500k"
    return True, ""
