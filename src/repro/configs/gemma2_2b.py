"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000 — local/global alternating attention, logit softcaps.
[arXiv:2408.00118]

8 heads < the 16-wide model axis -> sequence-sharded attention
(ShardingPlan.heads_axis returns None; activations stay seq-sharded).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    d_ff=9216,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("local", "global"),
    window_size=4096,
    logit_softcap=50.0,
    final_softcap=30.0,
    act="geglu",
    tie_embeddings=True,
    dtype="bfloat16",
    remat="full",
)
