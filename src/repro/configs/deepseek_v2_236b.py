"""deepseek-v2-236b [moe] — 60L d_model=5120 128H (kv=128 via MLA)
d_ff=1536 per expert, vocab=102400 — MLA kv_lora=512, 2 shared + 160
routed experts top-6.  [arXiv:2405.04434]

Primary MixNet target arch: 160 experts over the 16-wide model axis =
10 experts/device, sparse shifting all-to-all.
"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=1536,
    vocab_size=102400,
    head_dim=128,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        nope_head_dim=128,
        rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160,
        top_k=6,
        d_ff=1536,
        num_shared_experts=2,
        capacity_factor=1.25,
        backend="einsum",
        a2a_group=4,
    ),
    act="silu",
    dtype="bfloat16",
    opt_moment_dtype="bfloat16",
    remat="full",
)
