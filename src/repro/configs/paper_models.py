"""The paper's own evaluated MoE models (Table 1 / §D.1) as netsim
``SimModel``s — used by the benchmark suite to reproduce Figs 11-14/25-28 —
plus a trainable Mixtral-8x7B ``ModelConfig`` for the end-to-end examples.
"""

from repro.core.netsim import SimModel
from repro.models.config import ModelConfig, MoEConfig

# ---- netsim models (Table 1 + §D.1 parallelization) -----------------------

MIXTRAL_8X7B = SimModel(
    "mixtral-8x7b", num_blocks=32, d_model=4096, d_ff=14336, num_experts=8,
    top_k=2, num_heads=32, ep_degree=8, tp_degree=4, pp_degree=4,
)
MIXTRAL_8X22B = SimModel(
    "mixtral-8x22b", num_blocks=56, d_model=6144, d_ff=16384, num_experts=8,
    top_k=2, num_heads=48, ep_degree=8, tp_degree=8, pp_degree=8,
)
QWEN_MOE = SimModel(
    "qwen-moe", num_blocks=24, d_model=2048, d_ff=1408, num_experts=64,
    top_k=4, num_heads=16, ep_degree=32, tp_degree=1, pp_degree=4,
)
DEEPSEEK_R1 = SimModel(
    "deepseek-r1", num_blocks=61, d_model=7168, d_ff=2048, num_experts=256,
    top_k=8, num_heads=128, ep_degree=64, tp_degree=1, pp_degree=16,
)

SIM_MODELS = {
    m.name: m for m in (MIXTRAL_8X7B, MIXTRAL_8X22B, QWEN_MOE, DEEPSEEK_R1)
}

# ---- paper-scale cluster shapes (Fig 26's sweep axis, DESIGN.md §13) ------

PAPER_SCALE_GPUS = (32, 64, 128, 256, 512, 1024)


def scale_layout(model: SimModel, num_gpus: int) -> SimModel:
    """Re-layout ``model``'s parallelism onto a ``num_gpus`` cluster.

    TP stays at the model's native degree (it is shape-bound: head count /
    d_ff divisibility); PP absorbs what the depth allows, EP takes the
    rest — the same priority order the paper's Table 1 layouts follow.
    Raises when ``num_gpus`` cannot be factored over the model's shape.
    """
    import dataclasses

    tp = model.tp_degree
    if num_gpus % tp:
        raise ValueError(f"{num_gpus} GPUs not divisible by tp={tp}")
    rest = num_gpus // tp
    # Deepest pipeline the block count supports without exceeding the
    # model's native stage count or the remaining GPU budget.
    pp = model.pp_degree
    while pp > 1 and (rest % pp or model.num_blocks % pp):
        pp //= 2
    ep = rest // pp
    if ep < 1:
        raise ValueError(f"{num_gpus} GPUs too few for tp={tp} x pp={pp}")
    return dataclasses.replace(model, ep_degree=ep, tp_degree=tp, pp_degree=pp)

# ---- trainable Mixtral-8x7B (prototype-scale examples, Fig 10) ------------

MIXTRAL_8X7B_CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=14336, backend="mixnet"),
    act="silu",
    dtype="bfloat16",
    remat="full",
)
