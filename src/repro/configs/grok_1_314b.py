"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1]

8 experts on a 16-wide model axis -> virtual experts r=2 (each expert
tensor-split in two; DESIGN.md §5).  Primary MixNet target arch.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_ff=32768,
        capacity_factor=1.25,
        backend="einsum",  # baseline; perf path flips to "mixnet"
        a2a_group=4,
    ),
    act="gelu",
    dtype="bfloat16",
    opt_moment_dtype="bfloat16",  # 314B total params
    remat="full",
)
