"""Three-term roofline from a compiled dry-run artifact (§Roofline).

    compute    = HLO_FLOPs        / (chips * 197e12 FLOP/s)   [bf16 v5e]
    memory     = HLO_bytes        / (chips * 819e9  B/s HBM)
    collective = collective_bytes / (chips * 50e9   B/s ICI link)

plus MODEL_FLOPS = 6 * N_active * tokens and the useful-compute ratio
MODEL_FLOPS / HLO_FLOPs (catches remat / dispatch-einsum waste).
"""

from __future__ import annotations

import dataclasses

__all__ = ["HW", "RooflineTerms", "roofline_from_counts"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 per chip (TPU v5e)
    hbm_bw: float = 819e9  # bytes/s per chip
    link_bw: float = 50e9  # bytes/s per ICI link
    hbm_bytes: float = 16e9  # capacity per chip


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # global (all chips)
    hlo_bytes: float  # global HBM traffic
    collective_bytes: float  # global wire bytes
    model_flops: float  # 6 * N_active * tokens processed
    per_device_hbm_peak: float  # from memory_analysis
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-based MFU at the bound step time."""
        if self.step_time_s <= 0:
            return 0.0
        hw = HW()
        return self.model_flops / (self.step_time_s * self.chips * hw.peak_flops)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            bottleneck=self.bottleneck,
            step_time_s=self.step_time_s,
            useful_ratio=self.useful_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def analytic_hbm_bytes(cfg, shape) -> float:
    """TPU-granularity HBM traffic model (global bytes for one step).

    The HLO-parsed byte count inherits the CPU backend's per-op fusion
    granularity (every elementwise intermediate 'touches HBM'), overstating
    a TPU's traffic by ~2 orders of magnitude.  This analytic model counts
    what a well-fused TPU program actually moves:

      train:   params (fwd read + bwd read + remat re-read + grad rw +
               optimizer rw) + layer activations (carry write/read +
               recompute traffic) + chunked-CE logits.
      prefill: params once + fwd activations + KV-cache writes.
      decode:  params once (dense-dispatch MoE reads all experts) +
               KV-cache/state read + small activation traffic.
    """
    pb = {"bfloat16": 2, "float32": 4}.get(cfg.dtype, 2)
    mb = {"bfloat16": 2, "float32": 4}.get(cfg.opt_moment_dtype, 4)
    p_total = cfg.param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    d = cfg.d_model
    if cfg.is_moe:
        eff_ff = (cfg.moe.top_k + cfg.moe.num_shared_experts) * cfg.moe.d_ff
    else:
        eff_ff = cfg.d_ff
    act_width = 6 * d + 3 * eff_ff  # qkvo + gated-mlp intermediates per token
    layer_act = cfg.num_layers * tokens * act_width * pb

    if shape.kind == "train":
        param_traffic = p_total * (pb * (2 + 1 + 2) + 4 * mb)  # fwd+bwd+remat reads, grad rw, opt rw
        act_traffic = 2.0 * layer_act  # write + read (recompute counted via remat read)
        logits = 3.0 * tokens * cfg.vocab_size * 4
        return param_traffic + act_traffic + logits
    if shape.kind == "prefill":
        kv_bytes = _kv_bytes_per_token(cfg) * tokens
        return p_total * pb + layer_act + kv_bytes
    # decode
    cache = _kv_bytes_per_token(cfg) * shape.global_batch * shape.seq_len
    return p_total * pb + cache + tokens * act_width * pb * cfg.num_layers


def _kv_bytes_per_token(cfg) -> float:
    pb = {"bfloat16": 2, "float32": 4}.get(cfg.dtype, 2)
    if cfg.mla is not None:
        return (cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim) * pb
    per_layer = 0.0
    n_attn = 0
    for kind in (*cfg.block_pattern, *cfg.tail_pattern):
        if kind in ("global", "local"):
            n_attn += 1
    frac = n_attn / max(len(cfg.block_pattern) + len(cfg.tail_pattern), 1)
    attn_layers = cfg.num_layers * frac
    per_layer = 2 * cfg.num_kv_heads * cfg.resolved_head_dim * pb
    # SSM/LRU state is O(1) per sequence — negligible per token at 32k+.
    return attn_layers * per_layer


def roofline_from_counts(
    *,
    arch: str,
    shape: str,
    mesh: str,
    chips: int,
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    model_flops: float,
    per_device_hbm_peak: float,
    hw: HW = HW(),
) -> RooflineTerms:
    t = RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh,
        chips=chips,
        hlo_flops=hlo_flops,
        hlo_bytes=hlo_bytes,
        collective_bytes=collective_bytes,
        model_flops=model_flops,
        per_device_hbm_peak=per_device_hbm_peak,
    )
    t.compute_s = hlo_flops / (chips * hw.peak_flops)
    t.memory_s = hlo_bytes / (chips * hw.hbm_bw)
    t.collective_s = collective_bytes / (chips * hw.link_bw)
    return t
