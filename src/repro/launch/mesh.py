"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure DP across pods; the "model" axis is the regionally
reconfigurable high-bandwidth domain (DESIGN.md §5).

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any import).
"""

from __future__ import annotations

import jax

__all__ = ["make_mesh", "use_mesh", "make_production_mesh", "make_test_mesh"]


def use_mesh(mesh):
    """Version-compatible ``jax.set_mesh`` context manager.

    Newer jax exposes ``jax.set_mesh``/``jax.sharding.use_mesh``; on older
    releases the ``Mesh`` object itself is the context manager that installs
    the thread-local mesh.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def make_mesh(shape, axes):
    """Version-compatible ``jax.make_mesh``.

    Newer jax wants explicit ``axis_types`` (Auto) for shard_map + pjit
    mixing; older releases predate ``jax.sharding.AxisType`` and default to
    the same behavior.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device tests (8 forced host devices)."""
    return make_mesh(shape, axes)
