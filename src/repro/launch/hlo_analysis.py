"""Collective / FLOP / HBM-byte extraction from compiled HLO text
(§Roofline).

``compiled.cost_analysis()`` has two gaps: collective bytes are absent, and
while-loop bodies (scan-over-layers!) are counted ONCE instead of
trip-count times.  This module re-derives all three roofline numerators
from the optimized HLO with loop multipliers applied:

  * FLOPs: every ``dot`` = 2 * prod(result dims) * prod(contracting dims).
  * HBM bytes: operands + result of every top-level instruction of every
    non-fused computation (fusion internals never touch HBM).
  * Collective wire bytes per device, ring formulas:
      all-gather        out * (g-1)/g
      all-reduce        2 * size * (g-1)/g
      reduce-scatter    out * (g-1)
      all-to-all        size * (g-1)/g
      collective-permute  size
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["analyze_hlo", "parse_hlo_collectives", "collective_bytes"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")

_NO_HBM_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "copy-start", "copy-done", "add-dependency", "domain",
}


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims_of(type_str: str) -> tuple[list[int], int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return [], 0
    dt, dims = m.groups()
    return ([int(d) for d in dims.split(",")] if dims else []), _DTYPE_BYTES.get(dt, 0)


def _match_paren(s: str, start: int = 0) -> int:
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return -1


class Instr:
    __slots__ = ("name", "type_str", "kind", "operands", "attrs", "raw")

    def __init__(self, name, type_str, kind, operands, attrs, raw=""):
        self.name = name
        self.type_str = type_str
        self.kind = kind
        self.operands = operands
        self.attrs = attrs
        self.raw = raw


def _parse_instr(line: str) -> Instr | None:
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%") or " = " not in s:
        return None
    name, _, rhs = s.partition(" = ")
    name = name.strip().lstrip("%")
    rhs = rhs.strip()
    if rhs.startswith("("):
        end = _match_paren(rhs)
        if end < 0:
            return None
        type_str, rest = rhs[: end + 1], rhs[end + 1 :].strip()
    else:
        type_str, _, rest = rhs.partition(" ")
    m = re.match(r"([\w\-]+)\(", rest)
    if not m:
        return None
    kind = m.group(1)
    op_start = m.end() - 1
    op_end = _match_paren(rest, op_start)
    if op_end < 0:
        op_end = len(rest) - 1
    operands = _NAME_RE.findall(rest[op_start : op_end + 1])
    attrs = rest[op_end + 1 :]
    return Instr(name, type_str, kind, operands, attrs, raw=s)


def _parse_module(hlo_text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    current = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if current is None:
            if line.endswith("{") and "->" in line and ("(" in line):
                header = line.lstrip("ENTRY ").strip()
                m = re.match(r"%?([\w\.\-]+)\s*\(", header)
                if m:
                    current = m.group(1)
                    comps[current] = []
            continue
        if line.startswith("}"):
            current = None
            continue
        instr = _parse_instr(line)
        if instr is not None:
            comps[current].append(instr)
    return comps


def _loop_multipliers(comps: dict[str, list[Instr]]):
    """(multiplier per computation, fused computation set)."""
    calls: dict[str, set[str]] = defaultdict(set)
    fused: set[str] = set()
    trip_of_body: dict[str, float] = {}
    for name, instrs in comps.items():
        for it in instrs:
            for m in re.finditer(
                r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)", it.attrs
            ):
                callee = m.group(1)
                if callee in comps:
                    calls[name].add(callee)
                    if it.kind == "fusion" and "calls=" in it.attrs:
                        if f"calls=%{callee}" in it.attrs or f"calls={callee}" in it.attrs:
                            fused.add(callee)
            # branch computations: {%a, %b}
            for m in re.finditer(r"branch_computations=\{([^}]*)\}", it.attrs):
                for callee in _NAME_RE.findall(m.group(1)):
                    if callee in comps:
                        calls[name].add(callee)
            if it.kind == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", it.attrs)
                cm = re.search(r"condition=%?([\w\.\-]+)", it.attrs)
                trip = 1.0
                if cm and cm.group(1) in comps:
                    trip = _cond_trip(comps[cm.group(1)])
                if bm:
                    trip_of_body[bm.group(1)] = max(
                        trip_of_body.get(bm.group(1), 1.0), trip
                    )

    # mult[c] = number of times computation c executes: the caller's
    # multiplier, times the trip count when c is entered as a while body.
    mult: dict[str, float] = defaultdict(float)
    called = {c for cs in calls.values() for c in cs}

    def visit(name: str, m: float, depth: int = 0):
        if depth > 64 or m <= mult[name]:
            return
        mult[name] = m
        for callee in calls.get(name, ()):
            visit(callee, m * trip_of_body.get(callee, 1.0), depth + 1)

    for root in set(comps) - called:
        visit(root, 1.0)
    return mult, fused


def _cond_trip(cond_instrs: list[Instr]) -> float:
    """Trip count from a while condition: the max integer constant compared."""
    vals = [int(x) for it in cond_instrs for x in _TRIP_RE.findall(it.raw)]
    return float(max(vals)) if vals else 1.0


def _group_size(attrs: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def analyze_hlo(
    hlo_text: str, total_devices: int, *, f32_collective_scale: float = 1.0
) -> dict:
    """Per-device {flops, hbm_bytes, collectives, collective_counts} with
    while-loop trip counts applied.

    ``f32_collective_scale``: the CPU XLA backend promotes bf16 dots to f32,
    so collectives adjacent to GEMMs carry f32 copies of tensors a TPU
    program would move in bf16.  Passing 0.5 for bf16 models deflates
    f32-typed collectives back to target-dtype bytes (documented in
    EXPERIMENTS.md §Dry-run).
    """
    comps = _parse_module(hlo_text)
    mult, fused = _loop_multipliers(comps)

    flops = 0.0
    hbm = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, int] = defaultdict(int)

    for name, instrs in comps.items():
        m = mult[name]
        table = {it.name: it.type_str for it in instrs}
        in_fusion = name in fused
        for it in instrs:
            if it.kind == "dot":
                out_shape, _ = _dims_of(it.type_str)
                lhs_shape, _ = _dims_of(table.get(it.operands[0], "")) if it.operands else ([], 0)
                contract = 1
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", it.attrs)
                if cm and cm.group(1):
                    for ci in cm.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_shape):
                            contract *= lhs_shape[ci]
                n_out = 1
                for d in out_shape:
                    n_out *= d
                flops += 2.0 * n_out * contract * m
            if it.kind in _COLLECTIVES or any(
                it.kind == f"{c}-start" for c in _COLLECTIVES
            ):
                kind = it.kind.replace("-start", "")
                size = _shape_bytes(it.type_str)
                g = _group_size(it.attrs, total_devices)
                if kind == "all-gather":
                    wire = size * (g - 1) / max(g, 1)
                elif kind == "all-reduce":
                    wire = 2.0 * size * (g - 1) / max(g, 1)
                elif kind == "reduce-scatter":
                    wire = size * (g - 1)
                elif kind == "all-to-all":
                    wire = size * (g - 1) / max(g, 1)
                else:
                    wire = size
                if "f32[" in it.type_str and f32_collective_scale != 1.0:
                    wire *= f32_collective_scale
                coll_bytes[kind] += wire * m
                coll_counts[kind] += 1
            if not in_fusion and it.kind not in _NO_HBM_OPS:
                size = _shape_bytes(it.type_str)
                opsz = sum(_shape_bytes(table[o]) for o in it.operands if o in table)
                hbm += (size + opsz) * m

    return {
        "flops": flops,
        "hbm_bytes": hbm,
        "collectives": dict(coll_bytes),
        "collective_counts": dict(coll_counts),
    }


def parse_hlo_collectives(hlo_text: str, total_devices: int):
    r = analyze_hlo(hlo_text, total_devices)
    return r["collectives"], r["collective_counts"]


def collective_bytes(hlo_text: str, total_devices: int) -> float:
    return float(sum(parse_hlo_collectives(hlo_text, total_devices)[0].values()))
