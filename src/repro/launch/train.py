"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch grok-1-314b --reduced \
        --steps 50 --moe-backend mixnet --reconfig-every 8

Full-size configs target the production mesh (run under real TPU slices or
the dry-run); ``--reduced`` trains the same-family smoke config on whatever
devices exist, with the complete runtime (MixNet control loop, checkpoints,
watchdog) active.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.data.pipeline import FileLM, SyntheticLM
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="grok-1-314b")
    ap.add_argument("--reduced", action="store_true",
                    help="same-family smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--data", default="", help="path for byte-level FileLM")
    ap.add_argument("--moe-backend", choices=("einsum", "mixnet"), default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reconfig-every", type=int, default=0,
                    help="MixNet runtime reconfiguration cadence (0=off)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if args.moe_backend and cfg.is_moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, backend=args.moe_backend)
        )

    devices = jax.devices()
    mesh = None
    if len(devices) > 1:
        # Largest (data, model) factorization available.
        n = len(devices)
        model = 1
        for m in (16, 8, 4, 2):
            if n % m == 0:
                model = m
                break
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((n // model, model), ("data", "model"))
    plan = make_plan(mesh)

    opt = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                      total_steps=args.steps * 2,
                      moment_dtype=cfg.opt_moment_dtype)
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir or f"/tmp/repro_ckpt_{cfg.name}",
        reconfig_every=args.reconfig_every,
    )
    trainer = Trainer(cfg, opt, tcfg, plan, mesh=mesh, seed=args.seed)
    if args.resume and trainer.maybe_restore():
        print(f"resumed from step {trainer.step}")

    if args.data:
        data = FileLM(args.data, args.seq_len, args.batch, vocab_size=cfg.vocab_size)
    else:
        data = SyntheticLM(cfg.vocab_size, args.seq_len, args.batch, seed=args.seed)

    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params on "
          f"{len(devices)} device(s), mesh={mesh and mesh.devices.shape}")
    log = trainer.train(iter(data))
    losses = [float(m["loss"]) for m in log]
    print(f"steps {trainer.step}: loss {np.mean(losses[:3]):.3f} -> "
          f"{np.mean(losses[-3:]):.3f}; reconfigs={trainer.reconfig_count}; "
          f"stragglers={trainer.straggler_events}")


if __name__ == "__main__":
    main()
