import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) cell on the production meshes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch grok-1-314b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all

For each cell this prints ``compiled.memory_analysis()`` (proves it fits
HBM) and ``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline), parses
collective bytes from the compiled HLO, and appends the roofline record to
the output JSON consumed by EXPERIMENTS.md.

The XLA_FLAGS assignment above MUST run before any jax import — jax locks
the device count at first init.  Do not set it globally (tests and benches
see 1 device).
"""

import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import SHAPES, cell_supported
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.launch.roofline import HW, analytic_hbm_bytes, roofline_from_counts
from repro.launch.specs import make_cell


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    *,
    verbose: bool = True,
    moe_backend: str | None = None,
    remat: str | None = None,
    microbatches: int | None = None,
    sp_shardmap: bool = False,
):
    import dataclasses

    cfg = get_config(arch)
    if moe_backend and cfg.is_moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, backend=moe_backend)
        )
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if sp_shardmap:
        cfg = dataclasses.replace(cfg, sp_shardmap=True)
    shape = SHAPES[shape_name]
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    mesh_name = "x".join(map(str, mesh.devices.shape))
    t0 = time.time()
    fn, args = make_cell(cfg, shape, mesh, microbatches=microbatches)
    donate = getattr(fn, "donate_argnums", ())
    with use_mesh(mesh):
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax wraps the dict in a list
        cost = cost[0] if cost else {}
    if verbose:
        print(f"--- {arch} / {shape_name} / mesh {mesh_name} ---")
        print("memory_analysis:", mem)
        print("cost_analysis keys:", {k: v for k, v in sorted(cost.items())
                                      if isinstance(v, (int, float)) and v})

    # Trip-aware per-device FLOPs / HBM bytes / collective bytes from the
    # optimized HLO (cost_analysis counts while bodies once — see
    # hlo_analysis docstring); cost_analysis itself is printed above.
    hlo = compiled.as_text()
    analysis = analyze_hlo(
        hlo, chips,
        f32_collective_scale=0.5 if cfg.dtype == "bfloat16" else 1.0,
    )
    coll_by_kind = analysis["collectives"]
    coll_counts = analysis["collective_counts"]
    per_dev_coll = float(sum(coll_by_kind.values()))

    flops_per_dev = float(analysis["flops"])
    # Memory numerator: analytic TPU-granularity traffic (the parsed count
    # inherits CPU fusion granularity — kept as a diagnostic upper bound).
    parsed_bytes_per_dev = float(analysis["hbm_bytes"])
    bytes_global = analytic_hbm_bytes(cfg, shape)
    bytes_per_dev = bytes_global / chips
    per_dev_hbm = float(
        getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
    )

    # Tokens processed by one step of this cell.
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
    else:
        tokens = shape.global_batch  # one token per sequence
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd + bwd ~ 3x fwd
    model_flops = 2.0 * cfg.active_param_count() * tokens * mult

    terms = roofline_from_counts(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops_per_dev * chips,
        hlo_bytes=bytes_per_dev * chips,
        collective_bytes=per_dev_coll * chips,
        model_flops=model_flops,
        per_device_hbm_peak=per_dev_hbm,
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "mesh": mesh_name,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "collectives": {k: v for k, v in sorted(coll_by_kind.items())},
        "collective_counts": coll_counts,
        "hlo_bytes_parsed_per_dev": parsed_bytes_per_dev,
        "cost_analysis_flops_per_dev": float(cost.get("flops", 0.0)),
        "cost_analysis_bytes_per_dev": float(cost.get("bytes accessed", 0.0)),
        "fits_hbm": bool(per_dev_hbm <= HW().hbm_bytes),
        **{k: (float(v) if isinstance(v, (int, float)) else v)
           for k, v in terms.to_dict().items()},
    }
    if verbose:
        print("collectives/dev:", {k: f"{v/1e9:.2f}GB" for k, v in
                                   sorted(coll_by_kind.items())}, coll_counts)
        print(
            f"roofline: compute={terms.compute_s*1e3:.2f}ms "
            f"memory={terms.memory_s*1e3:.2f}ms "
            f"collective={terms.collective_s*1e3:.2f}ms "
            f"bottleneck={terms.bottleneck} useful={terms.useful_ratio:.2f} "
            f"HBM/dev={per_dev_hbm/1e9:.2f}GB fits={rec['fits_hbm']}"
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all cells, both meshes")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--moe-backend", choices=("einsum", "mixnet"), default=None,
                    help="override the MoE dispatch backend (perf hillclimb)")
    ap.add_argument("--remat", choices=("none", "full", "dots"), default=None)
    ap.add_argument("--microbatches", type=int, default=None,
                    help="gradient-accumulation microbatches for train cells")
    ap.add_argument("--sp", action="store_true",
                    help="explicit Megatron-SP shard_map (beyond-paper perf)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                cells.append((arch, shape, False))
                if not args.single_pod_only:
                    cells.append((arch, shape, True))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required without --all")
        cells.append((args.arch, args.shape, args.multi_pod))

    results = []
    if args.out and os.path.exists(args.out):
        results = json.load(open(args.out))
        done = {(r["arch"], r["shape"], r["multi_pod"]) for r in results}
        cells = [c for c in cells if c not in done]

    failures = 0
    for arch, shape, mp in cells:
        try:
            rec = run_cell(arch, shape, mp, moe_backend=args.moe_backend,
                           remat=args.remat, microbatches=args.microbatches,
                           sp_shardmap=args.sp)
        except Exception as e:  # a failure here is a bug in the system
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "multi_pod": mp,
                   "status": "FAILED", "error": str(e)[:500]}
            failures += 1
        results.append(rec)
        if args.out:
            json.dump(results, open(args.out, "w"), indent=1)
    print(f"\n{len(results)} cells recorded, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
