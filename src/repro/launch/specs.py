"""ShapeDtypeStruct stand-ins for every dry-run input: weak-type-correct,
carrying NamedShardings, zero device allocation.

``make_cell`` assembles everything one (arch x shape x mesh) cell needs:
the step function plus sharded abstract (params, opt_state, batch / caches).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, init_adamw
from repro.parallel.sharding import ShardingPlan, make_plan, virtual_experts
from repro.train.train_step import make_serve_step, make_train_step

__all__ = ["input_specs", "abstract_state", "make_cell"]


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def input_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, plan: ShardingPlan) -> dict:
    """Abstract model inputs for one cell (tokens/labels or decode inputs)."""
    b, s = shape.global_batch, shape.seq_len
    batch_ax = plan.batch_axes if b % max(plan.data_size, 1) == 0 else ()
    batch_spec = P(batch_ax or None)
    seq_ax = plan.model_axis if s % max(plan.model_size, 1) == 0 else None
    tok_spec = P(batch_ax or None, seq_ax)

    if shape.kind == "train":
        batch = {
            "tokens": _sds((b, s), jnp.int32, mesh, tok_spec),
            "labels": _sds((b, s), jnp.int32, mesh, tok_spec),
        }
    elif shape.kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32, mesh, tok_spec)}
    else:  # decode: one new token
        batch = {"tokens": _sds((b, 1), jnp.int32, mesh, P(batch_ax or None, None))}
    if cfg.family == "audio" and shape.kind != "decode":
        batch["frames"] = _sds(
            (b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype), mesh,
            P(batch_ax or None, None, None),
        )
    if cfg.vision_patches and shape.kind != "decode":
        batch["patches"] = _sds(
            (b, cfg.vision_patches, cfg.d_model), jnp.dtype(cfg.dtype), mesh,
            P(batch_ax or None, None, None),
        )
    return batch


def _shaped(tree, spec_tree, mesh):
    """eval_shape pytree + spec pytree -> sharded ShapeDtypeStructs."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=NamedSharding(mesh, s)
        ),
        tree,
        spec_tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, P)),
    )


def abstract_state(cfg: ModelConfig, plan: ShardingPlan, mesh, opt_cfg: AdamWConfig):
    """(params_sds, opt_sds, param_specs) without allocating anything."""
    key = jax.random.PRNGKey(0)
    spec_box = {}

    def init_params_only(k):
        p, s = tfm.init_model(k, cfg, plan)
        spec_box["specs"] = s  # specs are static python, captured at trace
        return p

    params_shape = jax.eval_shape(init_params_only, key)
    specs = spec_box["specs"]
    params_sds = _shaped(params_shape, specs, mesh)
    opt_shape = jax.eval_shape(partial(init_adamw, cfg=opt_cfg), params_shape)
    opt_specs = {
        "mu": specs,
        "nu": specs,
        "step": P(),
    }
    opt_sds = _shaped(opt_shape, opt_specs, mesh)
    return params_sds, opt_sds, specs


def abstract_caches(cfg: ModelConfig, shape: ShapeSpec, plan, mesh):
    b, s = shape.global_batch, shape.seq_len
    cache_shape = jax.eval_shape(
        partial(tfm.init_caches, cfg=cfg, batch=b, max_len=s),
    )
    # Batch axes only when divisible (long_500k has batch 1 -> replicated).
    spec_plan = plan if b % max(plan.data_size, 1) == 0 else _no_batch(plan)
    spec_tree = tfm.cache_specs(cfg, spec_plan)
    return _shaped(cache_shape, spec_tree, mesh)


def _no_batch(plan: ShardingPlan) -> ShardingPlan:
    import dataclasses

    return dataclasses.replace(plan, batch_axes=())


def make_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, opt_cfg=None, microbatches=None):
    """(fn, args_sds) ready for jax.jit(fn).lower(*args_sds)."""
    plan = make_plan(mesh)
    opt_cfg = opt_cfg or AdamWConfig(moment_dtype=cfg.opt_moment_dtype)
    batch = input_specs(cfg, shape, mesh, plan)

    if shape.kind == "train":
        params_sds, opt_sds, _ = abstract_state(cfg, plan, mesh, opt_cfg)
        step = make_train_step(
            cfg, plan, opt_cfg, mesh=mesh, microbatches=microbatches or 1
        )

        def fn(params, opt_state, b):
            return step(params, opt_state, b)

        fn.donate_argnums = (0, 1)  # params/opt updated in place
        return fn, (params_sds, opt_sds, batch)

    if shape.kind == "prefill":
        params_sds, _, _ = abstract_state(cfg, plan, mesh, opt_cfg)

        def fn(params, b):
            feats, _, caches = tfm.model_apply(
                params, b, cfg, plan, mesh=mesh, mode="prefill"
            )
            logits = tfm.logits_from_features(params, feats[:, -1:], cfg)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

        return fn, (params_sds, batch)

    # decode
    params_sds, _, _ = abstract_state(cfg, plan, mesh, opt_cfg)
    caches_sds = abstract_caches(cfg, shape, plan, mesh)
    serve = make_serve_step(cfg, plan, mesh=mesh)
    t_sds = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))

    def fn(params, caches, tokens, t):
        return serve(params, caches, tokens, t)

    fn.donate_argnums = (1,)  # KV caches updated in place
    return fn, (params_sds, caches_sds, batch["tokens"], t_sds)
