"""Gradient compression for the DP all-reduce (distributed-optimization
trick; used by the trainer's ``grad_reduce="compressed"`` mode).

int8 codec: per-tensor symmetric quantization with an error-feedback
residual (Seide et al. / 1-bit-Adam style) so quantization noise does not
accumulate across steps.  The compressed hierarchical reduce mirrors the
paper's §5.3 DP path: quantize -> reduce-scatter inside the region ->
all-reduce across regions -> all-gather -> dequantize, cutting cross-region
gradient bytes 4x (f32) / 2x (bf16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "int8_encode",
    "int8_decode",
    "compressed_psum",
    "compressed_hierarchical_psum",
    "error_feedback_update",
]


def int8_encode(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-quantized all-reduce over ``axis_name`` (inside shard_map).

    Each shard quantizes its contribution; the sum happens in int32 (exact
    over the quantized values), then one shared dequantization.  The scale
    is the max over shards so decoding is consistent.
    """
    q, scale = int8_encode(x)
    scale = lax.pmax(scale, axis_name)
    # Re-quantize against the global scale so the integer sum is coherent.
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale), -127, 127
    ).astype(jnp.int8)
    total = lax.psum(q.astype(jnp.int32), axis_name)
    return (total.astype(jnp.float32) * scale).astype(x.dtype)


def compressed_hierarchical_psum(
    x: jax.Array,
    inner_axis: str | None,
    outer_axis: str | None = None,
    *,
    scatter_dim: int = 0,
    with_local: bool = False,
):
    """int8 hierarchical all-reduce — the codec run *through* the
    CommRuntime :class:`~repro.core.commruntime.AllReduce` stages (§5.3):

      quantize against a pmax-shared scale -> reduce-scatter inside the
      region (int32, exact over the quantized values) -> all-reduce across
      regions -> all-gather back -> ONE shared dequantization.

    Wire bytes drop by ``dtype_bytes``x on every stage (int8 payload, the
    scale scalar is noise); the integer sum is exact so the only error is
    the shared quantization step — which the caller's error-feedback
    residual absorbs across steps (:func:`error_feedback_update`).

    ``with_local=True`` additionally returns this shard's own decoded
    contribution (f32) — what error feedback subtracts to form the residual.
    """
    from repro.core.commruntime import hierarchical_psum

    axes = [a for a in (inner_axis, outer_axis) if a]
    if not axes:
        return (x, x.astype(jnp.float32)) if with_local else x
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    for a in axes:
        scale = lax.pmax(scale, a)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int32)
    # The reduction IS the runtime's hierarchical lowering, applied to the
    # quantized int32 payload (its divisibility fallback included) — one
    # reduction topology, shared with the uncompressed path.
    if inner_axis is None:
        total = lax.psum(q, outer_axis)
    else:
        total = hierarchical_psum(q, inner_axis, outer_axis, scatter_dim=scatter_dim)
    out = (total.astype(jnp.float32) * scale).astype(x.dtype)
    if with_local:
        return out, q.astype(jnp.float32) * scale
    return out


def error_feedback_update(grad, residual, encode_decode):
    """One error-feedback step: compress (grad + residual), keep the error.

    Returns (decoded, new_residual).
    """
    target = grad.astype(jnp.float32) + residual.astype(jnp.float32)
    decoded = encode_decode(target)
    new_residual = target - decoded.astype(jnp.float32)
    return decoded.astype(grad.dtype), new_residual
