"""AdamW with dtype-configurable moments + global-norm clipping.

Giant configs (grok-1-314b, mistral-large-123b, ...) store moments in
bfloat16 so params+optimizer fit the 16 GB/chip HBM budget at 256-way
sharding (DESIGN.md §5); optimizer state sharding mirrors the param specs
exactly (ZeRO-3 style, free under pjit).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_adamw", "adamw_update", "global_norm", "cosine_lr"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def init_adamw(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step; returns (params, state, metrics)."""
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    dt = jnp.dtype(cfg.moment_dtype)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = mu32 / b1c
        vhat = nu32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mu32.astype(dt), nu32.astype(dt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
