"""Shared MoE routing/dispatch engine (DESIGN.md §6).

One implementation of the router math and the token-ordering machinery that
all three dispatch backends in :mod:`repro.models.moe` consume:

  router logits -> top-k (``ops.topk_gating``) -> renormalize -> virtual-slot
  destinations (replication r + the runtime ``expert_perm`` re-addressing the
  control plane plans) -> **argsort-by-expert token permutation** -> layout.

The ordering core is MegaBlocks-style (Gale et al.): ranks within each
destination bucket come from one stable ``argsort`` over the flat choice
array — O(N log N) — instead of the O(N·E) ``one_hot``+``cumsum`` rank
machinery the backends used to triplicate.  Shapes stay static everywhere
(Kossmann et al.: dynamic shapes force recompilation), so "dropless" is
expressed as a data-independent worst-case layout, not a dynamic one:

* ``dropless`` (default) — every routed token is placed.  The expert-side
  layout packs tokens into ``block``-row tiles (``dropless_plan``), each tile
  owned by one expert via a block→expert map that feeds the grouped GEMM's
  scalar-prefetch index map; padding is bounded by ``E·(block-1)`` rows.
* ``capacity`` — classic GShard buffers ``[E, C]`` with overflow dropped
  (``capacity_plan``); kept as an option because it bounds wire traffic for
  the sharded all-to-all stage.

The heavy data movement (gathering token rows into the packed layout and the
weighted combine back) goes through ``ops.moe_dispatch`` / ``ops.moe_combine``
(:mod:`repro.kernels.moe_dispatch` on TPU, jnp oracles elsewhere).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

__all__ = [
    "MoEStats",
    "RoutingInfo",
    "DispatchPlan",
    "compute_routing",
    "effective_top_k",
    "resolve_perm",
    "router_losses",
    "expert_load",
    "capacity",
    "bucket_ranks",
    "capacity_plan",
    "dropless_plan",
    "dense_dispatch_masks",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MoEStats:
    """Per-layer telemetry consumed by the MixNet control plane (§5.1)."""

    expert_load: jax.Array  # [E] tokens routed to each (real) expert
    balance_loss: jax.Array
    z_loss: jax.Array
    dropped_fraction: jax.Array


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RoutingInfo:
    """Router decisions for a flat batch of T tokens (S = top_k · r)."""

    weights: jax.Array  # [T, K] f32, renormalized over the kept top-k
    idx: jax.Array  # [T, K] i32 real-expert ids (for load/loss telemetry)
    vdest: jax.Array  # [T, S] i32 physical virtual-slot destinations
    wfull: jax.Array  # [T, S] f32 combine weight per virtual destination


@dataclasses.dataclass
class DispatchPlan:
    """Static-shape token layout for one dispatch stage.

    ``slot[i]`` is the packed-buffer row of flat choice ``i`` (-1 dropped);
    ``src[p]`` is the flat choice occupying packed row ``p`` (-1 empty) —
    the two are inverse views of the same permutation.  ``num_rows`` is the
    static packed-buffer height; ``block_experts`` (dropless layouts only)
    maps each ``block``-row tile to its owning expert for the grouped GEMM.
    ``kept`` counts the placed choices (telemetry).
    """

    slot: jax.Array  # [N] i32
    src: jax.Array  # [num_rows] i32
    num_rows: int
    block_experts: jax.Array | None
    kept: jax.Array  # scalar


# ---------------------------------------------------------------------------
# router math
# ---------------------------------------------------------------------------


def effective_top_k(top_k: int, draft_mode: str = "off") -> int:
    """Routed choices per token under a speculative draft mode (DESIGN.md §11).

    ``topk1`` narrows the gate to its single best expert; ``shared_only``
    routes nothing (the draft is attention + shared experts — callers must
    skip routing entirely, so 0 is returned as a sentinel, not a valid
    ``top_k``).  Engines use this for a2a accounting: draft tokens pay
    ``effective_top_k`` choices on the wire, verify tokens the full ``top_k``.
    """
    if draft_mode == "topk1":
        return min(top_k, 1)
    if draft_mode == "shared_only":
        return 0
    return top_k


def compute_routing(
    logits: jax.Array,
    *,
    top_k: int,
    num_virtual: int,
    replication: int,
    expert_perm: jax.Array | None = None,
    renormalize: bool = True,
    draft_mode: str = "off",
) -> RoutingInfo:
    """Top-k gate + virtual-slot destination map for ``[T, E]`` logits.

    Each choice (t, k) targets all ``r = replication`` tensor shards of its
    expert, re-addressed by the layer's ``expert_perm`` (virtual expert ->
    physical slot, the OCS cross-map analogue); ``wfull`` repeats the full
    combine weight per shard (row-split matmul partials sum under one
    weight).  ``draft_mode`` narrows the fan-out for speculative draft
    passes (``shared_only`` callers bypass routing and must not land here).
    """
    top_k = effective_top_k(top_k, draft_mode)
    if top_k <= 0:
        raise ValueError("shared_only drafts skip routing entirely")
    t = logits.shape[0]
    weights, idx = ops.topk_gating(logits, top_k)
    if renormalize:
        weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    r = replication
    vdest = (idx[..., None] * r + jnp.arange(r, dtype=jnp.int32)).reshape(
        t, top_k * r
    )
    if expert_perm is not None:
        vdest = resolve_perm(expert_perm, num_virtual)[vdest]
    wfull = jnp.repeat(weights, r, axis=-1)
    return RoutingInfo(weights=weights, idx=idx, vdest=vdest, wfull=wfull)


def resolve_perm(expert_perm, num_virtual: int) -> jax.Array:
    """Validate one layer's [E_virtual] expert->slot map (identity if None)."""
    if expert_perm is None:
        return jnp.arange(num_virtual, dtype=jnp.int32)
    perm = jnp.asarray(expert_perm, jnp.int32)
    if perm.shape != (num_virtual,):
        raise ValueError(
            f"expert_perm must be this layer's [E_virtual]={num_virtual} row, "
            f"got shape {perm.shape}"
        )
    return perm


def expert_load(
    idx: jax.Array, num_experts: int, weights: jax.Array | None = None
) -> jax.Array:
    """[E] f32 routed-token counts per real expert (scatter-add, no one-hot).

    ``weights`` is an optional per-token weight ``[T]`` (broadcast over the
    top-k choices).  The serving engine passes the live-slot mask here so the
    control plane's monitor only sees traffic from occupied decode slots —
    the decode-path gate-stat export (DESIGN.md §9); ``None`` keeps the
    historical unweighted count.
    """
    if weights is None:
        contrib = jnp.ones(idx.size, jnp.float32)
    else:
        k = idx.shape[-1]
        contrib = jnp.repeat(weights.reshape(-1).astype(jnp.float32), k)
    return jnp.zeros((num_experts,), jnp.float32).at[idx.reshape(-1)].add(contrib)


def router_losses(logits: jax.Array, idx: jax.Array, num_experts: int):
    """Switch-style balance loss + router z-loss (both f32 scalars)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    mean_prob = probs.reshape(-1, num_experts).mean(axis=0)
    counts = expert_load(idx, num_experts)
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    balance = num_experts * jnp.sum(frac * mean_prob)
    z = jnp.mean(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1) ** 2)
    return balance, z


def capacity(tokens: int, top_k: int, num_buckets: int, factor: float) -> int:
    """Per-bucket capacity for the capacity-factor mode (multiple of 4)."""
    c = int(np.ceil(tokens * top_k * factor / num_buckets))
    return max(4, int(np.ceil(c / 4) * 4))


# ---------------------------------------------------------------------------
# sort-based token ordering
# ---------------------------------------------------------------------------


def bucket_ranks(
    dest: jax.Array, num_buckets: int, *, valid: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """Rank of each flat choice within its destination bucket.

    One stable argsort over ``dest [N]`` orders choices by bucket while
    preserving token order inside each bucket — so capacity-mode keep
    decisions match the historical cumsum ranks exactly, at O(N log N)
    instead of O(N·E).  Entries with ``valid`` False sort into a trash bucket
    and get ranks that no real bucket counts.  Returns ``(rank [N] i32,
    counts [num_buckets] i32)``.
    """
    n = dest.shape[0]
    if valid is not None:
        key = jnp.where(valid, dest, num_buckets)
        total = num_buckets + 1
    else:
        key = dest
        total = num_buckets
    order = jnp.argsort(key, stable=True)
    skey = key[order]
    counts = jnp.zeros((total,), jnp.int32).at[key].add(1)
    starts = jnp.cumsum(counts) - counts  # cumsum over buckets, not tokens
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - starts[skey].astype(jnp.int32)
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    return rank, counts[:num_buckets]


def _invert_slots(slot: jax.Array, keep: jax.Array, num_rows: int) -> jax.Array:
    """src[p] = flat choice occupying packed row p, -1 where empty."""
    n = slot.shape[0]
    scatter_to = jnp.where(keep, slot, num_rows)
    src = (
        jnp.full((num_rows + 1,), -1, jnp.int32)
        .at[scatter_to]
        .set(jnp.arange(n, dtype=jnp.int32))
    )
    return src[:num_rows]


def capacity_plan(
    dest: jax.Array,
    rank: jax.Array,
    valid: jax.Array | None,
    num_buckets: int,
    cap: int,
) -> DispatchPlan:
    """GShard layout: bucket-major ``[num_buckets · cap]`` rows, overflow
    (rank >= cap) dropped.  With ``cap`` >= the worst-case bucket count this
    layout is dropless (how the all-to-all send stage expresses dropless
    without dynamic buffer sizes)."""
    keep = rank < cap
    if valid is not None:
        keep = keep & valid
    slot = jnp.where(keep, dest * cap + rank, -1)
    num_rows = num_buckets * cap
    src = _invert_slots(slot, keep, num_rows)
    return DispatchPlan(
        slot=slot, src=src, num_rows=num_rows, block_experts=None,
        kept=keep.sum(),
    )


def dropless_plan(
    dest: jax.Array,
    rank: jax.Array,
    counts: jax.Array,
    valid: jax.Array | None,
    num_buckets: int,
    block: int,
) -> DispatchPlan:
    """MegaBlocks layout: every valid choice placed, buckets padded up to a
    multiple of ``block`` rows so each ``block``-row tile is owned by exactly
    one expert (``block_experts`` drives the grouped GEMM's scalar-prefetch
    index map).  Static height: padding never exceeds ``E·(block-1)`` rows
    regardless of the realized load split."""
    n = dest.shape[0]
    nblk = (n + num_buckets * (block - 1)) // block
    num_rows = nblk * block
    pcounts = ((counts + block - 1) // block) * block
    ends = jnp.cumsum(pcounts)
    starts = ends - pcounts
    ok = valid if valid is not None else jnp.ones((n,), bool)
    safe_dest = jnp.clip(dest, 0, num_buckets - 1)
    slot = jnp.where(ok, starts[safe_dest] + rank, -1)
    src = _invert_slots(slot, ok, num_rows)
    block_experts = jnp.clip(
        jnp.searchsorted(ends, jnp.arange(nblk) * block, side="right"),
        0,
        num_buckets - 1,
    ).astype(jnp.int32)
    return DispatchPlan(
        slot=slot, src=src, num_rows=num_rows, block_experts=block_experts,
        kept=ok.sum(),
    )


# ---------------------------------------------------------------------------
# dense masks (einsum backend)
# ---------------------------------------------------------------------------


def dense_dispatch_masks(
    vdest: jax.Array,
    rank: jax.Array,
    keep: jax.Array,
    wfull: jax.Array,
    num_slots: int,
    cap: int,
) -> tuple[jax.Array, jax.Array]:
    """(dispatch, combine) masks ``[..., num_slots, cap]`` for the GShard
    einsum backend, built from the sort-based ranks (any leading batch/group
    dims broadcast through).  ``dispatch`` is the 0/1 token->buffer scatter;
    ``combine`` additionally carries the combine weights."""
    de = jax.nn.one_hot(vdest, num_slots, dtype=jnp.float32)
    dc = jax.nn.one_hot(jnp.clip(rank, 0, cap - 1), cap, dtype=jnp.float32)
    keepf = keep.astype(jnp.float32)
    dispatch = jnp.einsum("...se,...sc,...s->...ec", de, dc, keepf)
    combine = jnp.einsum("...se,...sc,...s->...ec", de, dc, keepf * wfull)
    return dispatch, combine
