"""Shared neural layers: norms, RoPE (+M-RoPE), GQA attention (full /
sliding-window / softcap), MLA attention (DeepSeek-V2), MLPs.

All layers are pure functions ``apply(params, x, ...)`` with matching
``init(key, cfg, plan)`` that return ``(params, specs)`` — the spec tree
mirrors the param tree with `jax.sharding.PartitionSpec` leaves so the
launcher can feed both straight into pjit.  ``mode`` selects train / prefill
/ decode paths; decode consumes and updates a KV cache laid out for
flash-decoding (sequence dim sharded over the ``model`` axis).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops
from repro.parallel.sharding import shard_map

Params = dict
Specs = dict

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dtype)


def init_rms_norm(d: int, dtype) -> tuple[jax.Array, P]:
    return jnp.zeros((d,), dtype), P(None)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    theta: float = 10_000.0,
    mrope_sections: tuple | None = None,
) -> jax.Array:
    """Rotate ``x [..., S, H, D]`` by ``positions``.

    ``positions`` is ``[..., S]`` for standard RoPE or ``[3, ..., S]`` for
    M-RoPE (qwen2-vl): the frequency axis is split into (t, h, w) sections,
    each rotated by its own position stream.  For text tokens all three
    streams are equal, reducing to standard RoPE.
    """
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # [d/2]
    if mrope_sections is not None:
        if positions.ndim == x.ndim - 2:  # text-only: broadcast to 3 streams
            positions = jnp.stack([positions] * 3)
        # angles[..., S, d/2]: frequency slots are partitioned into (t, h, w)
        # sections, each driven by its own position stream.
        ang = positions[..., None].astype(jnp.float32) * freqs  # [3, ..., S, d/2]
        parts, start = [], 0
        for i, sec_size in enumerate(mrope_sections):
            parts.append(ang[i, ..., start : start + sec_size])
            start += sec_size
        angles = jnp.concatenate(parts, axis=-1)
    else:
        angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads: [..., S, 1, d/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg, plan) -> tuple[Params, Specs]:
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d**-0.5
    head_ax = plan.heads_axis(h)
    kv_ax = plan.heads_axis(hkv)
    params = {
        "wq": jax.random.normal(k1, (d, h, dh), dtype) * scale,
        "wk": jax.random.normal(k2, (d, hkv, dh), dtype) * scale,
        "wv": jax.random.normal(k3, (d, hkv, dh), dtype) * scale,
        "wo": jax.random.normal(k4, (h, dh, d), dtype) * (h * dh) ** -0.5,
    }
    specs = {
        "wq": P(plan.fsdp_axis, head_ax, None),
        "wk": P(plan.fsdp_axis, kv_ax, None),
        "wv": P(plan.fsdp_axis, kv_ax, None),
        "wo": P(head_ax, None, plan.fsdp_axis),
    }
    return params, specs


def _decode_attention(
    q: jax.Array,  # [B, C, H, dh]
    k_new: jax.Array,  # [B, C, Hkv, dh]
    v_new: jax.Array,
    cache: dict,
    t: jax.Array,  # first written position (scalar or [B])
    *,
    window: int | None,
    softcap: float | None,
    write_mask: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """Chunk attention against a [B, S, Hkv, dh] cache (flash-decoding
    layout: S shardable; reductions over S lower to local + all-reduce).

    ``C = 1`` is the classic decode step; ``C > 1`` is the chunked-prefill
    continuation (DESIGN.md §9): positions ``t .. t+C-1`` are written into
    the cache and attended causally together with the ``<= t`` prefix.
    ``write_mask`` ([B] bool) suppresses the K/V write for dead batch rows —
    a continuous-batching slot that is empty or still mid-chunked-prefill
    must not have its cache stomped at a stale position."""
    ck, cv = cache["k"], cache["v"]
    b, s, hkv, dh = ck.shape
    c, h = q.shape[1], q.shape[2]
    group = h // hkv
    # Write the new K/V at positions t..t+C-1 (ring-buffer semantics beyond
    # S).  t may be a scalar (lockstep batch) or [B] (continuous batching:
    # every slot at its own position).
    t = jnp.broadcast_to(jnp.asarray(t), (b,))
    pos_c = t[:, None] + jnp.arange(c)  # [B, C] absolute positions
    idx = jnp.mod(pos_c, s)
    bi = jnp.arange(b)[:, None]
    if write_mask is not None:
        # Dead rows scatter out of bounds and are dropped.
        idx = jnp.where(write_mask[:, None], idx, s)
    ck = ck.at[bi, idx].set(k_new.astype(ck.dtype), mode="drop")
    cv = cv.at[bi, idx].set(v_new.astype(cv.dtype), mode="drop")
    out = _attend_cache_view(q, ck, cv, pos_c, window=window, softcap=softcap)
    return out, {"k": ck, "v": cv}


def _attend_cache_view(
    q: jax.Array,  # [B, C, H, dh]
    ck: jax.Array,  # [B, S, Hkv, dh] — dense cache or gathered paged view
    cv: jax.Array,
    pos_c: jax.Array,  # [B, C] absolute position of each chunk row
    *,
    window: int | None,
    softcap: float | None,
) -> jax.Array:
    """Full-softmax chunk attention against a contiguous K/V view.

    Shared by the dense ring-buffer path and the paged path's gathered view:
    positions past ``pos_c`` (and, for paged, anything reachable through an
    unallocated table entry) are forced to -1e30 before the softmax, so the
    two paths run the identical graph on identical post-mask values — this
    is what makes paged-vs-dense generation bit-identical off-TPU."""
    b, s, hkv, dh = ck.shape
    c, h = q.shape[1], q.shape[2]
    group = h // hkv
    scale = dh**-0.5
    # bf16 operands + f32 accumulation: the cache is read in its own dtype
    # (no f32 copy of a multi-GB buffer), scores accumulate in f32.
    qg = (q * scale).reshape(b, c, hkv, group, dh)
    logits = jnp.einsum(
        "bckgd,bskd->bkgcs", qg, ck, preferred_element_type=jnp.float32
    )  # [B, Hkv, group, C, S]
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jnp.arange(s)
    valid = pos[None, None, :] <= pos_c[:, :, None]  # [B, C, S]
    if window is not None:
        valid &= (pos_c[:, :, None] - pos[None, None, :]) < window
    logits = jnp.where(valid[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgcs,bskd->bckgd", probs.astype(cv.dtype), cv,
        preferred_element_type=jnp.float32,
    ).reshape(b, c, h, dh)
    return out.astype(q.dtype)


def _paged_decode_attention(
    q: jax.Array,  # [B, C, H, dh]
    k_new: jax.Array,  # [B, C, Hkv, dh]
    v_new: jax.Array,
    cache: dict,  # {"k","v": [N, page, Hkv, dh]} page pool shared by slots
    page_table: jax.Array,  # [B, P] i32 page ids, -1 = unallocated
    t: jax.Array,  # first written position (scalar or [B])
    *,
    window: int | None,
    softcap: float | None,
    write_mask: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """Paged-cache counterpart of :func:`_decode_attention` (DESIGN.md §10).

    K/V for positions ``t .. t+C-1`` are scattered into the page pool at
    ``(table[b, pos // page], pos % page)``; rows whose table entry is -1
    (or whose ``write_mask`` is off) scatter out of bounds and drop — the
    host-side allocator is responsible for mapping every live position to a
    private (CoW-forked) page before the step runs.  Reads go through the
    pallas kernel on TPU and through a gathered contiguous view into the
    shared full-softmax math elsewhere, which keeps off-TPU generation
    bit-identical to the dense ring buffer."""
    ck, cv = cache["k"], cache["v"]
    n_pages, page, hkv, dh = ck.shape
    b, c = q.shape[0], q.shape[1]
    maxp = page_table.shape[1]
    t = jnp.broadcast_to(jnp.asarray(t), (b,))
    pos_c = t[:, None] + jnp.arange(c)  # [B, C] absolute positions
    logical = jnp.minimum(pos_c // page, maxp - 1)
    pid = jnp.take_along_axis(page_table, logical, axis=1)  # [B, C]
    off = jnp.mod(pos_c, page)
    if write_mask is not None:
        pid = jnp.where(write_mask[:, None], pid, -1)
    pid = jnp.where(pid >= 0, pid, n_pages)  # unallocated/dead -> dropped
    ck = ck.at[pid, off].set(k_new.astype(ck.dtype), mode="drop")
    cv = cv.at[pid, off].set(v_new.astype(cv.dtype), mode="drop")
    new_cache = {"k": ck, "v": cv}
    if ops.on_tpu():
        out = ops.paged_flash_decode(
            q, ck, cv, page_table, t, window=window, softcap=softcap
        )
        return out.astype(q.dtype), new_cache
    safe = jnp.maximum(page_table, 0)
    view_k = jnp.take(ck, safe, axis=0).reshape(b, maxp * page, hkv, dh)
    view_v = jnp.take(cv, safe, axis=0).reshape(b, maxp * page, hkv, dh)
    out = _attend_cache_view(
        q, view_k, view_v, pos_c, window=window, softcap=softcap
    )
    return out, new_cache


def attention_apply(
    params: Params,
    x: jax.Array,  # [B, S, D]
    cfg,
    *,
    kind: str = "global",  # "global" | "local"
    mode: str = "train",  # "train" | "prefill" | "decode"
    positions: jax.Array | None = None,
    cache: dict | None = None,
    t: jax.Array | None = None,
    attn_backend: str = "auto",
    plan=None,
    mesh=None,
    write_mask: jax.Array | None = None,
    page_table: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    import math

    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import constrain

    b, s, d = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    window = cfg.window_size if kind == "local" else None
    if positions is None:
        positions = jnp.arange(s)[None, :] if mode != "decode" else t
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if mode == "decode":
        # Per-slot positions: t scalar (lockstep) or [B] (continuous
        # batching); s > 1 writes the chunked-prefill positions t..t+s-1.
        pos = jnp.broadcast_to(jnp.asarray(t), (b,))[:, None] + jnp.arange(s)
        if cfg.mrope_sections is not None:
            pos = jnp.stack([pos] * 3)
        q = apply_rope(q, pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, pos, cfg.rope_theta, cfg.mrope_sections)
        if page_table is not None:
            out, cache = _paged_decode_attention(
                q, k, v, cache, page_table, t, window=window,
                softcap=cfg.logit_softcap, write_mask=write_mask,
            )
        else:
            out, cache = _decode_attention(
                q, k, v, cache, t, window=window, softcap=cfg.logit_softcap,
                write_mask=write_mask,
            )
    else:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        if mode == "prefill":
            cache = {"k": k, "v": v}  # [B, S, Hkv, dh] seq-shardable layout
        # GQA / TP alignment: when q heads shard over the model axis but the
        # kv-head count does not divide it, replicate kv heads up to the axis
        # size.  Each device then holds exactly the kv head its q heads read
        # (a local slice of a replicated tensor — no collective), instead of
        # XLA inserting a resharding gather around the grouped einsum.
        if plan is not None and plan.heads_axis(h) and not plan.heads_axis(hkv):
            rep = plan.model_size // math.gcd(hkv, plan.model_size)
            if rep > 1:
                k = jnp.repeat(k, rep, axis=2)
                v = jnp.repeat(v, rep, axis=2)
        qt = q.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        # SP -> TP reshard: attention runs head-sharded over the model axis
        # (one all-to-all in, one out) — without this the partitioner keeps
        # seq sharding and re-gathers full K/V inside every q-chunk step.
        if plan is not None and mesh is not None and plan.heads_axis(h):
            batch_ok = b % max(plan.data_size, 1) == 0
            hspec = P(
                (plan.batch_axes or None) if batch_ok else None,
                plan.model_axis, None, None,
            )
            qt = constrain(qt, mesh, hspec)
            kt = constrain(kt, mesh, hspec)
            vt = constrain(vt, mesh, hspec)
        out = ops.flash_attention(
            qt, kt, vt,
            causal=True,
            window=window,
            softcap=cfg.logit_softcap,
            backend=attn_backend,
        ).transpose(0, 2, 1, 3)
        if (
            cfg.sp_shardmap
            and plan is not None
            and mesh is not None
            and plan.heads_axis(h)
            and s % plan.model_size == 0
            and b % max(plan.data_size, 1) == 0
        ):
            # Explicit row-parallel o-proj + seq reduce-scatter (§Perf).
            y = oproj_sp(out, params["wo"], plan, mesh)
            return y, cache
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, cache


def init_attention_cache(cfg, batch: int, max_len: int, dtype=None) -> dict:
    dh = cfg.resolved_head_dim
    dt = dtype or jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, dh), dt),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, dh), dt),
    }


def init_paged_attention_cache(
    cfg, num_pages: int, page_size: int, dtype=None
) -> dict:
    """Page-pool K/V cache: ``[N, page, Hkv, dh]`` shared by every slot —
    sequences own pages through a ``[slots, P]`` table, not a batch dim."""
    dh = cfg.resolved_head_dim
    dt = dtype or jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((num_pages, page_size, cfg.num_kv_heads, dh), dt),
        "v": jnp.zeros((num_pages, page_size, cfg.num_kv_heads, dh), dt),
    }


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2) — compressed KV cache
# ---------------------------------------------------------------------------


def init_mla_attention(key, cfg, plan) -> tuple[Params, Specs]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 6)
    qk = m.nope_head_dim + m.rope_head_dim
    head_ax = plan.heads_axis(h)
    params = {
        "wq_a": jax.random.normal(keys[0], (d, m.q_lora_rank), dtype) * d**-0.5,
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": jax.random.normal(keys[1], (m.q_lora_rank, h, qk), dtype)
        * m.q_lora_rank**-0.5,
        "wkv_a": jax.random.normal(
            keys[2], (d, m.kv_lora_rank + m.rope_head_dim), dtype
        )
        * d**-0.5,
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "wk_b": jax.random.normal(keys[3], (m.kv_lora_rank, h, m.nope_head_dim), dtype)
        * m.kv_lora_rank**-0.5,
        "wv_b": jax.random.normal(keys[4], (m.kv_lora_rank, h, m.v_head_dim), dtype)
        * m.kv_lora_rank**-0.5,
        "wo": jax.random.normal(keys[5], (h, m.v_head_dim, d), dtype)
        * (h * m.v_head_dim) ** -0.5,
    }
    specs = {
        "wq_a": P(plan.fsdp_axis, None),
        "q_norm": P(None),
        "wq_b": P(plan.fsdp_axis, head_ax, None),
        "wkv_a": P(plan.fsdp_axis, None),
        "kv_norm": P(None),
        "wk_b": P(plan.fsdp_axis, head_ax, None),
        "wv_b": P(plan.fsdp_axis, head_ax, None),
        "wo": P(head_ax, None, plan.fsdp_axis),
    }
    return params, specs


def mla_attention_apply(
    params: Params,
    x: jax.Array,
    cfg,
    *,
    mode: str = "train",
    positions: jax.Array | None = None,
    cache: dict | None = None,
    t: jax.Array | None = None,
    attn_backend: str = "auto",
    plan=None,
    mesh=None,
    write_mask: jax.Array | None = None,
) -> tuple[jax.Array, dict | None]:
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import constrain
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.num_heads
    if positions is None:
        positions = jnp.arange(s)[None, :]
    cq = rms_norm(x @ params["wq_a"], params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["wq_b"])
    q_nope = q[..., : m.nope_head_dim]
    q_rope = q[..., m.nope_head_dim :]
    ckv_full = x @ params["wkv_a"]  # [B, S, kv_lora + rope]
    ckv = rms_norm(ckv_full[..., : m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = ckv_full[..., m.kv_lora_rank :][:, :, None, :]  # [B,S,1,rope]

    if mode == "decode":
        # s = 1 is the decode step, s > 1 the chunked-prefill continuation
        # writing positions t..t+s-1 (attended causally within the chunk).
        tb = jnp.broadcast_to(jnp.asarray(t), (b,))
        pos = tb[:, None] + jnp.arange(s)  # [B, s]
        q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
        k_rope = apply_rope(k_rope, pos, cfg.rope_theta)
        c_cache, r_cache = cache["ckv"], cache["k_rope"]
        smax = c_cache.shape[1]
        idx = jnp.mod(pos, smax)
        bi = jnp.arange(b)[:, None]
        if write_mask is not None:
            idx = jnp.where(write_mask[:, None], idx, smax)  # drop dead rows
        c_cache = c_cache.at[bi, idx].set(
            ckv.astype(c_cache.dtype), mode="drop"
        )
        r_cache = r_cache.at[bi, idx].set(
            k_rope[:, :, 0, :].astype(r_cache.dtype), mode="drop"
        )
        # Absorbed attention: score = q_nope·(W_uk c) + q_rope·k_rope.
        # Cache stays in its storage dtype; f32 only in the accumulators.
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_b"])  # [B,s,H,r]
        logits = jnp.einsum(
            "bshr,btr->bhst", q_abs, c_cache, preferred_element_type=jnp.float32
        )
        logits += jnp.einsum(
            "bshk,btk->bhst", q_rope, r_cache, preferred_element_type=jnp.float32
        )
        scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
        logits = logits * scale
        valid = jnp.arange(smax)[None, None, :] <= pos[:, :, None]  # [B, s, S]
        logits = jnp.where(valid[:, None, :, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        ov = jnp.einsum(
            "bhst,btr->bshr", probs.astype(c_cache.dtype), c_cache,
            preferred_element_type=jnp.float32,
        )
        out = jnp.einsum(
            "bshr,rhk->bshk", ov.astype(params["wv_b"].dtype), params["wv_b"]
        )
        cache = {"ckv": c_cache, "k_rope": r_cache}
    else:
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
        k_nope = jnp.einsum("bsr,rhk->bshk", ckv, params["wk_b"])
        v = jnp.einsum("bsr,rhk->bshk", ckv, params["wv_b"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.rope_head_dim))], axis=-1
        )
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        # v head dim may differ from qk head dim -> pad v for the kernel.
        qk_dim = qq.shape[-1]
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - m.v_head_dim)))
        qt = qq.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v_pad.transpose(0, 2, 1, 3)
        # SP -> TP reshard (same as attention_apply): without this the
        # q-chunk scan re-gathers full-sequence K every step — the single
        # biggest collective in the deepseek-v2 baseline (§Perf).
        if plan is not None and mesh is not None and plan.heads_axis(h):
            batch_ok = b % max(plan.data_size, 1) == 0
            hspec = P(
                (plan.batch_axes or None) if batch_ok else None,
                plan.model_axis, None, None,
            )
            qt = constrain(qt, mesh, hspec)
            kt = constrain(kt, mesh, hspec)
            vt = constrain(vt, mesh, hspec)
        out = ops.flash_attention(
            qt, kt, vt,
            causal=True,
            backend=attn_backend,
        ).transpose(0, 2, 1, 3)[..., : m.v_head_dim]
        if mode == "prefill":
            cache = {"ckv": ckv, "k_rope": k_rope[:, :, 0, :]}
        out = out.astype(jnp.float32)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])
    return y, cache


def init_mla_cache(cfg, batch: int, max_len: int, dtype=None) -> dict:
    m = cfg.mla
    dt = dtype or jnp.dtype(cfg.dtype)
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((batch, max_len, m.rope_head_dim), dt),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, plan, d_ff: int | None = None) -> tuple[Params, Specs]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    gated = cfg.act in ("silu", "swiglu", "geglu")
    params = {
        "w_in": jax.random.normal(k1, (d, f), dtype) * d**-0.5,
        "w_out": jax.random.normal(k2, (f, d), dtype) * f**-0.5,
    }
    specs = {
        "w_in": P(plan.fsdp_axis, plan.model_axis),
        "w_out": P(plan.model_axis, plan.fsdp_axis),
    }
    if gated:
        params["w_gate"] = jax.random.normal(k3, (d, f), dtype) * d**-0.5
        specs["w_gate"] = P(plan.fsdp_axis, plan.model_axis)
    return params, specs


def mlp_apply(params: Params, x: jax.Array, cfg) -> jax.Array:
    h = x @ params["w_in"]
    if "w_gate" in params:
        act = jax.nn.silu if cfg.act in ("silu", "swiglu") else jax.nn.gelu
        h = act(x @ params["w_gate"]) * h
    else:
        h = jax.nn.gelu(h) if cfg.act == "gelu" else jax.nn.silu(h)
    return h @ params["w_out"]


def mlp_apply_sp(params: Params, x: jax.Array, cfg, plan, mesh) -> jax.Array:
    """Megatron sequence-parallel MLP as an explicit shard_map program.

    x arrives seq-sharded over the model axis; the program is
    all-gather(seq) -> column-parallel w_in/w_gate -> row-parallel w_out ->
    reduce-scatter(seq).  Guarantees the TP combine is a reduce-scatter (half
    the wire bytes of the all-reduce the auto-partitioner emits) regardless
    of backend heuristics.  §Perf beyond-paper optimization.
    """
    from jax.sharding import PartitionSpec as P

    gated = "w_gate" in params
    actfn = (
        (jax.nn.silu if cfg.act in ("silu", "swiglu") else jax.nn.gelu)
        if gated
        else (jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu)
    )

    def local(xl, w_in, w_gate, w_out):
        xg = jax.lax.all_gather(xl, "model", axis=1, tiled=True)  # [B, S, D]
        h = xg @ w_in  # [B, S, F/m]
        if gated:
            h = actfn(xg @ w_gate) * h
        else:
            h = actfn(h)
        y_part = h @ w_out  # [B, S, D] partial over the model axis
        return jax.lax.psum_scatter(y_part, "model", scatter_dimension=1, tiled=True)

    w_gate = params.get("w_gate", params["w_in"])
    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(plan.batch_axes or None, plan.model_axis, None),
            P(None, plan.model_axis),
            P(None, plan.model_axis),
            P(plan.model_axis, None),
        ),
        out_specs=P(plan.batch_axes or None, plan.model_axis, None),
        check_vma=False,
    )
    return fn(x, params["w_in"], w_gate, params["w_out"])


def can_use_sp_mlp(params, x, cfg, plan, mesh, mode) -> bool:
    if mesh is None or plan is None or plan.model_axis is None or mode == "decode":
        return False
    b, s, _ = x.shape
    f = params["w_in"].shape[1]
    return (
        s % plan.model_size == 0
        and f % plan.model_size == 0
        and b % max(plan.data_size, 1) == 0
    )


def oproj_sp(out: jax.Array, wo: jax.Array, plan, mesh) -> jax.Array:
    """Row-parallel attention output projection with an explicit seq
    reduce-scatter.  out [B, S, H, dh] head-sharded -> y [B, S, D]
    seq-sharded."""
    from jax.sharding import PartitionSpec as P

    def local(o, w):
        y_part = jnp.einsum("bshk,hkd->bsd", o, w)
        return jax.lax.psum_scatter(y_part, "model", scatter_dimension=1, tiled=True)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(plan.batch_axes or None, None, plan.model_axis, None),
            P(plan.model_axis, None, None),
        ),
        out_specs=P(plan.batch_axes or None, plan.model_axis, None),
        check_vma=False,
    )
    return fn(out, wo)
