"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

A gated diagonal linear recurrence:

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_i x_t + b_i)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill evaluate the whole sequence with ``lax.associative_scan``
(the recurrence is linear-diagonal, so it parallelizes); decode is the
single-step update on a ``[B, W]`` state — constant memory, hence this
family runs the ``long_500k`` cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["init_rglru", "rglru_apply", "init_rglru_cache"]

_C = 8.0


def init_rglru(key, cfg, plan):
    d = cfg.d_model
    w = d  # lru width = d_model (RecurrentGemma-9b uses width == d_model)
    dtype = jnp.dtype(cfg.dtype)
    k = jax.random.split(key, 6)
    wax = plan.dim_axis(w)
    params = {
        "w_y": jax.random.normal(k[0], (d, w), dtype) * d**-0.5,
        "w_x": jax.random.normal(k[1], (d, w), dtype) * d**-0.5,
        "conv": jax.random.normal(k[2], (4, w), dtype) * 0.1,
        "w_a": jax.random.normal(k[3], (w, w), dtype) * w**-0.5,
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": jax.random.normal(k[4], (w, w), dtype) * w**-0.5,
        "b_i": jnp.zeros((w,), jnp.float32),
        "lam": jnp.full((w,), 2.0, jnp.float32),  # softplus(2) ~ healthy decay
        "w_out": jax.random.normal(k[5], (w, d), dtype) * w**-0.5,
    }
    specs = {
        "w_y": P(plan.fsdp_axis, wax),
        "w_x": P(plan.fsdp_axis, wax),
        "conv": P(None, wax),
        "w_a": P(plan.fsdp_axis, wax),
        "b_a": P(wax),
        "w_i": P(plan.fsdp_axis, wax),
        "b_i": P(wax),
        "lam": P(wax),
        "w_out": P(wax, plan.fsdp_axis),
    }
    return params, specs


def _conv1d(x, w, state):
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width))
    return y, xp[:, -(width - 1) :, :]


def _lru_gates(params, xb):
    r = jax.nn.sigmoid(xb.astype(jnp.float32) @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(xb.astype(jnp.float32) @ params["w_i"].astype(jnp.float32) + params["b_i"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r  # [B,S,W]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a**2, 1e-12)) * (i * xb.astype(jnp.float32))
    return a, gated


def rglru_apply(params, x, cfg, *, mode="train", cache=None, t=None):
    b, s, d = x.shape
    y_branch = jax.nn.gelu(x @ params["w_y"])
    xb = x @ params["w_x"]
    conv_state = cache.get("conv") if cache else None

    if mode == "decode":
        xb, new_conv = _conv1d(xb, params["conv"], conv_state)
        a, gated = _lru_gates(params, xb)
        h_prev = cache["state"]  # [B, W]
        h = a[:, 0] * h_prev + gated[:, 0]
        out = h[:, None, :]
        cache = {"state": h, "conv": new_conv}
    else:
        xb, new_conv = _conv1d(xb, params["conv"], None)
        a, gated = _lru_gates(params, xb)

        def compose(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        coeffs, h_all = jax.lax.associative_scan(compose, (a, gated), axis=1)
        out = h_all
        if mode == "prefill":
            cache = {"state": h_all[:, -1], "conv": new_conv}
    out = out.astype(x.dtype) * y_branch
    return out @ params["w_out"], cache


def init_rglru_cache(cfg, batch: int, dtype=None):
    w = cfg.d_model
    return {
        "state": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, 3, w), jnp.float32),
    }
