"""Model assembly: embeddings -> scanned block stack -> (chunked) LM head.

One scan step = one repetition of ``cfg.block_pattern`` (e.g. gemma2's
[local, global] pair, recurrentgemma's [rglru, rglru, local] triple), with
per-kind params stacked over repetitions — the HLO stays one pattern body
regardless of depth, which keeps 88-layer dry-runs compilable in seconds.

Modes:
  * ``train``   — full-sequence forward, returns chunked-CE-ready features;
  * ``prefill`` — forward + emits per-layer caches (KV / SSM / LRU / conv);
  * ``decode``  — one token against the caches (flash-decoding KV layout).

Whisper (family "audio") adds an encoder scan over stub frame embeddings and
cross-attention in every decoder block.  VLM (qwen2-vl) splices stub patch
embeddings into the first positions and uses M-RoPE positions.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.parallel.sharding import ShardingPlan, constrain, virtual_experts

__all__ = ["init_model", "model_apply", "init_caches", "chunked_cross_entropy"]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _block_init(key, kind: str, cfg, plan):
    """(params, specs) for one block of the given kind."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params: dict = {}
    specs: dict = {}
    params["norm1"], specs["norm1"] = L.init_rms_norm(cfg.d_model, jnp.dtype(cfg.dtype))
    if kind in ("global", "local"):
        if cfg.mla is not None:
            params["attn"], specs["attn"] = L.init_mla_attention(k1, cfg, plan)
        else:
            params["attn"], specs["attn"] = L.init_attention(k1, cfg, plan)
        if cfg.family == "audio":  # decoder cross-attention
            params["xnorm"], specs["xnorm"] = L.init_rms_norm(
                cfg.d_model, jnp.dtype(cfg.dtype)
            )
            params["xattn"], specs["xattn"] = L.init_attention(k4, cfg, plan)
        params["norm2"], specs["norm2"] = L.init_rms_norm(
            cfg.d_model, jnp.dtype(cfg.dtype)
        )
        if cfg.is_moe:
            params["moe"], specs["moe"] = moe_mod.init_moe(k2, cfg, plan)
        else:
            params["mlp"], specs["mlp"] = L.init_mlp(k2, cfg, plan)
    elif kind == "rglru":
        params["rglru"], specs["rglru"] = rglru_mod.init_rglru(k1, cfg, plan)
        params["norm2"], specs["norm2"] = L.init_rms_norm(
            cfg.d_model, jnp.dtype(cfg.dtype)
        )
        params["mlp"], specs["mlp"] = L.init_mlp(k2, cfg, plan)
    elif kind == "ssm":
        params["ssm"], specs["ssm"] = ssm_mod.init_ssm(k1, cfg, plan)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return params, specs


def init_model(key, cfg, plan: ShardingPlan):
    cfg.validate()
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    reps = cfg.pattern_repeats

    params: dict = {
        "embed": jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model), dtype)
        * cfg.d_model**-0.5
    }
    specs: dict = {"embed": P(plan.dim_axis(cfg.vocab_size), plan.fsdp_axis)}

    blocks = {}
    block_specs = {}
    for i, kind in enumerate(cfg.block_pattern):
        kname = f"{i}_{kind}"
        bkeys = jax.random.split(keys[1 + (i % 5)], reps)
        stacked = jax.vmap(lambda k: _block_init(k, kind, cfg, plan)[0])(bkeys)
        _, spec1 = _block_init(bkeys[0], kind, cfg, plan)
        blocks[kname] = stacked
        block_specs[kname] = jax.tree.map(
            lambda s: P(None, *s), spec1, is_leaf=lambda s: isinstance(s, P)
        )
    params["blocks"] = blocks
    specs["blocks"] = block_specs
    if cfg.tail_pattern:
        tail, tail_specs = {}, {}
        tkeys = jax.random.split(keys[5], len(cfg.tail_pattern))
        for i, kind in enumerate(cfg.tail_pattern):
            tail[f"{i}_{kind}"], tail_specs[f"{i}_{kind}"] = _block_init(
                tkeys[i], kind, cfg, plan
            )
        params["tail"] = tail
        specs["tail"] = tail_specs
    params["final_norm"], specs["final_norm"] = L.init_rms_norm(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[6], (cfg.d_model, cfg.vocab_size), dtype)
            * cfg.d_model**-0.5
        )
        specs["lm_head"] = P(plan.fsdp_axis, plan.dim_axis(cfg.vocab_size))

    if cfg.encoder_layers:
        ekeys = jax.random.split(keys[7], cfg.encoder_layers)
        enc = jax.vmap(lambda k: _enc_block_init(k, cfg, plan)[0])(ekeys)
        _, enc_spec1 = _enc_block_init(ekeys[0], cfg, plan)
        params["encoder"] = {
            "blocks": enc,
            "final_norm": L.init_rms_norm(cfg.d_model, dtype)[0],
        }
        specs["encoder"] = {
            "blocks": jax.tree.map(
                lambda s: P(None, *s), enc_spec1, is_leaf=lambda s: isinstance(s, P)
            ),
            "final_norm": P(None),
        }
    return params, specs


def _enc_block_init(key, cfg, plan):
    k1, k2 = jax.random.split(key)
    params, specs = {}, {}
    params["norm1"], specs["norm1"] = L.init_rms_norm(cfg.d_model, jnp.dtype(cfg.dtype))
    params["attn"], specs["attn"] = L.init_attention(k1, cfg, plan)
    params["norm2"], specs["norm2"] = L.init_rms_norm(cfg.d_model, jnp.dtype(cfg.dtype))
    params["mlp"], specs["mlp"] = L.init_mlp(k2, cfg, plan)
    return params, specs


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def init_caches(cfg, batch: int, max_len: int, plan: ShardingPlan | None = None):
    """Stacked per-pattern-position caches: leaves [repeats, ...]."""
    reps = cfg.pattern_repeats

    def one(kind):
        if kind in ("global", "local"):
            if cfg.mla is not None:
                c = L.init_mla_cache(cfg, batch, max_len)
            else:
                c = L.init_attention_cache(cfg, batch, max_len)
            if cfg.family == "audio":
                c["xk"] = jnp.zeros(
                    (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.resolved_head_dim),
                    jnp.dtype(cfg.dtype),
                )
                # Distinct buffer, not an alias: serving scatters caches
                # through donated jit calls, and XLA rejects a pytree that
                # donates the same buffer twice.
                c["xv"] = jnp.zeros_like(c["xk"])
            return c
        if kind == "rglru":
            return rglru_mod.init_rglru_cache(cfg, batch)
        if kind == "ssm":
            return ssm_mod.init_ssm_cache(cfg, batch)
        raise ValueError(kind)

    caches = {}
    for i, kind in enumerate(cfg.block_pattern):
        c = one(kind)
        caches[f"{i}_{kind}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (reps, *x.shape)), c
        )
    if cfg.tail_pattern:
        caches["__tail__"] = {
            f"{i}_{kind}": one(kind) for i, kind in enumerate(cfg.tail_pattern)
        }
    return caches


def paged_supported(cfg) -> bool:
    """True when every decode-time cache is a plain GQA attention K/V pair —
    the only layout the page pool holds.  MLA (compressed kv), audio
    cross-attention, and rglru/ssm state caches stay on the dense path."""
    return (
        all(k in ("global", "local") for k in cfg.block_pattern)
        and not cfg.tail_pattern
        and cfg.mla is None
        and cfg.family != "audio"
    )


def init_paged_caches(cfg, num_pages: int, page_size: int):
    """Paged decode caches: one ``[repeats, N, page, Hkv, dh]`` K/V page pool
    per pattern position, shared by every serving slot.  Sequences own pages
    through a single ``[slots, P]`` page table (the same logical position
    maps to the same page id in every layer's pool), passed to
    :func:`model_apply` as a traced argument — shapes stay static under jit
    while the table contents change every tick."""
    if not paged_supported(cfg):
        raise ValueError(
            f"paged KV cache requires an attention-only pattern, got "
            f"{cfg.block_pattern} / tail {cfg.tail_pattern} / mla={cfg.mla}"
        )
    reps = cfg.pattern_repeats
    caches = {}
    for i, kind in enumerate(cfg.block_pattern):
        c = L.init_paged_attention_cache(cfg, num_pages, page_size)
        caches[f"{i}_{kind}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (reps, *x.shape)), c
        )
    return caches


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _apply_block(
    kind, p, x, cfg, plan, mesh, mode, cache, t, enc_out, expert_perm, positions,
    act_spec=None, wire_perm=None, gate_weights=None, page_table=None,
):
    new_cache = dict(cache) if cache is not None else ({} if mode != "train" else None)
    stats = None
    # Decode-time live mask: dead continuous-batching slots must not write
    # K/V at their stale positions (a mid-chunked-prefill slot's cache would
    # be stomped).  Derived from the same per-token weights the MoE gate
    # telemetry uses (DESIGN.md §9).
    write_mask = None
    if mode == "decode" and gate_weights is not None:
        write_mask = gate_weights[:, 0] > 0

    def seq_shard(y):
        # Constrain each sublayer output to the sequence-parallel spec BEFORE
        # the residual add: TP partial sums lower to reduce-scatters instead
        # of full-sequence all-reduces.
        return constrain(y, mesh, act_spec) if act_spec is not None else y

    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local"):
        attn_cache = (
            {k: v for k, v in cache.items() if k not in ("xk", "xv")} if cache else None
        )
        if cfg.mla is not None:
            y, ac = L.mla_attention_apply(
                p["attn"], h, cfg, mode=mode, cache=attn_cache, t=t,
                positions=positions, plan=plan, mesh=mesh,
                write_mask=write_mask,
            )
        else:
            y, ac = L.attention_apply(
                p["attn"], h, cfg, kind=kind, mode=mode, cache=attn_cache, t=t,
                positions=positions, plan=plan, mesh=mesh,
                write_mask=write_mask, page_table=page_table,
            )
        x = x + seq_shard(y)
        if ac is not None:
            new_cache.update(ac)
        if cfg.family == "audio":
            hx = L.rms_norm(x, p["xnorm"], cfg.norm_eps)
            y, xc = _cross_attention(p["xattn"], hx, cfg, mode, cache, enc_out)
            x = x + seq_shard(y)
            if xc is not None:
                new_cache.update(xc)
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.is_moe:
            y, stats = moe_mod.moe_apply(
                p["moe"], h2, cfg, plan, mesh=mesh, expert_perm=expert_perm,
                wire_perm=wire_perm, mode=mode, gate_weights=gate_weights,
            )
        elif cfg.sp_shardmap and L.can_use_sp_mlp(p["mlp"], h2, cfg, plan, mesh, mode):
            y = L.mlp_apply_sp(p["mlp"], h2, cfg, plan, mesh)
        else:
            y = L.mlp_apply(p["mlp"], h2, cfg)
        x = x + seq_shard(y)
    elif kind == "rglru":
        y, rc = rglru_mod.rglru_apply(p["rglru"], h, cfg, mode=mode, cache=cache, t=t)
        x = x + seq_shard(y)
        if rc is not None:
            new_cache = rc
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if cfg.sp_shardmap and L.can_use_sp_mlp(p["mlp"], h2, cfg, plan, mesh, mode):
            x = x + seq_shard(L.mlp_apply_sp(p["mlp"], h2, cfg, plan, mesh))
        else:
            x = x + seq_shard(L.mlp_apply(p["mlp"], h2, cfg))
    elif kind == "ssm":
        y, sc = ssm_mod.ssm_apply(p["ssm"], h, cfg, mode=mode, cache=cache, t=t)
        x = x + seq_shard(y)
        if sc is not None:
            new_cache = sc
    return x, new_cache, stats


def _cross_attention(p, x, cfg, mode, cache, enc_out):
    """Non-causal attention over encoder output (whisper decoder)."""
    b = x.shape[0]
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if mode == "decode":
        k, v = cache["xk"], cache["xv"]
        new_cache = None
    else:
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
        new_cache = {"xk": k, "xv": v} if mode == "prefill" else None
    group = h // hkv
    scale = dh**-0.5
    qg = (q * scale).reshape(b, -1, hkv, group, dh)
    logits = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgst,btkd->bskgd", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    out = out.reshape(b, -1, h, dh).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def _encoder_apply(params, frames, cfg, plan):
    """Whisper encoder over stub frame embeddings (bidirectional)."""
    x = frames
    pos = jnp.arange(x.shape[1])[None, :]

    def body(carry, p):
        x = carry
        h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wv"])
        from repro.kernels import ops

        o = ops.flash_attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=False,
        ).transpose(0, 2, 1, 3)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        h2 = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp_apply(p["mlp"], h2, cfg)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


@dataclasses.dataclass
class ForwardAux:
    moe_stats: object | None  # stacked MoEStats or None
    balance_loss: jax.Array
    z_loss: jax.Array


jax.tree_util.register_dataclass(
    ForwardAux, data_fields=["moe_stats", "balance_loss", "z_loss"], meta_fields=[]
)


_FFN_PREFETCH_DIMS = {
    # weight leaf -> (fsdp-sharded dim, model-sharded dim) per FFN kind,
    # matching the init specs in layers.init_mlp / moe.init_moe.
    "mlp": {"w_in": (0, 1), "w_gate": (0, 1), "w_out": (1, 0)},
    "moe": {"w_in": (1, 0), "w_gate": (1, 0), "w_out": (2, 0)},
}


def model_apply(
    params,
    batch: dict,
    cfg,
    plan: ShardingPlan,
    *,
    mesh=None,
    mode: str = "train",
    caches=None,
    t=None,
    expert_perm=None,
    wire_perm=None,
    gate_weights=None,
    page_table=None,
):
    """Run the model.

    ``batch``: tokens [B,S] (+ optional "frames" [B,Se,D] for audio,
    "patches" [B,Np,D] for vlm, "positions" for M-RoPE).
    ``expert_perm``: [repeats, E_virtual] per-layer expert->slot maps;
    ``wire_perm``: optional [repeats, P] per-layer device maps for plans the
    control plane installed as wire re-addresses instead of weight gathers.
    ``gate_weights``: optional [B, S] per-token weight for the exported MoE
    gate-load telemetry (the serving engine's live-slot mask, DESIGN.md §9).
    ``page_table``: optional [B, P] i32 page ids (-1 = unallocated) switching
    decode-mode attention onto the paged KV pool from
    :func:`init_paged_caches` (DESIGN.md §10).
    Returns (features [B,S,D], aux, new_caches).  Use
    :func:`chunked_cross_entropy` / :func:`logits` on the features.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = x * (cfg.d_model**0.5)
    if cfg.vision_patches and "patches" in batch and mode != "decode":
        np_ = batch["patches"].shape[1]
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x[:, np_:]], axis=1)
    positions = batch.get("positions")

    enc_out = None
    if cfg.encoder_layers and "frames" in batch:
        enc_out = _encoder_apply(params["encoder"], batch["frames"], cfg, plan)

    reps = cfg.pattern_repeats
    pattern = cfg.block_pattern
    names = [f"{i}_{k}" for i, k in enumerate(pattern)]
    perm_stack = expert_perm  # [reps, Ev] or None

    # Sequence-parallel residual stream: keep the scan carry sharded
    # (batch over DP axes, seq over the model axis) so TP partial sums lower
    # to reduce-scatters instead of full all-reduces.
    from jax.sharding import PartitionSpec as _P

    seq_shardable = mode != "decode" and s % max(plan.model_size, 1) == 0
    batch_ok = b % max(plan.data_size, 1) == 0
    if mode == "decode":
        # Weight-stationary decode: residual [B, 1, D] keeps D sharded over
        # the FSDP axis so projections contract against *local* weight shards
        # (psum of tiny activations) instead of all-gathering multi-GB
        # weights every token; batch stays replicated (it is tiny), the KV
        # cache carries the batch x seq sharding.
        d_ok = cfg.d_model % max(plan.data_size, 1) == 0
        _act_spec = _P(None, None, plan.fsdp_axis if d_ok else None)
    else:
        _act_spec = _P(
            (plan.batch_axes or None) if batch_ok else None,
            plan.model_axis if seq_shardable else None,
            None,
        )

    scan_caches = (
        {k: v for k, v in caches.items() if k != "__tail__"} if caches else None
    )

    # --- FSDP weight prefetch (DESIGN.md §8): gather block l+1's FFN weights
    # over the fsdp axis with the explicit AllGather ring while block l
    # computes.  The gathered tree rides the scan carry (double buffer); the
    # gather for the NEXT step is issued at the top of the body, before this
    # step's compute, so its ring hops are independent of — and overlap —
    # the current block's FFN.
    from repro.core import overlap as overlap_mod

    ffn_kinds = {}
    if (
        cfg.fsdp_prefetch and mesh is not None and plan.fsdp_axis is not None
        and mode == "train"
    ):
        for name in names:
            bp = params["blocks"][name]
            if "moe" in bp:
                ffn_kinds[name] = "moe"
            elif "mlp" in bp:
                ffn_kinds[name] = "mlp"
    prefetch = bool(ffn_kinds)

    def gather_ffn_group(li):
        out = {}
        for name, fkind in ffn_kinds.items():
            sub = params["blocks"][name][fkind]
            got = {}
            for wname, (fdim, mdim) in _FFN_PREFETCH_DIMS[fkind].items():
                if wname not in sub:
                    continue
                leaf = jax.lax.dynamic_index_in_dim(
                    sub[wname], li, 0, keepdims=False
                )
                got[wname] = overlap_mod.ring_gather_leaf(
                    leaf, mesh, plan.fsdp_axis, fdim, plan.model_axis, mdim
                )
            out[name] = got
        return out

    def group_body(carry, xs):
        if prefetch:
            x, full_caches, li, gathered = carry
        else:
            x, full_caches, li = carry
            gathered = None
        if wire_perm is not None:
            group_params, perm, wire = xs
        else:
            group_params, perm = xs
            wire = None
        if prefetch:
            # Issue the NEXT block group's weight gather first — it depends
            # only on li, so its ring hops overlap this group's compute.
            nxt_gathered = gather_ffn_group(jnp.minimum(li + 1, reps - 1))
        new_caches = {} if mode != "train" else None
        stats_list = []
        for i, kind in enumerate(pattern):
            # Caches live in the carry (not xs/ys): dynamic index in/out lets
            # XLA alias the stacked buffers in place instead of keeping a
            # second multi-GB copy across the while loop.
            cache_i = None
            if full_caches is not None:
                cache_i = jax.tree.map(
                    lambda c: jax.lax.dynamic_index_in_dim(c, li, 0, keepdims=False),
                    full_caches[names[i]],
                )
            gp = group_params[names[i]]
            if gathered is not None and names[i] in ffn_kinds:
                fkind = ffn_kinds[names[i]]
                gp = dict(gp)
                gp[fkind] = {**gp[fkind], **gathered[names[i]]}
            x, nc, st = _apply_block(
                kind, gp, x, cfg, plan, mesh, mode, cache_i, t,
                enc_out, perm, positions, act_spec=_act_spec, wire_perm=wire,
                gate_weights=gate_weights, page_table=page_table,
            )
            x = constrain(x, mesh, _act_spec)
            if new_caches is not None:
                new_caches[names[i]] = nc if nc is not None else cache_i
            if st is not None:
                stats_list.append(st)
        if full_caches is not None and new_caches is not None:
            full_caches = {
                k: jax.tree.map(
                    lambda full, nc: jax.lax.dynamic_update_index_in_dim(
                        full, nc.astype(full.dtype), li, 0
                    ),
                    full_caches[k],
                    new_caches[k],
                )
                for k in full_caches
            }
        elif new_caches is not None:
            # prefill: build stacked caches up from per-group outputs.
            pass
        bal = (
            sum(s.balance_loss for s in stats_list) / max(len(stats_list), 1)
            if stats_list
            else jnp.zeros((), jnp.float32)
        )
        zl = (
            sum(s.z_loss for s in stats_list) / max(len(stats_list), 1)
            if stats_list
            else jnp.zeros((), jnp.float32)
        )
        load = stats_list[0].expert_load if stats_list else jnp.zeros((1,), jnp.float32)
        ys = (new_caches if full_caches is None else None, bal, zl, load)
        if prefetch:
            return (x, full_caches, li + 1, nxt_gathered), ys
        return (x, full_caches, li + 1), ys

    body = group_body
    if cfg.remat == "full" and mode == "train":
        body = jax.checkpoint(group_body)
    elif cfg.remat == "dots" and mode == "train":
        body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )

    if perm_stack is None:
        ev, _ = (
            virtual_experts(cfg.moe.num_experts, plan.model_size)
            if cfg.is_moe
            else (1, 1)
        )
        perm_stack = jnp.broadcast_to(jnp.arange(ev, dtype=jnp.int32), (reps, ev))

    xs = (
        (params["blocks"], perm_stack)
        if wire_perm is None
        else (params["blocks"], perm_stack, wire_perm)
    )
    init_carry = (x, scan_caches, jnp.zeros((), jnp.int32))
    if prefetch:
        init_carry = (*init_carry, gather_ffn_group(0))
    carry_out, (stacked_caches, bal, zl, loads) = jax.lax.scan(
        body, init_carry, xs
    )
    x, carried_caches = carry_out[0], carry_out[1]
    new_caches = carried_caches if carried_caches is not None else stacked_caches

    # Non-repeating tail blocks (e.g. recurrentgemma's final 2 RG-LRU layers).
    if cfg.tail_pattern:
        tail_caches = caches.get("__tail__") if caches else None
        new_tail = {} if mode != "train" else None
        for i, kind in enumerate(cfg.tail_pattern):
            name = f"{i}_{kind}"
            cache_i = tail_caches.get(name) if tail_caches else None
            x, nc, _ = _apply_block(
                kind, params["tail"][name], x, cfg, plan, mesh, mode, cache_i, t,
                enc_out, perm_stack[0] if perm_stack is not None else None, positions,
                act_spec=_act_spec,
                wire_perm=wire_perm[0] if wire_perm is not None else None,
                gate_weights=gate_weights,
            )
            if new_tail is not None:
                new_tail[name] = nc if nc is not None else cache_i
        if new_caches is not None and new_tail is not None:
            new_caches = dict(new_caches)
            new_caches["__tail__"] = new_tail

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    aux = ForwardAux(
        moe_stats=loads if cfg.is_moe else None,
        balance_loss=jnp.mean(bal),
        z_loss=jnp.mean(zl),
    )
    return x, aux, new_caches


def cache_specs(cfg, plan):
    """PartitionSpec tree matching :func:`init_caches` (flash-decoding
    layout: attention caches shard their sequence axis over ``model``)."""
    batch = plan.batch_axes or None
    m = plan.model_axis

    def attn():
        c = {
            "k": P(None, batch, m, None, None),
            "v": P(None, batch, m, None, None),
        }
        if cfg.mla is not None:
            c = {
                "ckv": P(None, batch, m, None),
                "k_rope": P(None, batch, m, None),
            }
        if cfg.family == "audio":
            c["xk"] = P(None, batch, None, None, None)
            c["xv"] = P(None, batch, None, None, None)
        return c

    def one(kind):
        if kind in ("global", "local"):
            return attn()
        if kind == "rglru":
            w = cfg.d_model
            return {
                "state": P(None, batch, plan.dim_axis(w)),
                "conv": P(None, batch, None, plan.dim_axis(w)),
            }
        if kind == "ssm":
            inner = cfg.ssm.expand * cfg.d_model
            heads = inner // cfg.ssm.head_dim
            return {
                "state": P(None, batch, plan.heads_axis(heads), None, None),
                "conv": P(None, batch, None, plan.dim_axis(inner)),
            }
        raise ValueError(kind)

    specs = {f"{i}_{k}": one(k) for i, k in enumerate(cfg.block_pattern)}
    if cfg.tail_pattern:
        def drop_lead(spec_tree):
            return jax.tree.map(
                lambda s: P(*s[1:]), spec_tree, is_leaf=lambda s: isinstance(s, P)
            )

        specs["__tail__"] = {
            f"{i}_{k}": drop_lead(one(k))
            for i, k in enumerate(cfg.tail_pattern)
        }
    return specs


_SEQ_CACHE_KEYS = ("k", "v", "ckv", "k_rope")


def pad_caches(caches, target_len: int):
    """Grow the sequence axis of attention caches to ``target_len`` (zeros).

    Decode writes at ``t mod cache_len`` (ring/streaming eviction at
    capacity); padding after prefill gives true append semantics while the
    cache still has headroom.
    """

    def pad(path, x):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key in _SEQ_CACHE_KEYS and x.ndim >= 3:
            cur = x.shape[2]
            if cur < target_len:
                pad_width = [(0, 0)] * x.ndim
                pad_width[2] = (0, target_len - cur)
                return jnp.pad(x, pad_width)
        return x

    return jax.tree_util.tree_map_with_path(pad, caches)


def logits_from_features(params, x, cfg):
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        out = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    if cfg.final_softcap is not None:
        out = cfg.final_softcap * jnp.tanh(out / cfg.final_softcap)
    return out


def chunked_cross_entropy(
    params, features, labels, cfg, *, num_chunks: int = 8
) -> jax.Array:
    """Mean CE computed in sequence chunks so [B,S,V] logits never fully
    materialize (vocab-sharded logsumexp lowers to local + all-reduce)."""
    b, s, d = features.shape
    while s % num_chunks:
        num_chunks -= 1
    fc = features.reshape(b, num_chunks, s // num_chunks, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, num_chunks, s // num_chunks).transpose(1, 0, 2)

    # checkpoint: recompute each chunk's logits in backward instead of saving
    # [B, S, V] f32 across the scan (13+ GB/device for 250k vocabs).
    @jax.checkpoint
    def body(acc, xs):
        f, l = xs
        lg = logits_from_features(params, f, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, l[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (fc, lc))
    return total / (b * s)
