"""Mixture-of-Experts layer with two dispatch backends.

``einsum``  — GShard-style dense dispatch/combine einsums.  Fully
  auto-shardable under pjit (the expert dim rides the ``model`` axis and XLA
  inserts the all-to-alls): this is the *paper-faithful baseline* a static
  fabric serves.

``mixnet``  — the paper's data plane (§5.3) as an explicit ``shard_map``
  program over the ``model`` axis: tokens are sorted into per-destination
  send buffers, exchanged with the **hierarchical delegation all-to-all**
  (:func:`repro.core.collectives.mixnet_all_to_all`), computed with the
  grouped Pallas GEMM, and returned the same way.  EP traffic never leaves
  the ``model`` axis — the regional locality the measurement study (§3)
  found.  Runtime expert re-placement (the OCS-reconfiguration analogue) is
  realized by permuting expert->slot assignments *per layer*: the control
  plane (:mod:`repro.core.controlplane`) plans one permutation per MoE
  layer, the trainer gathers that layer's stacked expert weights into their
  new slots (:func:`repro.train.trainer.permute_expert_weights`), and the
  transformer scan feeds this module the matching row of the ``[repeats,
  E_virtual]`` ``expert_perm`` stack so the router addresses the new slots —
  the wire protocol itself never changes, exactly like pushing a per-region
  cross-map to the OCS.

Virtual experts (DESIGN.md §5): when E < model-axis size P, every expert is
split into r = P/E tensor shards; a token is dispatched to all r shards of
its expert and the combine sums the partial products, restoring the
row-split matmul identity.  This makes the expert dim shard exactly for any
assigned architecture (grok-1: 8 experts -> 16 virtual on a 16-wide axis).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.collectives import mixnet_all_to_all
from repro.kernels import ops
from repro.parallel.sharding import ShardingPlan, constrain, shard_map, virtual_experts

__all__ = ["init_moe", "moe_apply", "MoEStats", "router_losses"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MoEStats:
    """Per-layer telemetry consumed by the MixNet control plane (§5.1)."""

    expert_load: jax.Array  # [E] tokens routed to each (real) expert
    balance_loss: jax.Array
    z_loss: jax.Array
    dropped_fraction: jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_moe(key, cfg, plan: ShardingPlan):
    e = cfg.moe
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    ev, r = virtual_experts(e.num_experts, plan.model_size)
    if e.d_ff % r != 0:
        raise ValueError(f"expert d_ff {e.d_ff} not divisible by replication {r}")
    f_shard = e.d_ff // r
    keys = jax.random.split(key, 5)

    params = {
        "router": jax.random.normal(keys[0], (d, e.num_experts), jnp.float32) * d**-0.5,
        "w_in": jax.random.normal(keys[1], (ev, d, f_shard), dtype) * d**-0.5,
        "w_gate": jax.random.normal(keys[2], (ev, d, f_shard), dtype) * d**-0.5,
        "w_out": jax.random.normal(keys[3], (ev, f_shard, d), dtype) * e.d_ff**-0.5,
    }
    ex_ax = plan.dim_axis(ev)
    specs = {
        "router": P(None, None),
        "w_in": P(ex_ax, plan.fsdp_axis, None),
        "w_gate": P(ex_ax, plan.fsdp_axis, None),
        "w_out": P(ex_ax, None, plan.fsdp_axis),
    }
    if e.num_shared_experts:
        f_sh = e.d_ff * e.num_shared_experts
        k5, k6, k7 = jax.random.split(keys[4], 3)
        params["shared"] = {
            "w_in": jax.random.normal(k5, (d, f_sh), dtype) * d**-0.5,
            "w_gate": jax.random.normal(k6, (d, f_sh), dtype) * d**-0.5,
            "w_out": jax.random.normal(k7, (f_sh, d), dtype) * f_sh**-0.5,
        }
        sh_ax = plan.dim_axis(f_sh)
        specs["shared"] = {
            "w_in": P(plan.fsdp_axis, sh_ax),
            "w_gate": P(plan.fsdp_axis, sh_ax),
            "w_out": P(sh_ax, plan.fsdp_axis),
        }
    return params, specs


# ---------------------------------------------------------------------------
# routing helpers
# ---------------------------------------------------------------------------


def router_losses(logits: jax.Array, idx: jax.Array, num_experts: int):
    """Switch-style balance loss + router z-loss (both f32 scalars)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    mean_prob = probs.reshape(-1, num_experts).mean(axis=0)
    counts = jax.nn.one_hot(idx.reshape(-1), num_experts, dtype=jnp.float32).sum(0)
    frac = counts / jnp.maximum(counts.sum(), 1.0)
    balance = num_experts * jnp.sum(frac * mean_prob)
    z = jnp.mean(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1) ** 2)
    return balance, z


def _capacity(tokens: int, top_k: int, num_experts: int, factor: float) -> int:
    c = int(np.ceil(tokens * top_k * factor / num_experts))
    return max(4, int(np.ceil(c / 4) * 4))


def _expert_ffn(x, w_in, w_gate, w_out, act):
    """x [..., E, C, D] grouped through per-expert SwiGLU."""
    h = jnp.einsum("...ecd,edf->...ecf", x, w_in)
    g = jnp.einsum("...ecd,edf->...ecf", x, w_gate)
    actfn = jax.nn.silu if act in ("silu", "swiglu") else jax.nn.gelu
    h = actfn(g) * h
    return jnp.einsum("...ecf,efd->...ecd", h, w_out)


# ---------------------------------------------------------------------------
# einsum (GShard) backend
# ---------------------------------------------------------------------------


def _moe_einsum(params, x, cfg, plan: ShardingPlan, mesh=None):
    e = cfg.moe
    b, s, d = x.shape
    ev, r = virtual_experts(e.num_experts, plan.model_size)
    # Token groups: one group per sequence shard so the dispatch einsum's
    # quadratic term stays bounded and group boundaries match the sharding.
    g = plan.model_size if (plan.model_size > 1 and s % plan.model_size == 0) else 1
    t = s // g
    batch_ok = b % max(plan.data_size, 1) == 0
    gspec = (plan.batch_axes or None) if batch_ok else None
    xg = x.reshape(b * g, t, d)
    # GShard-baseline sharding: groups ride the DP axes only (tokens gathered
    # over the model axis), the expert dim rides the model axis.  Dispatch is
    # an all-gather, combine a reduce-scatter — the static-fabric baseline
    # the mixnet backend's true hierarchical a2a improves on (§Perf).
    xg = constrain(xg, mesh, P(gspec, None, None))
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    weights, idx = ops.topk_gating(logits.reshape(-1, e.num_experts), e.top_k)
    weights = weights.reshape(b * g, t, e.top_k)
    idx = idx.reshape(b * g, t, e.top_k)
    # Renormalize the kept top-k weights (standard for k>1 routers).
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    cap = _capacity(t, e.top_k, e.num_experts, e.capacity_factor)
    onehot = jax.nn.one_hot(idx, e.num_experts, dtype=jnp.float32)  # [G,T,K,E]
    # Position of each (token, choice) within its expert's capacity buffer.
    flat = onehot.reshape(b * g, t * e.top_k, e.num_experts)
    pos = jnp.cumsum(flat, axis=1) - flat  # rank among same-expert picks
    pos = pos.reshape(b * g, t, e.top_k, e.num_experts)
    keep = (pos < cap) * onehot
    dropped = 1.0 - keep.sum() / (b * g * t * e.top_k)
    pos_oh = jax.nn.one_hot(
        jnp.minimum(pos, cap - 1).astype(jnp.int32), cap, dtype=jnp.float32
    )
    dispatch = jnp.einsum("gtke,gtkec->gtec", keep, pos_oh)  # [G,T,E,C]
    combine = jnp.einsum("gtke,gtkec,gtk->gtec", keep, pos_oh, weights)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)  # [G,E,C,D]
    if r > 1:
        xe = jnp.repeat(xe, r, axis=1)  # duplicate to all r virtual shards
    ex_ax = plan.dim_axis(ev)
    xe = constrain(xe, mesh, P(gspec, ex_ax, None, None))
    ye = _expert_ffn(xe, params["w_in"], params["w_gate"], params["w_out"], cfg.act)
    ye = constrain(ye, mesh, P(gspec, ex_ax, None, None))
    if r > 1:
        ye = ye.reshape(b * g, e.num_experts, r, cap, d).sum(axis=2)
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    out = out.reshape(b, s, d)

    balance, z = router_losses(logits, idx, e.num_experts)
    load = jax.nn.one_hot(idx.reshape(-1), e.num_experts, dtype=jnp.float32).sum(0)
    stats = MoEStats(load, balance, z, dropped)
    return out, stats


# ---------------------------------------------------------------------------
# mixnet (shard_map hierarchical a2a) backend
# ---------------------------------------------------------------------------


def _pack_by_expert(tokens, expert_ids, valid, num_local, capacity):
    """Scatter ``tokens [N, D]`` into ``[num_local, capacity, D]`` buffers by
    local expert id; returns (packed, slot, keep) where ``slot`` maps each
    source row to its buffer slot for the unpack (fixed shapes, overflow
    dropped)."""
    n, d = tokens.shape
    onehot = jax.nn.one_hot(expert_ids, num_local, dtype=jnp.int32) * valid[:, None].astype(jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot  # [N, E_local]
    my_pos = jnp.sum(pos * onehot, axis=1)
    keep = valid & (my_pos < capacity)
    slot = jnp.where(keep, expert_ids * capacity + my_pos, num_local * capacity)
    packed = jnp.zeros((num_local * capacity + 1, d), tokens.dtype)
    packed = packed.at[slot].set(jnp.where(keep[:, None], tokens, 0))
    packed = packed[:-1].reshape(num_local, capacity, d)
    return packed, slot, keep


def _moe_mixnet_local(params_local, xl, cfg, plan: ShardingPlan, expert_perm, axis_names):
    """Per-device MoE body (runs inside shard_map, or standalone at P=1)."""
    e = cfg.moe
    ev, r = virtual_experts(e.num_experts, plan.model_size)
    p_axis = max(plan.model_size, 1)
    ev_local = ev // p_axis
    router, w_in, w_gate, w_out = params_local
    bl, sl, d = xl.shape
    tl = bl * sl
    xt = xl.reshape(tl, d)

    logits = xt.astype(jnp.float32) @ router
    weights, idx = ops.topk_gating(logits, e.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Virtual destinations: choice (t, k) -> r shard targets, re-addressed by
    # the runtime placement permutation (expert_perm[v] = physical slot).
    vdest = (idx[..., None] * r + jnp.arange(r)).reshape(tl, e.top_k * r)
    vdest = expert_perm[vdest]
    wfull = jnp.repeat(weights, r, axis=-1)
    dest_dev = vdest // ev_local
    local_e = vdest % ev_local

    # --- send buffers [P, Cp, D] + expert-id metadata ----------------------
    cp = _capacity(tl, e.top_k * r, p_axis, e.capacity_factor)
    flat_dev = dest_dev.reshape(-1)
    oh = jax.nn.one_hot(flat_dev, p_axis, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh
    my_pos = jnp.sum(pos * oh, axis=1)
    keep = my_pos < cp
    slot = jnp.where(keep, flat_dev * cp + my_pos, p_axis * cp)
    src_rows = jnp.repeat(jnp.arange(tl), e.top_k * r)
    send_x = jnp.zeros((p_axis * cp + 1, d), xl.dtype).at[slot].set(
        jnp.where(keep[:, None], xt[src_rows], 0)
    )
    send_e = jnp.full((p_axis * cp + 1,), -1, jnp.int32).at[slot].set(
        jnp.where(keep, local_e.reshape(-1), -1)
    )
    send_x = send_x[:-1].reshape(p_axis, cp, d)
    send_e = send_e[:-1].reshape(p_axis, cp)

    # --- hierarchical delegation all-to-all (the MixNet fabric) ------------
    if p_axis > 1:
        recv_x = mixnet_all_to_all(send_x, "model", e.a2a_group)
        recv_e = mixnet_all_to_all(send_e[..., None], "model", e.a2a_group)[..., 0]
    else:
        recv_x, recv_e = send_x, send_e

    # --- pack by local expert, grouped FFN, unpack --------------------------
    rx = recv_x.reshape(p_axis * cp, d)
    re = recv_e.reshape(p_axis * cp)
    c2 = _capacity(p_axis * cp, 1, ev_local, e.capacity_factor)
    packed, slot2, keep2 = _pack_by_expert(rx, jnp.maximum(re, 0), re >= 0, ev_local, c2)
    ye = _expert_ffn(packed[None], w_in, w_gate, w_out, cfg.act)[0]
    flat_y = jnp.concatenate(
        [ye.reshape(ev_local * c2, d), jnp.zeros((1, d), ye.dtype)], axis=0
    )
    back = jnp.where(keep2[:, None], flat_y[jnp.minimum(slot2, ev_local * c2)], 0.0)
    back = back.reshape(p_axis, cp, d)

    # --- return trip + weighted combine -------------------------------------
    ret = mixnet_all_to_all(back, "model", e.a2a_group) if p_axis > 1 else back
    flat_ret = jnp.concatenate(
        [ret.reshape(p_axis * cp, d), jnp.zeros((1, d), ret.dtype)], axis=0
    )
    contrib = flat_ret[jnp.minimum(slot, p_axis * cp)] * keep[:, None]
    contrib = contrib.reshape(tl, e.top_k * r, d)
    out = jnp.sum(contrib * wfull[..., None].astype(contrib.dtype), axis=1)
    out = out.reshape(bl, sl, d).astype(xl.dtype)

    balance, z = router_losses(logits, idx, e.num_experts)
    load = jax.nn.one_hot(idx.reshape(-1), e.num_experts, dtype=jnp.float32).sum(0)
    drop = 1.0 - jnp.mean(keep.astype(jnp.float32))
    # Reduce telemetry over every mesh axis so replicated out_specs hold.
    for ax in axis_names:
        load = jax.lax.psum(load, ax)
        balance = jax.lax.pmean(balance, ax)
        z = jax.lax.pmean(z, ax)
        drop = jax.lax.pmean(drop, ax)
    return out, load, balance, z, drop


def _moe_mixnet(params, x, cfg, plan: ShardingPlan, mesh, expert_perm=None):
    """``expert_perm`` is THIS layer's ``[E_virtual]`` expert->slot map (one
    row of the trainer's per-layer perm stack); None means identity."""
    e = cfg.moe
    ev, _ = virtual_experts(e.num_experts, plan.model_size)
    perm_arr = (
        jnp.asarray(expert_perm, jnp.int32)
        if expert_perm is not None
        else jnp.arange(ev, dtype=jnp.int32)
    )
    if perm_arr.shape != (ev,):
        raise ValueError(
            f"expert_perm must be this layer's [E_virtual]={ev} row, "
            f"got shape {perm_arr.shape}"
        )

    def body(router, w_in, w_gate, w_out, xl, perm, axis_names=()):
        return _moe_mixnet_local(
            (router, w_in, w_gate, w_out), xl, cfg, plan, perm, axis_names
        )

    if mesh is None or plan.model_size <= 1:
        out, load, balance, z, drop = body(
            params["router"], params["w_in"], params["w_gate"], params["w_out"],
            x, perm_arr,
        )
    else:
        ex_ax = plan.dim_axis(ev)
        axis_names = tuple(a for a in (plan.batch_axes or ()) if a) + (
            (plan.model_axis,) if plan.model_axis else ()
        )
        # Token sharding for the shard_map region: seq over the model axis
        # for train/prefill; decode (S=1) shards batch only — every device
        # dispatches its batch rows to the expert owners over the a2a.
        b_sz, s_sz = x.shape[0], x.shape[1]
        batch_ax = (
            (plan.batch_axes or None)
            if b_sz % max(plan.data_size, 1) == 0
            else None
        )
        seq_ax = plan.model_axis if s_sz % plan.model_size == 0 else None
        tok_spec = P(batch_ax, seq_ax, None)
        fn = shard_map(
            lambda r_, wi, wg, wo, xl, pm: body(
                r_, wi, wg, wo, xl, pm, axis_names=axis_names
            ),
            mesh=mesh,
            in_specs=(
                P(None, None),
                P(ex_ax, None, None),
                P(ex_ax, None, None),
                P(ex_ax, None, None),
                tok_spec,
                P(None),
            ),
            out_specs=(
                tok_spec,
                P(None), P(), P(), P(),
            ),
            check_vma=False,
        )
        out, load, balance, z, drop = fn(
            params["router"], params["w_in"], params["w_gate"], params["w_out"],
            x, perm_arr,
        )
    return out, MoEStats(load, balance, z, drop)


# ---------------------------------------------------------------------------
# dense decode backend
# ---------------------------------------------------------------------------


def _moe_dense_decode(params, x, cfg, plan: ShardingPlan, mesh=None):
    """Decode-time MoE: compute ALL experts densely on the handful of live
    tokens and combine with the (sparse) gate weights.

    At decode the token count is tiny, so the extra FLOPs of computing every
    expert (~1 ms on 256 chips for deepseek-v2's 128 tokens) are nothing —
    while the sparse dispatch path must gather 2D-sharded expert weights
    over the FSDP axis every layer (~27 GB/step for deepseek-v2).  Dense
    decode keeps weights stationary: activations ride the contractions
    (psums of a few MB).  §Perf beyond-paper optimization.
    """
    e = cfg.moe
    b, s, d = x.shape
    ev, r = virtual_experts(e.num_experts, plan.model_size)
    xt = x.reshape(b * s, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    weights, idx = ops.topk_gating(logits, e.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Scatter the kept top-k weights into a dense [T, E] map, then expand to
    # virtual experts (each of the r shards contributes a partial product).
    wmap = jnp.zeros((b * s, e.num_experts), jnp.float32)
    wmap = wmap.at[jnp.arange(b * s)[:, None], idx].add(weights)
    wv = jnp.repeat(wmap, r, axis=1)  # [T, Ev]

    ex_ax = plan.dim_axis(ev)
    h = jnp.einsum("td,edf->tef", xt, params["w_in"])
    g = jnp.einsum("td,edf->tef", xt, params["w_gate"])
    actfn = jax.nn.silu if cfg.act in ("silu", "swiglu") else jax.nn.gelu
    h = actfn(g) * h
    h = constrain(h, mesh, P(None, ex_ax, None))
    y = jnp.einsum("tef,efd->ted", h, params["w_out"])
    out = jnp.einsum("te,ted->td", wv.astype(y.dtype), y).reshape(b, s, d)

    balance, z = router_losses(logits, idx, e.num_experts)
    load = jax.nn.one_hot(idx.reshape(-1), e.num_experts, dtype=jnp.float32).sum(0)
    return out, MoEStats(load, balance, z, jnp.zeros((), jnp.float32))


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def moe_apply(
    params,
    x: jax.Array,
    cfg,
    plan: ShardingPlan,
    *,
    mesh=None,
    expert_perm=None,
    backend: str | None = None,
):
    e = cfg.moe
    backend = backend or e.backend
    if x.shape[1] == 1 and backend != "einsum":
        # Single-token decode: weight-stationary dense path (see docstring).
        backend = "dense_decode"
    if backend == "dense_decode":
        out, stats = _moe_dense_decode(params, x, cfg, plan, mesh=mesh)
    elif backend == "mixnet":
        out, stats = _moe_mixnet(params, x, cfg, plan, mesh, expert_perm)
    elif backend == "einsum":
        out, stats = _moe_einsum(params, x, cfg, plan, mesh=mesh)
    else:
        raise ValueError(f"unknown MoE backend {backend!r}")
    if "shared" in params:
        sh = params["shared"]
        h = x @ sh["w_in"]
        g = jax.nn.silu(x @ sh["w_gate"])
        out = out + (g * h) @ sh["w_out"]
    return out, stats
