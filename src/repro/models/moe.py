"""Mixture-of-Experts layer: three dispatch backends, one routing core.

All router math and token ordering lives in :mod:`repro.models.routing` (the
sort-based dropless engine, DESIGN.md §6); this module owns the three
*execution strategies* layered on top of it:

``einsum``  — GShard-style dense dispatch/combine einsums, with the
  dispatch masks built from the shared sort-based ranks.  Fully
  auto-shardable under pjit (the expert dim rides the ``model`` axis and XLA
  inserts the all-to-alls): this is the *paper-faithful baseline* a static
  fabric serves.

``mixnet``  — the paper's data plane (§5.3) as an explicit ``shard_map``
  program over the ``model`` axis: tokens are gathered into per-destination
  send buffers (``ops.moe_dispatch``), exchanged with the **hierarchical
  delegation all-to-all** — the CommRuntime :class:`AllToAll` op built from
  a :class:`repro.core.commruntime.CommSpec` (DESIGN.md §7), with the
  payload and its gate metadata fused into ONE packed wire transfer —
  packed by local expert and computed with the grouped Pallas GEMM
  (``ops.grouped_matmul`` — capacity buffers or the dropless block layout),
  and returned the same way (``ops.moe_combine``).  EP traffic never leaves
  the ``model`` axis — the regional locality the measurement study (§3)
  found.  With ``overlap_chunks > 1`` the whole dispatch/FFN/combine
  sequence runs as a chunked software pipeline over ``AllToAll.stages()``
  (:mod:`repro.core.overlap`, DESIGN.md §8) — chunk k+1's dispatch a2a
  under chunk k's expert FFN under chunk k-1's combine, bit-identical to
  the serial schedule.  Runtime expert re-placement (the
  OCS-reconfiguration analogue) is
  realized by permuting expert->slot assignments *per layer*: the control
  plane (:mod:`repro.core.controlplane`) plans one permutation per MoE
  layer, the trainer gathers that layer's stacked expert weights into their
  new slots (:func:`repro.train.trainer.permute_expert_weights`), and the
  transformer scan feeds this module the matching row of the ``[repeats,
  E_virtual]`` ``expert_perm`` stack so the router addresses the new slots —
  the wire protocol itself never changes, exactly like pushing a per-region
  cross-map to the OCS.  Plans that move WHOLE device blocks skip the
  weight gather entirely: the trainer installs a per-layer ``wire_perm``
  device map and this module re-addresses the a2a's wire chunks instead
  (``dest_perm``/``src_perm`` — the literal cross-map push).

``dense_decode`` — decode-time weight-stationary path: ALL experts computed
  densely on the handful of live tokens, combined by gathering each choice's
  expert output through the routing core's virtual-slot map and summing in
  GATE order — which both applies ``expert_perm`` and keeps decode tokens
  BIT-identical across runtime reconfigurations (DESIGN.md §9).  MoE configs
  with ``decode_backend="sparse"`` skip this path and keep the mixnet
  backend's sparse EP dispatch at decode — the serving engine's
  a2a-per-tick mode, where wire perms re-address the decode all-to-all.

Dispatch semantics (``cfg.moe.dispatch``): **dropless** (default) routes
every token — the einsum backend sizes its dense buffers at the worst case
(~E/(top_k·capacity_factor)× the capacity-mode FFN rows: fine for parity
validation and small models, use capacity mode or the mixnet backend at
scale), the mixnet backend packs the MegaBlocks block layout (dropless
without the padding) — while **capacity** keeps the classic capacity-factor
buffers and drops overflow (bounded wire traffic for the sharded
all-to-all).  ``dropped_fraction`` telemetry counts losses from *every*
stage of a backend's pipeline.

Virtual experts (DESIGN.md §5): when E < model-axis size P, every expert is
split into r = P/E tensor shards; a token is dispatched to all r shards of
its expert and the combine sums the partial products, restoring the
row-split matmul identity.  This makes the expert dim shard exactly for any
assigned architecture (grok-1: 8 experts -> 16 virtual on a 16-wide axis).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import overlap
from repro.core.commruntime import AllToAll, CommSpec, fuse_pack, fuse_unpack
from repro.kernels import ops
from repro.models import routing
from repro.models.routing import MoEStats, router_losses
from repro.parallel.sharding import ShardingPlan, constrain, shard_map, virtual_experts

__all__ = [
    "init_moe", "moe_apply", "MoEStats", "router_losses",
    "resolve_draft_mode", "draft_config",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_moe(key, cfg, plan: ShardingPlan):
    e = cfg.moe
    d = cfg.d_model
    dtype = jnp.dtype(cfg.dtype)
    ev, r = virtual_experts(e.num_experts, plan.model_size)
    if e.d_ff % r != 0:
        raise ValueError(f"expert d_ff {e.d_ff} not divisible by replication {r}")
    f_shard = e.d_ff // r
    keys = jax.random.split(key, 5)

    params = {
        "router": jax.random.normal(keys[0], (d, e.num_experts), jnp.float32) * d**-0.5,
        "w_in": jax.random.normal(keys[1], (ev, d, f_shard), dtype) * d**-0.5,
        "w_gate": jax.random.normal(keys[2], (ev, d, f_shard), dtype) * d**-0.5,
        "w_out": jax.random.normal(keys[3], (ev, f_shard, d), dtype) * e.d_ff**-0.5,
    }
    ex_ax = plan.dim_axis(ev)
    specs = {
        "router": P(None, None),
        "w_in": P(ex_ax, plan.fsdp_axis, None),
        "w_gate": P(ex_ax, plan.fsdp_axis, None),
        "w_out": P(ex_ax, None, plan.fsdp_axis),
    }
    if e.num_shared_experts:
        f_sh = e.d_ff * e.num_shared_experts
        k5, k6, k7 = jax.random.split(keys[4], 3)
        params["shared"] = {
            "w_in": jax.random.normal(k5, (d, f_sh), dtype) * d**-0.5,
            "w_gate": jax.random.normal(k6, (d, f_sh), dtype) * d**-0.5,
            "w_out": jax.random.normal(k7, (f_sh, d), dtype) * f_sh**-0.5,
        }
        sh_ax = plan.dim_axis(f_sh)
        specs["shared"] = {
            "w_in": P(plan.fsdp_axis, sh_ax),
            "w_gate": P(plan.fsdp_axis, sh_ax),
            "w_out": P(sh_ax, plan.fsdp_axis),
        }
    return params, specs


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _actfn(act: str):
    return jax.nn.silu if act in ("silu", "swiglu") else jax.nn.gelu


def _expert_ffn(x, w_in, w_gate, w_out, act):
    """x [..., E, C, D] grouped through per-expert SwiGLU (einsum form, for
    the pjit-partitioned dense backends)."""
    h = jnp.einsum("...ecd,edf->...ecf", x, w_in)
    g = jnp.einsum("...ecd,edf->...ecf", x, w_gate)
    h = _actfn(act)(g) * h
    return jnp.einsum("...ecf,efd->...ecd", h, w_out)


# ---------------------------------------------------------------------------
# einsum (GShard) backend
# ---------------------------------------------------------------------------


def _moe_einsum(params, x, cfg, plan: ShardingPlan, mesh=None, expert_perm=None):
    e = cfg.moe
    b, s, d = x.shape
    ev, r = virtual_experts(e.num_experts, plan.model_size)
    sc = e.top_k * r
    # Token groups: one group per sequence shard so the dispatch einsum's
    # quadratic term stays bounded and group boundaries match the sharding.
    g = plan.model_size if (plan.model_size > 1 and s % plan.model_size == 0) else 1
    t = s // g
    batch_ok = b % max(plan.data_size, 1) == 0
    gspec = (plan.batch_axes or None) if batch_ok else None
    xg = x.reshape(b * g, t, d)
    # GShard-baseline sharding: groups ride the DP axes only (tokens gathered
    # over the model axis), the expert dim rides the model axis.  Dispatch is
    # an all-gather, combine a reduce-scatter — the static-fabric baseline
    # the mixnet backend's true hierarchical a2a improves on (§Perf).
    xg = constrain(xg, mesh, P(gspec, None, None))
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), params["router"])
    info = routing.compute_routing(
        logits.reshape(-1, e.num_experts),
        top_k=e.top_k,
        num_virtual=ev,
        replication=r,
        expert_perm=expert_perm,
    )
    vdest = info.vdest.reshape(b * g, t * sc)
    wfull = info.wfull.reshape(b * g, t, sc)

    # Per-virtual-slot capacity: a slot can receive at most t tokens (top-k
    # indices are distinct), so cap = t is exactly dropless; capacity mode
    # keeps the classic factor-bounded buffers and drops overflow.  Dense
    # dropless is inherently padded-worst-case — buffers and expert-FFN rows
    # grow by ~E/(top_k·capacity_factor) over capacity mode, the waste
    # MegaBlocks measures — so at scale run this baseline in capacity mode
    # (or use the mixnet backend, whose block layout is dropless WITHOUT the
    # padding).
    if e.dispatch == "dropless":
        cap = t
    else:
        cap = routing.capacity(t, e.top_k, e.num_experts, e.capacity_factor)
    rank, _ = jax.vmap(lambda dv: routing.bucket_ranks(dv, ev))(vdest)
    vdest = vdest.reshape(b * g, t, sc)
    rank = rank.reshape(b * g, t, sc)
    keep = rank < cap
    dispatch, combine = routing.dense_dispatch_masks(
        vdest, rank, keep, wfull, ev, cap
    )
    dropped = 1.0 - keep.sum() / (b * g * t * sc)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)  # [G,Ev,C,D]
    ex_ax = plan.dim_axis(ev)
    xe = constrain(xe, mesh, P(gspec, ex_ax, None, None))
    ye = _expert_ffn(xe, params["w_in"], params["w_gate"], params["w_out"], cfg.act)
    ye = constrain(ye, mesh, P(gspec, ex_ax, None, None))
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    out = out.reshape(b, s, d)

    balance, z = router_losses(logits, info.idx, e.num_experts)
    load = routing.expert_load(info.idx, e.num_experts)
    stats = MoEStats(load, balance, z, dropped.astype(jnp.float32))
    return out, stats


# ---------------------------------------------------------------------------
# mixnet (shard_map hierarchical a2a) backend
# ---------------------------------------------------------------------------


def _wire_perms(wire_perm, p_axis):
    """(dest_perm, src_perm) realizing a ControlPlane wire re-address.

    ``wire_perm`` is the layer's device map ``D`` (logical device k's experts
    physically live on device ``D[k]``, installed instead of a weight gather
    when a placement plan moves whole device blocks).  The dispatch trip
    re-addresses chunks with ``D^-1`` (physical device j serves logical
    ``D^-1[j]``); the return trip restores logical order with ``D``.
    """
    if wire_perm is None:
        return None, None
    wire_src = wire_perm.astype(jnp.int32)
    wire_dest = (
        jnp.zeros((p_axis,), jnp.int32)
        .at[wire_src]
        .set(jnp.arange(p_axis, dtype=jnp.int32))
    )
    return wire_dest, wire_src


def _moe_mixnet_local(
    params_local, xl, cfg, plan: ShardingPlan, expert_perm, axis_names,
    wire_perm=None, token_axes=(), gate_weights=None,
):
    """Per-device MoE body (runs inside shard_map, or standalone at P=1).

    ``cfg.moe.overlap_chunks > 1`` runs the chunked software pipeline
    (DESIGN.md §8): the token dim splits into C chunks and chunk k+1's
    dispatch a2a runs under chunk k's expert FFN under chunk k-1's combine,
    each a2a further split into its delegation stages
    (``AllToAll.stages()``).  Chunk rows are independent and capacity-mode
    keep decisions are computed globally, so the chunked output is
    bit-identical to the serial path (the only semantic divergence is the
    stage-2 overflow regime of capacity mode, which the chunked layout does
    not drop — see §8).
    """
    e = cfg.moe
    ev, r = virtual_experts(e.num_experts, plan.model_size)
    p_axis = max(plan.model_size, 1)
    ev_local = ev // p_axis
    dropless = e.dispatch == "dropless"
    router, w_in, w_gate, w_out = params_local
    bl, sl, d = xl.shape
    tl = bl * sl
    sc = e.top_k * r
    n = tl * sc
    xt = xl.reshape(tl, d)
    if gate_weights is not None:
        gate_weights = gate_weights.reshape(tl)
    act = _actfn(cfg.act)

    logits = xt.astype(jnp.float32) @ router
    info = routing.compute_routing(
        logits, top_k=e.top_k, num_virtual=ev, replication=r,
        expert_perm=expert_perm,
    )
    flat_dev = (info.vdest // ev_local).reshape(n)
    local_e = (info.vdest % ev_local).reshape(n)
    wire_dest, wire_src = _wire_perms(wire_perm, p_axis)

    # Stage-1 keep decisions are GLOBAL (chunk-invariant): dropless keeps
    # everything; capacity mode keeps global rank < the serial capacity, so
    # chunking never changes which tokens survive the send stage.
    rank1, _ = routing.bucket_ranks(flat_dev, p_axis)
    cp = n if dropless else routing.capacity(tl, sc, p_axis, e.capacity_factor)
    keep1 = None if dropless else rank1 < cp

    # One CommRuntime op serves the whole layer: the dispatch trip moves the
    # token payload and its expert-id metadata as ONE packed wire transfer
    # (bit-identical payload to the unfused pair, tested), the return trip
    # reuses the same lowering.  P = 1 degrades to identity inside the op.
    a2a = AllToAll(CommSpec.from_plan(plan, group_size=e.a2a_group))
    chunks = overlap.chunk_count(tl, e.overlap_chunks)

    def expert_ffn_block(rx, re):
        """Received rows -> per-expert pack -> grouped GEMM -> unpacked rows.
        Returns (back rows aligned with the receive layout, kept count)."""
        valid = re >= 0
        rank2, counts2 = routing.bucket_ranks(re, ev_local, valid=valid)
        if dropless or chunks > 1:
            # Block layout: every valid received row is placed (the chunked
            # pipeline uses it for capacity mode too — static shapes, and
            # stage-2 never drops below the stage-1 capacity bound).
            plan2 = routing.dropless_plan(
                re, rank2, counts2, valid, ev_local, e.dispatch_block
            )
            packed = ops.moe_dispatch(rx, plan2.src).reshape(-1, e.dispatch_block, d)
            be = plan2.block_experts
            h = ops.grouped_matmul(packed, w_in, block_experts=be)
            gt = ops.grouped_matmul(packed, w_gate, block_experts=be)
            ye = ops.grouped_matmul(act(gt) * h, w_out, block_experts=be)
        else:
            c2 = routing.capacity(re.shape[0], 1, ev_local, e.capacity_factor)
            plan2 = routing.capacity_plan(re, rank2, valid, ev_local, c2)
            packed = ops.moe_dispatch(rx, plan2.src).reshape(ev_local, c2, d)
            h = ops.grouped_matmul(packed, w_in)
            gt = ops.grouped_matmul(packed, w_gate)
            ye = ops.grouped_matmul(act(gt) * h, w_out)
        back = ops.moe_dispatch(ye.reshape(plan2.num_rows, d), plan2.slot)
        return back, plan2.kept

    if chunks == 1:
        # --- serial path: one send buffer, one a2a pair ---------------------
        plan1 = routing.capacity_plan(flat_dev, rank1, keep1, p_axis, cp)
        src_tok = jnp.where(plan1.src >= 0, plan1.src // sc, -1)
        send_x = ops.moe_dispatch(xt, src_tok).reshape(p_axis, cp, d)
        send_e = jnp.where(
            plan1.src >= 0, local_e[jnp.clip(plan1.src, 0, n - 1)], -1
        ).reshape(p_axis, cp).astype(jnp.int32)
        if e.a2a_fuse:
            recv_x, recv_e = a2a.fused(send_x, send_e, dest_perm=wire_dest)
        else:
            recv_x = a2a(send_x, dest_perm=wire_dest)
            recv_e = a2a(send_e[..., None], dest_perm=wire_dest)[..., 0]
        back, kept = expert_ffn_block(
            recv_x.reshape(p_axis * cp, d), recv_e.reshape(p_axis * cp)
        )
        ret = a2a(back.reshape(p_axis, cp, d), src_perm=wire_src)
        out = ops.moe_combine(
            ret.reshape(p_axis * cp, d), plan1.slot.reshape(tl, sc), info.wfull
        )
    else:
        # --- chunked software pipeline (repro.core.overlap) -----------------
        out, kept = _mixnet_chunked(
            xt, info, flat_dev, local_e, keep1, a2a, expert_ffn_block,
            wire_dest, wire_src, chunks=chunks, p_axis=p_axis, sc=sc, cp=cp,
            fuse=e.a2a_fuse,
        )
    out = out.reshape(bl, sl, d).astype(xl.dtype)

    balance, z = router_losses(logits, info.idx, e.num_experts)
    load = routing.expert_load(info.idx, e.num_experts, weights=gate_weights)
    # Drop telemetry folds BOTH stages: `kept` counts received rows that
    # won an expert slot, i.e. choices that survived the send-buffer stage
    # AND the pack stage (stage-1 drops never arrive).  psum'ing kept and
    # offered over the mesh yields the global realized loss the control
    # plane acts on (exactly 0 in dropless mode).
    kept = kept.astype(jnp.float32)
    offered = jnp.asarray(float(n), jnp.float32)
    # Reduce telemetry over every mesh axis so replicated out_specs hold.
    # Counts SUM over axes that shard the token dim and MEAN over axes the
    # tokens ride replicated (the sparse decode path: every device sees the
    # whole live batch — a psum there would overcount the load P-fold).
    for ax in axis_names:
        red = jax.lax.psum if ax in token_axes else jax.lax.pmean
        load = red(load, ax)
        balance = jax.lax.pmean(balance, ax)
        z = jax.lax.pmean(z, ax)
        kept = red(kept, ax)
        offered = red(offered, ax)
    drop = 1.0 - kept / offered
    return out, load, balance, z, drop


def _mixnet_chunked(
    xt, info, flat_dev, local_e, keep1, a2a, expert_ffn_block,
    wire_dest, wire_src, *, chunks, p_axis, sc, cp, fuse,
):
    """Chunked double-buffered dispatch/FFN/combine pipeline (DESIGN.md §8).

    The token dim splits into ``chunks`` equal chunks; each chunk runs
    send-buffer build -> dispatch a2a (per delegation stage) -> expert FFN ->
    return a2a (per stage) -> weighted combine, and the stage list executes
    through :func:`repro.core.overlap.software_pipeline` so chunk k+1's
    dispatch is issued under chunk k's FFN under chunk k-1's combine.
    Returns (``[T, D]`` f32 combined output, kept-choice count).
    """
    tl, d = xt.shape
    tc = tl // chunks
    nc = tc * sc
    # Per-chunk send capacity: dropless keeps the exact worst case (all nc
    # choices to one device); capacity mode is bounded by the GLOBAL serial
    # capacity (keep decisions are global, so no chunk exceeds it).
    cp_c = nc if keep1 is None else min(nc, cp)
    disp_stages = a2a.stages()
    ret_stages = a2a.stages()
    fused = fuse and jnp.dtype(xt.dtype).itemsize in (2, 4)

    def s_build(_, k):
        lo = k * nc
        dest_c = jax.lax.slice_in_dim(flat_dev, lo, lo + nc)
        keep_c = None if keep1 is None else jax.lax.slice_in_dim(keep1, lo, lo + nc)
        rank_c, _ = routing.bucket_ranks(dest_c, p_axis, valid=keep_c)
        plan1_c = routing.capacity_plan(dest_c, rank_c, keep_c, p_axis, cp_c)
        src_tok = jnp.where(plan1_c.src >= 0, k * tc + plan1_c.src // sc, -1)
        send_x = ops.moe_dispatch(xt, src_tok).reshape(p_axis, cp_c, d)
        le_c = jax.lax.slice_in_dim(local_e, lo, lo + nc)
        send_e = jnp.where(
            plan1_c.src >= 0, le_c[jnp.clip(plan1_c.src, 0, nc - 1)], -1
        ).reshape(p_axis, cp_c).astype(jnp.int32)
        st = {"plan1": plan1_c}
        if fused:
            st["x"] = disp_stages[0](fuse_pack(send_x, send_e), dest_perm=wire_dest)
        else:
            st["x"] = disp_stages[0](send_x, dest_perm=wire_dest)
            st["e"] = disp_stages[0](send_e[..., None], dest_perm=wire_dest)
        return st

    def s_disp2(st, _):
        st = dict(st)
        st["x"] = disp_stages[1](st["x"])
        if not fused:
            st["e"] = disp_stages[1](st["e"])
        return st

    def s_ffn(st, _):
        if fused:
            recv_x, recv_e = fuse_unpack(st["x"], d)
        else:
            recv_x, recv_e = st["x"], st["e"][..., 0]
        back, kept_c = expert_ffn_block(
            recv_x.reshape(p_axis * cp_c, d), recv_e.reshape(p_axis * cp_c)
        )
        back = back.reshape(p_axis, cp_c, d)
        if len(ret_stages) == 1:
            back = ret_stages[0](back, src_perm=wire_src)
        else:
            back = ret_stages[0](back)
        return {"plan1": st["plan1"], "back": back, "kept": kept_c}

    def s_ret2(st, _):
        st = dict(st)
        st["back"] = ret_stages[1](st["back"], src_perm=wire_src)
        return st

    def s_combine(st, k):
        wf_c = jax.lax.slice_in_dim(info.wfull, k * tc, (k + 1) * tc)
        out_c = ops.moe_combine(
            st["back"].reshape(p_axis * cp_c, d),
            st["plan1"].slot.reshape(tc, sc),
            wf_c,
        )
        return out_c, st["kept"]

    stage_fns = [s_build]
    if len(disp_stages) == 2:
        stage_fns.append(s_disp2)
    stage_fns.append(s_ffn)
    if len(ret_stages) == 2:
        stage_fns.append(s_ret2)
    stage_fns.append(s_combine)

    results = overlap.software_pipeline(chunks, stage_fns)
    out = jnp.concatenate([r[0] for r in results], axis=0)
    kept = sum(r[1] for r in results)
    return out, kept


def _moe_mixnet(
    params, x, cfg, plan: ShardingPlan, mesh, expert_perm, wire_perm=None,
    gate_weights=None,
):
    """``expert_perm`` is THIS layer's ``[E_virtual]`` expert->slot map (one
    row of the trainer's per-layer perm stack); ``wire_perm`` its optional
    ``[P]`` device map when the plan was installed as a wire re-address
    instead of a weight gather (``op.reconfigure`` semantics);
    ``gate_weights`` an optional ``[B, S]`` per-token telemetry weight (the
    serving engine's live-slot mask, DESIGN.md §9)."""
    e = cfg.moe
    ev, _ = virtual_experts(e.num_experts, plan.model_size)

    def body(
        router, w_in, w_gate, w_out, xl, perm, wire=None, gw=None,
        axis_names=(), token_axes=(),
    ):
        return _moe_mixnet_local(
            (router, w_in, w_gate, w_out), xl, cfg, plan, perm, axis_names,
            wire_perm=wire, token_axes=token_axes, gate_weights=gw,
        )

    if mesh is None or plan.model_size <= 1:
        out, load, balance, z, drop = body(
            params["router"], params["w_in"], params["w_gate"], params["w_out"],
            x, expert_perm, wire_perm, gate_weights,
        )
    else:
        ex_ax = plan.dim_axis(ev)
        axis_names = tuple(a for a in (plan.batch_axes or ()) if a) + (
            (plan.model_axis,) if plan.model_axis else ()
        )
        # Token sharding for the shard_map region: seq over the model axis
        # for train/prefill; decode (S=1) shards batch only — every device
        # dispatches its batch rows to the expert owners over the a2a.
        b_sz, s_sz = x.shape[0], x.shape[1]
        batch_ax = (
            (plan.batch_axes or None)
            if b_sz % max(plan.data_size, 1) == 0
            else None
        )
        seq_ax = plan.model_axis if s_sz % plan.model_size == 0 else None
        # Axes that actually shard the token dim (telemetry psums over these;
        # axes the tokens ride replicated take pmean instead).
        token_axes = (
            tuple(a for a in (batch_ax or ()) if a)
            + ((seq_ax,) if seq_ax else ())
        )
        tok_spec = P(batch_ax, seq_ax, None)
        gw_spec = P(batch_ax, seq_ax)
        weight_specs = (
            P(None, None),
            P(ex_ax, None, None),
            P(ex_ax, None, None),
            P(ex_ax, None, None),
        )
        out_specs = (tok_spec, P(None), P(), P(), P())
        args = [
            params["router"], params["w_in"], params["w_gate"], params["w_out"],
            x, expert_perm,
        ]
        in_specs = [*weight_specs, tok_spec, P(None)]
        has_wire = wire_perm is not None
        has_gw = gate_weights is not None
        if has_wire:
            args.append(wire_perm)
            in_specs.append(P(None))
        if has_gw:
            args.append(gate_weights)
            in_specs.append(gw_spec)

        def wrapped(*a):
            base, rest = a[:6], list(a[6:])
            wire = rest.pop(0) if has_wire else None
            gw = rest.pop(0) if has_gw else None
            return body(
                *base, wire=wire, gw=gw, axis_names=axis_names,
                token_axes=token_axes,
            )

        fn = shard_map(
            wrapped, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs,
            check_vma=False,
        )
        out, load, balance, z, drop = fn(*args)
    return out, MoEStats(load, balance, z, drop)


# ---------------------------------------------------------------------------
# dense decode backend
# ---------------------------------------------------------------------------


def _moe_dense_decode(
    params, x, cfg, plan: ShardingPlan, mesh=None, expert_perm=None,
    gate_weights=None,
):
    """Decode-time MoE: compute ALL experts densely on the handful of live
    tokens and combine with the (sparse) gate weights.

    At decode the token count is tiny, so the extra FLOPs of computing every
    expert (~1 ms on 256 chips for deepseek-v2's 128 tokens) are nothing —
    while the sparse dispatch path must gather 2D-sharded expert weights
    over the FSDP axis every layer (~27 GB/step for deepseek-v2).  Dense
    decode keeps weights stationary: activations ride the contractions
    (psums of a few MB).  §Perf beyond-paper optimization.

    The gate map comes from the routing core's virtual-slot destinations, so
    the layer's ``expert_perm`` re-addressing applies here exactly as it
    does on the sparse paths (decode after a runtime reconfiguration hits
    physically permuted expert weights).  The combine gathers each choice's
    expert output by ``vdest`` and sums in GATE order — the contraction
    never sees the physical slot positions, so decode tokens are
    BIT-identical under any ``expert_perm`` (the DESIGN.md §9
    generation-consistency guarantee; a dense ``[T, Ev]`` scatter would sum
    in slot order, whose float association moves with the permutation).

    ``gate_weights`` (``[B, S]``) weights the exported expert-load telemetry
    per token — the serving engine's live-slot mask (DESIGN.md §9).
    """
    e = cfg.moe
    b, s, d = x.shape
    ev, r = virtual_experts(e.num_experts, plan.model_size)
    xt = x.reshape(b * s, d)
    logits = xt.astype(jnp.float32) @ params["router"]
    info = routing.compute_routing(
        logits, top_k=e.top_k, num_virtual=ev, replication=r,
        expert_perm=expert_perm,
    )

    ex_ax = plan.dim_axis(ev)
    h = jnp.einsum("td,edf->tef", xt, params["w_in"])
    g = jnp.einsum("td,edf->tef", xt, params["w_gate"])
    h = _actfn(cfg.act)(g) * h
    h = constrain(h, mesh, P(None, ex_ax, None))
    y = jnp.einsum("tef,efd->ted", h, params["w_out"])
    ysel = jnp.take_along_axis(y, info.vdest[:, :, None], axis=1)  # [T, S, D]
    out = jnp.einsum("ts,tsd->td", info.wfull.astype(y.dtype), ysel)
    out = out.reshape(b, s, d)

    balance, z = router_losses(logits, info.idx, e.num_experts)
    load = routing.expert_load(info.idx, e.num_experts, weights=gate_weights)
    return out, MoEStats(load, balance, z, jnp.zeros((), jnp.float32))


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def moe_apply(
    params,
    x: jax.Array,
    cfg,
    plan: ShardingPlan,
    *,
    mesh=None,
    expert_perm=None,
    wire_perm=None,
    backend: str | None = None,
    mode: str | None = None,
    gate_weights: jax.Array | None = None,
):
    """``wire_perm``: optional ``[P]`` device map from a wire-level
    re-address (this layer's experts logically on device k physically live
    on device ``wire_perm[k]``; weights were NOT gathered).  The mixnet
    backend realizes it on the a2a wire; the dense backends compose it into
    the slot addressing so every path hits the physically-resident weights.
    ``gate_weights``: optional ``[B, S]`` per-token telemetry weight — the
    serving engine's live-slot mask, so the exported expert load counts only
    occupied decode slots (DESIGN.md §9)."""
    e = cfg.moe
    if e.draft_mode == "topk1" and e.top_k > 1:
        # Speculative draft (DESIGN.md §11): narrow the routed fan-out to the
        # gate's single best expert.  Rewriting the frozen config keeps every
        # backend below unchanged; the draft step jit-compiles separately
        # because the config is its static key.
        cfg = dataclasses.replace(
            cfg,
            moe=dataclasses.replace(
                e, top_k=routing.effective_top_k(e.top_k, e.draft_mode),
                draft_mode="off",
            ),
        )
        e = cfg.moe
    backend = backend or e.backend
    if backend != "einsum" and (x.shape[1] == 1 or mode == "decode"):
        # Single-token decode: weight-stationary dense path (see docstring)
        # unless the config pins the sparse EP dispatch at decode — the
        # serving engine's a2a-per-tick path (``MoEConfig.decode_backend``).
        if not (backend == "mixnet" and e.decode_backend == "sparse"):
            backend = "dense_decode"
    ev, _ = virtual_experts(e.num_experts, plan.model_size)
    perm = routing.resolve_perm(expert_perm, ev)
    if wire_perm is not None and backend != "mixnet":
        # Logical slot s lives at physical slot wire[s // epd] * epd + s % epd.
        p_axis = max(plan.model_size, 1)
        epd = ev // p_axis
        wire = jnp.asarray(wire_perm, jnp.int32)
        perm = wire[perm // epd] * epd + perm % epd
        wire_perm = None
    if e.draft_mode == "shared_only":
        # Speculative draft with only the always-on lane: skip the routed
        # experts (and their dispatch a2a) entirely.  Zero routed output plus
        # the shared-expert addition below; telemetry exports a zero load so
        # draft passes never perturb the control plane's gate statistics.
        zero = jnp.zeros((), jnp.float32)
        out = jnp.zeros_like(x)
        stats = MoEStats(
            jnp.zeros((e.num_experts,), jnp.float32), zero, zero, zero
        )
    elif backend == "dense_decode":
        out, stats = _moe_dense_decode(
            params, x, cfg, plan, mesh=mesh, expert_perm=perm,
            gate_weights=gate_weights,
        )
    elif backend == "mixnet":
        out, stats = _moe_mixnet(
            params, x, cfg, plan, mesh, perm, wire_perm=wire_perm,
            gate_weights=gate_weights,
        )
    elif backend == "einsum":
        out, stats = _moe_einsum(params, x, cfg, plan, mesh=mesh, expert_perm=perm)
    else:
        raise ValueError(f"unknown MoE backend {backend!r}")
    if "shared" in params:
        sh = params["shared"]
        h = x @ sh["w_in"]
        g = jax.nn.silu(x @ sh["w_gate"])
        out = out + (g * h) @ sh["w_out"]
    return out, stats


# ---------------------------------------------------------------------------
# speculative drafts (DESIGN.md §11)
# ---------------------------------------------------------------------------


def resolve_draft_mode(cfg, mode: str = "auto") -> str:
    """Pick the cheap draft pass for speculative decoding.

    ``auto`` prefers ``shared_only`` when the model has an always-on shared
    lane (the draft is then a dense sub-network of the full model) and falls
    back to ``topk1`` for pure sparse MoEs; non-MoE models have no cheaper
    self-draft, so the draft IS the target model (``off`` — acceptance 1.0).
    """
    if mode != "auto":
        return mode
    if not cfg.is_moe:
        return "off"
    return "shared_only" if cfg.moe.num_shared_experts > 0 else "topk1"


def draft_config(cfg, mode: str = "auto"):
    """The draft-model config: same weights, ``draft_mode`` set on the MoE
    block.  A distinct frozen config means the draft step is its own jit
    program (Kossmann et al.: bucket the specializations, don't re-jit)."""
    mode = resolve_draft_mode(cfg, mode)
    if mode == "off" or not cfg.is_moe:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, draft_mode=mode)
    )
