"""Mamba-2 SSD (state-space duality) block — arXiv:2405.21060.

Training/prefill use the chunked dual form: quadratic attention-like
computation inside length-``L`` chunks plus a linear inter-chunk state
recurrence (a ``lax.scan`` over chunks).  Decode is the pure recurrence on a
``[B, H, P, N]`` state — constant memory per token, which is why the
``long_500k`` cell runs for this family (DESIGN.md §4).

Head dim P shards over the ``model`` axis through the heads dim of the
projections; the state dim N stays local.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import rms_norm

__all__ = ["init_ssm", "ssm_apply", "init_ssm_cache"]


def init_ssm(key, cfg, plan):
    s = cfg.ssm
    d = cfg.d_model
    inner = s.expand * d
    heads = inner // s.head_dim
    dtype = jnp.dtype(cfg.dtype)
    k = jax.random.split(key, 6)
    head_ax = plan.heads_axis(heads)
    params = {
        # Fused input projection: [z | x | B | C | dt].
        "w_z": jax.random.normal(k[0], (d, inner), dtype) * d**-0.5,
        "w_x": jax.random.normal(k[1], (d, inner), dtype) * d**-0.5,
        "w_B": jax.random.normal(k[2], (d, s.state_dim), dtype) * d**-0.5,
        "w_C": jax.random.normal(k[3], (d, s.state_dim), dtype) * d**-0.5,
        "w_dt": jax.random.normal(k[4], (d, heads), dtype) * d**-0.5,
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "A_log": jnp.zeros((heads,), jnp.float32),  # A = -exp(A_log)
        "D": jnp.ones((heads,), jnp.float32),
        "conv": jax.random.normal(k[5], (s.conv_width, inner), dtype) * 0.1,
        "norm": jnp.zeros((inner,), dtype),
        "w_out": jax.random.normal(k[5], (inner, d), dtype) * inner**-0.5,
    }
    specs = {
        "w_z": P(plan.fsdp_axis, plan.dim_axis(inner)),
        "w_x": P(plan.fsdp_axis, plan.dim_axis(inner)),
        "w_B": P(plan.fsdp_axis, None),
        "w_C": P(plan.fsdp_axis, None),
        "w_dt": P(plan.fsdp_axis, head_ax),
        "dt_bias": P(head_ax),
        "A_log": P(head_ax),
        "D": P(head_ax),
        "conv": P(None, plan.dim_axis(inner)),
        "norm": P(plan.dim_axis(inner)),
        "w_out": P(plan.dim_axis(inner), plan.fsdp_axis),
    }
    return params, specs


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None):
    """Depthwise causal conv, width W.  x [B,S,C], w [W,C].

    Returns (y, new_state) where state carries the last W-1 inputs for
    decode continuation.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+W-1, C]
    y = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width))
    new_state = xp[:, -(width - 1) :, :]
    return jax.nn.silu(y), new_state


def _ssd_chunked(x, dt, a_log, bmat, cmat, chunk):
    """Chunked SSD scan.

    x ``[B,S,H,P]``, dt ``[B,S,H]`` (softplus-ed), a_log ``[H]``,
    bmat/cmat ``[B,S,N]`` -> y ``[B,S,H,P]``.
    """
    b, s_len, h, p = x.shape
    n = bmat.shape[-1]
    l = min(chunk, s_len)
    while s_len % l:
        l -= 1
    c = s_len // l
    a = -jnp.exp(a_log)  # [H] negative
    xd = x * dt[..., None]  # dt-weighted input
    da = dt * a  # [B,S,H] log-decay per step

    xc = xd.reshape(b, c, l, h, p)
    dac = da.reshape(b, c, l, h)
    bc = bmat.reshape(b, c, l, n)
    cc = cmat.reshape(b, c, l, n)
    cum = jnp.cumsum(dac, axis=2)  # [B,c,L,H] within-chunk cumulative decay

    # ---- intra-chunk (attention-like, lower-triangular) -------------------
    cb = jnp.einsum("bcln,bcsn->bcls", cc, bc)  # [B,c,L,L]
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,c,L(q),L(k),H]
    mask = jnp.tril(jnp.ones((l, l), bool))
    gate = jnp.where(mask[None, None, :, :, None], jnp.exp(decay), 0.0)
    y_intra = jnp.einsum("bcls,bclsh,bcshp->bclhp", cb, gate, xc)

    # ---- chunk state summaries --------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,c,L,H]
    states = jnp.einsum("bcsn,bcsh,bcshp->bchpn", bc, decay_to_end, xc)

    # ---- inter-chunk recurrence (scan over chunks) -------------------------
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B,c,H]

    def step(h_prev, inputs):
        st, dec = inputs  # [B,H,P,N], [B,H]
        h_new = h_prev * dec[:, :, None, None] + st
        return h_new, h_prev

    init = jnp.zeros((b, h, p, n), jnp.float32)
    _, h_prevs = jax.lax.scan(
        step,
        init,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2).astype(jnp.float32)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # [B,c,H,P,N]

    decay_from_start = jnp.exp(cum)  # [B,c,L,H]
    y_inter = jnp.einsum(
        "bcln,bclh,bchpn->bclhp", cc, decay_from_start, h_prevs.astype(cc.dtype)
    )
    y = (y_intra + y_inter).reshape(b, s_len, h, p)
    return y


def ssm_apply(params, x, cfg, *, mode="train", cache=None, t=None):
    """Mamba-2 block.  x [B,S,D] -> [B,S,D]; decode keeps S==1."""
    s = cfg.ssm
    b, seq, d = x.shape
    inner = s.expand * d
    heads = inner // s.head_dim
    z = x @ params["w_z"]
    xi = x @ params["w_x"]
    bm = x @ params["w_B"]
    cm = x @ params["w_C"]
    dt = jax.nn.softplus(
        (x @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"]
    )  # [B,S,H]

    conv_state = cache.get("conv") if cache else None
    if mode == "decode":
        xi, new_conv = _causal_conv(xi, params["conv"], conv_state)
        xh = xi.reshape(b, 1, heads, s.head_dim)
        a = -jnp.exp(params["A_log"])
        h_prev = cache["state"]  # [B,H,P,N]
        dec = jnp.exp(dt[:, 0, :] * a)  # [B,H]
        upd = jnp.einsum(
            "bn,bhp->bhpn", bm[:, 0].astype(jnp.float32),
            (xh[:, 0] * dt[:, 0, :, None]).astype(jnp.float32),
        )
        h_new = h_prev * dec[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", cm[:, 0].astype(jnp.float32), h_new)
        y = y + params["D"][:, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(b, 1, inner).astype(x.dtype)
        cache = {"state": h_new, "conv": new_conv}
    else:
        xi, new_conv = _causal_conv(xi, params["conv"], None)
        xh = xi.reshape(b, seq, heads, s.head_dim)
        y = _ssd_chunked(
            xh.astype(jnp.float32), dt, params["A_log"], bm.astype(jnp.float32),
            cm.astype(jnp.float32), s.chunk,
        )
        y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(b, seq, inner).astype(x.dtype)
        if mode == "prefill":
            # Final state for decode continuation: rerun recurrence tail.
            a = -jnp.exp(params["A_log"])
            da = dt * a
            cum_total = jnp.cumsum(da, axis=1)
            decay_to_end = jnp.exp(cum_total[:, -1:, :] - cum_total)
            state = jnp.einsum(
                "bsn,bsh,bshp->bhpn",
                bm.astype(jnp.float32),
                decay_to_end,
                (xh * dt[..., None]).astype(jnp.float32),
            )
            cache = {"state": state, "conv": new_conv}
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["norm"], cfg.norm_eps)
    return y @ params["w_out"], cache


def init_ssm_cache(cfg, batch: int, dtype=None):
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    heads = inner // s.head_dim
    return {
        "state": jnp.zeros((batch, heads, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, inner), jnp.float32),
    }
