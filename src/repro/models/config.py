"""Model configuration schema covering all assigned architecture families:
dense / MoE / SSM / hybrid (RG-LRU) / audio enc-dec / VLM backbones.

A model is a cycle of block kinds (``block_pattern``) scanned
``num_layers / len(pattern)`` times — this keeps the HLO small (one scan
body per pattern) while expressing alternating structures like gemma2's
local/global attention or recurrentgemma's 2:1 RG-LRU:attention ratio.
"""

from __future__ import annotations

import dataclasses
import math

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0  # routed experts
    top_k: int = 0
    d_ff: int = 0  # per-expert hidden width
    num_shared_experts: int = 0  # deepseek-v2 style always-on experts
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    balance_loss: float = 1e-2
    # Dispatch backend: "einsum" (GShard dense dispatch — the paper-faithful
    # baseline a fat-tree style fabric serves) or "mixnet" (hierarchical
    # shard_map all-to-all with runtime expert placement — the paper's
    # system, adapted per DESIGN.md).
    backend: str = "einsum"
    # Hierarchical a2a group size (scale-up stage width) for the mixnet path.
    a2a_group: int = 4
    # Fuse the payload + gate-metadata transfers of the mixnet dispatch into
    # ONE packed a2a (bit-identical payload; halves the phase count).  Off
    # only for the unfused-parity regression baseline.
    a2a_fuse: bool = True
    # Token-dispatch semantics (repro.models.routing): "dropless" routes every
    # token (MegaBlocks-style sort-based layout, static shapes; capacity_factor
    # ignored) or "capacity" drops overflow beyond the capacity_factor buffers.
    dispatch: str = "dropless"
    # Row-tile height of the dropless block layout (the grouped GEMM's unit of
    # expert ownership; 8 = f32 sublane minimum, raise towards 128 for MXU).
    dispatch_block: int = 8
    # Chunked comm/compute overlap (repro.core.overlap, DESIGN.md §8): the
    # mixnet backend splits the token dim into this many chunks and
    # software-pipelines chunk k+1's dispatch a2a under chunk k's expert FFN
    # under chunk k-1's combine a2a.  1 = the serial path; >1 is bit-identical
    # to it (chunk rows are independent; capacity-mode keep decisions stay
    # global).  Degrades to the nearest divisor of the local token count.
    overlap_chunks: int = 1
    # Decode-time MoE path (DESIGN.md §9): "dense" computes every expert on
    # the handful of live tokens (weight-stationary, the §Perf default);
    # "sparse" keeps the configured backend's sparse dispatch at decode — the
    # mixnet backend then runs the EP all-to-all (with wire perms) for every
    # decode tick, the serving engine's EP-sharded decode path.
    decode_backend: str = "dense"
    # Speculative-decoding draft pass (DESIGN.md §11): same weights, cheaper
    # routed fan-out.  "off" = the full model; "topk1" narrows routing to the
    # single best expert per token; "shared_only" skips the routed experts
    # entirely (shared-expert + attention only — free when
    # num_shared_experts > 0).  Being part of the frozen config makes the
    # draft step a *separate jit program* from the verify step.
    draft_mode: str = "off"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    nope_head_dim: int = 128
    rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD parameters."""

    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    # Block pattern cycled over layers. Kinds: "global" (full attention),
    # "local" (sliding window), "rglru" (RG-LRU recurrent), "ssm" (mamba2).
    block_pattern: tuple = ("global",)
    # Extra non-repeating blocks appended after the scanned stack (for layer
    # counts not divisible by the pattern, e.g. recurrentgemma's 38 = 12x3+2).
    tail_pattern: tuple = ()
    window_size: int = 4096
    logit_softcap: float | None = None  # gemma2 attention softcap
    final_softcap: float | None = None  # gemma2 final-logit softcap
    rope_theta: float = 10_000.0
    mrope_sections: tuple | None = None  # qwen2-vl M-RoPE (t,h,w) halves
    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Optional sub-configs
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # Encoder-decoder (whisper): encoder layers + fixed source length; the
    # modality frontend is a stub — inputs are precomputed frame embeddings.
    encoder_layers: int = 0
    encoder_seq: int = 0
    # VLM stub frontend: number of prepended patch embeddings in input_specs.
    vision_patches: int = 0
    # Optimizer moment dtype ("float32" | "bfloat16") — giant configs use
    # bf16 moments to fit HBM (DESIGN.md §5).
    opt_moment_dtype: str = "float32"
    # Remat policy for the scanned blocks: "none" | "full" | "dots".
    remat: str = "full"
    # Explicit Megatron-SP shard_map for dense MLP + attention o-proj
    # (beyond-paper perf path: guarantees reduce-scatter TP combines).
    sp_shardmap: bool = False
    # Double-buffered FSDP weight prefetch (repro.core.overlap, DESIGN.md §8):
    # block l+1's FFN weights are gathered over the fsdp axis with the
    # explicit AllGather ring while block l computes, instead of XLA's
    # on-demand gather at first use.  Train mode only; needs a mesh with an
    # fsdp axis.
    fsdp_prefetch: bool = False

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def pattern_repeats(self) -> int:
        scanned = self.num_layers - len(self.tail_pattern)
        if scanned % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: {scanned} scanned layers not divisible by "
                f"pattern {self.block_pattern}"
            )
        return scanned // len(self.block_pattern)

    @property
    def is_moe(self) -> bool:
        return self.moe is not None and self.moe.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends to unbounded context quadratically."""
        return all(
            k in ("local", "rglru", "ssm")
            for k in (*self.block_pattern, *self.tail_pattern)
        )

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have decode paths (see DESIGN.md)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline's
        MODEL_FLOPS = 6*N*D."""
        d = self.d_model
        dh = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        for kind in self.block_pattern:
            total += self._block_params(kind, d, dh) * self.pattern_repeats
        for kind in self.tail_pattern:
            total += self._block_params(kind, d, dh)
        if self.encoder_layers:
            total += self.encoder_layers * self._block_params("global", d, dh)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dh = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = emb
        attn = self._attn_params(d, dh)
        expert = 3 * d * self.moe.d_ff
        active = (self.moe.top_k + self.moe.num_shared_experts) * expert
        total += self.num_layers * (attn + active + 2 * d)
        return total

    def _attn_params(self, d: int, dh: int) -> int:
        if self.mla is not None:
            m = self.mla
            q = d * m.q_lora_rank + m.q_lora_rank * self.num_heads * (
                m.nope_head_dim + m.rope_head_dim
            )
            kv = d * (m.kv_lora_rank + m.rope_head_dim) + m.kv_lora_rank * (
                self.num_heads * (m.nope_head_dim + m.v_head_dim)
            )
            o = self.num_heads * m.v_head_dim * d
            return q + kv + o
        return d * self.num_heads * dh + 2 * d * self.num_kv_heads * dh + self.num_heads * dh * d

    def _block_params(self, kind: str, d: int, dh: int) -> int:
        norm = 2 * d
        if kind == "ssm":
            s = self.ssm
            inner = s.expand * d
            return norm + 2 * d * inner + inner * d + inner * (s.conv_width + 2)
        if kind == "rglru":
            width = d
            mult = 3 if self.act in ("silu", "swiglu", "geglu") else 2
            return (
                2 * norm
                + 3 * d * width  # y / x / out projections
                + 2 * width * width  # recurrence + input gates
                + 7 * width  # conv(4) + biases + lambda
                + mult * d * self.d_ff
            )
        attn = self._attn_params(d, dh)
        if self.is_moe:
            e = self.moe
            ffn = (e.num_experts + e.num_shared_experts) * 3 * d * e.d_ff + d * e.num_experts
        else:
            mult = 3 if self.act in ("silu", "swiglu", "geglu") else 2
            ffn = mult * d * self.d_ff
        return norm + attn + ffn

    def model_flops_per_token(self) -> float:
        """6 * N_active * 1 — the roofline MODEL_FLOPS rate (per token)."""
        return 6.0 * self.active_param_count()

    def validate(self) -> None:
        assert self.d_model > 0 and self.num_layers > 0
        if self.num_heads and self.num_kv_heads:
            assert self.num_heads % self.num_kv_heads == 0
        _ = self.pattern_repeats
        if self.is_moe:
            assert self.moe.top_k <= self.moe.num_experts
            assert self.moe.dispatch in ("dropless", "capacity")
            assert self.moe.overlap_chunks >= 1
            assert self.moe.decode_backend in ("dense", "sparse")
            assert self.moe.draft_mode in ("off", "topk1", "shared_only")


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A small same-family config for CPU smoke tests."""
    pattern = cfg.block_pattern
    defaults = dict(
        num_layers=2 * len(pattern) + len(cfg.tail_pattern),
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        dtype="float32",
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=32 if cfg.encoder_seq else 0,
        vision_patches=8 if cfg.vision_patches else 0,
        remat="none",
    )
    if cfg.moe is not None:
        defaults["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            d_ff=64,
        )
    if cfg.mla is not None:
        defaults["mla"] = MLAConfig(
            kv_lora_rank=32, q_lora_rank=48, nope_head_dim=16, rope_head_dim=8,
            v_head_dim=16,
        )
    if cfg.ssm is not None:
        defaults["ssm"] = SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=16)
    defaults.update(overrides)
    return dataclasses.replace(cfg, **defaults)
