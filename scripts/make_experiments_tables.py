"""Regenerate the EXPERIMENTS.md roofline tables from dryrun_results.json."""

import json
import sys


def table(rows, multi_pod):
    out = []
    out.append(
        "| arch | shape | bottleneck | compute (s) | memory (s) | collective (s) "
        "| MODEL/HLO | roofline frac | HBM/dev | fits 16G |"
    )
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if bool(r.get("multi_pod")) != multi_pod:
            continue
        if r["status"] == "skipped":
            out.append(
                f"| {r['arch']} | {r['shape']} | — skipped — | | | | | | | |"
            )
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['bottleneck']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {r['per_device_hbm_peak']/1e9:.1f} GB | {'yes' if r['fits_hbm'] else 'NO'} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    rows = json.load(open(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"))
    print("### Single-pod (16x16 = 256 chips)\n")
    print(table(rows, False))
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    print(table(rows, True))
