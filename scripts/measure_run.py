"""Offline measurement report from an exported trace file (DESIGN.md §14).

Reads a Chrome/Perfetto trace written by ``repro.obs.trace.export`` (e.g.
``examples/serve.py --trace /tmp/serve_trace.json``), schema-checks it, and
reproduces the paper's §3 measurement study from the ``traffic.report``
audit events the engines embed: per-layer expert-traffic locality, hottest-
device concentration, effective expert count, and regional skew.  Also
summarizes the structured decision/reconfiguration audit stream and the
counter series, so one trace file answers "what did this run do and why".

    PYTHONPATH=src python scripts/measure_run.py TRACE.json [--json OUT.json]
"""

import argparse
import json
import sys
from collections import Counter, defaultdict

sys.path.insert(0, "src")

from repro.obs import trace
from repro.obs.traffic import TrafficObservatory


def load_events(path: str) -> list[dict]:
    with open(path) as f:
        doc = json.load(f)
    return doc.get("traceEvents", doc) if isinstance(doc, dict) else doc


def span_summary(events: list[dict]) -> dict[str, dict]:
    """name -> {count, total_ms, mean_ms} over complete (ph="X") spans."""
    agg: dict[str, list[float]] = defaultdict(list)
    for ev in events:
        if ev.get("ph") == "X":
            agg[ev["name"]].append(float(ev.get("dur", 0.0)) / 1e3)
    return {
        name: {
            "count": len(durs),
            "total_ms": sum(durs),
            "mean_ms": sum(durs) / len(durs),
        }
        for name, durs in sorted(agg.items())
    }


def counter_totals(events: list[dict]) -> dict[str, float]:
    """Last sample per counter series (samples are cumulative per emitter)."""
    last: dict[str, float] = {}
    for ev in events:
        if ev.get("ph") == "C":
            for series, v in ev.get("args", {}).items():
                key = (
                    ev["name"] if series == "value" else f"{ev['name']}.{series}"
                )
                last[key] = float(v)
    return last


def decision_summary(events: list[dict]) -> Counter:
    kinds: Counter = Counter()
    for ev in events:
        if ev.get("cat") in ("decision", "reconfig_audit") and ev.get("ph") in (
            "i", "I",
        ):
            kinds[ev["name"]] += 1
    return kinds


def traffic_reports(events: list[dict]) -> dict[str, TrafficObservatory]:
    """scope -> rebuilt observatory (last report per scope wins: reports are
    cumulative snapshots of the run so far)."""
    out: dict[str, TrafficObservatory] = {}
    for ev in events:
        if ev.get("name") == "traffic.report" and ev.get("cat") == "traffic":
            args = ev.get("args", {})
            if "report" in args:
                out[args.get("scope", "run")] = TrafficObservatory.from_report(
                    args["report"]
                )
    return out


def print_observatory(scope: str, obs: TrafficObservatory) -> None:
    loc = obs.locality_per_layer()
    conc = obs.device_concentration()
    eff = obs.effective_experts()
    print(f"\n  §3 traffic study [{scope}] — {obs.ticks} ticks, "
          f"{obs.num_layers} layers x {obs.num_experts} experts on "
          f"{obs.num_devices} devices:")
    print(f"    locality score (normalized HHI, 0=uniform 1=one expert): "
          f"{obs.locality_score():.3f}")
    print("    layer  locality  hottest-device-share  effective-experts")
    for l in range(obs.num_layers):
        print(f"    {l:>5}  {loc[l]:>8.3f}  {conc[l]:>20.3f}  {eff[l]:>17.2f}")
    if obs.num_regions:
        print(f"    regional skew (Bhattacharyya miss vs global mix): "
              f"{obs.regional_skew():.3f} over {obs.num_regions} regions")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="trace JSON exported by repro.obs.trace")
    ap.add_argument("--json", default="", help="also dump the report as JSON")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero on schema failures")
    args = ap.parse_args()

    events = load_events(args.trace)
    failures = trace.validate_events(events)
    n_spans = sum(1 for e in events if e.get("ph") == "X")
    print(f"{args.trace}: {len(events)} events ({n_spans} spans), "
          f"schema {'OK' if not failures else 'FAILED'}")
    for f in failures[:10]:
        print(f"  schema: {f}")
    if failures and args.strict:
        raise SystemExit(1)

    spans = span_summary(events)
    if spans:
        print("\n  span time by name:")
        for name, s in spans.items():
            print(f"    {name:<24} x{s['count']:<5} total {s['total_ms']:>9.1f} ms"
                  f"  mean {s['mean_ms']:>8.2f} ms")

    decisions = decision_summary(events)
    if decisions:
        print("\n  decision / reconfiguration audit events:")
        for name, n in decisions.most_common():
            print(f"    {name:<24} x{n}")

    counters = counter_totals(events)
    if counters:
        print("\n  counter series (last sample):")
        for name, v in sorted(counters.items()):
            print(f"    {name:<24} {v:,.0f}")

    observatories = traffic_reports(events)
    for scope, obs in sorted(observatories.items()):
        print_observatory(scope, obs)
    if not observatories:
        print("\n  no traffic.report events (run a MoE serve/fleet example "
              "with --trace to capture the §3 study)")

    if args.json:
        doc = {
            "events": len(events),
            "schema_failures": failures,
            "spans": spans,
            "decisions": dict(decisions),
            "counters": counters,
            "traffic": {s: o.report() for s, o in observatories.items()},
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"\n  wrote {args.json}")


if __name__ == "__main__":
    main()
