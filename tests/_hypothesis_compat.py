"""Hypothesis shim: use the real library when installed, else a tiny
deterministic fallback.

The container running tier-1 does not ship ``hypothesis``; rather than skip
the property tests we fall back to a minimal implementation of the subset
they use (``given``, ``settings``, ``st.integers``, ``st.sampled_from``).
The fallback enumerates ``max_examples`` deterministic draws seeded from the
test name, so failures reproduce exactly across runs.  With hypothesis
installed (see requirements-dev.txt) the real engine — shrinking, the full
strategy library — is used instead.
"""

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ModuleNotFoundError:
    import zlib

    import numpy as np

    class _Strategy:
        def sample(self, rng):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, min_value, max_value):
            self.min_value = min_value
            self.max_value = max_value

        def sample(self, rng):
            return int(rng.integers(self.min_value, self.max_value + 1))

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def sample(self, rng):
            return self.options[int(rng.integers(len(self.options)))]

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=2**30):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

    st = _Strategies()

    def settings(max_examples=10, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            default_n = getattr(fn, "_compat_max_examples", 10)

            def wrapper():
                n = getattr(wrapper, "_compat_max_examples", default_n)
                base = zlib.crc32(fn.__qualname__.encode("utf-8"))
                for i in range(n):
                    rng = np.random.default_rng((base + i) % 2**32)
                    kwargs = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(**kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
