"""DESIGN.md §13: the pipeline-tier overlap accounting, the priced a2a
lowerings, capacity-dispatch goodput, the paper-scale layout helper, and the
cached measured autotuner that searches over all of them."""

import dataclasses
import json

import numpy as np
import pytest

from repro.configs.paper_models import MIXTRAL_8X7B, PAPER_SCALE_GPUS, scale_layout
from repro.core import autotune
from repro.core.commruntime import AllToAll, CommSpec
from repro.core.fabric import FabricConfig, make_fabric
from repro.core.netsim import simulate_training


def run(model, gbps=400, servers=16, iters=3, seed=0, fabric="mixnet"):
    fab = make_fabric(fabric, FabricConfig(num_servers=servers, link_gbps=gbps))
    return simulate_training(
        model, fab, iterations=iters, seed=seed,
        use_copilot=(fabric == "mixnet"),
    )


def mean_total(results):
    return float(np.mean([r.total for r in results[1:]]))


# ---- pipeline-tier overlap ------------------------------------------------

def test_pp_overlap_reduces_total_within_bubble_floor():
    base = dataclasses.replace(MIXTRAL_8X7B, overlap_chunks=2)
    on = dataclasses.replace(base, pp_overlap=True)
    r_off = run(base)[1:]
    r_on = run(on)[1:]
    assert mean_total(r_on) < mean_total([None] + r_off)
    for a, b in zip(r_off, r_on):
        hidden = b.pp_hidden_comm + b.dp_hidden
        assert hidden > 0.0
        # exact identity: the tier only ever subtracts what it hid
        np.testing.assert_allclose(a.total - hidden, b.total, rtol=1e-12)
        # the bubble is the hard budget, exposed comm + DP the supply
        assert b.pp_hidden_comm <= b.pp_bubble * (1 + 1e-12)
        assert b.pp_hidden_comm <= b.exposed_comm * (1 + 1e-12)
        assert b.dp_hidden <= b.dp_allreduce * (1 + 1e-12)
        assert hidden <= b.pp_bubble * (1 + 1e-12)


def test_pp_overlap_noop_without_bubble():
    base = dataclasses.replace(MIXTRAL_8X7B, pp_degree=1)
    on = dataclasses.replace(base, pp_overlap=True)
    for a, b in zip(run(base)[1:], run(on)[1:]):
        assert a.pp_bubble == b.pp_bubble == 0.0
        assert b.pp_hidden_comm == 0.0 and b.dp_hidden == 0.0
        np.testing.assert_allclose(a.total, b.total, rtol=1e-12)


# ---- a2a lowering pricing -------------------------------------------------

def test_a2a_lowering_pricing_order():
    """At training-scale payloads delegation wins: flat pays the per-message
    latency it amortizes, ring pays (r-1) store-and-forward hops."""
    servers = 16
    fab = make_fabric(
        "mixnet", FabricConfig(num_servers=servers, link_gbps=400))
    rng = np.random.default_rng(0)
    demand = rng.random((servers, servers)) * 256e6  # ~256 MB entries
    spec = CommSpec.from_fabric(fab, servers)
    costs = {
        low: AllToAll(spec, lowering=low).cost(fab, demand)
        for low in ("hier", "flat", "ring")
    }
    assert costs["hier"] <= costs["flat"]
    assert costs["hier"] <= costs["ring"]
    # default == hier (the executed delegation lowering)
    assert AllToAll(spec).cost(fab, demand) == costs["hier"]
    # unknown lowering rejected at construction/validation time
    with pytest.raises(ValueError):
        AllToAll(spec, lowering="mesh")


def test_a2a_lowering_execution_unchanged():
    """The lowering knob is pricing-side only: __call__ has no lowering
    branch, so every mode shares the executed delegation path."""
    import inspect

    src = inspect.getsource(AllToAll.__call__)
    assert "lowering" not in src


# ---- capacity dispatch ----------------------------------------------------

def test_capacity_dispatch_trades_tokens_for_time():
    dropless = MIXTRAL_8X7B
    capped = dataclasses.replace(
        MIXTRAL_8X7B, moe_dispatch="capacity", capacity_factor=1.0)
    r_drop = run(dropless)[1:]
    r_cap = run(capped)[1:]
    for a, b in zip(r_drop, r_cap):
        assert a.kept_fraction == 1.0
        assert 0.0 < b.kept_fraction < 1.0
        assert b.total < a.total  # dropped tokens skip wire + FFN
    # a generous cap keeps ~everything
    loose = dataclasses.replace(capped, capacity_factor=8.0)
    assert all(r.kept_fraction > 0.99 for r in run(loose)[1:])


# ---- paper-scale layouts --------------------------------------------------

def test_scale_layout_factorizations():
    for gpus in PAPER_SCALE_GPUS:
        m = scale_layout(MIXTRAL_8X7B, gpus)
        assert m.ep_degree * m.tp_degree * m.pp_degree == gpus, gpus
        assert m.tp_degree == MIXTRAL_8X7B.tp_degree  # shape-bound, fixed
        assert m.num_blocks % m.pp_degree == 0, gpus
        assert m.ep_degree >= 1 and m.pp_degree >= 1
    with pytest.raises(ValueError):
        scale_layout(MIXTRAL_8X7B, MIXTRAL_8X7B.tp_degree // 2 or 1)


# ---- the autotuner --------------------------------------------------------

SMALL_SPACE = {
    "overlap_chunks": (1, 4),
    "moe_dispatch": ("dropless", "capacity"),
    "a2a_lowering": ("hier",),
    "dp_compress": (False, True),
}


def test_tune_beats_default_and_caches(tmp_path):
    cache = str(tmp_path / "tune.json")
    res = autotune.tune(
        MIXTRAL_8X7B, "mixnet", 400, num_servers=16, cache_path=cache,
        iterations=2, space=SMALL_SPACE,
    )
    # pp_overlap never hurts in the flow model, so the winner (searched with
    # it on) must match or beat the default (priced with it off).
    assert res.speedup >= 1.0 - 1e-9
    assert res.knobs["pp_overlap"] is True
    assert set(res.knobs) == set(SMALL_SPACE) | {"pp_overlap"}
    assert res.key == autotune.cache_key(MIXTRAL_8X7B, "mixnet", 400)

    # on-disk round trip
    hit = autotune.load_cached(cache, res.key)
    assert hit is not None and hit.to_json() == res.to_json()
    # a second tune() call is a pure cache hit (no re-measurement): force a
    # broken space — a measurement would crash, the hit path never looks
    again = autotune.tune(
        MIXTRAL_8X7B, "mixnet", 400, num_servers=16, cache_path=cache,
        iterations=2, space={"a2a_lowering": ("not-a-lowering",)},
    )
    assert again.to_json() == res.to_json()
    # the file is plain JSON keyed by cache_key (the trainer reads it raw)
    with open(cache) as f:
        assert res.key in json.load(f)

    # apply() stamps the knobs onto a SimModel
    tuned_model = autotune.apply(MIXTRAL_8X7B, res)
    assert tuned_model.pp_overlap is True
    assert tuned_model.overlap_chunks == res.knobs["overlap_chunks"]
    # and the stamped model reproduces the measured winner's goodput
    assert tuned_model.moe_dispatch == res.knobs["moe_dispatch"]


def test_load_cached_misses_are_none(tmp_path):
    assert autotune.load_cached(str(tmp_path / "nope.json"), "k") is None
    p = tmp_path / "other.json"
    p.write_text(json.dumps({"other-key": {
        "key": "other-key", "knobs": {}, "goodput_tok_s": 1.0,
        "default_goodput_tok_s": 1.0}}))
    assert autotune.load_cached(str(p), "k") is None
    assert autotune.load_cached(str(p), "other-key") is not None


def test_apply_to_trainer_maps_only_executable_knobs():
    from repro.models.config import ModelConfig, MoEConfig
    from repro.train.trainer import TrainerConfig

    cfg = ModelConfig(
        "t", "moe", 2, 16, 2, 1, 0, 32, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=32, backend="mixnet"),
    )
    res = autotune.TuneResult(
        key="k",
        knobs={"overlap_chunks": 4, "moe_dispatch": "capacity",
               "a2a_lowering": "ring", "dp_compress": True,
               "pp_overlap": True},
        goodput_tok_s=2.0, default_goodput_tok_s=1.0,
    )
    # dp_comm='auto': dp_compress has no execution path -> dropped
    cfg2, tcfg2 = autotune.apply_to_trainer(cfg, TrainerConfig(), res)
    assert cfg2.moe.overlap_chunks == 4
    assert cfg2.moe.dispatch == "capacity"
    assert tcfg2.dp_compress is False
    # runtime DP reduction without PP: the knob maps
    _, tcfg3 = autotune.apply_to_trainer(
        cfg, TrainerConfig(dp_comm="runtime"), res)
    assert tcfg3.dp_compress is True
    # PP composes with dp_comm='auto' only -> again dropped
    _, tcfg4 = autotune.apply_to_trainer(
        cfg, TrainerConfig(pp_stages=2), res)
    assert tcfg4.dp_compress is False
    assert res.speedup == pytest.approx(2.0)


def test_trainer_consumes_autotune_cache(tmp_path):
    from repro.models.config import ModelConfig, MoEConfig
    from repro.optim.adamw import AdamWConfig
    from repro.parallel.sharding import make_plan
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = ModelConfig(
        "t", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=32, backend="mixnet"),
    )
    res = autotune.TuneResult(
        key="t|cache-key", knobs={"overlap_chunks": 4, "pp_overlap": True},
        goodput_tok_s=2.0, default_goodput_tok_s=1.0)
    cache = tmp_path / "cache.json"
    cache.write_text(json.dumps({res.key: res.to_json()}))
    opt = AdamWConfig(lr=1e-3)
    tr = Trainer(cfg, opt, TrainerConfig(
        total_steps=1, autotune_cache=str(cache), autotune_key=res.key),
        make_plan(None), seed=0)
    assert tr.cfg.moe.overlap_chunks == 4
    # a miss (wrong key or missing file) is a silent no-op
    tr2 = Trainer(cfg, opt, TrainerConfig(
        total_steps=1, autotune_cache=str(cache), autotune_key="absent"),
        make_plan(None), seed=0)
    assert tr2.cfg.moe.overlap_chunks == cfg.moe.overlap_chunks
