"""Pipeline parallelism x the MixNet control plane: a mid-run expert
re-placement (perm + wire re-address) applied to a PP(S=2) trainer matches
the flat trainer applying the SAME plan, and the PP trainer's checkpoint
round-trips params + placement state (DESIGN.md §13).

Both tests force the reconfiguration with a fixed load matrix (the
injection pattern from test_train.py) so the two trainers compare the same
plan rather than two independently-observed ones."""

_COMMON = """
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_mesh, use_mesh
from repro.models.config import ModelConfig, MoEConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.train.trainer import Trainer, TrainerConfig

CFG = ModelConfig('tiny-moe', 'moe', 4, 32, 3, 1, 0, 64, head_dim=8,
                  dtype='float32', remat='none',
                  moe=MoEConfig(num_experts=4, top_k=2, d_ff=32,
                                capacity_factor=2.0, backend='mixnet',
                                overlap_chunks=2))
OPT = AdamWConfig(lr=1e-3)
# A co-located hot pair per layer (device 0 holds experts {0,1}, device 1
# holds {2,3} at identity placement) -> splitting it is a real gain; the
# hot device alternates so adjacent layers get different perms.
LOADS = np.array([[40.0, 40.0, 1.0, 1.0],
                  [1.0, 1.0, 40.0, 40.0],
                  [40.0, 40.0, 1.0, 1.0],
                  [1.0, 1.0, 40.0, 40.0]])

def make_trainer(pp, tmp=None, **tkw):
    if pp > 1:
        mesh = make_mesh((pp, 2), ('stage', 'model'))
        plan = make_plan(mesh, fsdp=False)
    else:
        mesh = make_mesh((2,), ('model',))
        plan = make_plan(mesh)
    kw = dict(total_steps=2, num_microbatches=2, reconfig_every=1000,
              reconfig_min_gain=0.01, pp_stages=pp, ckpt_every=0)
    if tmp:
        kw.update(ckpt_every=2, ckpt_dir=tmp, ckpt_async=False)
    kw.update(tkw)
    tcfg = TrainerConfig(**kw)
    tr = Trainer(CFG, OPT, tcfg, plan, mesh=mesh, seed=0)
    return tr, mesh

def run_chunk(tr, mesh, data, upto):
    tr.tcfg = dataclasses.replace(tr.tcfg, total_steps=upto)
    with use_mesh(mesh):
        tr.train(data)
    return [float(m['loss']) for m in tr.metrics_log]

def force_reconfig(tr):
    # align the modulo gate, push the fixed plan, restore the step counter
    saved, tr.step = tr.step, tr.tcfg.reconfig_every
    tr._reconfigure_step(LOADS)
    tr.step = saved
"""

PARITY = _COMMON + """
tr_pp, mesh_pp = make_trainer(2)
tr_ref, mesh_ref = make_trainer(1)

d_pp = iter(SyntheticLM(CFG.vocab_size, 16, 4, seed=0))
d_ref = iter(SyntheticLM(CFG.vocab_size, 16, 4, seed=0))

run_chunk(tr_pp, mesh_pp, d_pp, 2)
run_chunk(tr_ref, mesh_ref, d_ref, 2)

force_reconfig(tr_pp)
force_reconfig(tr_ref)
# the forced plan actually moved experts, identically on both trainers
assert tr_pp.reconfig_count + tr_pp.wire_reconfig_count >= 1
assert tr_pp.reconfig_count == tr_ref.reconfig_count
assert tr_pp.wire_reconfig_count == tr_ref.wire_reconfig_count
perm_pp = np.asarray(tr_pp.expert_perm)
np.testing.assert_array_equal(perm_pp, np.asarray(tr_ref.expert_perm))
moved = (perm_pp != np.arange(CFG.moe.num_experts)).any()
wired = (tr_pp.wire_perm is not None
         and (np.asarray(tr_pp.wire_perm) != np.arange(2)).any())
assert moved or wired, (perm_pp, tr_pp.wire_perm)

l_pp = run_chunk(tr_pp, mesh_pp, d_pp, 4)
l_ref = run_chunk(tr_ref, mesh_ref, d_ref, 4)
np.testing.assert_allclose(l_pp, l_ref, rtol=1e-5)
for a, b in zip(jax.tree.leaves(tr_pp.params), jax.tree.leaves(tr_ref.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)
print('PP_RECONFIG_PARITY_OK')
"""


def test_pp_trainer_matches_flat_across_forced_reconfig(multidevice):
    out = multidevice(PARITY, devices=4, timeout=900)
    assert "PP_RECONFIG_PARITY_OK" in out


CKPT = _COMMON + """
import os, tempfile
tmp = tempfile.mkdtemp()

tr, mesh = make_trainer(2, tmp=tmp)
data = iter(SyntheticLM(CFG.vocab_size, 16, 4, seed=0))
run_chunk(tr, mesh, data, 2)
force_reconfig(tr)
run_chunk(tr, mesh, data, 4)  # checkpoints at steps 2 and 4
assert tr.reconfig_count + tr.wire_reconfig_count >= 1

tr2, mesh2 = make_trainer(2, tmp=tmp)
assert tr2.maybe_restore()
assert tr2.step == 4
# params AND placement state ride the same manifest (stage-stacking is a
# runtime view; the checkpoint stays in the canonical [repeats, ...] layout)
for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(tr2.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
np.testing.assert_array_equal(np.asarray(tr.expert_perm),
                              np.asarray(tr2.expert_perm))
assert tr2.reconfig_count == tr.reconfig_count
if tr.wire_perm is not None:
    np.testing.assert_array_equal(np.asarray(tr.wire_perm),
                                  np.asarray(tr2.wire_perm))

# one more step from the SAME restored state produces the same trajectory
d1 = iter(SyntheticLM(CFG.vocab_size, 16, 4, seed=7))
d2 = iter(SyntheticLM(CFG.vocab_size, 16, 4, seed=7))
l1 = run_chunk(tr, mesh, d1, 5)[-1]
l2 = run_chunk(tr2, mesh2, d2, 5)[-1]
np.testing.assert_allclose(l1, l2, rtol=1e-6)
print('PP_CKPT_OK')
"""


def test_pp_trainer_checkpoint_roundtrip_with_placement(multidevice):
    out = multidevice(CKPT, devices=4, timeout=900)
    assert "PP_CKPT_OK" in out
