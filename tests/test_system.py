"""End-to-end behaviour of the paper's system: the full MixNet control loop
(traffic monitor -> COPILOT -> Algorithm-1 placement -> expert-weight
permutation) running inside real training, plus the serving path and the
multi-device train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticLM
from repro.models.config import ModelConfig, MoEConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.serve.decode import generate
from repro.train.trainer import Trainer, TrainerConfig

PLAN = make_plan(None)


def test_mixnet_control_loop_reconfigures_under_skew():
    """Skewed expert demand must trigger at least one runtime re-placement,
    and training must stay numerically healthy through it (§6: 'MixNet does
    not affect the training accuracy')."""
    cfg = ModelConfig(
        "e2e-moe", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=2.0,
                      backend="mixnet"),
    )
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    tcfg = TrainerConfig(total_steps=16, reconfig_every=4, reconfig_min_gain=0.0)
    tr = Trainer(cfg, opt, tcfg, PLAN, seed=0)
    log = tr.train(iter(SyntheticLM(cfg.vocab_size, 16, 4, seed=0)))
    assert all(np.isfinite(m["loss"]) for m in log)
    # the control plane observed traffic and made decisions
    assert tr.controlplane is not None
    assert tr.controlplane.monitor.step == 16


def test_generate_end_to_end():
    cfg = ModelConfig("serve", "dense", 2, 32, 4, 2, 64, 64, dtype="float32",
                      remat="none")
    params, _ = __import__("repro.models.transformer", fromlist=["init_model"]).init_model(
        jax.random.PRNGKey(0), cfg, PLAN
    )
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out = generate(params, cfg, PLAN, prompt, max_new_tokens=6)
    assert out.shape == (2, 6)
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))
    # greedy decode is deterministic
    out2 = generate(params, cfg, PLAN, prompt, max_new_tokens=6)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


MULTIDEV_TRAIN = """
import jax, jax.numpy as jnp, numpy as np
from repro.models.config import ModelConfig, MoEConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.train.train_step import init_all, make_train_step, step_shardings

from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.launch.mesh import use_mesh as _compat_use_mesh
mesh = _compat_make_mesh((2, 4), ('data', 'model'))
plan = make_plan(mesh)
cfg = ModelConfig('md', 'moe', 2, 32, 4, 2, 0, 64, dtype='float32', remat='none',
                  moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=4.0,
                                backend='mixnet', a2a_group=2))
opt_cfg = AdamWConfig(lr=1e-3)
params, specs, opt_state = init_all(jax.random.PRNGKey(0), cfg, plan, opt_cfg)
p_sh, opt_sh, b_sh = step_shardings(cfg, plan, mesh, specs)
params = jax.device_put(params, p_sh)
opt_state = jax.device_put(opt_state, opt_sh)
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
batch = {'tokens': jax.device_put(tokens, b_sh['tokens']),
         'labels': jax.device_put(jnp.roll(tokens, -1, 1), b_sh['labels'])}
with _compat_use_mesh(mesh):
    step = jax.jit(make_train_step(cfg, plan, opt_cfg, mesh=mesh))
    params2, opt2, metrics = step(params, opt_state, batch)
loss_md = float(metrics['loss'])

# single-device reference
plan1 = make_plan(None)
cfg1 = cfg
params1, _, opt1 = init_all(jax.random.PRNGKey(0), cfg1, plan1, opt_cfg)
step1 = jax.jit(make_train_step(cfg1, plan1, opt_cfg))
_, _, m1 = step1(params1, opt1, {'tokens': tokens, 'labels': jnp.roll(tokens, -1, 1)})
loss_1 = float(m1['loss'])
# NOTE: params differ in expert-shard layout across plans (virtual experts),
# so only check both are finite and in the same ballpark.
assert np.isfinite(loss_md) and np.isfinite(loss_1)
assert abs(loss_md - loss_1) / loss_1 < 0.2, (loss_md, loss_1)
print('MULTIDEV_TRAIN_OK', loss_md, loss_1)
"""


def test_train_step_multidevice(multidevice):
    out = multidevice(MULTIDEV_TRAIN, devices=8, timeout=900)
    assert "MULTIDEV_TRAIN_OK" in out


def test_elastic_restore_across_meshes(multidevice):
    """Checkpoint written under one sharding restores under another (elastic
    restart: 8 devices -> different layout)."""
    code = """
import jax, jax.numpy as jnp, numpy as np, tempfile, os
from repro.train import checkpoint as ckpt
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.launch.mesh import use_mesh as _compat_use_mesh
mesh_a = _compat_make_mesh((8,), ('data',))
mesh_b = _compat_make_mesh((2, 4), ('data', 'model'))
tree = {'w': jax.device_put(jnp.arange(64.0).reshape(8, 8),
                            NamedSharding(mesh_a, P('data', None)))}
d = tempfile.mkdtemp()
ckpt.save(d, 1, tree)
target_sh = {'w': NamedSharding(mesh_b, P('model', 'data'))}
back = ckpt.restore(d, 1, tree, shardings=target_sh)
np.testing.assert_array_equal(np.asarray(back['w']), np.arange(64.0).reshape(8, 8))
assert back['w'].sharding == target_sh['w']
print('ELASTIC_OK')
"""
    out = multidevice(code, devices=8)
    assert "ELASTIC_OK" in out


SP_EQUIV = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.models.config import ModelConfig
from repro.models import transformer as tfm
from repro.parallel.sharding import make_plan

from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.launch.mesh import use_mesh as _compat_use_mesh
mesh = _compat_make_mesh((2, 4), ('data', 'model'))
plan = make_plan(mesh)
cfg = ModelConfig('sp', 'dense', 2, 32, 8, 4, 64, 128, dtype='float32', remat='none')
params, _ = tfm.init_model(jax.random.PRNGKey(0), cfg, plan)
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
with _compat_use_mesh(mesh):
    base, _, _ = jax.jit(lambda p, t: tfm.model_apply(p, {'tokens': t}, cfg, plan, mesh=mesh, mode='train'))(params, tokens)
    cfg_sp = dataclasses.replace(cfg, sp_shardmap=True)
    sp, _, _ = jax.jit(lambda p, t: tfm.model_apply(p, {'tokens': t}, cfg_sp, plan, mesh=mesh, mode='train'))(params, tokens)
err = float(jnp.max(jnp.abs(base - sp)))
assert err < 1e-4, err
print('SP_EQUIV_OK', err)
"""


def test_sp_shardmap_equivalence(multidevice):
    """The explicit Megatron-SP shard_map path (beyond-paper perf) computes
    the same function as the auto-partitioned path."""
    out = multidevice(SP_EQUIV, devices=8, timeout=900)
    assert "SP_EQUIV_OK" in out
