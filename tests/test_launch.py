"""Launch-layer units: trip-aware HLO analyzer, roofline math, sharding
plan rules, data pipeline determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import Batch, SyntheticLM
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import HW, analytic_hbm_bytes, roofline_from_counts
from repro.parallel.sharding import ShardingPlan, make_plan


def test_analyzer_counts_scan_trips():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    sds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(sds, sds).compile()
    r = analyze_hlo(compiled.as_text(), 1)
    assert r["flops"] == pytest.approx(10 * 2 * 64**3, rel=0.01)


def test_analyzer_collective_formulas():
    hlo = """
HloModule test, entry_computation_layout={()->f32[16,16]{1,0}}

ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  %ag = f32[16,16]{1,0} all-gather(%p), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %ar = f32[16,16]{1,0} all-reduce(%ag), replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    r = analyze_hlo(hlo, 8)
    size = 16 * 16 * 4
    assert r["collectives"]["all-gather"] == pytest.approx(size * 3 / 4)
    assert r["collectives"]["all-reduce"] == pytest.approx(2 * size * 3 / 4)


def test_roofline_terms_and_bottleneck():
    t = roofline_from_counts(
        arch="a", shape="s", mesh="16x16", chips=256,
        hlo_flops=256 * 197e12,  # exactly 1 second of compute
        hlo_bytes=256 * 819e9 * 0.5,
        collective_bytes=256 * 50e9 * 2.0,
        model_flops=256 * 197e12 * 0.8,
        per_device_hbm_peak=8e9,
    )
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.collective_s == pytest.approx(2.0)
    assert t.bottleneck == "collective"
    assert t.useful_ratio == pytest.approx(0.8)
    assert t.roofline_fraction == pytest.approx(0.4)


def test_analytic_hbm_scales_with_kind():
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES

    cfg = get_config("deepseek-7b")
    train = analytic_hbm_bytes(cfg, SHAPES["train_4k"])
    prefill = analytic_hbm_bytes(cfg, SHAPES["prefill_32k"])
    decode = analytic_hbm_bytes(cfg, SHAPES["decode_32k"])
    assert train > prefill > 0
    # decode traffic is dominated by weights + KV reads, far below train
    assert decode < train


def test_sharding_plan_rules():
    plan = ShardingPlan(("pod", "data"), "model", 16, "data", data_size=16)
    assert plan.heads_axis(96) == "model"
    assert plan.heads_axis(8) is None
    assert plan.dim_axis(28672) == "model"
    assert plan.dim_axis(1500) is None
    assert plan.fsdp_for(4096) == "data"
    none_plan = make_plan(None)
    assert none_plan.model_axis is None and none_plan.model_size == 1


def test_synthetic_data_deterministic_and_sharded():
    a = next(iter(SyntheticLM(256, 32, 8, seed=3)))
    b = next(iter(SyntheticLM(256, 32, 8, seed=3)))
    np.testing.assert_array_equal(a.tokens, b.tokens)
    # labels are next-token shifted
    np.testing.assert_array_equal(a.labels[:, :-1], a.tokens[:, 1:])
    # host sharding: two hosts see disjoint streams, each half the batch
    h0 = next(iter(SyntheticLM(256, 32, 8, seed=3, host_id=0, num_hosts=2)))
    h1 = next(iter(SyntheticLM(256, 32, 8, seed=3, host_id=1, num_hosts=2)))
    assert h0.tokens.shape == (4, 32)
    assert not np.array_equal(h0.tokens, h1.tokens)


def test_input_specs_no_allocation():
    """input_specs must be pure ShapeDtypeStructs (never device arrays)."""
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.launch.specs import input_specs
    from jax.sharding import Mesh

    from repro.launch.mesh import make_mesh as _compat_make_mesh

    mesh = _compat_make_mesh((1, 1), ("data", "model"))
    plan = make_plan(mesh)
    for arch in ("whisper-base", "qwen2-vl-72b", "mamba2-1.3b"):
        cfg = get_config(arch)
        for shape in SHAPES.values():
            specs = input_specs(cfg, shape, mesh, plan)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct), (arch, shape.name)
