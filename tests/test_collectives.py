"""Hierarchical (delegation) collectives == flat collectives, on 8 forced
host devices in a subprocess (this process keeps 1 device)."""

import pytest

HIER_A2A = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.collectives import hierarchical_all_to_all, flat_all_to_all, hierarchical_psum

from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.parallel.sharding import shard_map as _compat_shard_map
mesh = _compat_make_mesh((8,), ('model',))
x = jnp.arange(8 * 8 * 4, dtype=jnp.float32).reshape(8, 8 * 4)  # per-dev [8,4]

def run(group):
    def hier(v):
        return hierarchical_all_to_all(v.reshape(8, 4), 'model', group).reshape(1, 32)
    def flat(v):
        return flat_all_to_all(v.reshape(8, 4), 'model').reshape(1, 32)
    h = _compat_shard_map(hier, mesh=mesh, in_specs=P('model'), out_specs=P('model'))(x)
    f = _compat_shard_map(flat, mesh=mesh, in_specs=P('model'), out_specs=P('model'))(x)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(f)), group

for g in (1, 2, 4, 8):
    run(g)

# hierarchical psum == plain psum over both axes
from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.parallel.sharding import shard_map as _compat_shard_map
mesh2 = _compat_make_mesh((2, 4), ('data', 'model'))
y = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
a = _compat_shard_map(lambda v: hierarchical_psum(v, 'model', 'data', scatter_dim=0),
                  mesh=mesh2, in_specs=P(('data', 'model')), out_specs=P(('data', 'model')))(y)
b = _compat_shard_map(lambda v: jax.lax.psum(jax.lax.psum(v, 'model'), 'data'),
                  mesh=mesh2, in_specs=P(('data', 'model')), out_specs=P(('data', 'model')))(y)
np.testing.assert_allclose(np.asarray(a), np.asarray(b))
print('COLLECTIVES_OK')
"""


def test_hierarchical_collectives_multidevice(multidevice):
    out = multidevice(HIER_A2A, devices=8)
    assert "COLLECTIVES_OK" in out


RING_AG = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.collectives import ring_all_gather
from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.parallel.sharding import shard_map as _compat_shard_map
mesh = _compat_make_mesh((8,), ('model',))
x = jnp.arange(8 * 2 * 3, dtype=jnp.float32).reshape(8 * 2, 3)
ring = _compat_shard_map(lambda v: ring_all_gather(v, 'model'), mesh=mesh,
                     in_specs=P('model'), out_specs=P(None), check_vma=False)(x)
ref = _compat_shard_map(lambda v: jax.lax.all_gather(v, 'model', axis=0, tiled=True),
                    mesh=mesh, in_specs=P('model'), out_specs=P(None), check_vma=False)(x)
np.testing.assert_allclose(np.asarray(ring), np.asarray(ref))
print('RING_OK')
"""


def test_ring_all_gather(multidevice):
    out = multidevice(RING_AG, devices=8)
    assert "RING_OK" in out
