"""Hierarchical (delegation) collectives == flat collectives, on 8 forced
host devices in a subprocess (this process keeps 1 device).

The first two tests drive the functional lowerings
(``hierarchical_all_to_all`` / ``hierarchical_psum`` / ``ring_all_gather``);
the rest drive the CommRuntime spec/op API directly: group-size x wire-perm
parity sweeps, the fused payload+metadata a2a (bit-identical to the unfused
pair), and the AllGather ring lowering across axis sizes including P=1.
The historical ``repro.core.collectives`` shim is gone — a guard test keeps
it from coming back as an import target."""

import pytest

HIER_A2A = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.commruntime import hierarchical_all_to_all, flat_all_to_all, hierarchical_psum

from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.parallel.sharding import shard_map as _compat_shard_map
mesh = _compat_make_mesh((8,), ('model',))
x = jnp.arange(8 * 8 * 4, dtype=jnp.float32).reshape(8, 8 * 4)  # per-dev [8,4]

def run(group):
    def hier(v):
        return hierarchical_all_to_all(v.reshape(8, 4), 'model', group).reshape(1, 32)
    def flat(v):
        return flat_all_to_all(v.reshape(8, 4), 'model').reshape(1, 32)
    h = _compat_shard_map(hier, mesh=mesh, in_specs=P('model'), out_specs=P('model'))(x)
    f = _compat_shard_map(flat, mesh=mesh, in_specs=P('model'), out_specs=P('model'))(x)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(f)), group

for g in (1, 2, 4, 8):
    run(g)

# hierarchical psum == plain psum over both axes
from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.parallel.sharding import shard_map as _compat_shard_map
mesh2 = _compat_make_mesh((2, 4), ('data', 'model'))
y = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)
a = _compat_shard_map(lambda v: hierarchical_psum(v, 'model', 'data', scatter_dim=0),
                  mesh=mesh2, in_specs=P(('data', 'model')), out_specs=P(('data', 'model')))(y)
b = _compat_shard_map(lambda v: jax.lax.psum(jax.lax.psum(v, 'model'), 'data'),
                  mesh=mesh2, in_specs=P(('data', 'model')), out_specs=P(('data', 'model')))(y)
np.testing.assert_allclose(np.asarray(a), np.asarray(b))
print('COLLECTIVES_OK')
"""


def test_hierarchical_collectives_multidevice(multidevice):
    out = multidevice(HIER_A2A, devices=8)
    assert "COLLECTIVES_OK" in out


RING_AG = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.commruntime import ring_all_gather
from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.parallel.sharding import shard_map as _compat_shard_map
mesh = _compat_make_mesh((8,), ('model',))
x = jnp.arange(8 * 2 * 3, dtype=jnp.float32).reshape(8 * 2, 3)
ring = _compat_shard_map(lambda v: ring_all_gather(v, 'model'), mesh=mesh,
                     in_specs=P('model'), out_specs=P(None), check_vma=False)(x)
ref = _compat_shard_map(lambda v: jax.lax.all_gather(v, 'model', axis=0, tiled=True),
                    mesh=mesh, in_specs=P('model'), out_specs=P(None), check_vma=False)(x)
np.testing.assert_allclose(np.asarray(ring), np.asarray(ref))
print('RING_OK')
"""


def test_ring_all_gather(multidevice):
    out = multidevice(RING_AG, devices=8)
    assert "RING_OK" in out


PARITY_SWEEP = """
import itertools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.commruntime import AllToAll, CommSpec
from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.parallel.sharding import shard_map as _compat_shard_map

PDEV = 8
mesh = _compat_make_mesh((PDEV,), ('model',))
x = jax.random.normal(jax.random.PRNGKey(0), (PDEV * PDEV, 4))  # per-dev [P,4]
rng = np.random.default_rng(0)
perms = [None, tuple(rng.permutation(PDEV).tolist()), tuple(np.roll(np.arange(PDEV), 3).tolist())]

def run(spec):
    op = AllToAll(spec)
    f = _compat_shard_map(lambda v: op(v.reshape(PDEV, 4)).reshape(1, PDEV * 4),
                          mesh=mesh, in_specs=P('model'), out_specs=P('model'))
    return np.asarray(f(x))

# Sweep group sizes {1, 2, P/2, P} x non-identity dest/src perms: every
# hierarchical factorization must be BIT-identical to the flat lowering
# under the same wire re-addressing.
for dp, sp in itertools.product(perms, perms):
    flat = run(CommSpec(axis='model', axis_size=PDEV, group_size=1,
                        dest_perm=dp, src_perm=sp))
    for g in (2, PDEV // 2, PDEV):
        hier = run(CommSpec(axis='model', axis_size=PDEV, group_size=g,
                            dest_perm=dp, src_perm=sp))
        np.testing.assert_array_equal(hier, flat), (g, dp, sp)
    # a non-identity dest_perm must actually move chunks
    if dp is not None and list(dp) != list(range(PDEV)):
        ident = run(CommSpec(axis='model', axis_size=PDEV, group_size=1, src_perm=sp))
        assert not np.array_equal(flat, ident), (dp, sp)
    # the reconfigure hook reproduces the statically-built spec
    hooked = run(AllToAll(CommSpec(axis='model', axis_size=PDEV, group_size=2))
                 .reconfigure(dest_perm=dp, src_perm=sp).spec)
    np.testing.assert_array_equal(hooked, flat)

# Permute shares AllToAll's GATHER semantics: after the hop, device k holds
# the payload of device dest_perm[k] (one dest_perm = one routing family-wide).
from repro.core.commruntime import Permute
blocks = jnp.arange(PDEV, dtype=jnp.float32).reshape(PDEV, 1)  # device k holds [k]
p = tuple(rng.permutation(PDEV).tolist())
op = Permute(CommSpec(axis='model', axis_size=PDEV)).reconfigure(dest_perm=p)
moved = _compat_shard_map(lambda v: op(v), mesh=mesh,
                          in_specs=P('model'), out_specs=P('model'),
                          check_vma=False)(blocks)
np.testing.assert_array_equal(np.asarray(moved)[:, 0], np.asarray(p, np.float32))
# default: +1 ring shift of the blocks (device k receives from k-1)
ring = _compat_shard_map(lambda v: Permute(CommSpec(axis='model', axis_size=PDEV))(v),
                         mesh=mesh, in_specs=P('model'), out_specs=P('model'),
                         check_vma=False)(blocks)
np.testing.assert_array_equal(np.asarray(ring)[:, 0],
                              np.roll(np.arange(PDEV, dtype=np.float32), 1))
print('PARITY_SWEEP_OK')
"""


def test_hierarchical_parity_under_wire_perms(multidevice):
    """Satellite: group sizes {1, 2, P/2, P} x non-identity dest/src perms."""
    out = multidevice(PARITY_SWEEP, devices=8, timeout=900)
    assert "PARITY_SWEEP_OK" in out


FUSED_A2A = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.commruntime import AllToAll, CommSpec
from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.parallel.sharding import shard_map as _compat_shard_map

PDEV, C, D = 8, 6, 10
mesh = _compat_make_mesh((PDEV,), ('model',))

for dtype in (jnp.float32, jnp.bfloat16):
    x = jax.random.normal(jax.random.PRNGKey(0), (PDEV * PDEV, C, D)).astype(dtype)
    e = jax.random.randint(jax.random.PRNGKey(1), (PDEV * PDEV, C), -1, 7).astype(jnp.int32)
    for g in (1, 2, 4):
        op = AllToAll(CommSpec(axis='model', axis_size=PDEV, group_size=g))
        def fused(v, m):
            rx, re = op.fused(v, m)
            return rx, re
        def unfused(v, m):
            return op(v), op(m[..., None])[..., 0]
        sm = lambda f: _compat_shard_map(f, mesh=mesh, in_specs=(P('model'), P('model')),
                                         out_specs=(P('model'), P('model')), check_vma=False)
        fx, fe = sm(fused)(x, e)
        ux, ue = sm(unfused)(x, e)
        # ONE packed wire transfer == the unfused pair, BIT-identical
        np.testing.assert_array_equal(np.asarray(fx).view(np.uint8),
                                      np.asarray(ux).view(np.uint8)), (dtype, g)
        np.testing.assert_array_equal(np.asarray(fe), np.asarray(ue)), (dtype, g)

    # gradients flow through the fused payload identically (metadata lanes carry none)
    x32 = jax.random.normal(jax.random.PRNGKey(2), (PDEV * PDEV, C, D))
    op = AllToAll(CommSpec(axis='model', axis_size=PDEV, group_size=2))
    def loss_fused(v, m):
        rx, _ = op.fused(v, m)
        return (rx ** 2).sum()
    def loss_unfused(v, m):
        return (op(v) ** 2).sum()
    smg = lambda f: _compat_shard_map(
        lambda v, m: jax.grad(f)(v, m), mesh=mesh,
        in_specs=(P('model'), P('model')), out_specs=P('model'), check_vma=False)
    gf = smg(loss_fused)(x32, e)
    gu = smg(loss_unfused)(x32, e)
    np.testing.assert_array_equal(np.asarray(gf), np.asarray(gu))
print('FUSED_A2A_OK')
"""


def test_fused_payload_metadata_a2a_bit_identical(multidevice):
    """Satellite: the packed payload+gate transfer == the unfused pair."""
    out = multidevice(FUSED_A2A, devices=8, timeout=900)
    assert "FUSED_A2A_OK" in out


RING_OP_SIZES = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.commruntime import AllGather, CommSpec
from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.parallel.sharding import shard_map as _compat_shard_map

# Equivalence of the runtime's ring AllGather lowering vs lax.all_gather
# across axis sizes, INCLUDING the P=1 degenerate mesh.
for p in (1, 2, 4, 8):
    mesh = _compat_make_mesh((p,), ('model',))
    x = jnp.arange(p * 2 * 3, dtype=jnp.float32).reshape(p * 2, 3)
    ring_op = AllGather(CommSpec(axis='model', axis_size=p), impl='ring')
    flat_op = AllGather(CommSpec(axis='model', axis_size=p), impl='flat')
    run = lambda op: _compat_shard_map(lambda v: op(v), mesh=mesh,
                                       in_specs=P('model'), out_specs=P(None),
                                       check_vma=False)(x)
    ref = _compat_shard_map(lambda v: jax.lax.all_gather(v, 'model', axis=0, tiled=True),
                            mesh=mesh, in_specs=P('model'), out_specs=P(None),
                            check_vma=False)(x)
    np.testing.assert_array_equal(np.asarray(run(ring_op)), np.asarray(ref)), p
    np.testing.assert_array_equal(np.asarray(run(flat_op)), np.asarray(ref)), p
print('RING_OP_OK')
"""


def test_allgather_ring_op_axis_sizes(multidevice):
    """Satellite: ring_all_gather wired as the AllGather ring lowering,
    equivalent to lax.all_gather for P in {1, 2, 4, 8}."""
    out = multidevice(RING_OP_SIZES, devices=8, timeout=900)
    assert "RING_OP_OK" in out


def test_allgather_op_single_device_no_mesh():
    """P=1 without any mesh at all: the op degrades to identity."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.commruntime import AllGather, CommSpec

    x = jnp.arange(6.0).reshape(2, 3)
    out = AllGather(CommSpec(), impl="ring")(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_collectives_shim_removed():
    """The deprecated ``repro.core.collectives`` shim is deleted: importing
    it fails, the package namespace no longer exposes it, and no in-repo
    module (src or tests) references it as an import target."""
    import importlib
    import os
    import re

    import pytest as _pytest

    import repro.core

    with _pytest.raises(ImportError):
        importlib.import_module("repro.core" + ".collectives")
    with _pytest.raises(AttributeError):
        repro.core.collectives  # noqa: B018
    assert "collectives" not in repro.core.__all__

    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(here)
    pat = re.compile(r"^\s*(from|import)\s+repro\.core\.collectives\b")
    offenders = []
    for top in (os.path.join(root, "src"), here):
        for dirpath, _, files in os.walk(top):
            for f in files:
                if not f.endswith(".py"):
                    continue
                path = os.path.join(dirpath, f)
                with open(path) as fh:
                    for line in fh:
                        if pat.match(line):
                            offenders.append(path)
    assert not offenders, offenders
