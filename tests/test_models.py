"""Per-architecture smoke tests: every assigned arch as a REDUCED config of
the same family — one forward/train step on CPU, asserting output shapes and
no NaNs — plus prefill+decode consistency for every family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_reduced
from repro.configs.shapes import SHAPES, cell_supported
from repro.models import transformer as tfm
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.train.train_step import init_all, make_train_step

PLAN = make_plan(None)
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=2, s=32):
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(KEY, (b, cfg.encoder_seq, cfg.d_model))
    if cfg.vision_patches:
        batch["patches"] = jax.random.normal(KEY, (b, cfg.vision_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    cfg.validate()
    batch = make_batch(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    params, specs, opt_state = init_all(KEY, cfg, PLAN, opt_cfg)
    # specs tree mirrors params tree
    assert jax.tree.structure(
        jax.tree.map(lambda _: 0, params)
    ) == jax.tree.structure(
        jax.tree.map(lambda _: 0, specs, is_leaf=lambda s: not isinstance(s, dict))
    )

    feats, aux, _ = tfm.model_apply(params, batch, cfg, PLAN, mode="train")
    assert feats.shape == (2, 32, cfg.d_model)
    assert not bool(jnp.isnan(feats).any()), f"{arch}: NaN features"

    step = jax.jit(make_train_step(cfg, PLAN, opt_cfg))
    params2, opt2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    assert float(metrics["grad_norm"]) > 0.0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l[0].astype(jnp.float32) - l[1].astype(jnp.float32)))),
        jax.tree.map(lambda a, b: (a, b), params, params2),
        0.0,
        is_leaf=lambda l: isinstance(l, tuple),
    )
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_arch_decode_matches_train(arch):
    cfg = get_reduced(arch)
    if cfg.is_moe:
        # Generous capacity: tight factors drop tokens in the 16-token train
        # pass but not in single-token decode — a real (intended) MoE
        # semantic, but noise for this consistency check.
        import dataclasses

        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    params, _ = tfm.init_model(KEY, cfg, PLAN)
    full, _, _ = tfm.model_apply(params, batch, cfg, PLAN, mode="train")
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : s - 1]
    pre.pop("labels")
    _, _, caches = tfm.model_apply(params, pre, cfg, PLAN, mode="prefill")
    caches = tfm.pad_caches(caches, s)
    feats, _, _ = tfm.model_apply(
        params, {"tokens": batch["tokens"][:, s - 1 : s]}, cfg, PLAN,
        mode="decode", caches=caches, t=jnp.asarray(s - 1),
    )
    err = float(jnp.max(jnp.abs(full[:, -1] - feats[:, 0])))
    assert err < 5e-3, f"{arch}: decode diverges from train path ({err})"


def test_shape_cell_matrix_covers_40():
    """10 archs x 4 shapes; skips only where DESIGN.md documents them."""
    from repro.configs import get_config

    total = skipped = 0
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            total += 1
            ok, reason = cell_supported(cfg, shape)
            if not ok:
                skipped += 1
                assert shape.name == "long_500k", (arch, shape.name, reason)
    assert total == 40
    assert skipped == 7  # the 7 pure-full-attention long_500k skips


def test_long500k_runs_for_subquadratic_families():
    from repro.configs import get_config

    for arch in ("mamba2-1.3b", "recurrentgemma-9b", "gemma2-2b"):
        ok, _ = cell_supported(get_config(arch), SHAPES["long_500k"])
        assert ok, arch


@pytest.mark.parametrize("arch", ["deepseek-7b", "gemma2-2b", "mamba2-1.3b",
                                  "recurrentgemma-9b"])
def test_causal_prefix_invariance(arch):
    """Causality property: features at position i never depend on tokens
    after i — forward of a prefix equals the prefix of the full forward."""
    cfg = get_reduced(arch)
    params, _ = tfm.init_model(KEY, cfg, PLAN)
    tokens = jax.random.randint(jax.random.PRNGKey(7), (2, 24), 0, cfg.vocab_size)
    full, _, _ = tfm.model_apply(params, {"tokens": tokens}, cfg, PLAN, mode="train")
    for k in (8, 16):
        part, _, _ = tfm.model_apply(
            params, {"tokens": tokens[:, :k]}, cfg, PLAN, mode="train"
        )
        err = float(jnp.max(jnp.abs(full[:, :k] - part)))
        assert err < 1e-4, (arch, k, err)
