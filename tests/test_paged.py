"""Paged KV cache (DESIGN.md §10): PageAllocator lifecycle (allocation,
eviction, copy-on-write, dirty-page reuse), paged-vs-dense bit-identical
generation through the continuous batcher and the serving engine (single- and
multi-device, dropless and capacity, reconfiguration on and off), and the
prefix-registry reuse path."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.models.config import ModelConfig, MoEConfig
from repro.models.transformer import init_model, paged_supported
from repro.parallel.sharding import make_plan
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.paged import PageAllocator
from repro.serve.workload import MIXES, WorkloadGenerator

PLAN = make_plan(None)


def _toy(name="pg"):
    cfg = ModelConfig(name, "dense", 2, 32, 4, 2, 64, 64, dtype="float32",
                      remat="none")
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    return cfg, params


# ---------------------------------------------------------------------------
# allocator lifecycle (host-side policy, no device work)
# ---------------------------------------------------------------------------


def test_allocator_slot_churn_recycles_pages():
    """Admit/release churn across slots: pages cycle through the free list,
    the table row is fully cleared on release, and residency never exceeds
    what the live slots actually map."""
    al = PageAllocator(slots=4, page_size=4, max_pages=4, num_pages=8,
                      prefix_cache=False)
    rng = np.random.default_rng(0)
    for round_ in range(20):
        slot = round_ % 4
        prompt = rng.integers(0, 97, size=int(rng.integers(3, 12)))
        plan = al.admit(slot, prompt, 4, 16)
        if plan is None:  # pool busy: release an older slot and retry
            al.release((slot + 1) % 4)
            plan = al.admit(slot, prompt, 4, 16)
            assert plan is not None
        assert plan.reuse_len == 0 and plan.start == 0
        al.ensure(slot, 0, len(prompt))
        mapped = (al.table[slot] >= 0).sum()
        assert mapped == -(-len(prompt) // 4)
        assert al.resident_pages() <= 8
        al.release(slot)
        assert (al.table[slot] == -1).all()
    # all pages returned
    assert al.resident_pages() - len(al._registry) == 0
    assert al.allocs > 0


def test_allocator_reservation_blocks_oversubscription():
    """Admission reserves every page the request can touch; a second request
    the pool cannot also cover is refused instead of deadlocking mid-decode."""
    al = PageAllocator(slots=2, page_size=4, max_pages=4, num_pages=4,
                      prefix_cache=False)
    assert al.admit(0, np.arange(8), 8, 16) is not None  # reserves all 4 pages
    assert al.admit(1, np.arange(8), 8, 16) is None  # pool cannot cover it
    # the refused admission left no state behind
    assert (al.table[1] == -1).all() and al._reserved[1] == 0
    al.release(0)
    assert al.admit(1, np.arange(8), 8, 16) is not None


def test_allocator_prefix_reuse_and_cow_fork():
    """A second request with the same prompt maps the registry's pages
    read-only; a write into the shared range copy-on-write forks."""
    al = PageAllocator(slots=4, page_size=4, max_pages=4, num_pages=16)
    prompt = np.arange(9)  # 2 full pages + 1 partial
    p0 = al.admit(0, prompt, 4, 16)
    assert p0.reuse_len == 0
    al.ensure(0, 0, 9)
    al.register_prefix(0, prompt)
    owner_pages = [int(al.table[0, j]) for j in range(3)]

    p1 = al.admit(1, prompt, 4, 16)
    assert p1.reuse_len == 8  # the two FULL pages, never the partial third
    assert p1.start == 8
    assert list(p1.reused_pages) == owner_pages[:2]
    assert al.prefix_hit_pages == 2
    assert [int(al.table[1, j]) for j in range(2)] == owner_pages[:2]
    # continuing the prompt at position 8 allocates a private third page
    forks = al.ensure(1, 8, 9)
    assert forks == [] and int(al.table[1, 2]) not in owner_pages
    # a write into the SHARED range forks: new page, old one still mapped by
    # slot 0 and the registry
    forks = al.ensure(1, 4, 8)
    assert len(forks) == 1 and al.cow_forks == 1
    src, dst = forks[0]
    assert src == owner_pages[1] and int(al.table[1, 1]) == dst != src
    assert int(al.table[0, 1]) == src and al.refcount[src] >= 2


def test_allocator_full_reuse_forks_for_first_token():
    """A prompt whose pages are ALL in the registry re-runs its last token:
    admission reserves the extra page and ensure() forks the shared page the
    re-run writes."""
    al = PageAllocator(slots=2, page_size=4, max_pages=4, num_pages=16)
    prompt = np.arange(8)  # exactly 2 full pages
    al.admit(0, prompt, 4, 16)
    al.ensure(0, 0, 8)
    al.register_prefix(0, prompt)
    p1 = al.admit(1, prompt, 4, 16)
    assert p1.reuse_len == 8 and p1.start == 7  # re-run the last token
    forks = al.ensure(1, 7, 8)
    assert len(forks) == 1 and forks[0][0] == int(al.table[0, 1])


def test_allocator_evicts_registry_pages_oldest_first():
    """Registry-only pages are the eviction victims (LRU): allocation
    pressure evicts the oldest prefix, and its hash stops hitting."""
    al = PageAllocator(slots=2, page_size=4, max_pages=2, num_pages=4)
    a, b = np.arange(4), np.arange(4) + 50
    for p in (a, b):  # publish a first (oldest), then b
        al.admit(0, p, 4, 8)
        al.ensure(0, 0, 4)
        al.register_prefix(0, p)
        al.release(0)  # page survives, held by the registry
    assert al.resident_pages() == 2
    # a live slot takes the two free pages; the next allocation must evict
    al.admit(0, np.arange(8) + 100, 0, 8)
    al.ensure(0, 0, 8)
    al.admit(1, np.arange(4) + 200, 0, 8)
    al.ensure(1, 0, 4)
    assert al.evictions == 1
    al.release(1)
    assert al.admit(1, b, 0, 8).reuse_len == 4  # newer prefix survived
    al.release(1)
    assert al.admit(1, a, 0, 8).reuse_len == 0  # oldest was the victim


def test_allocator_dirty_page_reuse_after_retirement():
    """Freed pages go back verbatim (no clearing) and get reallocated; the
    free list is exercised by churning one slot."""
    al = PageAllocator(slots=1, page_size=4, max_pages=4, num_pages=4,
                      prefix_cache=False)
    al.admit(0, np.arange(16), 0, 16)
    al.ensure(0, 0, 16)
    first = [int(x) for x in al.table[0]]
    al.release(0)
    al.admit(0, np.arange(16) + 7, 0, 16)
    al.ensure(0, 0, 16)
    assert sorted(int(x) for x in al.table[0]) == sorted(first)


# ---------------------------------------------------------------------------
# paged vs dense bit parity through the batcher (P=1)
# ---------------------------------------------------------------------------


def _run_batcher(params, cfg, prompts, **kw):
    cb = ContinuousBatcher(params, cfg, PLAN, slots=2, max_len=32, **kw)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    done = cb.run()
    return cb, {r.rid: r.out for r in done}


@pytest.mark.parametrize("prefill_chunk", [0, 5])
def test_batcher_paged_matches_dense_bitwise(prefill_chunk):
    """Unique prompts (no prefix sharing in play): the paged batcher emits
    BIT-identical tokens to the dense ring-buffer batcher, for whole-prompt
    AND chunked prefill."""
    cfg, params = _toy()
    assert paged_supported(cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 17, 9, 23)]
    _, dense = _run_batcher(params, cfg, prompts, paged=False,
                            prefill_chunk=prefill_chunk)
    cb, paged = _run_batcher(params, cfg, prompts, paged=True,
                             prefill_chunk=prefill_chunk)
    assert cb.paged and paged == dense
    assert cb.kv_resident_pages_peak > 0


def test_batcher_prefix_reuse_skips_prefill_and_matches():
    """Identical prompts: the second request admits with a prefix hit (pages
    mapped, only the tail recomputed) and still generates the same tokens as
    the dense path."""
    cfg, params = _toy()
    rng = np.random.default_rng(5)
    p = rng.integers(0, cfg.vocab_size, size=21).astype(np.int32)
    prompts = [p, p.copy(), p.copy()]
    _, dense = _run_batcher(params, cfg, prompts, paged=False)
    cb, paged = _run_batcher(params, cfg, prompts, paged=True)
    assert cb.alloc.prefix_hit_pages > 0, "prefix registry never hit"
    assert paged == dense
    # all three identical requests decode identically
    assert paged[0] == paged[1] == paged[2]


def test_batcher_paged_pool_pressure_queues_not_corrupts():
    """A pool sized for ~1 sequence forces requests to wait for pages; every
    request still completes with the dense-path tokens."""
    cfg, params = _toy()
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 13, 11)]
    _, dense = _run_batcher(params, cfg, prompts, paged=False)
    cb, paged = _run_batcher(params, cfg, prompts, paged=True, num_pages=3,
                             prefix_cache=False)
    assert cb.num_pages == 3  # 3 pages of 16 = 48 tokens for 2 slots of 32
    assert paged == dense


# ---------------------------------------------------------------------------
# shared-prefix workload (satellite: agentic traces)
# ---------------------------------------------------------------------------


def test_workload_shared_prefix_mix():
    gen = WorkloadGenerator("agentic_shared", seed=11, vocab_size=128)
    reqs = gen.generate(64)
    m = MIXES["agentic_shared"]
    carriers = [r for r in reqs if r.prefix_len > 0]
    assert 0.7 < len(carriers) / len(reqs) <= 1.0  # ratio ~0.9
    by_region: dict = {}
    for r in carriers:
        assert r.prefix_len == min(m.shared_prefix_tokens, r.prompt_len)
        toks = gen.prompt_tokens(r)
        assert toks[0] == r.region % 128
        key = r.region
        if key in by_region:
            np.testing.assert_array_equal(toks[:r.prefix_len],
                                          by_region[key][:r.prefix_len])
        else:
            by_region[key] = toks
    # non-carriers keep the old per-rid stream
    plain = [r for r in reqs if r.prefix_len == 0]
    if plain:
        assert gen.prompt_tokens(plain[0]).shape == (plain[0].prompt_len,)
    # determinism
    assert WorkloadGenerator("agentic_shared", seed=11,
                             vocab_size=128).generate(64) == reqs


# ---------------------------------------------------------------------------
# engine parity: paged vs dense x dropless/capacity x reconfig on/off
# ---------------------------------------------------------------------------


def _moe_cfg(dispatch):
    return ModelConfig(
        "pgs", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=8.0,
                      backend="mixnet", a2a_group=2, dispatch=dispatch),
    )


@pytest.mark.parametrize("dispatch", ["dropless", "capacity"])
@pytest.mark.parametrize("reconfig", [False, True])
def test_engine_paged_parity_single_device(dispatch, reconfig):
    cfg = _moe_cfg(dispatch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    gen = WorkloadGenerator("chat", seed=3, vocab_size=cfg.vocab_size)
    reqs = [dataclasses.replace(r, prompt_len=min(r.prompt_len, 20),
                                max_new_tokens=min(r.max_new_tokens, 5))
            for r in gen.generate(4)]

    def run(paged):
        scfg = ServeConfig(slots=2, max_len=32, paged=paged,
                           reconfig_every=(3 if reconfig else 0),
                           reconfig_min_gain=0.0, num_devices=4)
        eng = ServeEngine(jax.tree.map(lambda a: a, params), cfg, PLAN, scfg)
        rep = eng.run(reqs, gen)
        assert rep.completed == len(reqs)
        return eng, rep

    eng_p, rep_p = run(True)
    eng_d, rep_d = run(False)
    assert rep_p.kv_paged and not rep_d.kv_paged
    assert rep_p.kv_resident_pages_peak > 0
    a = {r.rid: r.out for r in eng_p.batcher.finished}
    b = {r.rid: r.out for r in eng_d.batcher.finished}
    assert a == b, (dispatch, reconfig)
    if reconfig:
        assert rep_p.reconfig_count > 0


PAGED_SWEEP = """
import dataclasses
import jax, numpy as np
from repro.core.controlplane import LayerPlan
from repro.models.config import ModelConfig, MoEConfig
from repro.models.transformer import init_model
from repro.parallel.sharding import make_plan
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.workload import WorkloadGenerator
from repro.launch.mesh import make_mesh as _mm
from repro.launch.mesh import use_mesh as _um

P = %(P)d
mesh = _mm((P,), ("model",))
plan = make_plan(mesh)

for dispatch in ("dropless", "capacity"):
    cfg = ModelConfig(
        "pgs", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=8.0,
                      backend="mixnet", a2a_group=2, dispatch=dispatch),
    )
    params, _ = init_model(jax.random.PRNGKey(0), cfg, plan)
    gen = WorkloadGenerator("chat", seed=3, vocab_size=cfg.vocab_size)
    reqs = [dataclasses.replace(r, prompt_len=12, max_new_tokens=4)
            for r in gen.generate(3)]

    def run(paged, reconfig):
        scfg = ServeConfig(slots=2, max_len=32, paged=paged,
                           reconfig_every=(2 if reconfig else 0),
                           reconfig_min_gain=0.0, num_devices=P)
        eng = ServeEngine(jax.tree.map(lambda a: a, params), cfg, plan, scfg,
                          mesh=mesh)
        with _um(mesh):
            if reconfig:
                # Force one expert-weight permutation so the paged decode
                # path provably runs under a moved placement (the control
                # plane may find no gainful move on a 3-request workload).
                perm = np.arange(8)
                perm[[0, 1]] = perm[[1, 0]]
                eng.apply_plans([
                    LayerPlan(l, True, perm=perm.copy())
                    for l in range(cfg.pattern_repeats)
                ])
            rep = eng.run(reqs, gen)
        assert rep.completed == len(reqs)
        return {r.rid: r.out for r in eng.batcher.finished}, rep

    for reconfig in (False, True):
        a, rep_p = run(True, reconfig)
        b, rep_d = run(False, reconfig)
        assert rep_p.kv_paged and not rep_d.kv_paged
        assert a == b, (dispatch, reconfig, a, b)
        if reconfig:
            assert rep_p.reconfig_count > 0
print("PAGED_SWEEP_OK_P%(P)d")
"""


@pytest.mark.parametrize("p", [2, 4, 8])
def test_engine_paged_parity_multidevice(multidevice, p):
    """P-device EP-sharded serving: paged vs dense generation is
    bit-identical for dropless AND capacity dispatch, with decode-time
    reconfiguration on and off."""
    out = multidevice(PAGED_SWEEP % {"P": p}, devices=8, timeout=900)
    assert f"PAGED_SWEEP_OK_P{p}" in out
