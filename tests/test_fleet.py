"""Fleet layer (DESIGN.md §12): cross-replica bit-exactness under every
steering policy and fleet size (including forced failure and drain/restore),
the drained-replica checkpoint round-trip, SLO-priority admission, the
region-conditioned gate statistics, and the priced fleet netsim scenario
(locality steering vs least-loaded vs the degradation gates)."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import commruntime as comm
from repro.core.controlplane import RegionGateStats
from repro.core.netsim import SimModel, simulate_fleet
from repro.models.config import ModelConfig, MoEConfig
from repro.models.transformer import init_model
from repro.parallel.sharding import make_plan
from repro.serve.batching import Request
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.fleet import (
    FleetConfig,
    FleetEngine,
    fleet_requests,
    locality_score,
)
from repro.serve.workload import WorkloadGenerator, clamp_requests, slo_for

PLAN = make_plan(None)


def moe_cfg():
    return ModelConfig(
        "flt", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=8.0,
                      backend="mixnet", a2a_group=2, dispatch="dropless",
                      decode_backend="dense"),
    )


@pytest.fixture(scope="module")
def fleet_setup():
    cfg = moe_cfg()
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    gen = WorkloadGenerator("chat", seed=3, vocab_size=cfg.vocab_size)
    raw = clamp_requests(gen.generate(8), prompt_max=16, max_new=5)
    freqs = fleet_requests(raw, gen)
    return cfg, params, freqs


def make_replica(params, cfg, *, slots=2, paged=None):
    scfg = ServeConfig(
        slots=slots, max_len=32, num_devices=4, paged=paged,
        external_control=True, num_regions=4, reconfig_min_gain=0.0,
    )
    return ServeEngine(jax.tree.map(lambda a: a, params), cfg, PLAN, scfg)


def make_fleet(params, cfg, n, policy, **fkw):
    engines = [make_replica(params, cfg) for _ in range(n)]
    fkw.setdefault("reconfig_every", 3)
    return FleetEngine(engines, FleetConfig(policy=policy, **fkw))


def reference_outputs(params, cfg, freqs):
    """Unsteered single-replica generation — the bit-exactness reference."""
    eng = make_replica(params, cfg)
    for fr in sorted(freqs, key=lambda f: (f.arrival_s, f.rid)):
        eng.submit(Request(rid=fr.rid, prompt=fr.prompt,
                           max_new_tokens=fr.max_new_tokens,
                           eos_id=fr.eos_id, region=fr.region))
    while eng.batcher.busy:
        eng.step()
    return {r.rid: list(r.out) for r in eng.batcher.finished if r.error is None}


@pytest.fixture(scope="module")
def reference(fleet_setup):
    cfg, params, freqs = fleet_setup
    ref = reference_outputs(params, cfg, freqs)
    assert len(ref) == len(freqs)
    return ref


# ---------------------------------------------------------------------------
# cross-replica determinism (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", ["locality", "least_loaded", "round_robin"])
@pytest.mark.parametrize("size", [1, 2, 4])
def test_fleet_bit_exact_across_policies_and_sizes(
    fleet_setup, reference, policy, size
):
    """Steered requests generate BIT-identical tokens to unsteered
    single-replica generation, for every policy x fleet size."""
    cfg, params, freqs = fleet_setup
    fleet = make_fleet(params, cfg, size, policy)
    rep = fleet.run(freqs)
    assert rep.completed == len(freqs)
    assert rep.outputs == reference
    assert sum(rep.steer_reasons.values()) >= len(freqs)
    if size > 1:
        # steering actually spread work across replicas
        assert len(set(fleet.assignment.values())) > 1


def test_fleet_bit_exact_under_replica_failure(fleet_setup, reference):
    """A replica failing mid-run loses its in-flight generation; the fleet
    restarts that work elsewhere and every token stays bit-identical."""
    cfg, params, freqs = fleet_setup
    fleet = make_fleet(params, cfg, 3, "locality")
    rep = fleet.run(freqs, fail_at={0: 4})
    assert rep.completed == len(freqs)
    assert rep.outputs == reference
    assert not fleet.alive[0]
    fails = [d for d in fleet.decision_log if d["kind"] == "fail"]
    assert fails and fails[0]["replica"] == 0


def test_fleet_bit_exact_under_drain_and_restore(fleet_setup, reference):
    """Draining a replica re-steers its queued work and stops admissions to
    it until restore; tokens stay bit-identical throughout."""
    cfg, params, freqs = fleet_setup
    fleet = make_fleet(params, cfg, 2, "locality")
    rep = fleet.run(freqs, drain_at={1: 2}, restore_at={1: 8})
    assert rep.completed == len(freqs)
    assert rep.outputs == reference
    kinds = [d["kind"] for d in fleet.decision_log]
    assert "drain" in kinds and "restore" in kinds
    # no admission steered to the draining replica while it was down
    for d in fleet.decision_log:
        if d["kind"] == "steer" and 2 <= d["tick"] < 8:
            assert d["replica"] != 1


def test_fleet_slo_priority_admission(fleet_setup):
    """With both classes queued at once, chat (priority 0) dispatches before
    batch (priority 2) regardless of submission order."""
    cfg, params, freqs = fleet_setup
    batch = dataclasses.replace(
        freqs[0], rid=900, arrival_s=0.0, slo=slo_for("batch_summarize")
    )
    chat = dataclasses.replace(
        freqs[1], rid=901, arrival_s=0.0, slo=slo_for("chat")
    )
    fleet = make_fleet(params, cfg, 1, "least_loaded")
    fleet.submit(batch)  # lower priority submitted FIRST
    fleet.submit(chat)
    while fleet.busy:
        fleet.step()
    steers = [d for d in fleet.decision_log if d["kind"] == "steer"]
    assert [d["rid"] for d in steers] == [901, 900]
    rep = fleet.report()
    assert rep.completed == 2
    assert set(rep.slo_attainment) == {"chat", "batch_summarize"}


# ---------------------------------------------------------------------------
# drain checkpoint round-trip (satellite)
# ---------------------------------------------------------------------------


def test_drain_checkpoint_restore_bit_identical(tmp_path):
    """Drain a paged replica mid-run, checkpoint it (KV pools + allocator +
    placement), restore into a FRESH engine, re-admit the handed-back work:
    the union of tokens is bit-identical to one uninterrupted run, and the
    warm prefix registry survives the round-trip."""
    cfg = moe_cfg()
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    gen = WorkloadGenerator("agentic_shared", seed=9, vocab_size=cfg.vocab_size)
    raw = clamp_requests(gen.generate(6), prompt_max=20, max_new=4)
    freqs = fleet_requests(raw, gen)
    ref = reference_outputs(params, cfg, freqs)

    eng_a = make_replica(params, cfg, paged=True)
    for fr in freqs[:4]:
        eng_a.submit(Request(rid=fr.rid, prompt=fr.prompt,
                             max_new_tokens=fr.max_new_tokens,
                             region=fr.region))
    for _ in range(3):
        if eng_a.batcher.busy:
            eng_a.step()
    handed = eng_a.drain()  # queued-but-unstarted hand back
    with pytest.raises(RuntimeError):
        eng_a.submit(Request(rid=999, prompt=freqs[0].prompt,
                             max_new_tokens=2))
    while eng_a.batcher.busy:  # finish in-flight work
        eng_a.step()
    step = eng_a.save_checkpoint(str(tmp_path))
    done_a = {r.rid: list(r.out) for r in eng_a.batcher.finished
              if r.error is None}

    eng_b = make_replica(params, cfg, paged=True)
    eng_b.restore_checkpoint(str(tmp_path), step)
    # allocator state (page table, refcounts, prefix registry) survived
    np.testing.assert_array_equal(eng_b.batcher.alloc.table,
                                  eng_a.batcher.alloc.table)
    assert eng_b.batcher.alloc._registry == eng_a.batcher.alloc._registry
    assert len(eng_b.batcher.alloc._registry) > 0
    hits_before = eng_b.batcher.alloc.prefix_hit_pages

    resume = {fr.rid for fr in freqs} - set(done_a)
    for fr in freqs:
        if fr.rid in resume:
            eng_b.submit(Request(rid=fr.rid, prompt=fr.prompt,
                                 max_new_tokens=fr.max_new_tokens,
                                 region=fr.region))
    while eng_b.batcher.busy:
        eng_b.step()
    done_b = {r.rid: list(r.out) for r in eng_b.batcher.finished
              if r.error is None}
    assert set(done_a) | set(done_b) == {fr.rid for fr in freqs}
    assert {**done_a, **done_b} == ref
    # agentic_shared same-region re-sends hit the restored warm registry
    assert eng_b.batcher.alloc.prefix_hit_pages > hits_before
    assert len(handed) + len(done_a) >= 4


# ---------------------------------------------------------------------------
# locality scoring + region-conditioned gate stats (units)
# ---------------------------------------------------------------------------


def test_locality_score_orders_by_residency_then_load():
    hot = np.array([0.7, 0.1, 0.1, 0.1])
    cold = np.array([0.1, 0.1, 0.1, 0.7])
    assert locality_score(hot, hot) < locality_score(hot, cold)
    assert locality_score(hot, None) >= 1.0  # no stats = worst-case miss
    # the load term breaks residency ties
    assert locality_score(hot, hot, backlog=4, slots=4) > locality_score(
        hot, hot, backlog=0, slots=4
    )
    # placement fit penalizes a mix the current perm concentrates
    assert locality_score(hot, hot, placement_fit=1.0) > locality_score(
        hot, hot, placement_fit=0.0
    )


def test_workload_region_churn_migrates_hot_region():
    """The agentic_churn stress mix: the hot region rotates every
    region_churn_every_s seconds — the drift that forces the
    steer-vs-reconfigure decision (steering alone must eventually lose)."""
    from repro.serve.workload import MIXES

    m = MIXES["agentic_churn"]
    assert m.region_churn_every_s > 0
    gen = WorkloadGenerator("agentic_churn", seed=2)
    reqs = gen.generate(400)
    epochs: dict[int, list[int]] = {}
    for r in reqs:
        epochs.setdefault(int(r.arrival_s // m.region_churn_every_s),
                          []).append(r.region)
    hot = {e: max(set(v), key=v.count) for e, v in epochs.items()
           if len(v) >= 10}
    assert len(set(hot.values())) > 1, "hot region never migrated"
    # consecutive well-sampled epochs rotate by region_churn_rot
    keys = sorted(hot)
    for a, b in zip(keys, keys[1:]):
        if b == a + 1:
            assert hot[b] == (hot[a] + m.region_churn_rot) % m.num_regions
    # determinism: churn is a pure function of (seed, arrivals)
    assert WorkloadGenerator("agentic_churn", seed=2).generate(400) == reqs


def test_region_gate_stats_learn_merge_roundtrip():
    st = RegionGateStats(num_regions=2, num_layers=2, num_experts=4)
    assert st.mix_for(0) is None  # cold until confidence accumulates
    load = np.array([[8.0, 1.0, 1.0, 0.0], [0.0, 1.0, 1.0, 8.0]])
    for _ in range(6):
        st.observe({0: 1.0}, load)
    m0 = st.mix_for(0)
    assert m0 is not None and m0.shape == (2, 4)
    assert m0[0].argmax() == 0 and m0[1].argmax() == 3
    assert st.mix_for(1) is None  # region 1 never observed
    # merged stats weight by confidence
    other = RegionGateStats(num_regions=2, num_layers=2, num_experts=4)
    for _ in range(6):
        other.observe({1: 1.0}, load[::-1])
    merged = RegionGateStats.merged([st, other, None])
    assert merged is not None
    assert merged.mix_for(0)[0].argmax() == 0
    assert merged.mix_for(1)[0].argmax() == 3
    # state round-trip
    clone = RegionGateStats(num_regions=2, num_layers=2, num_experts=4)
    clone.load_state_dict(st.state_dict())
    np.testing.assert_allclose(clone.mix, st.mix)
    np.testing.assert_allclose(clone.weight, st.weight)


# ---------------------------------------------------------------------------
# priced fleet netsim (satellites: goodput-per-dollar gates + a2a cross-check)
# ---------------------------------------------------------------------------


def _sim_model():
    return SimModel(
        name="flt-sim", num_blocks=8, d_model=1024, d_ff=4096,
        num_experts=16, top_k=2, num_heads=16, ep_degree=16, tp_degree=1,
        pp_degree=1, overlap_chunks=2,
    )


_SIM_KW = dict(num_replicas=4, num_requests=48, mixes=("chat", "agentic"),
               seed=0, arrival_scale=0.05)


def test_simulate_fleet_locality_beats_least_loaded():
    """The acceptance gate: on the region-skewed mix, gate-locality steering
    buys more goodput per dollar than least-loaded (fewer placement flaps,
    smaller resident expert working sets)."""
    model = _sim_model()
    loc = simulate_fleet(model, policy="locality", **_SIM_KW)
    ll = simulate_fleet(model, policy="least_loaded", **_SIM_KW)
    assert loc.completed == loc.requests
    assert ll.completed == ll.requests
    assert loc.goodput_per_mdollar > ll.goodput_per_mdollar
    # the steer-vs-reconfigure rule: steering absorbs what least-loaded
    # pays for in placement rewrites
    assert loc.reconfig_blocked_s <= ll.reconfig_blocked_s
    assert set(loc.slo_attainment) == {"chat", "agentic"}
    # replica a2a accounting ties to the CommRuntime formula exactly
    for j in range(loc.num_replicas):
        expect = model.layers_per_stage * comm.ep_alltoall_bytes(
            loc.replica_routed_tokens[j], model.top_k, model.d_model,
            model.dtype_bytes,
        )
        assert abs(loc.replica_a2a_bytes[j] - expect) < 1e-6


def test_simulate_fleet_degrades_gracefully():
    """One replica draining or failing mid-run: no admission deadlock, every
    request completes, SLO classes stay attainable."""
    model = _sim_model()
    for event in ({"drain": (1, 200)}, {"fail": (0, 200)}):
        r = simulate_fleet(model, policy="locality", **_SIM_KW, **event)
        assert r.completed == r.requests, f"stranded work under {event}"
        assert r.tokens_out > 0 and r.goodput_per_mdollar > 0
        assert set(r.slo_attainment) == {"chat", "agentic"}
        assert all(v > 0.5 for v in r.slo_attainment.values())


def test_simulate_fleet_deterministic_and_priced():
    model = _sim_model()
    kw = dict(num_replicas=2, num_requests=24, mixes=("chat",), seed=5,
              arrival_scale=0.05)
    a = simulate_fleet(model, policy="locality", **kw)
    b = simulate_fleet(model, policy="locality", **kw)
    assert a.breakdown() == b.breakdown()
    assert a.fleet_cost_usd > 0 and a.cross_tier_cost_usd > 0
    assert a.goodput_per_mdollar == pytest.approx(
        a.goodput_tok_s / ((a.fleet_cost_usd + a.cross_tier_cost_usd) / 1e6)
    )
    # a single-replica fleet has no cross-region tier to pay for
    single = simulate_fleet(model, policy="least_loaded", num_replicas=1,
                            num_requests=12, mixes=("chat",), seed=5,
                            arrival_scale=0.05)
    assert single.cross_tier_cost_usd == 0.0
