"""Algorithm 1 (greedy OCS reconfiguration) properties — hypothesis-driven."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import topology as topo


def random_demand(rng, e):
    d = rng.random((e, e)) * 1e9
    np.fill_diagonal(d, 0.0)
    return d


@given(
    n_servers=st.sampled_from([2, 4, 8]),
    alpha=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=1000),
)
@settings(max_examples=40, deadline=None)
def test_degree_and_symmetry_invariants(n_servers, alpha, seed):
    rng = np.random.default_rng(seed)
    demand = random_demand(rng, n_servers * 2)
    t = topo.reconfigure_ocs(demand, alpha=alpha, num_servers=n_servers)
    # symmetric circuit matrix, zero diagonal
    assert (t.circuits == t.circuits.T).all()
    assert (np.diag(t.circuits) == 0).all()
    # optical degree never exceeded
    for s in range(n_servers):
        assert t.links_of(s) <= alpha
    # NIC map consistent with the circuit matrix
    pair_counts = {}
    for i, _, j, _ in t.nic_map:
        pair_counts[(i, j)] = pair_counts.get((i, j), 0) + 1
    for (i, j), c in pair_counts.items():
        assert t.circuits[i, j] == c
    # no NIC used twice per server
    used = {}
    for i, ni, j, nj in t.nic_map:
        assert (i, ni) not in used and (j, nj) not in used
        used[(i, ni)] = used[(j, nj)] = True


@given(seed=st.integers(min_value=0, max_value=500))
@settings(max_examples=30, deadline=None)
def test_greedy_beats_uniform_on_skewed_demand(seed):
    """The demand-aware allocation completes skewed a2a no slower than the
    demand-oblivious round-robin topology."""
    rng = np.random.default_rng(seed)
    n = 8
    demand = random_demand(rng, n)
    # Skew: one hot pair dominates.
    demand[0, 1] = demand[1, 0] = demand.max() * 10
    solved = topo.reconfigure_ocs(demand, alpha=6, num_servers=n, experts_per_server=1)
    uniform = topo.uniform_topology(n, 6)
    pair = np.triu(demand + demand.T, 1)
    t_solved = topo.topology_completion_time(solved.circuits, pair, 1.0, 0.25)
    t_uniform = topo.topology_completion_time(uniform, pair, 1.0, 0.25)
    assert t_solved <= t_uniform * 1.0001


def test_monotone_in_alpha():
    rng = np.random.default_rng(7)
    demand = random_demand(rng, 8)
    pair = np.triu(demand + demand.T, 1)
    times = []
    for alpha in (1, 2, 4, 6, 8):
        t = topo.reconfigure_ocs(demand, alpha=alpha, num_servers=8, experts_per_server=1)
        times.append(topo.topology_completion_time(t.circuits, pair, 1.0, 0.25))
    # More optical degree never slows the all-to-all (Fig 27).
    for a, b in zip(times, times[1:]):
        assert b <= a * 1.0001


def test_server_demand_fold():
    e = np.arange(16, dtype=float).reshape(4, 4)
    d = topo.calculate_server_demand(e, experts_per_server=2)
    assert d.shape == (2, 2)
    # upper triangular with TX+RX folded
    assert d[1, 0] == 0.0
    block_up = e[:2, 2:].sum()
    block_down = e[2:, :2].sum()
    assert d[0, 1] == pytest.approx(block_up + block_down)
