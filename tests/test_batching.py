"""Continuous batching: per-slot positions produce exactly the tokens the
lockstep single-sequence path produces, with staggered admission."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_model
from repro.parallel.sharding import make_plan
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.decode import generate

PLAN = make_plan(None)


def test_continuous_batching_matches_lockstep():
    cfg = ModelConfig("cb", "dense", 2, 32, 4, 2, 64, 64, dtype="float32",
                      remat="none")
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 7)]

    # Reference: independent greedy decode per prompt.
    refs = []
    for p in prompts:
        out = generate(params, cfg, PLAN, jnp.asarray(p[None]), max_new_tokens=6)
        refs.append(np.asarray(out)[0].tolist())

    # Continuous batching with 2 slots over 3 requests (forces an eviction +
    # mid-flight admission at a different position).
    cb = ContinuousBatcher(params, cfg, PLAN, slots=2, max_len=32)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    done = cb.run()
    assert len(done) == 3
    by_rid = {r.rid: r.out for r in done}
    for i, ref in enumerate(refs):
        assert by_rid[i] == ref, (i, by_rid[i], ref)


def test_per_slot_t_decode_vector():
    """The decode step accepts a per-slot t vector and masks each slot at its
    own length."""
    cfg = ModelConfig("cbv", "dense", 2, 32, 4, 2, 64, 64, dtype="float32",
                      remat="none")
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    from repro.models import transformer as tfm

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    # slot 0 prefilled with 8 tokens, slot 1 with 11.
    full, _, _ = tfm.model_apply(params, {"tokens": toks}, cfg, PLAN, mode="train")
    _, _, c0 = tfm.model_apply(params, {"tokens": toks[:1, :8]}, cfg, PLAN, mode="prefill")
    _, _, c1 = tfm.model_apply(params, {"tokens": toks[1:, :11]}, cfg, PLAN, mode="prefill")
    c0 = tfm.pad_caches(c0, 16)
    c1 = tfm.pad_caches(c1, 16)
    caches = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=1), c0, c1)
    step_toks = jnp.stack([toks[0, 8], toks[1, 11]])[:, None]
    feats, _, _ = tfm.model_apply(
        params, {"tokens": step_toks}, cfg, PLAN, mode="decode",
        caches=caches, t=jnp.asarray([8, 11]),
    )
    err0 = float(jnp.max(jnp.abs(full[0, 8] - feats[0, 0])))
    err1 = float(jnp.max(jnp.abs(full[1, 11] - feats[1, 0])))
    assert err0 < 2e-3 and err1 < 2e-3, (err0, err1)
