"""Continuous batching: per-slot positions produce exactly the tokens the
lockstep single-sequence path produces, with staggered admission — plus the
slot-lifecycle hardening regressions (DESIGN.md §9): over-long prompts,
EOS on the final allowed token, and dirty-slot cache reuse."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_model
from repro.parallel.sharding import make_plan
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.decode import generate

PLAN = make_plan(None)


def _toy(name="cb"):
    cfg = ModelConfig(name, "dense", 2, 32, 4, 2, 64, 64, dtype="float32",
                      remat="none")
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    return cfg, params


def test_continuous_batching_matches_lockstep():
    cfg = ModelConfig("cb", "dense", 2, 32, 4, 2, 64, 64, dtype="float32",
                      remat="none")
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 7)]

    # Reference: independent greedy decode per prompt.
    refs = []
    for p in prompts:
        out = generate(params, cfg, PLAN, jnp.asarray(p[None]), max_new_tokens=6)
        refs.append(np.asarray(out)[0].tolist())

    # Continuous batching with 2 slots over 3 requests (forces an eviction +
    # mid-flight admission at a different position).
    cb = ContinuousBatcher(params, cfg, PLAN, slots=2, max_len=32)
    for i, p in enumerate(prompts):
        cb.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    done = cb.run()
    assert len(done) == 3
    by_rid = {r.rid: r.out for r in done}
    for i, ref in enumerate(refs):
        assert by_rid[i] == ref, (i, by_rid[i], ref)


def test_per_slot_t_decode_vector():
    """The decode step accepts a per-slot t vector and masks each slot at its
    own length."""
    cfg = ModelConfig("cbv", "dense", 2, 32, 4, 2, 64, 64, dtype="float32",
                      remat="none")
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    from repro.models import transformer as tfm

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    # slot 0 prefilled with 8 tokens, slot 1 with 11.
    full, _, _ = tfm.model_apply(params, {"tokens": toks}, cfg, PLAN, mode="train")
    _, _, c0 = tfm.model_apply(params, {"tokens": toks[:1, :8]}, cfg, PLAN, mode="prefill")
    _, _, c1 = tfm.model_apply(params, {"tokens": toks[1:, :11]}, cfg, PLAN, mode="prefill")
    c0 = tfm.pad_caches(c0, 16)
    c1 = tfm.pad_caches(c1, 16)
    caches = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=1), c0, c1)
    step_toks = jnp.stack([toks[0, 8], toks[1, 11]])[:, None]
    feats, _, _ = tfm.model_apply(
        params, {"tokens": step_toks}, cfg, PLAN, mode="decode",
        caches=caches, t=jnp.asarray([8, 11]),
    )
    err0 = float(jnp.max(jnp.abs(full[0, 8] - feats[0, 0])))
    err1 = float(jnp.max(jnp.abs(full[1, 11] - feats[1, 0])))
    assert err0 < 2e-3 and err1 < 2e-3, (err0, err1)


# ---------------------------------------------------------------------------
# slot-lifecycle hardening (DESIGN.md §9)
# ---------------------------------------------------------------------------


def test_overlong_prompt_rejected_not_corrupting():
    """A prompt longer than the slot cache is rejected with ``req.error``
    instead of wrapping the ring buffer, and co-scheduled requests still
    produce exactly their independent-decode tokens."""
    cfg, params = _toy()
    rng = np.random.default_rng(1)
    good = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    ref = np.asarray(generate(params, cfg, PLAN, jnp.asarray(good[None]),
                              max_new_tokens=4))[0].tolist()

    cb = ContinuousBatcher(params, cfg, PLAN, slots=2, max_len=16)
    too_long = rng.integers(0, cfg.vocab_size, size=17).astype(np.int32)
    cb.submit(Request(rid=0, prompt=too_long, max_new_tokens=4))
    cb.submit(Request(rid=1, prompt=good, max_new_tokens=4))
    done = cb.run()
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].error == "prompt_too_long"
    assert by_rid[0].out == []
    assert by_rid[1].error is None and by_rid[1].out == ref


def test_prompt_exactly_fills_cache_emits_one_token():
    """len(prompt) == max_len leaves no decode room: the prefill's next-token
    is emitted and the request finishes (no ring-buffer wrap)."""
    cfg, params = _toy()
    prompt = np.random.default_rng(2).integers(
        0, cfg.vocab_size, size=16).astype(np.int32)
    cb = ContinuousBatcher(params, cfg, PLAN, slots=1, max_len=16)
    cb.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    done = cb.run()
    assert len(done) == 1 and done[0].error is None
    assert len(done[0].out) == 1
    # and the one token matches the independent prefill
    ref = np.asarray(generate(params, cfg, PLAN, jnp.asarray(prompt[None]),
                              max_new_tokens=1))[0].tolist()
    assert done[0].out == ref


def test_eos_on_final_allowed_token():
    """EOS arriving exactly at the max_new_tokens boundary finishes the
    request like an early EOS: the token is kept, nothing decodes past it."""
    cfg, params = _toy()
    prompt = np.random.default_rng(3).integers(
        0, cfg.vocab_size, size=5).astype(np.int32)
    free = ContinuousBatcher(params, cfg, PLAN, slots=1, max_len=32)
    free.submit(Request(rid=0, prompt=prompt, max_new_tokens=6))
    base = free.run()[0].out
    assert len(base) == 6
    # EOS == the 6th emitted token: identical output either way.
    cb = ContinuousBatcher(params, cfg, PLAN, slots=1, max_len=32)
    cb.submit(Request(rid=0, prompt=prompt, max_new_tokens=6, eos_id=base[-1]))
    out = cb.run()[0].out
    assert out == base
    # EOS == an EARLIER token: truncates right there (sanity of the same path)
    cb2 = ContinuousBatcher(params, cfg, PLAN, slots=1, max_len=32)
    cb2.submit(Request(rid=0, prompt=prompt, max_new_tokens=6, eos_id=base[2]))
    out2 = cb2.run()[0].out
    assert out2 == base[:3]


def test_dirty_slot_reuse_matches_fresh_batcher():
    """Re-admission into a slot whose cache still holds a LONGER evicted
    sequence must decode exactly like a fresh batcher: decode reads are
    masked to pos <= t, so the stale tail is never attended."""
    cfg, params = _toy()
    rng = np.random.default_rng(4)
    long_p = rng.integers(0, cfg.vocab_size, size=14).astype(np.int32)
    short_p = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)

    dirty = ContinuousBatcher(params, cfg, PLAN, slots=1, max_len=32)
    dirty.submit(Request(rid=0, prompt=long_p, max_new_tokens=8))
    dirty.submit(Request(rid=1, prompt=short_p, max_new_tokens=8))
    done = {r.rid: r.out for r in dirty.run()}

    fresh = ContinuousBatcher(params, cfg, PLAN, slots=1, max_len=32)
    fresh.submit(Request(rid=1, prompt=short_p, max_new_tokens=8))
    ref = fresh.run()[0].out
    assert done[1] == ref


def test_chunked_prefill_matches_whole_prefill_tokens():
    """Chunked prefill (decode-mode continuation) produces the same greedy
    tokens as whole-prompt prefill admission for a toy model, including a
    chunk-size remainder, and is deterministic across runs."""
    cfg, params = _toy("cbchunk")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (11, 7)]

    def run(chunk):
        cb = ContinuousBatcher(params, cfg, PLAN, slots=2, max_len=32,
                               prefill_chunk=chunk)
        for i, p in enumerate(prompts):
            cb.submit(Request(rid=i, prompt=p, max_new_tokens=5))
        return {r.rid: r.out for r in cb.run()}

    whole = run(0)  # chunking disabled -> whole-prompt prefill
    chunked = run(4)
    assert chunked == whole
    assert run(4) == chunked  # deterministic
