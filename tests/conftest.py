import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


def run_multidevice(code: str, devices: int = 8, timeout: int = 600):
    """Run a snippet in a subprocess with N forced host devices.

    Tests in this process see 1 device (dryrun sets its own flag itself);
    multi-device semantics are validated out of process.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture
def multidevice():
    return run_multidevice
