"""ServeEngine (DESIGN.md §9): workload determinism, decode-time
reconfiguration parity (bit-identical generation, single- and multi-device,
dropless and capacity), CommRuntime-consistent a2a accounting, checkpointed
placement state, and the priced netsim serving scenario."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import commruntime as comm
from repro.core.controlplane import ControlPlane, LayerPlan
from repro.models.config import ModelConfig, MoEConfig
from repro.models.transformer import init_model
from repro.parallel.sharding import make_plan
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.workload import MIXES, WorkloadGenerator

PLAN = make_plan(None)


def moe_cfg(dispatch="dropless", decode_backend="dense", cf=8.0):
    return ModelConfig(
        "srv", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=cf,
                      backend="mixnet", a2a_group=2, dispatch=dispatch,
                      decode_backend=decode_backend),
    )


def small_requests(gen, n, *, prompt_cap=20, out_cap=6):
    return [
        dataclasses.replace(
            r, prompt_len=min(r.prompt_len, prompt_cap),
            max_new_tokens=min(r.max_new_tokens, out_cap),
        )
        for r in gen.generate(n)
    ]


def build_engine(params, cfg, *, reconfig, prefill_chunk=0, num_devices=4,
                 reconfig_every=3):
    scfg = ServeConfig(
        slots=2, max_len=40, prefill_chunk=prefill_chunk,
        reconfig_every=(reconfig_every if reconfig else 0),
        reconfig_min_gain=0.0, num_devices=num_devices,
    )
    return ServeEngine(jax.tree.map(lambda a: a, params), cfg, PLAN, scfg)


# ---------------------------------------------------------------------------
# workload generator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mix", sorted(MIXES))
def test_workload_generator_valid_and_deterministic(mix):
    gen = WorkloadGenerator(mix, seed=7)
    reqs = gen.generate(64)
    m = MIXES[mix]
    arr = [r.arrival_s for r in reqs]
    assert arr == sorted(arr) and arr[0] >= 0.0
    for r in reqs:
        assert m.prompt_min <= r.prompt_len <= m.prompt_max
        assert m.out_min <= r.max_new_tokens <= m.out_max
        assert 0 <= r.region < m.num_regions
    # deterministic in seed, including prompt materialization
    reqs2 = WorkloadGenerator(mix, seed=7).generate(64)
    assert reqs == reqs2
    np.testing.assert_array_equal(
        gen.prompt_tokens(reqs[0]), WorkloadGenerator(mix, seed=7).prompt_tokens(reqs2[0])
    )
    # a different seed moves the stream
    assert WorkloadGenerator(mix, seed=8).generate(64) != reqs


def test_workload_region_prefix_encoded():
    gen = WorkloadGenerator("chat", seed=1, vocab_size=97)
    for r in gen.generate(16):
        assert gen.prompt_tokens(r)[0] == r.region % 97


# ---------------------------------------------------------------------------
# decode-time reconfiguration parity (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dispatch", ["dropless", "capacity"])
def test_engine_reconfig_parity_single_device(dispatch):
    """A mixed workload served to completion with decode-time
    reconfiguration enabled generates BIT-identical tokens to the
    reconfiguration-off run under identical seeds."""
    cfg = moe_cfg(dispatch=dispatch)
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    gen = WorkloadGenerator("chat", seed=3, vocab_size=cfg.vocab_size)
    reqs = small_requests(gen, 6)

    eng_on = build_engine(params, cfg, reconfig=True)
    rep_on = eng_on.run(reqs, gen)
    eng_off = build_engine(params, cfg, reconfig=False)
    rep_off = eng_off.run(reqs, gen)

    assert rep_on.completed == len(reqs) == rep_off.completed
    assert rep_on.reconfig_count > 0, "control loop never reconfigured"
    toks_on = {r.rid: r.out for r in eng_on.batcher.finished}
    toks_off = {r.rid: r.out for r in eng_off.batcher.finished}
    assert toks_on == toks_off
    # the placement actually moved experts
    assert (eng_on.controlplane.perm_stack() != eng_off.batcher.expert_perm).any() or (
        eng_on.controlplane.perm_stack()
        != np.tile(np.arange(8, dtype=np.int32), (2, 1))
    ).any()


def test_engine_chunked_prefill_reconfig_parity():
    """Chunked prefill interleaved into decode ticks preserves the parity
    guarantee (prefill chunks run under the same perm state)."""
    cfg = moe_cfg()
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    gen = WorkloadGenerator("agentic", seed=5, vocab_size=cfg.vocab_size)
    reqs = small_requests(gen, 5)
    eng_on = build_engine(params, cfg, reconfig=True, prefill_chunk=8)
    eng_on.run(reqs, gen)
    eng_off = build_engine(params, cfg, reconfig=False, prefill_chunk=8)
    eng_off.run(reqs, gen)
    assert eng_on.controlplane.reconfig_count > 0
    assert {r.rid: r.out for r in eng_on.batcher.finished} == {
        r.rid: r.out for r in eng_off.batcher.finished
    }


SPARSE_SWEEP = """
import dataclasses
import jax, numpy as np
from repro.core.controlplane import LayerPlan
from repro.models.config import ModelConfig, MoEConfig
from repro.models.transformer import init_model
from repro.parallel.sharding import make_plan
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.workload import WorkloadGenerator
from repro.launch.mesh import make_mesh as _mm
from repro.launch.mesh import use_mesh as _um

P = %(P)d
mesh = _mm((P,), ("model",))
plan = make_plan(mesh)

for dispatch in ("dropless", "capacity"):
    cfg = ModelConfig(
        "srv", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=8.0,
                      backend="mixnet", a2a_group=2, dispatch=dispatch,
                      decode_backend="sparse"),
    )
    params, _ = init_model(jax.random.PRNGKey(0), cfg, plan)
    gen = WorkloadGenerator("chat", seed=3, vocab_size=cfg.vocab_size)
    reqs = [dataclasses.replace(r, prompt_len=12, max_new_tokens=4)
            for r in gen.generate(3)]

    def run(reconfig):
        scfg = ServeConfig(slots=2, max_len=32,
                           reconfig_every=(2 if reconfig else 0),
                           reconfig_min_gain=0.0, num_devices=P)
        eng = ServeEngine(jax.tree.map(lambda a: a, params), cfg, plan, scfg,
                          mesh=mesh)
        with _um(mesh):
            if reconfig:
                # Force one whole-device-block plan: realized as a WIRE
                # re-address on the decode a2a (weights never move).
                epd = 8 // P
                block = np.arange(8).reshape(P, epd)
                block[[0, 1]] = block[[1, 0]]
                eng.apply_plans([
                    LayerPlan(l, True, perm=block.reshape(-1).copy())
                    for l in range(cfg.pattern_repeats)
                ])
                assert eng.applier.wire_reconfig_count > 0, "wire path not taken"
            rep = eng.run(reqs, gen)
        assert rep.completed == len(reqs)
        return eng, rep

    eng_on, rep_on = run(True)
    eng_off, rep_off = run(False)
    assert rep_on.reconfig_count > 0
    a = {r.rid: r.out for r in eng_on.batcher.finished}
    b = {r.rid: r.out for r in eng_off.batcher.finished}
    assert a == b, (dispatch, a, b)
    # sparse decode accounted nonzero a2a bytes through the CommRuntime
    assert rep_on.a2a_bytes > 0
print("SPARSE_SWEEP_OK_P%(P)d")
"""


@pytest.mark.parametrize("p", [2, 4, 8])
def test_sparse_decode_reconfig_parity_multidevice(multidevice, p):
    """P-device EP-sharded decode (the mixnet a2a runs every tick, wire
    perms re-address it): reconfiguration on vs off is bit-identical for
    dropless AND capacity dispatch."""
    out = multidevice(SPARSE_SWEEP % {"P": p}, devices=8, timeout=900)
    assert f"SPARSE_SWEEP_OK_P{p}" in out


# ---------------------------------------------------------------------------
# a2a accounting cross-check (engine <-> CommRuntime <-> netsim)
# ---------------------------------------------------------------------------


def test_engine_a2a_bytes_match_commruntime_accounting():
    cfg = moe_cfg()
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    gen = WorkloadGenerator("chat", seed=2, vocab_size=cfg.vocab_size)
    reqs = small_requests(gen, 4)
    eng = build_engine(params, cfg, reconfig=True, prefill_chunk=4)
    rep = eng.run(reqs, gen)
    dtype_bytes = np.dtype(cfg.dtype).itemsize
    moe_layers = cfg.pattern_repeats  # one MoE block per repeat here
    expected = sum(
        moe_layers * comm.ep_alltoall_bytes(
            t.live + t.prefill_tokens, cfg.moe.top_k, cfg.d_model, dtype_bytes
        )
        for t in eng.tick_log
    )
    assert rep.a2a_bytes == expected > 0


def test_netsim_serving_a2a_bytes_match_commruntime_accounting():
    """The priced scenario's byte total is exactly the CommRuntime formula
    applied to every routed token (prefill + decode) — no private model."""
    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_serving

    model = dataclasses.replace(MIXTRAL_8X7B, num_blocks=8)
    fab = make_fabric("fat-tree", FabricConfig(num_servers=16, link_gbps=400))
    reqs = WorkloadGenerator("chat", seed=4).generate(12)
    res = simulate_serving(
        model, fab, mix="chat", num_requests=12, use_reconfig=False, seed=4
    )
    assert res.completed == len(reqs)
    routed = sum(r.prompt_len for r in reqs) + (res.tokens_out - len(reqs))
    expected = model.layers_per_stage * comm.ep_alltoall_bytes(
        routed, model.top_k, model.d_model, model.dtype_bytes
    )
    np.testing.assert_allclose(res.a2a_bytes_total, expected, rtol=1e-9)


# ---------------------------------------------------------------------------
# checkpointed placement state
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_mid_reconfig_bit_identical(tmp_path):
    """Save mid-reconfiguration, restore into a FRESH server: the restored
    perm stack composes with the restored (permuted) weights, so the next
    tokens are bit-identical to the original server's."""
    cfg = moe_cfg()
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    gen = WorkloadGenerator("chat", seed=9, vocab_size=cfg.vocab_size)
    warm = small_requests(gen, 3)

    eng = build_engine(params, cfg, reconfig=True, reconfig_every=2)
    eng.run(warm, gen)
    assert eng.controlplane.reconfig_count > 0
    stack = eng.controlplane.perm_stack()
    assert (stack != np.tile(np.arange(8, dtype=np.int32), (2, 1))).any()
    step = eng.save_checkpoint(str(tmp_path))

    fresh = build_engine(params, cfg, reconfig=True, reconfig_every=10**9)
    fresh.restore_checkpoint(str(tmp_path), step)
    np.testing.assert_array_equal(fresh.batcher.expert_perm, stack)

    probe = small_requests(WorkloadGenerator("chat", seed=11,
                                             vocab_size=cfg.vocab_size), 2)
    gen11 = WorkloadGenerator("chat", seed=11, vocab_size=cfg.vocab_size)
    # original server (reconfig loop frozen so no further plans land)
    eng.scfg.reconfig_every = 10**9
    eng.run(probe, gen11)
    fresh.run(probe, gen11)
    a = {r.rid: r.out for r in eng.batcher.finished if r.rid in {p.rid for p in probe}}
    b = {r.rid: r.out for r in fresh.batcher.finished}
    assert a == b

    # restoring placement into an engine without a control plane is an error
    bare = build_engine(params, cfg, reconfig=False)
    with pytest.raises(RuntimeError):
        bare.restore_checkpoint(str(tmp_path), step)


def test_controlplane_state_dict_validation():
    cp = ControlPlane(num_layers=2, num_experts=8, num_devices=4,
                      use_copilot=False)
    cp.apply(LayerPlan(0, True, perm=np.array([1, 0, 2, 3, 4, 5, 6, 7])))
    state = cp.state_dict()
    cp2 = ControlPlane(num_layers=2, num_experts=8, num_devices=4,
                      use_copilot=False)
    cp2.load_state_dict(state)
    np.testing.assert_array_equal(cp2.perm_stack(), cp.perm_stack())
    assert cp2.reconfig_count == cp.reconfig_count
    bad = dict(state, layer_perms=[[0, 0, 2, 3, 4, 5, 6, 7]] * 2)
    with pytest.raises(ValueError):
        cp2.load_state_dict(bad)
    with pytest.raises(ValueError):
        cp2.load_state_dict(dict(state, layer_perms=[[0, 1]]))


# ---------------------------------------------------------------------------
# priced serving scenario
# ---------------------------------------------------------------------------


def _serving(fabric_name, reconfig, **kw):
    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_serving

    model = dataclasses.replace(MIXTRAL_8X7B, num_blocks=8, overlap_chunks=4)
    fab = make_fabric(fabric_name, FabricConfig(num_servers=128, link_gbps=400))
    return simulate_serving(
        model, fab, mix="agentic", num_requests=32, use_reconfig=reconfig,
        seed=1, **kw,
    )


def test_netsim_serving_goodput_per_dollar_gate():
    """The acceptance gate: reconfigured-fabric goodput-per-dollar >= the
    static EPS baseline, with the 25 ms OCS fully amortized at the default
    serving cadence."""
    r_mix = _serving("mixnet", True)
    r_eps = _serving("fat-tree", False)
    assert r_mix.completed == r_mix.requests
    assert r_mix.reconfig_count > 0 and r_eps.reconfig_count == 0
    assert r_mix.goodput_per_mdollar >= r_eps.goodput_per_mdollar
    assert r_mix.reconfig_blocked_s == 0.0  # hidden in the window's compute
    for r in (r_mix, r_eps):
        assert 0.0 <= r.exposed_comm_fraction <= 1.0
        assert r.ttft_p99_s >= r.ttft_p50_s >= 0.0
        assert r.tpot_p99_s >= r.tpot_p50_s > 0.0


def test_netsim_serving_chunked_prefill_widens_hide_window():
    """Interleaved prefill compute joins the hideable window: with chunked
    overlap, a LARGER prefill budget never increases the exposed fraction."""
    lo = _serving("mixnet", False, prefill_chunk_tokens=32)
    hi = _serving("mixnet", False, prefill_chunk_tokens=512)
    assert hi.exposed_comm_fraction <= lo.exposed_comm_fraction + 1e-9


def test_netsim_serving_aggressive_reconfig_pays_blocking():
    """Fig 28's logic at serving cadence: reconfiguring every few ticks
    cannot hide the 25 ms OCS and stalls the pipe."""
    calm = _serving("mixnet", True)  # default cadence: fully hidden
    hot = _serving("mixnet", True, reconfig_every_ticks=4)
    assert calm.reconfig_blocked_s == 0.0
    assert hot.reconfig_blocked_s > 0.0
    assert hot.tpot_p50_s >= calm.tpot_p50_s
