"""Pipeline-parallel train step (repro.train.pp_step): gradient parity
against the non-PP step for S in {1,2,4} x P, with and without an applied
placement plan (expert/wire perms), on 8 fake devices.

The acceptance bar (DESIGN.md §13): the PP step's *math* is bit-identical
to the non-PP step — asserted by running both steps un-jitted (op-by-op
execution, no cross-program fusion) and requiring exact equality of the
updated params, loss, and gate telemetry.  Two sources of 1-ulp noise are
outside the math's control and get their own (far tighter than repo
standard) bars:

* whole-program jit: XLA fuses the two differently-shaped programs
  differently (reductions folded into different producers), perturbing
  single elements at the 1-ulp level -> jitted cross-checks use the
  repo's 1e-5 tolerance;
* P > 1 meshes: changing the stage count changes the device layout the
  model-axis reductions run over (the reduce-scatter adjoint of the
  sequence all_gather reassociates differently), perturbing ~1 element
  in a few thousand at ~1e-11 abs even un-jitted -> the P>1 tiers use
  rtol=1e-6/atol=1e-10 ("tight": two decades below repo tolerance, two
  above the observed noise floor).  P = 1 is the true bitwise tier.
"""

_COMMON = """
import jax, jax.numpy as jnp, numpy as np
from repro.models.config import ModelConfig, MoEConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.train.train_step import init_all, make_train_step
from repro.train.pp_step import make_pp_train_step
from repro.launch.mesh import make_mesh, use_mesh

# heads deliberately NOT divisible by the model axis (attention inside a PP
# stage computes replicated on the gathered sequence; keep the non-PP
# reference on the same no-TP-attention path).
CFG = ModelConfig('tiny-moe', 'moe', 4, 32, 3, 1, 0, 64, head_dim=8,
                  dtype='float32', remat='none',
                  moe=MoEConfig(num_experts=4, top_k=2, d_ff=32,
                                capacity_factor=2.0, backend='mixnet',
                                overlap_chunks=2))
OPT = AdamWConfig(lr=1e-3)
B, T = 4, 16

def batch_for(seed=0):
    k = jax.random.PRNGKey(seed)
    tok = jax.random.randint(k, (B, T), 0, CFG.vocab_size)
    lab = jnp.roll(tok, -1, axis=1)
    return {'tokens': tok, 'labels': lab}

def fresh_state():
    return init_all(jax.random.PRNGKey(0), CFG, make_plan(None), OPT)[::2]

def run_pp(s, p, m, perm=None, wire=None, seed=0, jit=False):
    mesh = make_mesh((s, p), ('stage', 'model'))
    plan = make_plan(mesh, fsdp=False)
    params, opt_state = fresh_state()
    with use_mesh(mesh):
        step = make_pp_train_step(
            CFG, plan, OPT, mesh, pp_stages=s, microbatches=m)
        if jit:
            step = jax.jit(step)
        out = step(params, opt_state, batch_for(seed), perm, wire)
        out = jax.tree.map(np.asarray, out)
    return out

def run_ref(p, m=1, perm=None, wire=None, seed=0, jit=False):
    mesh = make_mesh((p,), ('model',))
    plan = make_plan(mesh)
    params, opt_state = fresh_state()
    with use_mesh(mesh):
        step = make_train_step(CFG, plan, OPT, mesh=mesh, microbatches=m)
        if jit:
            step = jax.jit(step)
        out = step(params, opt_state, batch_for(seed), perm, wire)
        out = jax.tree.map(np.asarray, out)
    return out

def check(tag, a, b, mode):
    # mode: 'exact' (bitwise), 'tight' (1-ulp mesh-layout noise only), or
    # 'close' (repo tolerance, for jitted cross-checks).
    pa, _, ma = a
    pb, _, mb = b
    la, lb = jax.tree.leaves(pa), jax.tree.leaves(pb)
    assert len(la) == len(lb)
    if mode == 'exact':
        for x, y in zip(la, lb):
            np.testing.assert_array_equal(x, y, err_msg=tag)
        np.testing.assert_array_equal(ma['loss'], mb['loss'], err_msg=tag)
        np.testing.assert_array_equal(ma['expert_load'], mb['expert_load'],
                                      err_msg=tag)
    else:
        rtol, atol = (1e-6, 1e-10) if mode == 'tight' else (1e-5, 1e-6)
        for x, y in zip(la, lb):
            np.testing.assert_allclose(x, y, rtol=rtol, atol=atol,
                                       err_msg=tag)
        np.testing.assert_allclose(ma['loss'], mb['loss'], rtol=rtol,
                                   err_msg=tag)
        np.testing.assert_allclose(ma['expert_load'], mb['expert_load'],
                                   rtol=rtol, atol=max(atol, 1e-6),
                                   err_msg=tag)
    print(tag, 'ok')
"""

# ---- Tier A: P = 1, bitwise vs the non-PP step for S in {1, 2, 4} ----------
P1 = _COMMON + """
ref = run_ref(1)
for s in (1, 2, 4):
    check(f'pp(S={s},P=1) == ref', run_pp(s, 1, 1), ref, 'exact')
# the jitted production path stays on the same answer to float tolerance
check('jit pp(S=2,P=1) ~= jit ref', run_pp(2, 1, 1, jit=True),
      run_ref(1, jit=True), 'close')
print('PP_P1_OK')
"""


def test_pp_bitwise_vs_ref_p1(multidevice):
    out = multidevice(P1, devices=8, timeout=900)
    assert "PP_P1_OK" in out


# ---- Tier B: S x P over 8 devices; PP(S,P) matches PP(1,P) and the
# auto-sharded non-PP reference to the tight (near-bit) bar ----------------
TP = _COMMON + """
for s, p in ((2, 4), (4, 2)):
    pp = run_pp(s, p, 1)
    check(f'pp(S={s},P={p}) ~= pp(S=1,P={p})', pp, run_pp(1, p, 1), 'tight')
    check(f'pp(S={s},P={p}) ~= ref(P={p})', pp, run_ref(p), 'tight')
check('jit pp(S=2,P=4) ~= jit ref(P=4)', run_pp(2, 4, 1, jit=True),
      run_ref(4, jit=True), 'close')
print('PP_TP_OK')
"""


def test_pp_bitwise_vs_pp1_and_ref_tp(multidevice):
    out = multidevice(TP, devices=8, timeout=900)
    assert "PP_TP_OK" in out


# ---- Tier C: microbatched schedule (M > S, M = S, warmup/drain live) and a
# forced placement plan: expert perm + wire re-address through the pipe ----
PERMS = _COMMON + """
# M=4 microbatches: the full pipeline (warmup + steady + drain) matches the
# S=1 schedule (same single value_and_grad over the whole batch).
check('pp(S=4,P=2,M=4) ~= pp(S=1,P=2,M=4)',
      run_pp(4, 2, 4), run_pp(1, 2, 4), 'tight')

# Applied placement plan: per-layer expert->slot perms + wire device maps
# must flow through the stage pipe exactly as through the flat step.
from repro.parallel.sharding import virtual_experts
reps, p = CFG.pattern_repeats, 4
ev, _ = virtual_experts(CFG.moe.num_experts, p)
rng = np.random.RandomState(0)
perm = jnp.asarray(np.stack([rng.permutation(ev) for _ in range(reps)]),
                   jnp.int32)
wire = jnp.asarray(np.stack([np.roll(np.arange(p), l % p)
                             for l in range(reps)]), jnp.int32)
pp = run_pp(2, p, 1, perm=perm, wire=wire)
check('pp(S=2,P=4,perm+wire) ~= pp(S=1,P=4,perm+wire)',
      pp, run_pp(1, p, 1, perm=perm, wire=wire), 'tight')
check('pp(S=2,P=4,perm+wire) ~= ref(P=4,perm+wire)',
      pp, run_ref(p, perm=perm, wire=wire), 'tight')
print('PP_PERMS_OK')
"""


def test_pp_microbatches_and_placement_plan(multidevice):
    out = multidevice(PERMS, devices=8, timeout=900)
    assert "PP_PERMS_OK" in out


def test_pp_misconfigurations_rejected():
    import jax

    from repro.models.config import ModelConfig, MoEConfig
    from repro.optim.adamw import AdamWConfig
    from repro.parallel.sharding import make_plan
    from repro.train.pp_step import make_pp_train_step
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = ModelConfig(
        "tiny-moe", "moe", 4, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=32, backend="mixnet"),
    )
    opt = AdamWConfig(lr=1e-3)
    plan = make_plan(None)

    # no stage axis on the mesh
    try:
        make_pp_train_step(cfg, plan, opt, None, pp_stages=2)
        raise AssertionError("expected ValueError (no mesh)")
    except ValueError:
        pass
    # einsum backend has no per-device local body
    from jax.sharding import Mesh

    mesh = Mesh(jax.devices()[:1], ("stage",))
    import dataclasses

    bad = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, backend="einsum")
    )
    try:
        make_pp_train_step(bad, plan, opt, mesh, pp_stages=1)
        raise AssertionError("expected NotImplementedError (einsum)")
    except NotImplementedError:
        pass
    # repeats not divisible by stages
    try:
        make_pp_train_step(cfg, plan, opt, mesh, pp_stages=3)
        raise AssertionError("expected ValueError (3 stages, 4 repeats)")
    except ValueError:
        pass
    # expert replication (E < model axis) has no stage-body lowering
    import dataclasses as _dc

    rep_plan = _dc.replace(plan, model_axis="model", model_size=8)
    try:
        make_pp_train_step(cfg, rep_plan, opt, mesh, pp_stages=1)
        raise AssertionError("expected NotImplementedError (replication)")
    except NotImplementedError:
        pass
    # Trainer: PP composes with dp_comm='auto' only
    try:
        Trainer(cfg, opt, TrainerConfig(pp_stages=2, dp_comm="runtime"),
                plan, mesh=None)
        raise AssertionError("expected ValueError (dp_comm)")
    except ValueError:
        pass
