"""Overlap engine (repro.core.overlap, DESIGN.md §8): scheduler invariants,
the staged CollectiveOp surface, chunked-MoE bit-parity sweeps, the FSDP
prefetch path, netsim's hidden/exposed accounting, gradient compression
through the runtime, and wire-level re-addressing in the trainer."""

import dataclasses

import numpy as np
import pytest

from repro.core import overlap


# ---------------------------------------------------------------------------
# scheduler unit tests
# ---------------------------------------------------------------------------


def test_chunk_count_divisor():
    assert overlap.chunk_count(32, 4) == 4
    assert overlap.chunk_count(32, 5) == 4  # nearest divisor below
    assert overlap.chunk_count(30, 4) == 3
    assert overlap.chunk_count(7, 16) == 7
    assert overlap.chunk_count(7, 3) == 1
    assert overlap.chunk_count(8, 1) == 1


def test_software_pipeline_dataflow_and_order():
    """Each chunk flows through all stages in order; the global issue order
    is the skewed tick order with later stages drained first."""
    issued = []

    def stage(s):
        def run(prev, k):
            issued.append((s, k))
            return (prev or ()) + (s,)

        return run

    out = overlap.software_pipeline(3, [stage(0), stage(1), stage(2)])
    assert out == [(0, 1, 2)] * 3
    # tick t issues stage s of chunk t-s, deepest stage first
    assert issued == [
        (0, 0),
        (1, 0), (0, 1),
        (2, 0), (1, 1), (0, 2),
        (2, 1), (1, 2),
        (2, 2),
    ]
    assert overlap.software_pipeline(2, []) == [None, None]


def test_pipelined_phase_serial_equals_additive():
    total, exposed = overlap.pipelined_phase(3.0, 5.0, 2.0, 1, serial_prefix=1.0)
    assert total == pytest.approx(1.0 + 3.0 + 5.0 + 2.0)
    assert exposed == pytest.approx(5.0)  # all comm exposed


def test_pipelined_phase_invariants_sweep():
    rng = np.random.default_rng(0)
    for _ in range(200):
        d, e, cb, pre = rng.random(4) * 10
        serial = pre + d + e + cb
        prev_total = None
        for c in (1, 2, 4, 8, 16):
            total, exposed = overlap.pipelined_phase(d, e, cb, c, serial_prefix=pre)
            comm = d + cb
            # never exceeds the serial estimate, never undercuts either
            # resource's busy time
            assert total <= serial + 1e-9, (c, d, e, cb, pre)
            assert total >= pre + max(e, comm) - 1e-9
            assert -1e-9 <= exposed <= comm + 1e-9
            # hidden + exposed == comm by construction
            hidden = comm - exposed
            assert -1e-9 <= hidden <= comm + 1e-9
            if prev_total is not None:
                assert total <= prev_total + 1e-9  # more chunks never slower
            prev_total = total


def test_pipelined_phase_hides_comm_under_compute():
    # compute-dominated phase: almost all comm hides once chunked
    total, exposed = overlap.pipelined_phase(1.0, 10.0, 1.0, 8)
    assert exposed < 0.5
    assert total < 12.0 - 1.0  # strictly better than serial


# ---------------------------------------------------------------------------
# staged CollectiveOp surface
# ---------------------------------------------------------------------------


def test_a2a_stage_bytes_sum_to_op_bytes():
    from repro.core import commruntime as cr

    op = cr.AllToAll(cr.CommSpec(axis="model", axis_size=8, group_size=4))
    stages = op.stages()
    assert len(stages) == 2
    assert stages[0].link_class == "scale_up"
    assert stages[1].link_class == "scale_out"
    b = 4096.0
    full = op.bytes_on_link(b)
    s0 = stages[0].bytes_on_link(b)
    s1 = stages[1].bytes_on_link(b)
    assert s0.scale_up == pytest.approx(full.scale_up)
    assert s0.scale_out == 0.0
    assert s1.scale_out == pytest.approx(full.scale_out)
    assert s0.total + s1.total == pytest.approx(full.total)
    # flat spec: one stage that IS the op
    flat = cr.AllToAll(cr.CommSpec(axis="model", axis_size=8)).stages()
    assert len(flat) == 1
    assert flat[0].bytes_on_link(b).total == pytest.approx(
        cr.AllToAll(cr.CommSpec(axis="model", axis_size=8)).bytes_on_link(b).total
    )
    # cost-only hierarchical spec (netsim's) still exposes both stages
    cost_only = cr.AllToAll(cr.CommSpec(axis=None, axis_size=32, group_size=8))
    assert len(cost_only.stages()) == 2


STAGED_A2A = """
import itertools
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.commruntime import AllToAll, CommSpec
from repro.launch.mesh import make_mesh
from repro.parallel.sharding import shard_map

PDEV = 8
mesh = make_mesh((PDEV,), ('model',))
x = jax.random.normal(jax.random.PRNGKey(0), (PDEV * PDEV, 4))
rng = np.random.default_rng(1)
perms = [None, tuple(rng.permutation(PDEV).tolist())]

for g, dp, sp in itertools.product((1, 2, 4), perms, perms):
    op = AllToAll(CommSpec(axis='model', axis_size=PDEV, group_size=g,
                           dest_perm=dp, src_perm=sp))
    def whole(v):
        return op(v.reshape(PDEV, 4)).reshape(1, PDEV * 4)
    def staged(v):
        y = v.reshape(PDEV, 4)
        for s in op.stages():
            y = s(y)
        return y.reshape(1, PDEV * 4)
    run = lambda f: np.asarray(shard_map(f, mesh=mesh, in_specs=P('model'),
                                         out_specs=P('model'))(x))
    np.testing.assert_array_equal(run(staged), run(whole)), (g, dp, sp)
print('STAGED_A2A_OK')
"""


def test_a2a_stages_compose_bit_identical(multidevice):
    """Composing AllToAll.stages() in order == the whole lowering, bitwise,
    for group sizes {1,2,4} x non-identity dest/src wire perms."""
    out = multidevice(STAGED_A2A, devices=8, timeout=900)
    assert "STAGED_A2A_OK" in out


RING_RS = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.commruntime import ReduceScatter, CommSpec, ring_reduce_scatter
from repro.launch.mesh import make_mesh
from repro.parallel.sharding import shard_map

for p in (2, 4, 8):
    mesh = make_mesh((p,), ('model',))
    # integer payload: the ring's hop-ordered sum must be EXACTLY psum_scatter
    xi = jnp.arange(p * p * 3, dtype=jnp.int32).reshape(p * p, 3)
    ring_op = ReduceScatter(CommSpec(axis='model', axis_size=p), impl='ring')
    flat_op = ReduceScatter(CommSpec(axis='model', axis_size=p), impl='flat')
    run = lambda op, v: np.asarray(shard_map(
        lambda u: op(u), mesh=mesh, in_specs=P('model'), out_specs=P('model'),
        check_vma=False)(v))
    np.testing.assert_array_equal(run(ring_op, xi), run(flat_op, xi)), p
    # f32: allclose (ring order vs XLA tree order)
    xf = jax.random.normal(jax.random.PRNGKey(p), (p * p * 2, 3))
    np.testing.assert_allclose(run(ring_op, xf), run(flat_op, xf),
                               rtol=1e-5, atol=1e-5)
    # non-zero scatter_dim: per-device distinct [2, 2p] inputs reduced over
    # the axis and scattered along dim 1
    xt = jax.random.normal(jax.random.PRNGKey(p + 10), (p * 2, 2 * p))
    a = np.asarray(shard_map(lambda u: ring_reduce_scatter(u, 'model', scatter_dim=1),
                             mesh=mesh, in_specs=P('model', None),
                             out_specs=P(None, 'model'), check_vma=False)(xt))
    b = np.asarray(shard_map(
        lambda u: jax.lax.psum_scatter(u, 'model', scatter_dimension=1, tiled=True),
        mesh=mesh, in_specs=P('model', None), out_specs=P(None, 'model'),
        check_vma=False)(xt))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
print('RING_RS_OK')
"""


def test_ring_reduce_scatter_matches_psum_scatter(multidevice):
    """The Permute-ring ReduceScatter stepping == lax.psum_scatter (exact for
    ints, allclose for f32) across axis sizes and scatter dims."""
    out = multidevice(RING_RS, devices=8, timeout=900)
    assert "RING_RS_OK" in out


# ---------------------------------------------------------------------------
# chunked MoE parity (tentpole acceptance)
# ---------------------------------------------------------------------------


def test_moe_chunked_parity_single_device():
    """P=1 leg of the sweep: overlap_chunks {1,2,4} x dropless/capacity are
    bit-identical to the serial path."""
    import jax
    import jax.numpy as jnp

    from repro.models import moe as moe_mod
    from repro.models.config import ModelConfig, MoEConfig
    from repro.parallel.sharding import make_plan

    plan = make_plan(None)
    for dispatch in ("dropless", "capacity"):
        cfg = ModelConfig(
            "t", "moe", 2, 32, 4, 2, 64, 128, dtype="float32",
            moe=MoEConfig(num_experts=4, top_k=2, d_ff=48, capacity_factor=4.0,
                          a2a_group=2, dispatch=dispatch),
        )
        params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, plan)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        base, st0 = moe_mod.moe_apply(params, x, cfg, plan, backend="mixnet")
        for c in (2, 4):
            cfg_c = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, overlap_chunks=c)
            )
            out, st = moe_mod.moe_apply(params, x, cfg_c, plan, backend="mixnet")
            np.testing.assert_array_equal(np.asarray(base), np.asarray(out))
            assert float(st.dropped_fraction) == float(st0.dropped_fraction)


CHUNK_SWEEP = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.models import moe as moe_mod
from repro.models.config import ModelConfig, MoEConfig
from repro.parallel.sharding import make_plan
from repro.launch.mesh import make_mesh, use_mesh

# P sweep over model sizes {2, 4, 8} on 8 forced devices (P=1 runs
# in-process in the test file); chunks {1, 2, 4} x dropless/capacity,
# hierarchical a2a groups, and a non-identity wire perm on the P=4 mesh.
for shape, axes in (((4, 2), ('data', 'model')),
                    ((2, 4), ('data', 'model')),
                    ((8,), ('model',))):
    mesh = make_mesh(shape, axes)
    plan = make_plan(mesh)
    P_ = plan.model_size
    for dispatch in ('dropless', 'capacity'):
        cfg = ModelConfig('t', 'moe', 2, 32, 4, 2, 64, 128, dtype='float32',
                          moe=MoEConfig(num_experts=8, top_k=2, d_ff=48,
                                        capacity_factor=8.0, a2a_group=2,
                                        dispatch=dispatch))
        params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, plan)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        with use_mesh(mesh):
            base, st0 = jax.jit(lambda p, v: moe_mod.moe_apply(
                p, v, cfg, plan, mesh=mesh, backend='mixnet'))(params, x)
            for c in (2, 4):
                cfg_c = dataclasses.replace(
                    cfg, moe=dataclasses.replace(cfg.moe, overlap_chunks=c))
                out, st = jax.jit(lambda p, v: moe_mod.moe_apply(
                    p, v, cfg_c, plan, mesh=mesh, backend='mixnet'))(params, x)
                assert (np.asarray(base) == np.asarray(out)).all(), (P_, dispatch, c)
                assert float(st.dropped_fraction) == float(st0.dropped_fraction)

# Non-identity wire perm leg: physical weights laid out for device map D,
# logical placement identity; every chunk count must match the einsum
# reference AND the serial wire path bitwise.
mesh = make_mesh((2, 4), ('data', 'model'))
plan = make_plan(mesh)
plan1 = make_plan(None)
for dispatch in ('dropless', 'capacity'):
    cfg = ModelConfig('t', 'moe', 2, 32, 4, 2, 64, 128, dtype='float32',
                      moe=MoEConfig(num_experts=8, top_k=2, d_ff=48,
                                    capacity_factor=8.0, a2a_group=2,
                                    dispatch=dispatch))
    params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, plan)
    params1, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, plan1)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
    ref, _ = moe_mod.moe_apply(params1, x, cfg, plan1, backend='einsum')
    D = np.array([2, 3, 1, 0])
    Dinv = np.argsort(D)
    ev, epd = 8, 2
    t_ = np.arange(ev)
    inv_phi = Dinv[t_ // epd] * epd + t_ % epd
    pw = dict(params)
    for wname in ('w_in', 'w_gate', 'w_out'):
        pw[wname] = params[wname][inv_phi]
    wire = jnp.asarray(D, jnp.int32)
    with use_mesh(mesh):
        outs = {}
        for c in (1, 2, 4):
            cfg_c = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, overlap_chunks=c))
            ow, _ = jax.jit(lambda p, v, w: moe_mod.moe_apply(
                p, v, cfg_c, plan, mesh=mesh, backend='mixnet',
                wire_perm=w))(pw, x, wire)
            outs[c] = np.asarray(ow)
            assert float(jnp.max(jnp.abs(ow - ref))) < 1e-5, (dispatch, c)
        assert (outs[2] == outs[1]).all() and (outs[4] == outs[1]).all(), dispatch
    # decode after a wire-level reconfig: S=1 auto-routes to dense_decode,
    # which must compose the wire perm into the slot addressing to hit the
    # physically-resident weights (the analogue of PR 2's decode fix).
    x1 = x[:, :1]
    ref1, _ = moe_mod.moe_apply(params1, x1, cfg, plan1, backend='einsum')
    with use_mesh(mesh):
        od, _ = jax.jit(lambda p, v, w: moe_mod.moe_apply(
            p, v, cfg, plan, mesh=mesh, backend='mixnet', mode='decode',
            wire_perm=w))(pw, x1, wire)
    assert float(jnp.max(jnp.abs(od - ref1))) < 1e-5, dispatch
print('CHUNK_SWEEP_OK')
"""


def test_moe_chunked_parity_multidevice_sweep(multidevice):
    """Acceptance sweep: overlap_chunks {1,2,4} x dropless/capacity x
    P {2,4,8} x non-identity wire perms, bit-identical to the serial path."""
    out = multidevice(CHUNK_SWEEP, devices=8, timeout=900)
    assert "CHUNK_SWEEP_OK" in out


# ---------------------------------------------------------------------------
# netsim event timeline
# ---------------------------------------------------------------------------


def _sim(model, *, chunks, seed=7, delay=0.025):
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import GateTraceGenerator, simulate_iteration

    m = dataclasses.replace(model, overlap_chunks=chunks)
    fab = make_fabric(
        "mixnet",
        FabricConfig(num_servers=16, link_gbps=400, reconfig_delay_s=delay),
    )
    trace = GateTraceGenerator(m.layers_per_stage, m.num_experts, seed=seed)
    return simulate_iteration(m, fab, trace, num_servers_region=4)


def test_netsim_hidden_plus_exposed_equals_additive_a2a():
    """Cross-check: the overlap split partitions the old additive a2a total
    exactly, at every chunk count."""
    from repro.configs.paper_models import MIXTRAL_8X7B

    model = dataclasses.replace(MIXTRAL_8X7B, num_blocks=8)
    for chunks in (1, 2, 4, 8):
        res = _sim(model, chunks=chunks)
        assert res.hidden_comm + res.exposed_comm == pytest.approx(res.a2a)
        assert res.hidden_comm >= 0 and res.exposed_comm >= 0
        bd = res.breakdown()
        assert "hidden_comm" in bd and "exposed_comm" in bd


def test_netsim_serial_chunks_reproduce_additive_schedule():
    """overlap_chunks=1 IS the pre-overlap additive model: zero hidden comm
    and total == compute + a2a composition (the old formula)."""
    from repro.configs.paper_models import MIXTRAL_8X7B

    model = dataclasses.replace(MIXTRAL_8X7B, num_blocks=8)
    res = _sim(model, chunks=1)
    assert res.hidden_comm == pytest.approx(0.0)
    assert res.exposed_comm == pytest.approx(res.a2a)
    m, p = model.num_microbatches, model.pp_degree
    stretch = (m + p - 1) / m
    compute = m * 3.0 * (
        model.attention_time() + model.expert_time()
    )
    expected = stretch * compute + res.a2a + res.reconfig_blocked + res.dp_allreduce
    assert res.total == pytest.approx(expected, rel=1e-9)


def test_netsim_overlap_hides_comm_and_never_exceeds_serial():
    """Acceptance: nonzero hidden_comm for a production-shape model at 25 ms
    OCS, and the overlapped total never exceeds the serial estimate."""
    from repro.configs.paper_models import MIXTRAL_8X7B

    model = dataclasses.replace(MIXTRAL_8X7B, num_blocks=8)
    serial = _sim(model, chunks=1)
    for chunks in (2, 4, 8):
        res = _sim(model, chunks=chunks)
        assert res.hidden_comm > 0.0, chunks
        assert res.total <= serial.total * (1 + 1e-9), chunks
    assert _sim(model, chunks=4).total < serial.total


def test_netsim_stage_bytes_match_trainer_scheduler_accounting():
    """The per-link bytes netsim reports come from the identical
    AllToAllStage.bytes_on_link the trainer-side scheduler consumes."""
    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core import commruntime as cr
    from repro.core.fabric import FabricConfig, make_fabric

    model = dataclasses.replace(MIXTRAL_8X7B, num_blocks=8, overlap_chunks=4)
    res = _sim(model, chunks=4)
    fab = make_fabric("mixnet", FabricConfig(num_servers=16, link_gbps=400))
    op = cr.AllToAll(cr.CommSpec.from_fabric(fab, 4))
    phase = model.a2a_bytes_total() / 4
    expect = {}
    for st in op.stages():
        lb = st.bytes_on_link(phase)
        expect[st.link_class] = expect.get(st.link_class, 0.0) + getattr(
            lb, st.link_class
        )
    assert res.a2a_link_bytes == pytest.approx(expect)
    assert set(res.a2a_link_bytes) == {"scale_up", "scale_out"}


def test_netsim_dp_compress_prices_byte_savings():
    """dp_compress halves (bf16) the priced DP wire bytes through the same
    AllReduce accounting the trainer's compressed reduction uses."""
    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core import commruntime as cr

    from repro.core.fabric import FabricConfig, make_fabric

    model = dataclasses.replace(MIXTRAL_8X7B, num_blocks=8)
    base = _sim(model, chunks=1)
    comp = _sim(dataclasses.replace(model, dp_compress=True), chunks=1)
    assert comp.dp_allreduce < base.dp_allreduce
    # the priced savings ARE the op's compress_ratio accounting: cost with
    # ratio r == cost of r x the bytes (int8 wire = 1/dtype_bytes)
    fab = make_fabric("mixnet", FabricConfig(num_servers=16, link_gbps=400))
    dp_op = cr.AllReduce(cr.CommSpec(
        axis=None, axis_size=8, group_size=8, outer_size=16
    ))
    dp_bytes = model.dp_gradient_bytes_per_server(8)
    ratio = 1.0 / model.dtype_bytes
    assert comp.dp_allreduce == pytest.approx(
        0.5 * dp_op.cost(fab, dp_bytes, compress_ratio=ratio)
    )
    assert dp_op.cost(fab, dp_bytes, compress_ratio=ratio) == pytest.approx(
        dp_op.cost(fab, dp_bytes * ratio)
    )
    # op-level: bytes_on_link scales identically
    op = cr.AllReduce(cr.CommSpec(axis="data", axis_size=8, group_size=8,
                                  outer_axis="pod", outer_size=4))
    b = 1e9
    assert op.bytes_on_link(b, compress_ratio=0.5).total == pytest.approx(
        0.5 * op.bytes_on_link(b).total
    )


# ---------------------------------------------------------------------------
# FSDP prefetch
# ---------------------------------------------------------------------------


FSDP_PREFETCH = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.data.pipeline import SyntheticLM
from repro.models.config import ModelConfig, MoEConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.train.train_step import init_all, make_train_step
from repro.launch.mesh import make_mesh, use_mesh

mesh = make_mesh((2, 4), ('data', 'model'))
plan = make_plan(mesh)  # fsdp axis = data
opt = AdamWConfig(lr=1e-3)
data = SyntheticLM(64, 16, 8, seed=0)
b = next(data)
batch = {'tokens': jnp.asarray(b.tokens), 'labels': jnp.asarray(b.labels)}

# MoE (mixnet) and dense configs both run the double-buffered ring gather.
cfgs = [
    ModelConfig('moe', 'moe', 2, 32, 4, 2, 0, 64, dtype='float32', remat='none',
                moe=MoEConfig(num_experts=8, top_k=2, d_ff=32,
                              capacity_factor=2.0, backend='mixnet',
                              a2a_group=2)),
    ModelConfig('dense', 'dense', 2, 32, 4, 2, 64, 64, dtype='float32',
                remat='none'),
]
for cfg in cfgs:
    cfg_p = dataclasses.replace(cfg, fsdp_prefetch=True)
    params, specs, opt_state = init_all(jax.random.PRNGKey(0), cfg, plan, opt)
    opt_state2 = jax.tree.map(lambda a: a, opt_state)
    with use_mesh(mesh):
        s0 = jax.jit(make_train_step(cfg, plan, opt, mesh=mesh))
        s1 = jax.jit(make_train_step(cfg_p, plan, opt, mesh=mesh))
        p0, o0, m0 = s0(params, opt_state, batch)
        p1, o1, m1 = s1(params, opt_state2, batch)
    np.testing.assert_allclose(float(m0['loss']), float(m1['loss']), rtol=1e-5)
    for a, r in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(r, np.float64),
                                   rtol=5e-4, atol=1e-5)
print('FSDP_PREFETCH_OK')
"""


def test_fsdp_prefetch_matches_auto_gather(multidevice):
    """The double-buffered ring prefetch of block l+1's FFN weights computes
    the same step as XLA's on-demand FSDP gather (MoE and dense)."""
    out = multidevice(FSDP_PREFETCH, devices=8, timeout=900)
    assert "FSDP_PREFETCH_OK" in out


# ---------------------------------------------------------------------------
# gradient compression through the runtime
# ---------------------------------------------------------------------------


DP_COMPRESS = """
import jax, jax.numpy as jnp, numpy as np
from repro.data.pipeline import SyntheticLM
from repro.models.config import ModelConfig, MoEConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.train.train_step import init_all, init_ef_residual, make_train_step
from repro.launch.mesh import make_mesh, use_mesh

mesh = make_mesh((8,), ('data',))
plan = make_plan(mesh, fsdp=False)
cfg = ModelConfig('tiny-moe', 'moe', 2, 32, 4, 2, 0, 64, dtype='float32',
                  remat='none',
                  moe=MoEConfig(num_experts=4, top_k=2, d_ff=32,
                                capacity_factor=2.0, backend='einsum',
                                balance_loss=0.0, router_z_loss=0.0))
opt = AdamWConfig(lr=1e-3)
params, _, opt_state = init_all(jax.random.PRNGKey(0), cfg, plan, opt)
opt_state2 = jax.tree.map(lambda a: a, opt_state)
res = init_ef_residual(params, plan)
data = SyntheticLM(cfg.vocab_size, 16, 8, seed=0)
b = next(data)
batch = {'tokens': jnp.asarray(b.tokens), 'labels': jnp.asarray(b.labels)}
with use_mesh(mesh):
    base = jax.jit(make_train_step(cfg, plan, opt, mesh=mesh, dp_comm='runtime'))
    comp = jax.jit(make_train_step(cfg, plan, opt, mesh=mesh, dp_comm='runtime',
                                   dp_compress=True))
    pb, ob, mb = base(params, opt_state, batch)
    pc, oc, mc, new_res = comp(params, opt_state2, batch, None, None, res)
# identical forward loss; params close (int8 mean with exact int32 sums)
np.testing.assert_allclose(float(mb['loss']), float(mc['loss']), rtol=1e-5)
for a, r in zip(jax.tree.leaves(pb), jax.tree.leaves(pc)):
    np.testing.assert_allclose(np.asarray(a, np.float64), np.asarray(r, np.float64),
                               rtol=2e-2, atol=2e-3)
# the residual captured this step's quantization error (nonzero, bounded)
rmax = max(float(jnp.max(jnp.abs(l))) for l in jax.tree.leaves(new_res))
assert 0.0 < rmax < 1.0, rmax

# error feedback keeps the long-run mean unbiased: iterate the compressed
# step on a FIXED batch and compare the parameter drift direction
p1, o1, r1 = params, jax.tree.map(lambda a: a, opt_state), res
with use_mesh(mesh):
    for _ in range(3):
        p1, o1, m1, r1 = comp(p1, o1, batch, None, None, r1)
assert np.isfinite(float(m1['loss']))

# misconfigurations fail loudly
try:
    make_train_step(cfg, plan, opt, mesh=mesh, dp_compress=True)
    raise SystemExit('expected ValueError (compress without runtime)')
except ValueError:
    pass
try:
    make_train_step(cfg, plan, opt, mesh=mesh, dp_comm='runtime',
                    dp_compress=True, microbatches=2)
    raise SystemExit('expected ValueError (compress with microbatches)')
except ValueError:
    pass
print('DP_COMPRESS_OK')
"""


def test_dp_compress_through_runtime(multidevice):
    """Satellite: int8 + error-feedback gradient compression rides the
    runtime AllReduce's reduce-scatter stage."""
    out = multidevice(DP_COMPRESS, devices=8, timeout=900)
    assert "DP_COMPRESS_OK" in out


def test_compressed_hierarchical_psum_single_device_identity():
    import jax.numpy as jnp

    from repro.optim.compress import compressed_hierarchical_psum

    x = jnp.arange(8.0)
    out = compressed_hierarchical_psum(x, None, None)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    out, local = compressed_hierarchical_psum(x, None, None, with_local=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(local), np.asarray(x))


# ---------------------------------------------------------------------------
# wire-level re-addressing in the trainer
# ---------------------------------------------------------------------------


WIRE_TRAINER = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.core.controlplane import LayerPlan
from repro.data.pipeline import SyntheticLM
from repro.models.config import ModelConfig, MoEConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.train.train_step import loss_fn
from repro.train.trainer import Trainer, TrainerConfig
from repro.launch.mesh import make_mesh, use_mesh

mesh = make_mesh((2, 4), ('data', 'model'))
plan = make_plan(mesh)
cfg = ModelConfig('tiny-moe8', 'moe', 2, 32, 4, 2, 0, 64, dtype='float32',
                  remat='none',
                  moe=MoEConfig(num_experts=8, top_k=2, d_ff=32,
                                capacity_factor=8.0, backend='mixnet',
                                a2a_group=2))
opt = AdamWConfig(lr=1e-3)
tcfg = TrainerConfig(total_steps=1, reconfig_every=1)

def make():
    return Trainer(cfg, opt, tcfg, plan, mesh=mesh, seed=0)

# two plans: layer 0 moves WHOLE device blocks (wire-eligible),
# layer 1 swaps slots across a block boundary (weight path)
block_perm = np.array([2, 3, 0, 1, 6, 7, 4, 5])   # devices 0<->1, 2<->3
slot_perm = np.array([2, 1, 0, 3, 4, 5, 6, 7])    # slot 0 <-> 2 (not a block move)
plans = [LayerPlan(0, True, perm=block_perm.copy()),
         LayerPlan(1, True, perm=slot_perm.copy())]

tr = make()
w0_before = np.asarray(tr.params['blocks']['0_global']['moe']['w_in'][0])
assert tr._wire_capable()
tr._apply_layer_plans(plans)
# layer 0 realized on the wire: weights untouched, device map installed
w0_after = np.asarray(tr.params['blocks']['0_global']['moe']['w_in'][0])
np.testing.assert_array_equal(w0_before, w0_after)
assert tr.wire_perm is not None
assert (tr.wire_perm[0] != np.arange(4)).any()
assert (tr.wire_perm[1] == np.arange(4)).all()
assert tr.wire_reconfig_count == 1

# reference trainer: identical plans, forced through the weight path
ref = make()
ref._wire_capable = lambda: False
ref._apply_layer_plans([LayerPlan(0, True, perm=block_perm.copy()),
                        LayerPlan(1, True, perm=slot_perm.copy())])
assert ref.wire_perm is None
np.testing.assert_array_equal(np.asarray(tr.expert_perm), np.asarray(ref.expert_perm))

data = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)
b = next(data)
batch = {'tokens': jnp.asarray(b.tokens), 'labels': jnp.asarray(b.labels)}

def loss_of(t):
    wire = jnp.asarray(t.wire_perm, jnp.int32) if t.wire_perm is not None else None
    with use_mesh(mesh):
        l, _ = jax.jit(lambda p, bt, pm, wr: loss_fn(
            p, bt, cfg, plan, mesh, pm, wr))(
            t.params, batch, jnp.asarray(t.expert_perm), wire)
    return float(l)

lw = loss_of(tr)
lr = loss_of(ref)
np.testing.assert_allclose(lw, lr, rtol=1e-5)

# a later NON-block plan on layer 0 must flush the wire perm into the gather
flush_perm = np.array([1, 0, 2, 3, 4, 5, 6, 7])   # slot 0 <-> 1, within a block
tr._apply_layer_plans([LayerPlan(0, True, perm=flush_perm.copy())])
ref._apply_layer_plans([LayerPlan(0, True, perm=flush_perm.copy())])
assert (tr.wire_perm[0] == np.arange(4)).all()   # flushed
np.testing.assert_array_equal(np.asarray(tr.expert_perm), np.asarray(ref.expert_perm))
np.testing.assert_allclose(loss_of(tr), loss_of(ref), rtol=1e-5)

# and training still runs through the installed wire perms
tr2 = make()
tr2._apply_layer_plans([LayerPlan(0, True, perm=block_perm.copy())])
with use_mesh(mesh):
    log = tr2.train(iter(SyntheticLM(cfg.vocab_size, 16, 4, seed=0)))
assert np.isfinite([float(m['loss']) for m in log]).all()
print('WIRE_TRAINER_OK')
"""


def test_trainer_wire_readdressing_both_branches(multidevice):
    """Satellite: whole-device-block plans install wire perms (no weight
    gather); other plans gather weights, flushing any pending wire perm —
    both branches compute the same function."""
    out = multidevice(WIRE_TRAINER, devices=8, timeout=900)
    assert "WIRE_TRAINER_OK" in out
