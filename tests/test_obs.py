"""Measurement plane (DESIGN.md §14): tracer schema + no-op disabled path,
metrics registry, traffic observatory math, typed decision events, and the
tracing-changes-nothing guarantee for serve and train."""

import dataclasses
import json
import time

import jax
import numpy as np
import pytest

from repro.core import commruntime as comm
from repro.models.config import ModelConfig, MoEConfig
from repro.models.transformer import init_model
from repro.obs import metrics, trace
from repro.obs.trace import Tracer, validate_events
from repro.obs.traffic import TrafficObservatory
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.serve import events as sev
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.workload import WorkloadGenerator
from repro.train.trainer import Trainer, TrainerConfig

PLAN = make_plan(None)


@pytest.fixture(autouse=True)
def _clean_obs():
    """The default tracer/registry are process-wide; isolate each test."""
    trace.disable()
    trace.default().clear()
    metrics.reset()
    yield
    trace.disable()
    trace.default().clear()
    metrics.reset()


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_disabled_tracer_is_noop():
    tr = Tracer()
    assert not tr.enabled
    # the disabled span is ONE shared object — no allocation on the hot path
    s1 = tr.span("a", x=1)
    s2 = tr.span("b")
    assert s1 is s2
    with s1 as sp:
        sp.set(y=2)
    tr.instant("i", k=1)
    tr.counter("c", 3.0)
    tr.audit("d", {"kind": "x"})
    assert tr.events() == []


def test_disabled_tracer_overhead_bounded():
    tr = Tracer()
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("hot"):
            pass
        tr.counter("c", 1.0)
        tr.instant("i")
    dt = time.perf_counter() - t0
    # ~3 attribute checks per iteration; generous bound for slow CI hosts.
    assert dt < 2.0, f"disabled tracer cost {dt / n * 1e6:.2f} us/iter"
    assert tr.events() == []


def test_span_counter_instant_schema_and_export(tmp_path):
    tr = Tracer()
    tr.enabled = True
    tid = tr.track("unit")
    with tr.span("outer", tid=tid, step=1) as sp:
        with tr.span("inner", tid=tid):
            pass
        sp.set(result=7)
    tr.counter("tokens", {"served": 3.0}, tid=tid)
    tr.instant("boom", tid=tid, cat="event", why="test")
    tr.audit("plan", {"layer": 0, "reconfigure": True}, tid=tid)
    evs = tr.events()
    assert [e["ph"] for e in evs] == ["X", "X", "C", "i", "i"]
    # spans record on exit: inner lands first, outer carries set() args
    assert evs[0]["name"] == "inner"
    assert evs[1]["args"] == {"step": 1, "result": 7}
    assert validate_events(evs) == []

    path = str(tmp_path / "t.json")
    n = tr.export(path)
    assert n == len(evs) + 2  # + process_name, + one thread_name
    doc = json.load(open(path))
    assert doc["traceEvents"][0]["ph"] == "M"
    names = {e["args"]["name"] for e in doc["traceEvents"] if e["ph"] == "M"}
    assert {"repro", "unit"} <= names
    assert trace.validate_file(path) == []


def test_validate_events_catches_malformed():
    ok = {"name": "a", "ph": "i", "s": "t", "ts": 0.0, "pid": 1, "tid": 1,
          "args": {}}
    assert validate_events([ok]) == []
    assert validate_events([{k: v for k, v in ok.items() if k != "ts"}])
    assert validate_events([dict(ok, ph="X")])  # span without dur
    assert validate_events([dict(ok, ph="C", args={"v": "str"})])
    assert validate_events([dict(ok, ph="?")])
    # partially overlapping spans on one track fail the nesting sweep
    a = dict(ok, ph="X", ts=0.0, dur=10.0)
    b = dict(ok, ph="X", name="b", ts=5.0, dur=10.0)
    assert any("overlap" in f for f in validate_events([a, b]))
    # properly nested spans (shared start) pass
    c = dict(ok, ph="X", name="c", ts=0.0, dur=4.0)
    assert validate_events([a, c]) == []


def test_tracer_ring_buffer_drops_oldest():
    tr = Tracer(capacity=8)
    tr.enabled = True
    for i in range(20):
        tr.instant(f"e{i}")
    evs = tr.events()
    assert len(evs) <= 8
    assert evs[-1]["name"] == "e19"
    assert tr._dropped > 0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_registry_series_and_snapshot():
    reg = metrics.MetricsRegistry()
    c = reg.counter("comm.link_bytes", op="a2a", link="scale_out")
    c.inc(10)
    c.inc(5)
    assert reg.counter("comm.link_bytes", op="a2a", link="scale_out") is c
    reg.gauge("loss").set(2.5)
    h = reg.histogram("lat_s")
    for v in (0.001, 0.002, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    key = "comm.link_bytes{link=scale_out,op=a2a}"
    assert snap["counters"][key]["value"] == 15.0
    assert snap["gauges"]["loss"]["value"] == 2.5
    assert snap["histograms"]["lat_s"]["count"] == 3
    assert snap["histograms"]["lat_s"]["max"] == 4.0
    assert reg.value("comm.link_bytes", op="a2a", link="scale_out") == 15.0
    assert reg.value("never.written") == 0.0
    json.dumps(snap)  # snapshot must be JSON-able
    with pytest.raises(TypeError):
        reg.gauge("comm.link_bytes", op="a2a", link="scale_out")


def test_metrics_reset_bumps_generation():
    reg = metrics.MetricsRegistry()
    g0 = reg.generation
    reg.counter("x").inc()
    reg.reset()
    assert reg.generation == g0 + 1
    assert reg.value("x") == 0.0


def test_commruntime_link_bytes_survive_registry_reset():
    from repro.core.fabric import FabricConfig, make_fabric

    fab = make_fabric("fat-tree", FabricConfig(num_servers=4, link_gbps=100))
    op = comm.AllToAll(comm.CommSpec.from_fabric(fab, 4))
    demand = np.full((4, 4), 1000.0)
    np.fill_diagonal(demand, 0.0)
    op.cost(fab, demand)
    before = metrics.default().value("comm.link_bytes", op="a2a", link="scale_out")
    assert before == pytest.approx(demand.sum())
    metrics.reset()
    op.cost(fab, demand)  # cached Counter handles must re-resolve, not orphan
    after = metrics.default().value("comm.link_bytes", op="a2a", link="scale_out")
    assert after == pytest.approx(before)


# ---------------------------------------------------------------------------
# traffic observatory
# ---------------------------------------------------------------------------


def test_observatory_locality_and_effective_experts():
    obs = TrafficObservatory(2, 4, num_devices=2)
    obs.record(np.array([[9.0, 0, 0, 0], [1.0, 1, 1, 1]]))
    loc = obs.locality_per_layer()
    assert loc[0] == pytest.approx(1.0)  # single expert takes everything
    assert loc[1] == pytest.approx(0.0)  # uniform
    eff = obs.effective_experts()
    assert eff[0] == pytest.approx(1.0)
    assert eff[1] == pytest.approx(4.0)
    # devices 0/1 hold experts {0,1}/{2,3}: layer 0 all on device 0
    conc = obs.device_concentration()
    assert conc[0] == pytest.approx(1.0)
    assert conc[1] == pytest.approx(0.5)
    assert 0.0 <= obs.locality_score() <= 1.0


def test_observatory_follows_permutation():
    obs = TrafficObservatory(1, 4, num_devices=2)
    load = np.array([[10.0, 0, 0, 0]])
    # expert 0 re-placed onto slot 3 (device 1)
    perm = np.array([[3, 1, 2, 0]])
    obs.record(load, perm)
    np.testing.assert_allclose(obs.device_traffic, [[0.0, 10.0]])


def test_observatory_regional_skew_and_roundtrip():
    obs = TrafficObservatory(1, 4, num_regions=2)
    # two disjoint half-regions: each misses the global (uniform) mix by
    # exactly 1 - sqrt(1/2)
    obs.record(np.array([[5.0, 5.0, 0, 0]]), region_weights={0: 1.0})
    obs.record(np.array([[0, 0, 5.0, 5.0]]), region_weights={1: 1.0})
    assert obs.regional_skew() == pytest.approx(1.0 - 2 ** -0.5)
    rep = obs.report()
    json.dumps(rep)
    back = TrafficObservatory.from_report(json.loads(json.dumps(rep)))
    assert back.ticks == 2
    np.testing.assert_allclose(back.expert_traffic, obs.expert_traffic)
    assert back.regional_skew() == pytest.approx(obs.regional_skew())
    assert back.report() == rep
    # merging two copies doubles mass, keeps the normalized stats
    merged = TrafficObservatory.from_report(rep).merge(back)
    assert merged.ticks == 4
    assert merged.locality_score() == pytest.approx(obs.locality_score())


def test_observatory_identical_regions_zero_skew():
    obs = TrafficObservatory(1, 4, num_regions=2)
    for r in (0, 1):
        obs.record(np.array([[4.0, 3.0, 2.0, 1.0]]), region_weights={r: 1.0})
    assert obs.regional_skew() == pytest.approx(0.0, abs=1e-9)


# ---------------------------------------------------------------------------
# typed decision events
# ---------------------------------------------------------------------------


def test_decision_events_match_legacy_dict_shape():
    # as_dict() must reproduce the legacy decision_log dicts exactly,
    # including key ORDER (consumers print the dicts verbatim).
    cases = [
        (sev.DrainDecision(tick=3, handed_back=2),
         ["tick", "kind", "handed_back"]),
        (sev.ReconfigDecision(tick=4, applied=True, layers=[0],
                              gain_bytes=9.0, reasons=[]),
         ["tick", "kind", "applied", "layers", "gain_bytes", "reasons"]),
        (sev.SteerDecision(tick=0, rid=1, region=2, slo="strict",
                           replica=0, reason="locality"),
         ["tick", "kind", "rid", "region", "slo", "replica", "reason"]),
        (sev.FleetFailDecision(tick=5, replica=1, resteered=3),
         ["tick", "kind", "replica", "resteered"]),
    ]
    for ev, keys in cases:
        d = ev.as_dict()
        assert list(d) == keys
        assert d["kind"] == ev.kind
        json.dumps(d)


# ---------------------------------------------------------------------------
# tracing changes nothing (serve + train)
# ---------------------------------------------------------------------------


def _moe_cfg():
    return ModelConfig(
        "obs", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=8.0,
                      backend="mixnet", a2a_group=2, dispatch="dropless"),
    )


def _run_serve(params, cfg, reqs, gen):
    scfg = ServeConfig(slots=2, max_len=40, reconfig_every=3,
                       reconfig_min_gain=0.0, num_devices=4)
    eng = ServeEngine(jax.tree.map(lambda a: a, params), cfg, PLAN, scfg)
    eng.run(reqs, gen)
    return eng


def test_serve_bit_identical_with_tracing_and_trace_contents(tmp_path):
    cfg = _moe_cfg()
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    gen = WorkloadGenerator("chat", seed=3, vocab_size=cfg.vocab_size)
    reqs = [
        dataclasses.replace(r, prompt_len=min(r.prompt_len, 16),
                            max_new_tokens=min(r.max_new_tokens, 5))
        for r in gen.generate(3)
    ]
    base = _run_serve(params, cfg, reqs, gen)
    metrics.reset()  # count the traced run alone
    trace.enable()
    traced = _run_serve(params, cfg, reqs, gen)

    a = {r.rid: list(r.out) for r in base.batcher.finished}
    b = {r.rid: list(r.out) for r in traced.batcher.finished}
    assert a == b, "tracing changed generated tokens"
    # legacy dict view still works and matches the typed events
    assert traced.decision_log == [e.as_dict() for e in traced.decisions]

    evs = trace.default().events()
    names = {e["name"] for e in evs}
    assert "serve.tick" in names
    assert "controlplane.plan" in names
    assert "traffic.report" in names
    assert any(n.startswith("serve.") and e["cat"] == "decision"
               for e in evs for n in [e["name"]])
    path = str(tmp_path / "serve.json")
    trace.export(path)
    assert trace.validate_file(path) == []
    # metrics saw the same run: one counted tick per serve.tick span (the
    # engine clock can jump idle gaps without stepping)
    reg = metrics.default()
    n_tick_spans = sum(1 for e in evs if e["name"] == "serve.tick")
    assert reg.value("serve.ticks") == n_tick_spans > 0
    assert reg.value("serve.tokens_served") > 0
    # the observatory streamed the gate loads
    assert traced.observatory is not None and traced.observatory.ticks > 0
    assert 0.0 <= traced.observatory.locality_score() <= 1.0


def test_train_bit_identical_with_tracing(tmp_path):
    from repro.data.pipeline import SyntheticLM

    cfg = ModelConfig(
        "tiny-moe", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=32, capacity_factor=2.0,
                      backend="mixnet"),
    )
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    tcfg = TrainerConfig(total_steps=4, reconfig_every=2,
                         reconfig_min_gain=0.0)

    def losses():
        tr = Trainer(cfg, opt, tcfg, PLAN, seed=0)
        log = tr.train(iter(SyntheticLM(cfg.vocab_size, 16, 4, seed=0)))
        return [float(m["loss"]) for m in log]

    base = losses()
    trace.enable()
    traced = losses()
    assert base == traced, "tracing changed training"
    evs = trace.default().events()
    names = {e["name"] for e in evs}
    assert "train.step" in names
    assert "train.reconfig" in names
    assert validate_events(evs) == []
    reg = metrics.default()
    # the registry is always on: both the base and the traced run count
    assert reg.value("train.steps") == 2 * tcfg.total_steps
    assert reg.value("train.tokens") > 0


def test_trainer_autotune_cache_miss_warns_and_counts(tmp_path, capsys):
    cfg = ModelConfig(
        "tiny-moe", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=32, capacity_factor=2.0,
                      backend="mixnet"),
    )
    tcfg = TrainerConfig(
        total_steps=1,
        autotune_cache=str(tmp_path / "missing_cache.json"),
        autotune_key="no-such-key",
    )
    Trainer(cfg, AdamWConfig(), tcfg, PLAN, seed=0)
    assert metrics.default().value("autotune.cache_miss") == 1.0
    out = capsys.readouterr().out
    assert "autotune cache miss" in out and "no-such-key" in out
